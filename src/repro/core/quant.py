"""8-bit fixed-point quantization (paper Table I 'Quantize (8 bits)', Fig 16:
8b FXP weights, 8b FXP Vmem, 16b FXP accumulators).

Symmetric per-tensor (or per-channel) FXP: q = clip(round(x / s), -128, 127),
s = max|x| / 127. Quantization-aware paths use the straight-through
estimator so the pruned+quantized model can be fine-tuned (paper fine-tunes
5 epochs after quantization).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

INT8_MAX = 127
ACC_BITS = 16  # the ASIC accumulator width; asserted in tests, not enforced


class Quantized(NamedTuple):
    q: jax.Array  # int8 payload
    scale: jax.Array  # f32 scale(s)


def quantize(x: jax.Array, *, axis=None, bits: int = 8) -> Quantized:
    qmax = 2 ** (bits - 1) - 1
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    # All-zero slices (dead channels from pruning or ANN→SNN conversion)
    # have amax == 0; an unguarded max|x|/qmax scale would be 0 there and
    # x/scale → 0/0 NaN. Give dead slices scale 1 so q == 0 and dequantize
    # returns exact zeros; live slices keep the exact max|x|/qmax scale.
    scale = jnp.where(amax > 0, amax, float(qmax)) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int8)
    return Quantized(q=q, scale=scale.astype(jnp.float32))


def dequantize(qx: Quantized) -> jax.Array:
    return qx.q.astype(jnp.float32) * qx.scale


@jax.custom_vjp
def fake_quant(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Straight-through-estimator quantize→dequantize for QAT."""
    qmax = INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    return q * scale


def _fq_fwd(x, scale):
    return fake_quant(x, scale), None


def _fq_bwd(_, g):
    return (g, None)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def fake_quant_tensor(x: jax.Array, bits: int = 8) -> jax.Array:
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / qmax
    return fake_quant(x, scale)


def int8_conv_accumulate(x_q: jax.Array, w_q: jax.Array, dn) -> jax.Array:
    """int8 × int8 → int32 accumulation (TPU-native widening; the ASIC used
    16b accumulators — tests assert results stay within 16b range for the
    paper's layer sizes)."""
    return jax.lax.conv_general_dilated(
        x_q.astype(jnp.int32),
        w_q.astype(jnp.int32),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=dn,
    )


def acc_range_ok(acc: jax.Array, bits: int = ACC_BITS) -> jax.Array:
    lim = 2 ** (bits - 1)
    return jnp.all((acc >= -lim) & (acc < lim))


def conv_acc_worst_case(w_q) -> int:
    """Largest |accumulator| value ANY binary-spike input can drive through
    an int8 conv with kernel ``w_q`` (HWIO): max over output channels of
    Σ|w_q| across taps and input channels. The bound the eval harness
    reports against ``ACC_BITS`` (tests enforce it at the paper's layer
    sizes — the claim quant.ACC_BITS used to leave untested)."""
    aw = np.abs(np.asarray(w_q, np.int64))
    return int(aw.reshape(-1, aw.shape[-1]).sum(axis=0).max())
