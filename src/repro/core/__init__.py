"""Core paper contributions: LIF/tdBN, gated one-to-all sparse conv, bitmask
compression, block convolution, pruning, quantization, mIoUT, energy model."""

from . import bitmask, bitserial, block_conv, energy, lif, miout, plan, pruning, quant, spike_conv

__all__ = [
    "bitmask",
    "bitserial",
    "block_conv",
    "energy",
    "lif",
    "miout",
    "plan",
    "pruning",
    "quant",
    "spike_conv",
]
