"""Bit-serial multibit input processing (paper §III-C.2, Fig 12).

The accelerator supports the RGB encoding layer on the SAME spike datapath by
splitting 8-bit inputs into B=8 bit planes and processing them bit-serially:

    conv(x, w) = Σ_b 2^b · conv(bitplane_b(x), w)

Each bit plane is a binary map — identical to a spike map — so one datapath
serves both layer types (B=8 for the encoding layer, B=1 for SNN layers).

On TPU the *optimized* path computes the encoding conv directly in int8 on
the MXU; the bit-serial path here is the paper-faithful reference and the
two are asserted equal in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def to_bitplanes(x_u8: jax.Array, bits: int = 8) -> jax.Array:
    """uint8 NHWC -> (B, N, H, W, C) binary planes, LSB first."""
    x = x_u8.astype(jnp.uint8)
    planes = [((x >> b) & 1).astype(jnp.float32) for b in range(bits)]
    return jnp.stack(planes, axis=0)


def from_bitplanes(planes: jax.Array) -> jax.Array:
    """(B, ...) binary -> integer-valued f32."""
    bits = planes.shape[0]
    weights = jnp.asarray([2.0**b for b in range(bits)], planes.dtype)
    return jnp.tensordot(weights, planes, axes=(0, 0))


def bitserial_conv(x_u8: jax.Array, w: jax.Array, conv_fn) -> jax.Array:
    """Bit-serial conv: run ``conv_fn`` (any binary-input conv, e.g. the
    gated one-to-all product) once per bit plane, shift-add the results.

    This is the paper's unified encoding-layer support: the B loop sits
    directly above the input-channel loop (KTBC order).
    """
    planes = to_bitplanes(x_u8)

    def step(acc, bp):
        b, plane = bp
        return acc + (2.0**b) * conv_fn(plane, w), None

    bits = planes.shape[0]
    out0 = conv_fn(planes[0], w)
    acc = out0
    for b in range(1, bits):
        acc = acc + (2.0**b) * conv_fn(planes[b], w)
    return acc
