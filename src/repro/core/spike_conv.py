"""Gated one-to-all product — the paper's core contribution (§III-B.1,
Figs 8/9/11), in functional JAX form.

Semantics: a SAME 3×3 (or 1×1) convolution of a binary spike map with a
PRUNED weight tensor, computed as

    out = Σ_{(r,c,ci,k) : w[r,c,ci,k] != 0}  w[r,c,ci,k] · shift(s[..,ci], r,c)

i.e. one term per NONZERO weight; each term broadcasts ("one-to-all") a
single weight against the whole shifted spike plane, and the spike value
gates the accumulate. Zero weights are never visited — on the ASIC that is
the cycle saving; in the Pallas kernel the analogue is per-tap block
skipping.

Three implementations, all numerically identical (tests assert so):
  * :func:`conv_reference`   — dense lax.conv oracle (weights already masked).
  * :func:`gated_one_to_all` — the literal shift-accumulate decomposition,
    the paper-faithful dataflow (used to validate the kernel and to count
    the exact #accumulates the ASIC would perform).
  * kernels/gated_one_to_all.py — the Pallas TPU kernel (compressed weights
    decoded in VMEM, per-tap skip) with this module's functions as oracles.

Layouts: spikes NHWC, weights HWIO.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import bitmask as bm


def conv_reference(spikes: jax.Array, w: jax.Array) -> jax.Array:
    """Dense SAME conv oracle. spikes NHWC (any float/int dtype), w HWIO."""
    return jax.lax.conv_general_dilated(
        spikes.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _shift2d(x: jax.Array, dr: int, dc: int) -> jax.Array:
    """Shift an NHWC map by (dr, dc) with zero fill — the 'enable map'
    construction of Fig 8(b): the map for a weight at kernel offset (r,c)
    is the input shifted so that weight's receptive field aligns."""
    n, h, w_, c = x.shape
    out = jnp.zeros_like(x)
    src_r = slice(max(dr, 0), h + min(dr, 0))
    dst_r = slice(max(-dr, 0), h + min(-dr, 0))
    src_c = slice(max(dc, 0), w_ + min(dc, 0))
    dst_c = slice(max(-dc, 0), w_ + min(-dc, 0))
    return out.at[:, dst_r, dst_c, :].set(x[:, src_r, src_c, :])


def gated_one_to_all(spikes: jax.Array, w: jax.Array) -> jax.Array:
    """Paper-faithful shift-accumulate sparse conv.

    spikes: (N,H,W,Cin) binary; w: (kh,kw,Cin,K). Returns (N,H,W,K) f32.
    The (r,c) python loop is the tap loop (9 taps for 3×3); the per-tap
    input-channel contraction is a 1×1 matmul — exactly the PE array's
    one-to-all broadcast, vectorized.
    """
    kh, kw, cin, k = w.shape
    ph, pw = (kh - 1) // 2, (kw - 1) // 2
    s = spikes.astype(jnp.float32)
    out = jnp.zeros(spikes.shape[:3] + (k,), jnp.float32)
    for r in range(kh):
        for c in range(kw):
            # SAME conv: out[y,x] += s[y + r - ph, x + c - pw] @ w[r,c]
            shifted = _shift2d(s, r - ph, c - pw)
            out = out + shifted @ w[r, c].astype(jnp.float32)
    return out


def gated_one_to_all_compressed(
    spikes: jax.Array, cw: bm.BitmaskWeights, dtype=jnp.float32
) -> jax.Array:
    """Same, consuming bitmask-compressed weights (decode then accumulate —
    the functional model of the ASIC's NZ-Weight + Weight-Map SRAM read)."""
    w = bm.decode(cw, dtype)
    return gated_one_to_all(spikes, w)


def accumulate_count(w: jax.Array, spatial_size: int) -> int:
    """Exact number of accumulate operations the gated one-to-all dataflow
    performs for one layer: nnz(w) × spatial positions. This is the paper's
    'skip zero weights to save 47.3% latency' accounting."""
    return int(jnp.sum(w != 0)) * spatial_size


def dense_count(w: jax.Array, spatial_size: int) -> int:
    return int(w.size) * spatial_size
