"""Block convolution (paper §II-B, ref [25]).

Input feature maps are partitioned into NON-overlapping spatial blocks; each
block is convolved independently with *replicate* padding at its own border.
This removes cross-tile data dependency — on the ASIC that saves boundary
partial-sum buffers; on a TPU mesh it means the spatial block grid can be
sharded with ZERO halo exchange (no collective-permute between neighbors).

Paper block size: 32×18 (W×H). We keep (block_h, block_w) = (18, 32).

Layout convention throughout the detector: NHWC.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK_H = 18
BLOCK_W = 32


def _replicate_pad_hw(x: jax.Array, pad: int) -> jax.Array:
    """Edge-replicate pad H and W axes of an NHWC tensor."""
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)), mode="edge")


def conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1, padding="SAME") -> jax.Array:
    """Plain NHWC x HWIO conv (the oracle the blocked version approximates)."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32 if x.dtype in (jnp.float32,) else None,
    )


def to_blocks(x: jax.Array, block_h: int = BLOCK_H, block_w: int = BLOCK_W) -> jax.Array:
    """NHWC -> (N, nbh, nbw, block_h, block_w, C). H, W must divide evenly
    (the paper resizes inputs to 1024×576 = 32·32 × 32·18 so they do)."""
    n, h, w, c = x.shape
    if h % block_h or w % block_w:
        raise ValueError(f"({h},{w}) not divisible by block ({block_h},{block_w})")
    x = x.reshape(n, h // block_h, block_h, w // block_w, block_w, c)
    return x.transpose(0, 1, 3, 2, 4, 5)


def from_blocks(xb: jax.Array) -> jax.Array:
    """(N, nbh, nbw, bh, bw, C) -> NHWC."""
    n, nbh, nbw, bh, bw, c = xb.shape
    return xb.transpose(0, 1, 3, 2, 4, 5).reshape(n, nbh * bh, nbw * bw, c)


def block_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    block_h: int = BLOCK_H,
    block_w: int = BLOCK_W,
    stride: int = 1,
) -> jax.Array:
    """Block convolution: independent per-block SAME conv with replicate
    padding at block borders. 3×3 (or 1×1) HWIO weights, NHWC input.

    Every block is independent ⇒ vmap over the flattened block grid; when the
    block grid axis is sharded, XLA emits no halo communication.
    """
    kh, kw = w.shape[0], w.shape[1]
    pad = (kh - 1) // 2
    xb = to_blocks(x, block_h, block_w)
    n, nbh, nbw, bh, bw, c = xb.shape
    flat = xb.reshape(n * nbh * nbw, bh, bw, c)
    padded = _replicate_pad_hw(flat, pad)
    out = jax.lax.conv_general_dilated(
        padded,
        w,
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    oh, ow = out.shape[1], out.shape[2]
    out = out.reshape(n, nbh, nbw, oh, ow, w.shape[-1])
    return from_blocks(out)
