"""Whole-detector compression plan + pluggable conv executors.

This is the bridge between the paper's compressed dataflow and the model:
``build_plan`` walks the full ``snn_yolo`` parameter tree ONCE and
precompiles every conv layer into a :class:`CompressedLayerPlan` —

    prune (already applied to params) → quantize (FXP8, per-tensor
    symmetric) → bitmask-pack ({maskp, vals, tap_any} + K-blocking
    metadata, paper §III-B.2)

— so inference never touches dense float weights. Which engine actually
runs each conv is a pluggable *executor*, selected by
``SNNDetConfig.conv_exec``:

  * ``dense``  — ``lax.conv`` / block-conv oracle on the dequantized
                 weights (the numerical reference).
  * ``gated``  — the literal shift-accumulate gated one-to-all product
                 (paper-faithful dataflow, exact accumulate accounting).
  * ``pallas`` — the compressed Pallas TPU kernel: weights stream from HBM
                 in bitmask-compressed form and are decoded once per
                 K-block in VMEM (paper's −59.1% weight traffic).

Executors consume the full time-major activation volume ``(T, N, H, W, C)``
and fold T (and, for the 8-bit encoding layer, the bit-serial plane axis)
into the batch, so mixed time steps batch through ONE ``pallas_call`` whose
grid spans T·N·spatial-blocks instead of a Python vmap over T.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import bitserial
from . import block_conv as bc
from . import pruning, quant
from . import spike_conv as sc
from repro.kernels import autotune
from repro.kernels import ops as kops


class CompressedLayerPlan(NamedTuple):
    """One conv layer, compiled for the compressed gated one-to-all path."""

    name: str
    packed: kops.PackedConvWeights  # bitmask-compressed int8 weights
    scale: jax.Array  # () f32 — dequant scale (FXP8 per-tensor)
    w_q: jax.Array  # (kh, kw, cin, kout) int8 dense — gated/dense reference
    in_bits: int  # 1 = binary spikes, 8 = multibit input (bit-serial)
    nnz: int  # true nonzero count (accumulate accounting)
    # dispatch tiling for the fused pipeline kernel — autotuned per layer
    # shape (kernels/autotune.py); NEVER affects numerics, only wall-clock
    tile: autotune.TileConfig = autotune.DEFAULT_TILE

    @property
    def dense_bytes(self) -> int:
        return int(np.prod(self.w_q.shape))

    @property
    def compressed_bytes(self) -> int:
        return int(self.packed.compressed_bytes)


class DetectorPlan(NamedTuple):
    layers: dict  # name -> CompressedLayerPlan
    block_hw: tuple  # (bh, bw) spatial block for every executor

    @property
    def dense_bytes(self) -> int:
        return sum(lp.dense_bytes for lp in self.layers.values())

    @property
    def compressed_bytes(self) -> int:
        return sum(lp.compressed_bytes for lp in self.layers.values())

    def summary(self) -> dict:
        """JSON-serializable per-layer compression report (nnz, density,
        dense vs packed bytes, FXP scale) plus totals — what the conversion
        front-end embeds in its checkpoint report and ``examples/
        convert_ann_detector.py`` prints."""
        layers = {
            name: {
                "shape": list(lp.w_q.shape),
                "nnz": int(lp.nnz),
                "density": round(lp.nnz / max(1, lp.dense_bytes), 4),
                "dense_bytes": lp.dense_bytes,
                "compressed_bytes": lp.compressed_bytes,
                "scale": float(np.asarray(lp.scale)),
                "in_bits": lp.in_bits,
            }
            for name, lp in self.layers.items()
        }
        return {
            "block_hw": list(self.block_hw),
            "layers": layers,
            "dense_bytes": self.dense_bytes,
            "compressed_bytes": self.compressed_bytes,
            "compression_ratio": round(
                self.dense_bytes / max(1, self.compressed_bytes), 3
            ),
        }


# ------------------------------------------------------------------ build --


def build_layer_plan(
    name: str,
    w: jax.Array,
    *,
    kblk: int = 128,
    weight_bits: int = 8,
    in_bits: int = 1,
    vpad: int | None = None,
    tile: autotune.TileConfig | None = None,
) -> CompressedLayerPlan:
    """Quantize + bitmask-pack one HWIO kernel tensor. Must run outside jit
    (packing is host-side numpy). Raises if any K-block's nnz would overflow
    the packed-value buffer (the kernel cannot bounds-check its gather).

    ``tile`` (autotuned dispatch shape) overrides ``kblk`` — the packed
    K-block width is itself a tuning knob; any choice is bit-exact."""
    qw = quant.quantize(w, bits=weight_bits)
    w_q = np.asarray(qw.q).reshape(w.shape)
    kout = w.shape[-1]
    if tile is not None:
        kblk = tile.kblk
    kblk_l = min(kblk, -(-kout // 8) * 8)  # small layers: one tight K-block
    # pack_conv_weights itself raises on vpad overflow; validate_packed
    # stays available for externally-constructed PackedConvWeights
    packed = kops.pack_conv_weights(w_q, kblk=kblk_l, vpad=vpad)
    return CompressedLayerPlan(
        name=name,
        packed=packed,
        scale=qw.scale.reshape(()),
        w_q=jnp.asarray(w_q),
        in_bits=in_bits,
        nnz=int(np.count_nonzero(w_q)),
        tile=tile or autotune.TileConfig(kblk=kblk_l, nbt=autotune.DEFAULT_TILE.nbt),
    )


def _layer_shapes_for(cfg) -> dict:
    """Per-layer :class:`~repro.kernels.autotune.LayerShape` map for the
    autotune-cache lookup. Falls back to {} for configs the topology walk
    does not understand — those layers just run at DEFAULT_TILE."""
    try:
        return autotune.detector_layer_shapes(cfg)
    except Exception:
        return {}


def build_plan(
    params: Any,
    cfg,
    *,
    kblk: int = 128,
    prune_rate: float | None = None,
    tile_cache: dict | None = None,
) -> DetectorPlan:
    """Compile the whole detector parameter tree in one pass.

    ``params`` is the ``snn_yolo.init_params`` tree (name -> {"w", ...}).
    ``prune_rate`` optionally applies fine-grained magnitude pruning to the
    spatial (3×3) kernels first — pass the SAME pruned tree to the dense
    oracle when checking parity. The encoding layer is marked 8-bit input
    (RGB); every other layer consumes binary spikes.

    ``tile_cache``: shape→TileConfig entries for the fused kernel's
    dispatch tiling. ``None`` consults the persisted autotune cache
    (``kernels/autotune.py``; missing/stale caches fall back to default
    tilings); pass ``{}`` to force defaults. Tiling never changes numerics.
    """
    if not cfg.weight_bits:
        # the compressed path is FXP-int8 by construction; quantizing a
        # float-weight config silently would diverge from its dense baseline
        raise ValueError(
            "build_plan requires quantized weights (cfg.weight_bits > 0); "
            "weight_bits=0 means float weights, which only conv_exec='dense' runs"
        )
    shapes = _layer_shapes_for(cfg)
    layers = {}
    for name, layer_p in params.items():
        w = layer_p["w"]
        if prune_rate is not None and pruning.is_spatial_kernel(w):
            w = pruning.prune_by_rate(w, prune_rate)
        shape = shapes.get(name)
        tile = autotune.lookup(shape, tile_cache) if shape is not None else None
        layers[name] = build_layer_plan(
            name,
            w,
            kblk=kblk,
            weight_bits=cfg.weight_bits,
            in_bits=8 if name == "encode" else 1,
            tile=tile,
        )
    return DetectorPlan(layers=layers, block_hw=tuple(cfg.block_hw))


# -------------------------------------------------------------- executors --

# Registry: name -> fn(x_t (T,N,H,W,C) f32, CompressedLayerPlan, cfg) -> f32
CONV_EXECUTORS: dict[str, Callable] = {}


def register_conv_executor(name: str):
    def deco(fn):
        CONV_EXECUTORS[name] = fn
        return fn

    return deco


def run_conv(x_t: jax.Array, lp: CompressedLayerPlan, cfg) -> jax.Array:
    """Dispatch one conv layer through the configured executor."""
    try:
        fn = CONV_EXECUTORS[cfg.conv_exec]
    except KeyError:
        raise ValueError(
            f"unknown conv_exec={cfg.conv_exec!r}; registered: {sorted(CONV_EXECUTORS)}"
        ) from None
    return fn(x_t, lp, cfg)


def _fold_t(x_t: jax.Array) -> tuple[jax.Array, tuple]:
    t, n = x_t.shape[:2]
    return x_t.reshape((t * n,) + x_t.shape[2:]), (t, n)


def _unfold_t(y: jax.Array, tn: tuple) -> jax.Array:
    t, n = tn
    return y.reshape((t, n) + y.shape[1:])


def _quantize_input_u8(x: jax.Array) -> jax.Array:
    """[0,1] float → uint8 grid (the paper's 8-bit RGB input). Exact for
    images that already live on the k/255 grid."""
    return jnp.clip(jnp.round(x * 255.0), 0, 255).astype(jnp.uint8)


@register_conv_executor("dense")
def _exec_dense(x_t: jax.Array, lp: CompressedLayerPlan, cfg) -> jax.Array:
    """Oracle: dense conv on the int8 weights, dequantized AFTER the
    accumulation.

    All three executors accumulate integer-valued f32 (binary spikes ×
    int8 weights; every partial sum < 2^24 is exact in f32 regardless of
    summation order) and apply the FXP scale exactly once on the final
    integer — so dense, gated and the Pallas kernel agree BIT-EXACTLY,
    which is what the conformance suite (tests/conformance/) asserts.
    Scaling the weights first instead would make the result depend on the
    executor's float summation order (observed: ~1-ulp drift between the
    pre-refactor dense oracle and the Pallas kernel)."""
    w_int = lp.w_q.astype(jnp.float32)
    bh, bw = cfg.block_hw
    x, tn = _fold_t(x_t)
    if lp.in_bits == 8:
        # the paper's 8-bit RGB contract: inputs are quantized to the
        # uint8 grid (exact for k/255-grid frames), convolved as integers
        x = _quantize_input_u8(x).astype(jnp.float32)
        out_scale = lp.scale / 255.0
    else:
        out_scale = lp.scale
    if cfg.use_block_conv and w_int.shape[0] > 1:
        y = bc.block_conv2d(x, w_int, block_h=bh, block_w=bw)
    else:
        y = bc.conv2d(x, w_int)
    return _unfold_t(y * out_scale, tn)


def _blocked_gated(
    x: jax.Array,
    w: jax.Array,
    bh: int,
    bw: int,
    tap_alive: tuple | None = None,
) -> jax.Array:
    """Shift-accumulate gated one-to-all over independent replicate-padded
    blocks. Each live tap slices its aligned window straight out of the
    padded block and contracts input channels with one matmul — the same
    one-to-all broadcast as :func:`spike_conv.gated_one_to_all`, minus the
    zero-fill scatter per tap and the SAME-conv-then-crop waste (only the
    bh×bw interior is ever computed). ``tap_alive`` (pack-time liveness)
    skips fully-pruned taps at trace time. Integer-valued f32 accumulation
    is order-independent, so all of this is bit-exact with the literal
    shift-accumulate reference."""
    kh, kw = int(w.shape[0]), int(w.shape[1])
    if kh == 1 and kw == 1:
        # pointwise conv sees no block borders — skip the block round-trip
        # (two transposes) and contract channels in place
        return x @ w[0, 0].astype(jnp.float32)
    taps = tuple(range(kh * kw)) if tap_alive is None else tap_alive
    if len(taps) == kh * kw:
        # every gate open — the one-to-all visit order degenerates to the
        # full tap set, which is exactly the dense blocked conv (same
        # integer-exact accumulation, no im2col copy)
        return bc.block_conv2d(x, w.astype(jnp.float32), block_h=bh, block_w=bw)
    pad = (kh - 1) // 2
    xb = bc.to_blocks(x, bh, bw)
    n, nbh, nbw, _, _, c = xb.shape
    flat = xb.reshape(n * nbh * nbw, bh, bw, c)
    if pad:
        flat = jnp.pad(flat, ((0, 0), (pad, pad), (pad, pad), (0, 0)), mode="edge")
    m = flat.shape[0]
    kout = w.shape[-1]
    if not taps:  # fully-pruned layer: all taps gated off
        out = jnp.zeros((m, bh, bw, kout), jnp.float32)
    else:
        # all live taps in ONE contraction: stack each tap's window along a
        # new axis (im2col over live taps only) and contract (live·cin) at
        # once — integer-valued f32 partial sums stay exact (|acc| bounded
        # by live·cin·127 « 2^24), so the single dot is bit-identical to
        # the tap-by-tap shift-accumulate
        wins = [
            jax.lax.slice(flat, (0, t // kw, t % kw, 0),
                          (m, t // kw + bh, t % kw + bw, c))
            for t in taps
        ]
        patches = jnp.stack(wins, axis=-2)  # (m, bh, bw, live, cin)
        s2 = patches.reshape(m * bh * bw, len(taps) * c)
        w2 = jnp.stack([w[t // kw, t % kw] for t in taps])
        w2 = w2.reshape(len(taps) * c, kout).astype(jnp.float32)
        out = (s2 @ w2).reshape(m, bh, bw, kout)
    out = out.reshape(n, nbh, nbw, bh, bw, kout)
    return bc.from_blocks(out)


@register_conv_executor("gated")
def _exec_gated(x_t: jax.Array, lp: CompressedLayerPlan, cfg) -> jax.Array:
    """Paper-faithful shift-accumulate reference over the blocked layout.

    Accumulates the int8 weights as integer-valued f32 (exact) and scales
    the final integer once — see :func:`_exec_dense` for why this makes
    every executor bit-identical.

    The 8-bit encoding layer folds its bit-serial planes by conv linearity
    — conv(Σ_b 2^b·plane_b, w) = Σ_b 2^b·conv(plane_b, w) — into ONE gated
    pass over the integer-valued maps, exactly as the fused Pallas kernel
    does. :func:`repro.core.bitserial.bitserial_conv` remains the literal
    plane-serial reference and the two are asserted equal in tests; the
    accumulate accounting (nnz × bits_in) is analytic and unchanged."""
    w_int = lp.w_q.astype(jnp.float32)
    bh, bw = cfg.block_hw
    alive = tuple(lp.packed.tap_alive)
    x, tn = _fold_t(x_t)
    if lp.in_bits == 8:
        x = _quantize_input_u8(x).astype(jnp.float32)
        y = _blocked_gated(x, w_int, bh, bw, alive) * (lp.scale / 255.0)
    else:
        y = _blocked_gated(x, w_int, bh, bw, alive) * lp.scale
    return _unfold_t(y, tn)


def precompute_affines(plan: DetectorPlan, params, bn_state, cfg) -> dict:
    """Affine parameter bundles for every fused-eligible layer, built ONCE.

    The bundle (FXP scale / tdBN mean / rsqrt(var+eps) / gamma / beta, laid
    out per K-block — see :func:`repro.kernels.ops.affine_bundle`) depends
    only on the weights and calibrated BN statistics, never on the frames.
    Rebuilding it inside the per-frame step costs a dozen small XLA ops per
    layer that cannot fuse into the pallas_call consuming them; a compile-
    once detector hoists the whole set here instead and threads the result
    through ``forward(..., affines=...)``. Callers own staleness: the
    bundles describe THESE params/bn_state (CompiledDetector fingerprints
    the inputs and refuses on a swap)."""
    out = {}
    for name, lp in plan.layers.items():
        p = params.get(name)
        st = (bn_state or {}).get(name)
        if p is None or st is None or "gamma" not in p:
            continue
        scale_eff = lp.scale / 255.0 if lp.in_bits == 8 else lp.scale
        out[name] = kops.affine_bundle(
            lp.packed, scale_eff, st["mean"], st["var"], p["gamma"], p["beta"]
        )
    return out


def run_fused(
    x_t: jax.Array,
    lp: CompressedLayerPlan,
    cfg,
    *,
    gamma: jax.Array,
    beta: jax.Array,
    mean: jax.Array,
    var: jax.Array,
    v0: jax.Array | None,
    out_t: int,
    affine: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """The whole per-layer pipeline — conv → FXP rescale → tdBN inference
    affine → LIF over ``out_t`` steps — in ONE fused Pallas dispatch
    (kernels/fused_pipeline.py), membrane resident in VMEM across T.

    Returns (spikes (out_t, N, H, W, C) f32 {0,1}, final membrane
    (N, H, W, C) f32) — drop-in for the unfused conv → ``tdbn_apply``
    (training=False) → ``lif_over_time`` chain, BIT-IDENTICAL to it (same
    float ops in the same order; integer conv accumulation is
    order-independent).

    The 8-bit encoding layer folds its bit-serial planes into the u8 pixel
    values (Σ_b 2^b·conv(plane_b) = conv(u8), exact in f32), so encode is
    one dispatch too. Dispatch tiling comes from ``lp.tile`` (autotuned).

    ``affine``: optional precomputed parameter bundle (see
    :func:`precompute_affines`) — compile-once callers hoist the per-layer
    bundle build out of the frame loop; when None it is built inline from
    the gamma/beta/mean/var arguments (identical values either way)."""
    bh, bw = cfg.block_hw
    interpret = getattr(cfg, "kernel_interpret", None)
    if lp.in_bits == 8:
        # u8-grid values = the exact fold of the 8 bit-serial planes
        x = _quantize_input_u8(x_t).astype(jnp.float32)
        scale_eff = lp.scale / 255.0
    else:
        x = x_t
        scale_eff = lp.scale
    if affine is None:
        affine = kops.affine_bundle(lp.packed, scale_eff, mean, var, gamma, beta)
    return kops.fused_conv_bn_lif(
        x,
        lp.packed,
        affine,
        v0=v0,
        out_t=out_t,
        in_bits=lp.in_bits,
        bn_scale=1.0 * cfg.threshold,  # tdbn_apply's alpha(=1)·threshold
        threshold=cfg.threshold,
        leak=cfg.leak,
        reset=getattr(cfg, "reset", "hard"),
        v_init=getattr(cfg, "v_init", 0.0),
        bh=bh,
        bw=bw,
        nbt=lp.tile.nbt,
        mrows=lp.tile.mrows,
        mcols=lp.tile.mcols,
        interpret=interpret,
    )


@register_conv_executor("pallas")
def _exec_pallas(x_t: jax.Array, lp: CompressedLayerPlan, cfg) -> jax.Array:
    """Compressed Pallas kernel. T (and bit-serial planes for the 8-bit
    encoding layer) fold into the kernel's spatial-block grid, so the whole
    (T·N·blocks) volume is ONE pallas_call.

    Pointwise (1×1) spike layers — the detection head — bypass the kernel:
    with no spatial taps to gate and no halo, the blocked dispatch is pure
    layout overhead around a single channel contraction, so the executor
    contracts in place (integer-valued f32 matmul — bit-identical to the
    kernel's accumulation, which the conformance suite asserts)."""
    bh, bw = cfg.block_hw
    interpret = getattr(cfg, "kernel_interpret", None)
    x, tn = _fold_t(x_t)
    if lp.in_bits != 8 and lp.w_q.shape[0] == 1 and lp.w_q.shape[1] == 1:
        y = (x @ lp.w_q[0, 0].astype(jnp.float32)) * lp.scale
        return _unfold_t(y, tn)
    if lp.in_bits == 8:
        planes = bitserial.to_bitplanes(_quantize_input_u8(x))  # (8, TN, H, W, C)
        bits, m = planes.shape[0], planes.shape[1]
        flat = planes.reshape((bits * m,) + planes.shape[2:])
        acc = kops.gated_conv(flat.astype(jnp.int8), lp.packed, bh=bh, bw=bw, interpret=interpret)
        acc = acc.reshape((bits, m) + acc.shape[1:])
        weights = (2 ** jnp.arange(bits, dtype=jnp.int32)).reshape(bits, 1, 1, 1, 1)
        y_int = jnp.sum(acc * weights, axis=0)
        y = y_int.astype(jnp.float32) * (lp.scale / 255.0)
    else:
        acc = kops.gated_conv(x.astype(jnp.int8), lp.packed, bh=bh, bw=bw, interpret=interpret)
        y = acc.astype(jnp.float32) * lp.scale
    return _unfold_t(y, tn)
