"""Whole-detector compression plan + pluggable conv executors.

This is the bridge between the paper's compressed dataflow and the model:
``build_plan`` walks the full ``snn_yolo`` parameter tree ONCE and
precompiles every conv layer into a :class:`CompressedLayerPlan` —

    prune (already applied to params) → quantize (FXP8, per-tensor
    symmetric) → bitmask-pack ({maskp, vals, tap_any} + K-blocking
    metadata, paper §III-B.2)

— so inference never touches dense float weights. Which engine actually
runs each conv is a pluggable *executor*, selected by
``SNNDetConfig.conv_exec``:

  * ``dense``  — ``lax.conv`` / block-conv oracle on the dequantized
                 weights (the numerical reference).
  * ``gated``  — the literal shift-accumulate gated one-to-all product
                 (paper-faithful dataflow, exact accumulate accounting).
  * ``pallas`` — the compressed Pallas TPU kernel: weights stream from HBM
                 in bitmask-compressed form and are decoded once per
                 K-block in VMEM (paper's −59.1% weight traffic).

Executors consume the full time-major activation volume ``(T, N, H, W, C)``
and fold T (and, for the 8-bit encoding layer, the bit-serial plane axis)
into the batch, so mixed time steps batch through ONE ``pallas_call`` whose
grid spans T·N·spatial-blocks instead of a Python vmap over T.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import bitserial
from . import block_conv as bc
from . import pruning, quant
from . import spike_conv as sc
from repro.kernels import ops as kops


class CompressedLayerPlan(NamedTuple):
    """One conv layer, compiled for the compressed gated one-to-all path."""

    name: str
    packed: kops.PackedConvWeights  # bitmask-compressed int8 weights
    scale: jax.Array  # () f32 — dequant scale (FXP8 per-tensor)
    w_q: jax.Array  # (kh, kw, cin, kout) int8 dense — gated/dense reference
    in_bits: int  # 1 = binary spikes, 8 = multibit input (bit-serial)
    nnz: int  # true nonzero count (accumulate accounting)

    @property
    def dense_bytes(self) -> int:
        return int(np.prod(self.w_q.shape))

    @property
    def compressed_bytes(self) -> int:
        return int(self.packed.compressed_bytes)


class DetectorPlan(NamedTuple):
    layers: dict  # name -> CompressedLayerPlan
    block_hw: tuple  # (bh, bw) spatial block for every executor

    @property
    def dense_bytes(self) -> int:
        return sum(lp.dense_bytes for lp in self.layers.values())

    @property
    def compressed_bytes(self) -> int:
        return sum(lp.compressed_bytes for lp in self.layers.values())


# ------------------------------------------------------------------ build --


def build_layer_plan(
    name: str,
    w: jax.Array,
    *,
    kblk: int = 128,
    weight_bits: int = 8,
    in_bits: int = 1,
    vpad: int | None = None,
) -> CompressedLayerPlan:
    """Quantize + bitmask-pack one HWIO kernel tensor. Must run outside jit
    (packing is host-side numpy). Raises if any K-block's nnz would overflow
    the packed-value buffer (the kernel cannot bounds-check its gather)."""
    qw = quant.quantize(w, bits=weight_bits)
    w_q = np.asarray(qw.q).reshape(w.shape)
    kout = w.shape[-1]
    kblk_l = min(kblk, -(-kout // 8) * 8)  # small layers: one tight K-block
    # pack_conv_weights itself raises on vpad overflow; validate_packed
    # stays available for externally-constructed PackedConvWeights
    packed = kops.pack_conv_weights(w_q, kblk=kblk_l, vpad=vpad)
    return CompressedLayerPlan(
        name=name,
        packed=packed,
        scale=qw.scale.reshape(()),
        w_q=jnp.asarray(w_q),
        in_bits=in_bits,
        nnz=int(np.count_nonzero(w_q)),
    )


def build_plan(
    params: Any,
    cfg,
    *,
    kblk: int = 128,
    prune_rate: float | None = None,
) -> DetectorPlan:
    """Compile the whole detector parameter tree in one pass.

    ``params`` is the ``snn_yolo.init_params`` tree (name -> {"w", ...}).
    ``prune_rate`` optionally applies fine-grained magnitude pruning to the
    spatial (3×3) kernels first — pass the SAME pruned tree to the dense
    oracle when checking parity. The encoding layer is marked 8-bit input
    (RGB); every other layer consumes binary spikes.
    """
    if not cfg.weight_bits:
        # the compressed path is FXP-int8 by construction; quantizing a
        # float-weight config silently would diverge from its dense baseline
        raise ValueError(
            "build_plan requires quantized weights (cfg.weight_bits > 0); "
            "weight_bits=0 means float weights, which only conv_exec='dense' runs"
        )
    layers = {}
    for name, layer_p in params.items():
        w = layer_p["w"]
        if prune_rate is not None and pruning.is_spatial_kernel(w):
            w = pruning.prune_by_rate(w, prune_rate)
        layers[name] = build_layer_plan(
            name,
            w,
            kblk=kblk,
            weight_bits=cfg.weight_bits,
            in_bits=8 if name == "encode" else 1,
        )
    return DetectorPlan(layers=layers, block_hw=tuple(cfg.block_hw))


# -------------------------------------------------------------- executors --

# Registry: name -> fn(x_t (T,N,H,W,C) f32, CompressedLayerPlan, cfg) -> f32
CONV_EXECUTORS: dict[str, Callable] = {}


def register_conv_executor(name: str):
    def deco(fn):
        CONV_EXECUTORS[name] = fn
        return fn

    return deco


def run_conv(x_t: jax.Array, lp: CompressedLayerPlan, cfg) -> jax.Array:
    """Dispatch one conv layer through the configured executor."""
    try:
        fn = CONV_EXECUTORS[cfg.conv_exec]
    except KeyError:
        raise ValueError(
            f"unknown conv_exec={cfg.conv_exec!r}; registered: {sorted(CONV_EXECUTORS)}"
        ) from None
    return fn(x_t, lp, cfg)


def _fold_t(x_t: jax.Array) -> tuple[jax.Array, tuple]:
    t, n = x_t.shape[:2]
    return x_t.reshape((t * n,) + x_t.shape[2:]), (t, n)


def _unfold_t(y: jax.Array, tn: tuple) -> jax.Array:
    t, n = tn
    return y.reshape((t, n) + y.shape[1:])


def _quantize_input_u8(x: jax.Array) -> jax.Array:
    """[0,1] float → uint8 grid (the paper's 8-bit RGB input). Exact for
    images that already live on the k/255 grid."""
    return jnp.clip(jnp.round(x * 255.0), 0, 255).astype(jnp.uint8)


@register_conv_executor("dense")
def _exec_dense(x_t: jax.Array, lp: CompressedLayerPlan, cfg) -> jax.Array:
    """Oracle: dense conv on the int8 weights, dequantized AFTER the
    accumulation.

    All three executors accumulate integer-valued f32 (binary spikes ×
    int8 weights; every partial sum < 2^24 is exact in f32 regardless of
    summation order) and apply the FXP scale exactly once on the final
    integer — so dense, gated and the Pallas kernel agree BIT-EXACTLY,
    which is what the conformance suite (tests/conformance/) asserts.
    Scaling the weights first instead would make the result depend on the
    executor's float summation order (observed: ~1-ulp drift between the
    pre-refactor dense oracle and the Pallas kernel)."""
    w_int = lp.w_q.astype(jnp.float32)
    bh, bw = cfg.block_hw
    x, tn = _fold_t(x_t)
    if lp.in_bits == 8:
        # the paper's 8-bit RGB contract: inputs are quantized to the
        # uint8 grid (exact for k/255-grid frames), convolved as integers
        x = _quantize_input_u8(x).astype(jnp.float32)
        out_scale = lp.scale / 255.0
    else:
        out_scale = lp.scale
    if cfg.use_block_conv and w_int.shape[0] > 1:
        y = bc.block_conv2d(x, w_int, block_h=bh, block_w=bw)
    else:
        y = bc.conv2d(x, w_int)
    return _unfold_t(y * out_scale, tn)


def _blocked_gated(x: jax.Array, w: jax.Array, bh: int, bw: int) -> jax.Array:
    """Shift-accumulate gated one-to-all with block-conv border semantics:
    replicate-pad each independent block, SAME-conv it, crop the center."""
    kh = w.shape[0]
    pad = (kh - 1) // 2
    xb = bc.to_blocks(x, bh, bw)
    n, nbh, nbw, _, _, c = xb.shape
    flat = xb.reshape(n * nbh * nbw, bh, bw, c)
    if pad:
        flat = jnp.pad(flat, ((0, 0), (pad, pad), (pad, pad), (0, 0)), mode="edge")
    out = sc.gated_one_to_all(flat, w)
    if pad:
        out = out[:, pad:-pad, pad:-pad, :]
    out = out.reshape(n, nbh, nbw, bh, bw, out.shape[-1])
    return bc.from_blocks(out)


@register_conv_executor("gated")
def _exec_gated(x_t: jax.Array, lp: CompressedLayerPlan, cfg) -> jax.Array:
    """Paper-faithful shift-accumulate reference over the blocked layout.

    Accumulates the int8 weights as integer-valued f32 (exact) and scales
    the final integer once — see :func:`_exec_dense` for why this makes
    every executor bit-identical."""
    w_int = lp.w_q.astype(jnp.float32)
    bh, bw = cfg.block_hw
    x, tn = _fold_t(x_t)
    if lp.in_bits == 8:
        xq = _quantize_input_u8(x)
        y = bitserial.bitserial_conv(
            xq, w_int, lambda p, wt: _blocked_gated(p, wt, bh, bw)
        )
        y = y * (lp.scale / 255.0)
    else:
        y = _blocked_gated(x, w_int, bh, bw) * lp.scale
    return _unfold_t(y, tn)


@register_conv_executor("pallas")
def _exec_pallas(x_t: jax.Array, lp: CompressedLayerPlan, cfg) -> jax.Array:
    """Compressed Pallas kernel. T (and bit-serial planes for the 8-bit
    encoding layer) fold into the kernel's spatial-block grid, so the whole
    (T·N·blocks) volume is ONE pallas_call."""
    bh, bw = cfg.block_hw
    interpret = getattr(cfg, "kernel_interpret", None)
    x, tn = _fold_t(x_t)
    if lp.in_bits == 8:
        planes = bitserial.to_bitplanes(_quantize_input_u8(x))  # (8, TN, H, W, C)
        bits, m = planes.shape[0], planes.shape[1]
        flat = planes.reshape((bits * m,) + planes.shape[2:])
        acc = kops.gated_conv(flat.astype(jnp.int8), lp.packed, bh=bh, bw=bw, interpret=interpret)
        acc = acc.reshape((bits, m) + acc.shape[1:])
        weights = (2 ** jnp.arange(bits, dtype=jnp.int32)).reshape(bits, 1, 1, 1, 1)
        y_int = jnp.sum(acc * weights, axis=0)
        y = y_int.astype(jnp.float32) * (lp.scale / 255.0)
    else:
        acc = kops.gated_conv(x.astype(jnp.int8), lp.packed, bh=bh, bw=bw, interpret=interpret)
        y = acc.astype(jnp.float32) * lp.scale
    return _unfold_t(y, tn)
