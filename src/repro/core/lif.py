"""Leaky integrate-and-fire neuron dynamics (paper §I, §II-A).

Discrete-time approximate LIF with delta-shaped synaptic kernel:

    v[t]   = leak * v[t-1] * reset_mask[t-1] + x[t]
    s[t]   = H(v[t] - threshold)                     (Heaviside)

Paper constants: threshold = 0.5, leak = 0.25 ("for a simple hardware
implementation" — both are powers of two, shift-friendly).

Reset modes:
  * ``hard``  — v is zeroed where a spike fired (STBP/tdBN convention).
  * ``soft``  — v -= threshold where a spike fired.
  * ``none``  — no reset; used by the paper's Output Convolution layer which
    "accumulates the membrane potential with no reset and averages the output
    of all time steps".

Training uses the STBP rectangular surrogate gradient (Wu et al. 2019):
    d s / d v  ≈  (1/a) * 1[|v - θ| < a/2],   a = 1.
"""
from __future__ import annotations

import functools
from typing import Literal, NamedTuple

import jax
import jax.numpy as jnp

THRESHOLD = 0.5
LEAK = 0.25
SURROGATE_WIDTH = 1.0


@jax.custom_vjp
def spike_fn(v: jax.Array, threshold: float = THRESHOLD) -> jax.Array:
    """Heaviside spike with rectangular surrogate gradient."""
    return (v >= threshold).astype(v.dtype)


def _spike_fwd(v, threshold):
    return spike_fn(v, threshold), (v, threshold)


def _spike_bwd(res, g):
    v, threshold = res
    surrogate = (jnp.abs(v - threshold) < SURROGATE_WIDTH / 2).astype(g.dtype)
    return (g * surrogate / SURROGATE_WIDTH, None)


spike_fn.defvjp(_spike_fwd, _spike_bwd)

ResetMode = Literal["hard", "soft", "none"]


class LIFState(NamedTuple):
    v: jax.Array  # membrane potential, same shape as the neuron layer


def lif_init(shape, dtype=jnp.float32) -> LIFState:
    return LIFState(v=jnp.zeros(shape, dtype))


def lif_step(
    state: LIFState,
    x: jax.Array,
    *,
    threshold: float = THRESHOLD,
    leak: float = LEAK,
    reset: ResetMode = "hard",
):
    """One LIF time step. Returns (new_state, spikes).

    ``x`` is the synaptic input (conv/matmul output) at this time step.
    """
    v = state.v * leak + x
    s = spike_fn(v, threshold)
    if reset == "hard":
        v_next = v * (1.0 - s)
    elif reset == "soft":
        v_next = v - s * threshold
    elif reset == "none":
        v_next = v
    else:  # pragma: no cover
        raise ValueError(f"unknown reset mode {reset!r}")
    return LIFState(v=v_next), s


def lif_over_time(
    x_seq: jax.Array,
    *,
    threshold: float = THRESHOLD,
    leak: float = LEAK,
    reset: ResetMode = "hard",
    init: LIFState | None = None,
):
    """Run LIF over a leading time axis. x_seq: (T, ...) -> spikes (T, ...).

    Implemented with lax.scan so T is a loop in HLO, not unrolled — the
    paper's "weights resident across the T loop" maps to scan keeping the
    layer computation out of the T dimension.
    """
    if init is None:
        init = lif_init(x_seq.shape[1:], x_seq.dtype)

    def step(state, x):
        state, s = lif_step(state, x, threshold=threshold, leak=leak, reset=reset)
        return state, s

    final, spikes = jax.lax.scan(step, init, x_seq)
    return spikes, final


def membrane_readout(
    x_seq: jax.Array,
    *,
    leak: float = LEAK,
    v0: jax.Array | None = None,
    return_final: bool = False,
):
    """Paper's output layer: accumulate membrane potential with NO reset and
    average over time steps. x_seq: (T, ...) -> (...).

    ``v0`` warm-starts the accumulator (streaming sessions carry the head
    membrane across frames); ``return_final`` additionally returns the final
    membrane so the caller can thread it into the next frame.
    """
    if v0 is None:
        v0 = jnp.zeros(x_seq.shape[1:], x_seq.dtype)

    def step(v, x):
        v = v * leak + x
        return v, v

    final, vs = jax.lax.scan(step, v0, x_seq)
    out = jnp.mean(vs, axis=0)
    if return_final:
        return out, final
    return out


# ---------------------------------------------------------------------------
# Threshold-dependent batch normalization (tdBN, Zheng et al. 2020, §II-A).
# Normalizes over (T, N, spatial...) jointly per channel and scales by the
# firing threshold so pre-activations sit in the responsive LIF range.
# ---------------------------------------------------------------------------


class TdBNParams(NamedTuple):
    gamma: jax.Array
    beta: jax.Array


class TdBNState(NamedTuple):
    mean: jax.Array
    var: jax.Array
    count: jax.Array  # scalar update counter for debugging/restart


def tdbn_init(channels: int, dtype=jnp.float32):
    params = TdBNParams(gamma=jnp.ones((channels,), dtype), beta=jnp.zeros((channels,), dtype))
    state = TdBNState(
        mean=jnp.zeros((channels,), dtype),
        var=jnp.ones((channels,), dtype),
        count=jnp.zeros((), jnp.int32),
    )
    return params, state


def tdbn_apply(
    params: TdBNParams,
    state: TdBNState,
    x: jax.Array,
    *,
    channel_axis: int = -1,
    threshold: float = THRESHOLD,
    alpha: float = 1.0,
    momentum: float = 0.9,
    training: bool = True,
    eps: float = 1e-5,
):
    """tdBN: y = alpha * threshold * (x - mu) / sqrt(var + eps) * gamma + beta.

    ``x`` carries time in its leading axis (T, N, ..., C) — normalization
    statistics pool over every axis except the channel axis, which is the
    tdBN prescription (treat T like extra batch).
    Returns (y, new_state).
    """
    axis = channel_axis % x.ndim
    reduce_axes = tuple(i for i in range(x.ndim) if i != axis)
    shape = [1] * x.ndim
    shape[axis] = x.shape[axis]

    if training:
        mean = jnp.mean(x, axis=reduce_axes)
        var = jnp.var(x, axis=reduce_axes)
        new_state = TdBNState(
            mean=momentum * state.mean + (1 - momentum) * mean,
            var=momentum * state.var + (1 - momentum) * var,
            count=state.count + 1,
        )
    else:
        mean, var = state.mean, state.var
        new_state = state

    x_hat = (x - mean.reshape(shape)) * jax.lax.rsqrt(var.reshape(shape) + eps)
    y = alpha * threshold * x_hat * params.gamma.reshape(shape) + params.beta.reshape(shape)
    return y, new_state
