"""Analytic DRAM-traffic / energy / latency model (paper §IV-D, §IV-E).

The paper's energy story is accounting, and the accounting reproduces on any
platform: bytes moved × pJ/bit + ops × pJ/op. This module implements that
model generically over a layer list so benchmarks can reproduce:

  * §IV-D input/output/param DRAM traffic per frame
      (paper: 188.928 MB input / 3.327 MB output / 1.292 MB params with a
       36 KB input SRAM; input drops to 5.456 MB with 81 KB),
  * Fig 17 parameter-traffic comparison (dense vs CSR vs bitmask,
      −59.1% / −16.4%),
  * Table III / Fig 16 throughput (576 PEs @ 500 MHz, zero-weight skipping
      → −47.3% latency, 29 fps) and energy (1.05 mJ/frame core,
      70 pJ/bit DDR3).

The refetch model (paper §IV-D): the Input SRAM holds `sram_bits_per_pixel`
bits for every pixel of a 32×18 tile (36 KB ⇒ 512 bits/pixel ⇒ 512 channels
× 1 time step of 1-bit spikes). A layer whose input needs Cin × T_in ×
bits_in > capacity must re-stream its input from DRAM once per output
channel (KTBC loop order: K is outermost).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

# -- hardware constants (paper values) --------------------------------------
FREQ_HZ = 500e6
NUM_PES = 576  # 32×18 spatial tile, one accumulator per pixel
DRAM_PJ_PER_BIT = 70.0  # DDR3 [35]
TILE_PIXELS = 32 * 18
CORE_POWER_W = 30.5e-3  # measured core power (paper Fig 16)

# input SRAM configurations discussed in §IV-D
SRAM_36KB_BITS_PER_PIXEL = 512  # 512 ch × 1 T × 1 bit
SRAM_81KB_BITS_PER_PIXEL = 1152  # 384 ch × 3 T × 1 bit


@dataclass
class ConvLayerSpec:
    """One convolution layer as the accelerator sees it."""

    name: str
    h: int  # output spatial height
    w: int
    cin: int
    cout: int
    k: int = 3  # kernel size (1 or 3)
    t_in: int = 3  # input time steps
    t_out: int = 3
    bits_in: int = 1  # 1 for spikes, 8 for the RGB encoding layer
    bits_out: int = 1
    density: float = 1.0  # nonzero weight fraction after pruning

    @property
    def params(self) -> int:
        return self.k * self.k * self.cin * self.cout

    @property
    def nnz(self) -> int:
        return int(round(self.params * self.density))

    @property
    def macs_dense_1t(self) -> int:
        return self.h * self.w * self.params

    def macs(self, *, sparse: bool = True) -> int:
        """MACs per frame: conv executed once per input time step (mixed
        time steps: t_in=1 layers compute once), per bit plane."""
        per_t = self.h * self.w * (self.nnz if sparse else self.params)
        return per_t * self.t_in * self.bits_in

    def ops(self, *, sparse: bool = True) -> int:
        return 2 * self.macs(sparse=sparse)


def input_dram_bytes(layer: ConvLayerSpec, sram_bits_per_pixel: int) -> float:
    """DRAM bytes read for this layer's input per frame (refetch model)."""
    bits_needed = layer.cin * layer.t_in * layer.bits_in
    base_bits = layer.h * layer.w * bits_needed
    refetch = layer.cout if bits_needed > sram_bits_per_pixel else 1
    return base_bits * refetch / 8.0


def output_dram_bytes(layer: ConvLayerSpec) -> float:
    return layer.h * layer.w * layer.cout * layer.t_out * layer.bits_out / 8.0


def param_dram_bytes(layer: ConvLayerSpec, fmt: str = "bitmask", weight_bits: int = 8) -> float:
    """Parameter traffic per frame in a given storage format (Fig 17).

    1×1 layers are kept dense (unpruned per §II-C) in every format.
    """
    from . import bitmask as bm

    if layer.k == 1 or layer.density >= 1.0:
        return layer.params * weight_bits / 8.0
    shape = (layer.cout, layer.cin * layer.k * layer.k)
    return bm.format_bits(shape, layer.nnz, weight_bits=weight_bits, fmt=fmt) / 8.0


@dataclass
class TrafficReport:
    input_mb: float
    output_mb: float
    param_mb: float

    @property
    def total_mb(self) -> float:
        return self.input_mb + self.output_mb + self.param_mb

    def dram_energy_mj(self) -> float:
        return self.total_mb * 8e6 * DRAM_PJ_PER_BIT * 1e-12 * 1e3


def network_traffic(
    layers: Sequence[ConvLayerSpec],
    *,
    sram_bits_per_pixel: int = SRAM_36KB_BITS_PER_PIXEL,
    param_fmt: str = "bitmask",
) -> TrafficReport:
    mb = 1.0 / 1e6
    return TrafficReport(
        input_mb=sum(input_dram_bytes(l, sram_bits_per_pixel) for l in layers) * mb,
        output_mb=sum(output_dram_bytes(l) for l in layers) * mb,
        param_mb=sum(param_dram_bytes(l, param_fmt) for l in layers) * mb,
    )


def network_latency_s(layers: Sequence[ConvLayerSpec], *, sparse: bool = True) -> float:
    """Cycle model: each PE performs one accumulate per cycle; a layer's
    cycles = MACs / NUM_PES (spatial parallelism is perfectly balanced —
    the paper's Fig 6 argument). Zero-weight skipping ⇒ MACs counts nnz."""
    total_macs = sum(l.macs(sparse=sparse) for l in layers)
    return total_macs / NUM_PES / FREQ_HZ


def fps(layers: Sequence[ConvLayerSpec], *, sparse: bool = True) -> float:
    return 1.0 / network_latency_s(layers, sparse=sparse)


def peak_gops(*, sparse_speedup: float = 1.0) -> float:
    """576 adders × 2 ops × 500 MHz = 576 GOPS dense; 'considering weight
    sparsity' the paper quotes effective 1093 GOPS = 576 / (1 − 0.473)."""
    return NUM_PES * 2 * FREQ_HZ / 1e9 * sparse_speedup


def core_energy_mj_per_frame(layers: Sequence[ConvLayerSpec]) -> float:
    """Core energy per frame = power × latency (paper: 30.5 mW, 34.5 ms
    ⇒ 1.05 mJ/frame)."""
    return CORE_POWER_W * network_latency_s(layers, sparse=True) * 1e3
