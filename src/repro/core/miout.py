"""mIoUT — mean Intersection over Union across Time-steps (paper §II-D, eq 1,
Fig 4) — and the mixed time-step schedule it drives.

For a spike tensor s ∈ {0,1} with shape (T, ..., C):
  firing count f = Σ_t s[t]                    per neuron
  Intersection_c = #{neurons in channel c with f == T}
  Union_c        = #{neurons in channel c with f >= 1}
  mIoUT          = mean_c Intersection_c / Union_c

Fig 4's worked example: 4 neurons fire at every step, 2 fire some-but-not-all
steps → 4 / (4+2) = 0.67. High mIoUT ⇒ per-step features are nearly
identical ⇒ that layer's input time step can drop to 1 (conv computed once,
LIF still emits T distinct outputs — paper's C2 configuration).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def miout(spikes: jax.Array, *, channel_axis: int = -1, eps: float = 1e-9) -> jax.Array:
    """spikes: (T, ..., C) binary. Returns scalar mIoUT."""
    T = spikes.shape[0]
    counts = jnp.sum(spikes.astype(jnp.int32), axis=0)  # (..., C)
    axis = channel_axis % counts.ndim
    reduce_axes = tuple(i for i in range(counts.ndim) if i != axis)
    inter = jnp.sum((counts == T).astype(jnp.float32), axis=reduce_axes)
    union = jnp.sum((counts >= 1).astype(jnp.float32), axis=reduce_axes)
    iou = inter / jnp.maximum(union, eps)
    # channels that never fire contribute IoU 0 with union 0; the paper
    # averages over channels — mask out all-silent channels to avoid 0/0.
    valid = (union > 0).astype(jnp.float32)
    return jnp.sum(iou * valid) / jnp.maximum(jnp.sum(valid), 1.0)


def repeat_conv_for_timesteps(conv_out: jax.Array, out_t: int) -> jax.Array:
    """Mixed-time-step mechanics (paper §II-A): a layer with in_T=1 computes
    its convolution ONCE and feeds the same result to the LIF for ``out_t``
    steps; the LIF state evolution makes the outputs differ across steps.
    conv_out: (...,) single-step result -> (out_t, ...)."""
    return jnp.broadcast_to(conv_out[None], (out_t,) + conv_out.shape)


def schedule_ops(layer_macs: Sequence[int], in_ts: Sequence[int]) -> int:
    """Total MACs for a mixed-time-step schedule: each layer's conv runs
    in_T times (the LIF/elementwise cost is negligible in the paper's
    accounting)."""
    if len(layer_macs) != len(in_ts):
        raise ValueError("length mismatch")
    return int(sum(m * t for m, t in zip(layer_macs, in_ts)))


def choose_schedule(
    mious: Sequence[float],
    layer_macs: Sequence[int],
    *,
    threshold: float = 0.6,
    full_t: int = 3,
) -> list[int]:
    """Greedy prefix rule from the paper: layers at the FRONT of the network
    whose mIoUT exceeds the threshold run with in_T=1; the first layer with
    low mIoUT and everything after it runs at full_t. (The paper only drops
    prefix layers — dropping late layers hurts accuracy without saving much,
    Fig 15.)"""
    in_ts = []
    prefix = True
    for m in mious:
        if prefix and m >= threshold:
            in_ts.append(1)
        else:
            prefix = False
            in_ts.append(full_t)
    return [t for t, _ in zip(in_ts, layer_macs)]
