"""Fine-grained magnitude pruning (paper §II-C, ref [26] Han et al.).

Weights below a magnitude threshold are zeroed; the threshold is set by the
target pruning RATE. Per the paper: prune 3×3 kernels at 80%, keep all 1×1
kernels intact. Net effect on their model: −70% parameters, −47.3% operation
count.
"""
from __future__ import annotations

from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np


def magnitude_threshold(w: jax.Array, rate: float) -> jax.Array:
    """|w| value such that ``rate`` fraction of entries fall below it."""
    if not 0.0 <= rate < 1.0:
        raise ValueError(f"rate must be in [0,1), got {rate}")
    flat = jnp.abs(w).reshape(-1)
    k = int(np.floor(rate * flat.size))
    if k == 0:
        return jnp.zeros((), w.dtype)
    return jnp.sort(flat)[k - 1]


def prune_by_rate(w: jax.Array, rate: float) -> jax.Array:
    """Zero the smallest-|magnitude| ``rate`` fraction of ``w``."""
    thr = magnitude_threshold(w, rate)
    return jnp.where(jnp.abs(w) > thr, w, jnp.zeros_like(w))


def make_mask(w: jax.Array, rate: float) -> jax.Array:
    thr = magnitude_threshold(w, rate)
    return (jnp.abs(w) > thr).astype(w.dtype)


def is_spatial_kernel(w: jax.Array) -> bool:
    """True for HWIO conv kernels with spatial extent > 1 (the 3×3 targets)."""
    return w.ndim == 4 and (w.shape[0] > 1 or w.shape[1] > 1)


def prune_tree(
    params: Any,
    rate: float = 0.8,
    *,
    select: Callable[[jax.Array], bool] = is_spatial_kernel,
) -> Any:
    """Apply fine-grained pruning across a parameter pytree.

    Per the paper: only spatial (3×3) kernels are pruned; 1×1 kernels and
    biases/norms are left intact.
    """
    return jax.tree_util.tree_map(lambda w: prune_by_rate(w, rate) if select(w) else w, params)


def mask_tree(params: Any, rate: float = 0.8, *, select=is_spatial_kernel) -> Any:
    """Masks for prune-aware fine-tuning (masked gradient updates)."""
    return jax.tree_util.tree_map(
        lambda w: make_mask(w, rate) if select(w) else jnp.ones_like(w), params
    )


def density(w: jax.Array) -> float:
    """Fraction of nonzero weights (drives the Fig 3 benchmark)."""
    return float(jnp.mean((w != 0).astype(jnp.float32)))


def tree_sparsity_report(params: Mapping[str, Any]) -> dict:
    """Per-leaf density + aggregate params kept (Table I accounting)."""
    leaves = {}
    total = kept = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        nnz = int(jnp.sum(leaf != 0))
        leaves[name] = {"shape": tuple(leaf.shape), "nnz": nnz, "density": nnz / leaf.size}
        total += leaf.size
        kept += nnz
    return {"leaves": leaves, "total_params": total, "kept_params": kept, "kept_frac": kept / max(total, 1)}
