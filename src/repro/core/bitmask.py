"""Bit-mask sparse weight compression (paper §III-B.2, Fig 10, Fig 17).

A pruned kernel tensor is stored as
  * ``mask``    — one bit per weight position (uint8 here; bit-packing is a
                  storage accounting concern handled by :func:`format_bits`),
  * ``values``  — the nonzero weights, packed densely in scan order.

The paper chose bit-mask over CSR because at 70–80% sparsity of 3×3 kernels
the mask costs 1 bit/position while CSR pays an index per nonzero; Fig 17
reports bitmask = −59.1% vs dense and −16.4% vs CSR DRAM traffic.

Everything here is pure JAX/numpy so the codecs can run inside jitted code
(decode) or at pack time (encode, host side).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class BitmaskWeights(NamedTuple):
    """Compressed tensor. ``mask`` has the original shape (uint8 0/1);
    ``values`` is 1-D with ``nnz`` entries; ``shape``/``dtype`` describe the
    dense original. ``values`` may be padded (with zeros) to a static size
    for jit friendliness; ``nnz`` records the true count."""

    mask: jax.Array
    values: jax.Array
    nnz: int

    @property
    def shape(self):
        return self.mask.shape


def encode(dense: jax.Array, pad_to: int | None = None) -> BitmaskWeights:
    """Host-side pack: dense -> (mask, packed values)."""
    dense = np.asarray(dense)
    mask = (dense != 0).astype(np.uint8)
    values = dense[dense != 0].ravel()
    nnz = int(values.size)
    if pad_to is not None:
        if pad_to < nnz:
            raise ValueError(f"pad_to={pad_to} < nnz={nnz}")
        values = np.pad(values, (0, pad_to - nnz))
    return BitmaskWeights(mask=jnp.asarray(mask), values=jnp.asarray(values), nnz=nnz)


def decode(cw: BitmaskWeights, dtype=None) -> jax.Array:
    """Jit-safe unpack: (mask, values) -> dense.

    Uses the cumulative-sum scatter that the Pallas kernels replicate in
    VMEM: position i reads values[cumsum(mask)[i]-1] when mask[i] else 0.
    """
    mask = cw.mask.reshape(-1)
    if cw.values.shape[0] == 0:  # fully-pruned tensor
        dense = jnp.zeros(mask.shape, cw.values.dtype)
        if dtype is not None:
            dense = dense.astype(dtype)
        return dense.reshape(cw.mask.shape)
    # cumsum in int32: a uint8 cumsum silently wraps at 256 nonzeros
    # (hypothesis-found; any tensor with nnz > 255 decoded garbage)
    idx = jnp.cumsum(mask.astype(jnp.int32)) - 1
    vals = jnp.take(cw.values, jnp.clip(idx, 0, cw.values.shape[0] - 1))
    dense = jnp.where(mask.astype(bool), vals, jnp.zeros_like(vals))
    if dtype is not None:
        dense = dense.astype(dtype)
    return dense.reshape(cw.mask.shape)


# ---------------------------------------------------------------------------
# CSR, for the Fig 17 comparison. Kernel-sparse CSR as in the paper's Fig 10:
# per output-channel row pointers + column indices into the flattened
# (C_in * kh * kw) axis.
# ---------------------------------------------------------------------------


class CSRWeights(NamedTuple):
    indptr: jax.Array  # (rows + 1,)
    indices: jax.Array  # (nnz,)
    values: jax.Array  # (nnz,)
    shape: tuple


def encode_csr(dense: jax.Array) -> CSRWeights:
    dense = np.asarray(dense)
    rows = dense.shape[0]
    flat = dense.reshape(rows, -1)
    indptr = [0]
    indices = []
    values = []
    for r in range(rows):
        (nz,) = np.nonzero(flat[r])
        indices.append(nz)
        values.append(flat[r, nz])
        indptr.append(indptr[-1] + nz.size)
    return CSRWeights(
        indptr=jnp.asarray(np.asarray(indptr, np.int32)),
        indices=jnp.asarray(np.concatenate(indices).astype(np.int32) if indices else np.zeros(0, np.int32)),
        values=jnp.asarray(np.concatenate(values) if values else np.zeros(0, dense.dtype)),
        shape=dense.shape,
    )


def decode_csr(cw: CSRWeights) -> jax.Array:
    indptr = np.asarray(cw.indptr)
    indices = np.asarray(cw.indices)
    values = np.asarray(cw.values)
    rows = cw.shape[0]
    flat = np.zeros((rows, int(np.prod(cw.shape[1:]))), values.dtype)
    for r in range(rows):
        flat[r, indices[indptr[r] : indptr[r + 1]]] = values[indptr[r] : indptr[r + 1]]
    return jnp.asarray(flat.reshape(cw.shape))


# ---------------------------------------------------------------------------
# Storage accounting (drives benchmarks/fig17_dram.py).
# ---------------------------------------------------------------------------


def format_bits(
    dense_shape,
    nnz: int,
    *,
    weight_bits: int = 8,
    fmt: str = "bitmask",
    index_bits: int | None = None,
) -> int:
    """Bits needed to store a pruned tensor in a given format.

    ``dense``   : every position at weight_bits.
    ``bitmask`` : 1 bit/position + nnz * weight_bits.
    ``csr``     : per paper Fig 10 — index per nonzero + row pointers.
    """
    n = int(np.prod(dense_shape))
    rows = int(dense_shape[0]) if len(dense_shape) > 1 else 1
    cols = n // max(rows, 1)
    if fmt == "dense":
        return n * weight_bits
    if fmt == "bitmask":
        return n + nnz * weight_bits
    if fmt == "csr":
        ib = index_bits if index_bits is not None else max(int(np.ceil(np.log2(max(cols, 2)))), 1)
        pb = max(int(np.ceil(np.log2(max(nnz + 1, 2)))), 1)
        return nnz * (weight_bits + ib) + (rows + 1) * pb
    raise ValueError(f"unknown format {fmt!r}")
