"""Synthetic cityscape-like detection data matched to IVS 3cls statistics.

The IVS 3cls dataset (paper §IV-A: ~10k train / 1k test, 1920x1080 resized
to 1024x576, three classes: vehicle / bike / pedestrian) is not
redistributable and this container is offline, so we generate a synthetic
set with matched statistics (DESIGN.md §8.3):

* image: per-image sky/road gradient + textured noise (so the encoder sees
  non-trivial multibit input and activation sparsity statistics are
  realistic after the first LIF),
* objects: 1–12 boxes per image; class mix 55% vehicle / 22% bike / 23%
  pedestrian; log-normal box sizes with per-class aspect ratios (vehicles
  wide, pedestrians tall); objects rendered as filled rectangles with
  class-dependent intensity so boxes are actually learnable,
* deterministic per (split, index) — reproducible across hosts without a
  shared filesystem; each data-parallel host generates only its shard.

Targets use the YOLOv2 grid format of models/snn_yolo.py: (gh, gw, A, 5+C)
with [tx, ty, tw, th, obj, cls...].
"""
from __future__ import annotations

import functools
import zlib
from typing import Iterator, Optional

import numpy as np

CLASSES = ("vehicle", "bike", "pedestrian")
CLASS_P = np.array([0.55, 0.22, 0.23])
# per-class (mean_area_frac, aspect w/h)
SIZE_STATS = {0: (0.015, 1.9), 1: (0.004, 0.7), 2: (0.003, 0.45)}

# Anchor shapes in grid-cell units. Numerically pinned copy of
# repro.models.snn_yolo.DEFAULT_ANCHORS so the data pipeline stays
# numpy-only (tests/test_data.py asserts the two never diverge). Targets
# encode tw/th log-scale against the best-shape-IoU anchor — the exact
# inverse of snn_yolo.decode_head, so a trained head decodes to the boxes
# it was supervised on.
ANCHORS = ((1.0, 1.0), (2.0, 2.0), (4.0, 2.5), (2.5, 4.0), (6.0, 6.0))


def split_seed(split: str, index: int) -> int:
    """Deterministic per-(split, index) seed. zlib.crc32 — NOT Python's
    ``hash``, which is salted per process and would silently break the
    "reproducible across hosts" contract without PYTHONHASHSEED."""
    return (zlib.crc32(split.encode("utf-8")) & 0xFFFF) * 1_000_003 + index


def _best_anchor(bw_cells: float, bh_cells: float, anchors) -> int:
    """Anchor with max shape-IoU (boxes concentric, sizes in cell units)."""
    best, best_iou = 0, -1.0
    for a, (aw, ah) in enumerate(anchors):
        inter = min(bw_cells, aw) * min(bh_cells, ah)
        iou = inter / (bw_cells * bh_cells + aw * ah - inter)
        if iou > best_iou:
            best, best_iou = a, iou
    return best


def encode_targets(boxes, classes, *, gh: int, gw: int, num_anchors: int = 5,
                   num_classes: int = 3, anchors=ANCHORS) -> np.ndarray:
    """YOLOv2 grid targets from normalized (cx, cy, w, h) boxes — the exact
    inverse of ``snn_yolo.decode_head`` (best-shape-IoU anchor, within-cell
    tx/ty offsets, log-scale tw/th vs that anchor). Shared by the synthetic
    generator and the real-data loaders (``repro.data.detection_datasets``)
    so every source supervises the head identically."""
    tgt = np.zeros((gh, gw, num_anchors, 5 + num_classes), np.float32)
    for (cx, cy, bw, bh), c in zip(boxes, classes):
        gx, gy = min(int(cx * gw), gw - 1), min(int(cy * gh), gh - 1)
        a = _best_anchor(bw * gw, bh * gh, anchors[:num_anchors])
        aw, ah = anchors[a]
        tgt[gy, gx, a, 0:4] = (
            cx * gw - gx, cy * gh - gy,
            np.log(max(bw * gw / aw, 1e-6)), np.log(max(bh * gh / ah, 1e-6)),
        )
        tgt[gy, gx, a, 4] = 1.0
        tgt[gy, gx, a, 5 + int(c)] = 1.0
    return tgt


def _render_image(rng, hw, boxes, classes):
    h, w = hw
    sky = np.linspace(0.65, 0.25, h, dtype=np.float32)[:, None, None]
    img = np.repeat(np.repeat(sky, w, axis=1), 3, axis=2).copy()
    img += rng.normal(0, 0.05, (h, w, 3)).astype(np.float32)
    # low-frequency texture (buildings/road patches)
    for _ in range(6):
        x0, y0 = rng.integers(0, w - 8), rng.integers(0, h - 8)
        ww, hh = rng.integers(8, w // 2), rng.integers(8, h // 2)
        img[y0 : y0 + hh, x0 : x0 + ww] += rng.uniform(-0.15, 0.15)
    shade = {0: (0.15, 0.25, 0.55), 1: (0.55, 0.2, 0.2), 2: (0.2, 0.5, 0.25)}
    for (cx, cy, bw, bh), c in zip(boxes, classes):
        x0 = int(max(0, (cx - bw / 2) * w))
        x1 = int(min(w, (cx + bw / 2) * w))
        y0 = int(max(0, (cy - bh / 2) * h))
        y1 = int(min(h, (cy + bh / 2) * h))
        if x1 > x0 and y1 > y0:
            img[y0:y1, x0:x1] = np.asarray(shade[c]) + rng.normal(0, 0.03, 3)
    return np.clip(img, 0.0, 1.0)


def sample(index: int, *, split: str = "train", hw=(576, 1024), num_classes: int = 3,
           num_anchors: int = 5, grid_div: int = 32, anchors=ANCHORS):
    """Deterministic (image, target, boxes) for one index."""
    rng = np.random.default_rng(split_seed(split, index))
    n_obj = int(rng.integers(1, 13))
    classes = rng.choice(num_classes, size=n_obj, p=CLASS_P)
    boxes = []
    for c in classes:
        area, aspect = SIZE_STATS[int(c)]
        a = float(np.exp(rng.normal(np.log(area), 0.6)))
        bh = float(np.sqrt(a / aspect))
        bw = float(a / max(bh, 1e-6))
        bw, bh = min(bw, 0.6), min(bh, 0.6)
        cx = float(rng.uniform(bw / 2, 1 - bw / 2))
        # objects sit in the lower 2/3 (road) like driving footage
        cy = float(rng.uniform(max(bh / 2, 0.33), 1 - bh / 2))
        boxes.append((cx, cy, bw, bh))
    img = _render_image(rng, hw, boxes, classes)

    gh, gw = hw[0] // grid_div, hw[1] // grid_div
    tgt = encode_targets(boxes, classes, gh=gh, gw=gw, num_anchors=num_anchors,
                         num_classes=num_classes, anchors=anchors)
    return img, tgt, (boxes, classes)


def batches(
    batch_size: int,
    *,
    split: str = "train",
    hw=(576, 1024),
    steps: Optional[int] = None,
    host_id: int = 0,
    n_hosts: int = 1,
    start_index: int = 0,
    **kw,
) -> Iterator[dict]:
    """Host-sharded deterministic batch stream: host h yields indices
    h, h+n_hosts, ... so the global batch is disjoint across hosts.
    ``start_index`` skips the first ``start_index`` per-host samples —
    resuming (or fine-tuning past) a consumed prefix without replaying it,
    composable with host striping."""
    i = start_index
    step = 0
    while steps is None or step < steps:
        imgs, tgts = [], []
        for _ in range(batch_size):
            img, tgt, _ = sample(i * n_hosts + host_id, split=split, hw=hw, **kw)
            imgs.append(img)
            tgts.append(tgt)
            i += 1
        yield {"image": np.stack(imgs), "target": np.stack(tgts)}
        step += 1


def eval_shard_indices(n_images: int, shard_id: int = 0, n_shards: int = 1) -> list:
    """Global sample indices owned by one evaluation shard, under the SAME
    striping contract as :func:`batches` host striping: shard s of k owns
    indices s, s+k, s+2k, ... — disjoint across shards, union = range(n).
    A shard can be empty when n_shards > n_images."""
    if not 0 <= shard_id < n_shards:
        raise ValueError(f"shard_id {shard_id} out of range for {n_shards} shards")
    return list(range(shard_id, n_images, n_shards))


def eval_set(n_images: int, *, split: str = "val", hw=(576, 1024),
             shard_id: int = 0, n_shards: int = 1, **kw):
    """Fixed evaluation split for the mAP harness: returns
    (images (N,H,W,3), ground_truths) where ground_truths[i] is the
    {"boxes" (G,4) xywh-normalized, "classes" (G,)} dict
    ``repro.eval.detection_map`` consumes.

    ``shard_id``/``n_shards`` stripe the GLOBAL ``n_images`` split the way
    :func:`batches` stripes training data: this shard materializes only the
    samples of :func:`eval_shard_indices` (possibly none), so a mesh of k
    hosts generates k disjoint shards whose union is the single-host set."""
    imgs, gts = [], []
    for i in eval_shard_indices(n_images, shard_id, n_shards):
        img, _, (boxes, classes) = sample(i, split=split, hw=hw, **kw)
        imgs.append(img)
        gts.append({
            "boxes": np.asarray(boxes, np.float32).reshape(-1, 4),
            "classes": np.asarray(classes, np.int64).reshape(-1),
        })
    h, w = hw
    images = np.stack(imgs) if imgs else np.zeros((0, h, w, 3), np.float32)
    return images, gts
