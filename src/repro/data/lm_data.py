"""Deterministic sharded LM token pipeline (synthetic corpus).

Produces next-token-prediction batches {"tokens", "labels"} with a Zipfian
unigram mixture + per-document Markov bigram structure, so cross-entropy is
learnable (tests assert loss decreases). Properties that matter at scale:

* **Host-sharded**: host h of n yields disjoint document indices — the
  global batch is the union over hosts, no coordination needed.
* **Deterministic & restartable**: batch t is a pure function of
  (seed, split, host, t); checkpoint restore sets `start_step` and the
  stream continues exactly where it left off (no stateful iterators to
  snapshot).
* **Prefetch**: a small background-thread buffer hides host-side generation
  behind device compute (double-buffering; on a real pod this is where the
  hdf5/arrayrecord reader would sit).
"""
from __future__ import annotations

import queue
import threading
import zlib
from typing import Iterator, Optional

import numpy as np


def _doc(rng: np.random.Generator, length: int, vocab: int) -> np.ndarray:
    """Zipf unigrams + a sticky bigram chain → compressible structure."""
    base = rng.zipf(1.3, size=length).clip(1, vocab - 1)
    out = base.copy()
    stick = rng.random(length) < 0.35
    out[1:][stick[1:]] = (out[:-1][stick[1:]] * 7 + 11) % vocab  # bigram rule
    return out.astype(np.int32)


def batch_at(
    step: int,
    *,
    batch_size: int,
    seq_len: int,
    vocab: int,
    seed: int = 0,
    split: str = "train",
    host_id: int = 0,
    n_hosts: int = 1,
) -> dict:
    """The (host-local) batch for global step `step` — pure function."""
    rows = []
    for b in range(batch_size):
        idx = (step * batch_size + b) * n_hosts + host_id
        # crc32, not hash(): str hashing is salted per process (PYTHONHASHSEED)
        # and would feed every host of a multi-controller job DIFFERENT data
        # for the same (split, seed, idx).
        rng = np.random.default_rng((zlib.crc32(split.encode()) & 0xFFFF, seed, idx))
        rows.append(_doc(rng, seq_len + 1, vocab))
    arr = np.stack(rows)
    return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


def stream(
    *,
    batch_size: int,
    seq_len: int,
    vocab: int,
    start_step: int = 0,
    steps: Optional[int] = None,
    prefetch: int = 2,
    **kw,
) -> Iterator[dict]:
    """Prefetching restartable stream of batch_at() results."""
    stop = object()
    q: queue.Queue = queue.Queue(maxsize=prefetch)

    def producer():
        t = start_step
        while steps is None or t < start_step + steps:
            q.put(batch_at(t, batch_size=batch_size, seq_len=seq_len, vocab=vocab, **kw))
            t += 1
        q.put(stop)

    th = threading.Thread(target=producer, daemon=True)
    th.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
