from repro.data import lm_data, synthetic_detection  # noqa: F401
