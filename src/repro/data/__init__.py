from repro.data import detection_datasets, lm_data, synthetic_detection  # noqa: F401
from repro.data.detection_datasets import (  # noqa: F401
    CocoJsonSource,
    DetectionSource,
    SyntheticSource,
    VocXmlSource,
    parse_dataset_spec,
)
