"""Real-data detection sources: COCO-json and VOC-xml annotation loaders
behind the :class:`DetectionSource` protocol the eval/train stack consumes.

The paper scores 71.5% mAP on the IVS 3cls dataset — real annotated
frames. The repo's mAP surfaces (``eval/harness``, ``eval/sharded``,
``launch/serve --eval-map``, ``benchmarks/eval_map``, the training
example) historically hard-coded ``synthetic_detection``; this module
makes "which dataset" a value. Every source emits EXACTLY the structures
the synthetic pipeline produces today:

* ``eval_set(n, ...) -> (images (N, H, W, 3) float32 in [0, 1],
  [{"boxes" (G, 4) cxcywh normalized, "classes" (G,)} ...])`` — what
  ``repro.eval.detection_map`` / ``repro.eval.sharded`` consume, with the
  same ``shard_id``/``n_shards`` striping contract,
* ``batches(b, ...) -> iterator of {"image", "target"}`` with YOLO grid
  targets from ``synthetic_detection.encode_targets`` — the SAME encoding
  (best-shape-IoU anchor, log-scale tw/th), so ``decode_head`` stays the
  exact inverse of the supervision for real data too.

Real images rarely match the configured input resolution, so file-backed
sources letterbox: aspect-preserving nearest-neighbor resize (integer
index math — deterministic across hosts, no float filter kernels) onto a
gray canvas, with box coordinates rescaled by the SAME placed-pixel
geometry. Ground truth, targets and therefore decoded predictions all
live in the letterboxed normalized frame, mirroring how the synthetic
split keeps everything in one coordinate system.

Dataset selection is a string spec (the ``--dataset`` flag everywhere):

    synthetic            the deterministic IVS-3cls-like generator
    coco:<instances.json>  COCO-style json (bbox = [x, y, w, h] pixels)
    voc:<dir>            VOC layout (<dir>/Annotations/*.xml + images)

Image decoding: ``.npy`` (float in [0,1] or uint8) and binary ``.ppm`` /
``.pgm`` load with numpy alone; anything else (png/jpg) goes through PIL
when available. The committed CI fixture (tests/fixtures/coco_fixture)
uses ppm so the tier-1 suite has zero optional dependencies.
"""
from __future__ import annotations

import json
import os
import re
import xml.etree.ElementTree as ET
from dataclasses import dataclass
from typing import Any, Iterator, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.data import synthetic_detection as sd

LETTERBOX_PAD_VALUE = 0.5  # neutral gray, like YOLO's 114/255 convention


# ---------------------------------------------------------------- protocol --


@runtime_checkable
class DetectionSource(Protocol):
    """What the eval/train stack needs from a dataset. ``synthetic_detection``
    (wrapped by :class:`SyntheticSource`) and the file-backed loaders both
    satisfy it; ``repro.eval.harness`` / ``repro.eval.sharded`` /
    ``launch/serve`` accept any implementation."""

    name: str

    def num_eval_images(self, split: str) -> Optional[int]:
        """Finite eval-split size, or None for unbounded (synthetic)."""
        ...

    def eval_set(self, n_images: int, *, split: str = "val", hw=(576, 1024),
                 shard_id: int = 0, n_shards: int = 1, **kw) -> tuple:
        ...

    def batches(self, batch_size: int, *, split: str = "train", hw=(576, 1024),
                steps: Optional[int] = None, host_id: int = 0, n_hosts: int = 1,
                start_index: int = 0, **kw) -> Iterator[dict]:
        ...


class SyntheticSource:
    """The deterministic synthetic IVS-3cls-like generator as a source."""

    name = "synthetic"

    def num_eval_images(self, split: str) -> Optional[int]:
        return None  # generated on demand: any n_images is materializable

    def eval_set(self, n_images: int, **kw):
        return sd.eval_set(n_images, **kw)

    def batches(self, batch_size: int, **kw):
        return sd.batches(batch_size, **kw)


# --------------------------------------------------------------- letterbox --


def letterbox_image(img: np.ndarray, hw) -> tuple:
    """Aspect-preserving resize onto a ``hw`` gray canvas.

    Nearest-neighbor with integer index math (source row of output row i
    is ``i * h // nh``) — bit-deterministic across hosts and platforms,
    which the sharded-eval parity gate requires. Returns
    ``(canvas float32 (H, W, 3), (top, left, nh, nw))`` where (nh, nw) is
    the placed size and (top, left) the pad offset.
    """
    img = np.asarray(img)
    h, w = img.shape[:2]
    H, W = hw
    s = min(H / h, W / w)
    nh = min(H, max(1, int(round(h * s))))
    nw = min(W, max(1, int(round(w * s))))
    rows = (np.arange(nh) * h) // nh
    cols = (np.arange(nw) * w) // nw
    resized = img[rows][:, cols].astype(np.float32)
    if resized.ndim == 2:
        resized = np.repeat(resized[:, :, None], 3, axis=2)
    top, left = (H - nh) // 2, (W - nw) // 2
    canvas = np.full((H, W, 3), LETTERBOX_PAD_VALUE, np.float32)
    canvas[top : top + nh, left : left + nw] = resized
    return canvas, (top, left, nh, nw)


def letterbox_boxes(boxes: np.ndarray, geom, hw) -> np.ndarray:
    """Map (cx, cy, w, h) boxes normalized to the ORIGINAL image into the
    letterboxed normalized frame, using the placed-pixel geometry from
    :func:`letterbox_image` — the box transform and the pixel transform
    share (top, left, nh, nw), so targets built from these boxes stay
    ``decode_head``'s exact inverse on the letterboxed image."""
    top, left, nh, nw = geom
    H, W = hw
    b = np.asarray(boxes, np.float32).reshape(-1, 4).copy()
    b[:, 0] = (b[:, 0] * nw + left) / W
    b[:, 1] = (b[:, 1] * nh + top) / H
    b[:, 2] = b[:, 2] * nw / W
    b[:, 3] = b[:, 3] * nh / H
    return b


# ----------------------------------------------------------- image loading --


def _read_ppm(path: str) -> np.ndarray:
    """Binary PPM (P6) / PGM (P5) reader — numpy-only, so the committed
    fixture needs no imaging dependency. Returns uint8 (H, W, 3|1)."""
    with open(path, "rb") as f:
        data = f.read()
    # header: magic, width, height, maxval — whitespace/comment separated
    tokens, pos = [], 0
    while len(tokens) < 4:
        m = re.compile(rb"\s*(#[^\n]*\n|\S+)").match(data, pos)
        if m is None:
            raise ValueError(f"{path}: truncated PNM header")
        pos = m.end()
        if not m.group(1).startswith(b"#"):
            tokens.append(m.group(1))
    magic, w, h, maxval = tokens[0], int(tokens[1]), int(tokens[2]), int(tokens[3])
    if magic not in (b"P6", b"P5") or maxval > 255:
        raise ValueError(f"{path}: unsupported PNM variant {magic!r}/{maxval}")
    ch = 3 if magic == b"P6" else 1
    # spec: EXACTLY one whitespace byte between maxval and the raster.
    # Demand the rest of the file is that byte plus exactly h*w*ch pixel
    # bytes — a CRLF-written header would otherwise shift every pixel by
    # one byte while still passing a length-only check on the slice.
    body = data[pos + 1 :]
    if data[pos : pos + 1] not in (b" ", b"\t", b"\n", b"\r") or \
            len(body) != h * w * ch:
        raise ValueError(
            f"{path}: expected a single whitespace then {h * w * ch} pixel "
            f"bytes after the header, got {len(body)} trailing bytes"
        )
    return np.frombuffer(body, np.uint8).reshape(h, w, ch)


def _read_image(path: str) -> np.ndarray:
    """Image file -> float32 (H, W, C) in [0, 1]. Uint8 content scales by
    /255 exactly like ``serve.detector.synth_streams``, so uint8-sourced
    frames stay exact under the bit-serial 8-bit encode path."""
    ext = os.path.splitext(path)[1].lower()
    if ext == ".npy":
        arr = np.load(path)
    elif ext in (".ppm", ".pgm", ".pnm"):
        arr = _read_ppm(path)
    else:
        try:
            from PIL import Image
        except ImportError as e:  # pragma: no cover - PIL is present in CI
            raise ValueError(
                f"{path}: decoding {ext!r} needs PIL, which is not installed "
                "— convert to .ppm or .npy for a dependency-free load"
            ) from e
        arr = np.asarray(Image.open(path).convert("RGB"))
    if arr.dtype == np.uint8:
        arr = arr.astype(np.float32) / 255.0
    arr = np.asarray(arr, np.float32)
    if arr.ndim == 2:
        arr = arr[:, :, None]
    return arr


# ------------------------------------------------------- file-backed sources --


@dataclass(frozen=True)
class ImageRecord:
    """One annotated image: path + ground truth normalized to ITS size."""

    path: str
    hw: tuple  # (h, w) of the stored image, from the annotation
    boxes: Any  # (G, 4) float32 cxcywh in [0, 1] of the ORIGINAL image
    classes: Any  # (G,) int64


class _FileDetectionSource:
    """Shared machinery: letterboxed eval sets and target-encoded batches
    over a fixed record list. Subclasses only parse annotations.

    The whole annotation set backs every split — real train/val separation
    is a dataset-preparation concern (point the spec at the split's own
    annotation file); at fixture scale reusing one set for both is the
    point. ``batches`` cycles the records (index modulo size) so small
    sets still drive arbitrarily long fine-tunes.
    """

    name = "file"

    def __init__(self, records: Sequence[ImageRecord], class_names: Sequence[str]):
        if not records:
            raise ValueError(f"{self.name}: no annotated images found")
        self.records = list(records)
        self.class_names = tuple(class_names)

    def num_eval_images(self, split: str) -> Optional[int]:
        return len(self.records)

    def _check_classes(self, num_classes: int) -> None:
        if len(self.class_names) > num_classes:
            raise ValueError(
                f"{self.name}: dataset has {len(self.class_names)} classes "
                f"{self.class_names} but the detector is configured for "
                f"{num_classes} — they must agree for class indices to mean "
                "the same thing on both sides"
            )

    def _letterboxed(self, index: int, hw) -> tuple:
        rec = self.records[index % len(self.records)]
        img, geom = letterbox_image(_read_image(rec.path), hw)
        boxes = letterbox_boxes(rec.boxes, geom, hw)
        return img, boxes, np.asarray(rec.classes, np.int64).reshape(-1)

    def eval_set(self, n_images: int, *, split: str = "val", hw=(576, 1024),
                 shard_id: int = 0, n_shards: int = 1, num_classes: int = 3,
                 **kw) -> tuple:
        """Letterboxed (images, ground_truths) for this shard's stripe of
        the first ``min(n_images, len(records))`` images — same striping
        contract as ``synthetic_detection.eval_set``, so the sharded and
        single-host evaluators see identical per-image content."""
        self._check_classes(num_classes)
        n = min(n_images, len(self.records))
        imgs, gts = [], []
        for i in sd.eval_shard_indices(n, shard_id, n_shards):
            img, boxes, classes = self._letterboxed(i, hw)
            imgs.append(img)
            gts.append({"boxes": boxes.reshape(-1, 4), "classes": classes})
        h, w = hw
        images = np.stack(imgs) if imgs else np.zeros((0, h, w, 3), np.float32)
        return images, gts

    def batches(self, batch_size: int, *, split: str = "train", hw=(576, 1024),
                steps: Optional[int] = None, host_id: int = 0, n_hosts: int = 1,
                start_index: int = 0, grid_div: int = 32, num_anchors: int = 5,
                num_classes: int = 3, anchors=sd.ANCHORS) -> Iterator[dict]:
        """Host-striped {"image", "target"} stream with the SAME global
        index contract as ``synthetic_detection.batches`` (host h owns
        indices h, h+n_hosts, ...; ``start_index`` skips a consumed
        prefix); targets come from ``encode_targets`` on the letterboxed
        boxes."""
        self._check_classes(num_classes)
        gh, gw = hw[0] // grid_div, hw[1] // grid_div
        i = start_index
        step = 0
        while steps is None or step < steps:
            imgs, tgts = [], []
            for _ in range(batch_size):
                img, boxes, classes = self._letterboxed(i * n_hosts + host_id, hw)
                imgs.append(img)
                tgts.append(sd.encode_targets(
                    boxes, classes, gh=gh, gw=gw, num_anchors=num_anchors,
                    num_classes=num_classes, anchors=anchors,
                ))
                i += 1
            yield {"image": np.stack(imgs), "target": np.stack(tgts)}
            step += 1


class CocoJsonSource(_FileDetectionSource):
    """COCO-style annotation loader: ``images`` / ``annotations`` /
    ``categories``, bbox as [x, y, w, h] in absolute pixels. Image files
    resolve relative to the json's directory; category ids map to
    contiguous class indices in ascending-id order (the conventional
    COCO-to-training mapping); ``iscrowd`` regions are skipped."""

    name = "coco"

    def __init__(self, json_path: str):
        with open(json_path) as f:
            data = json.load(f)
        root = os.path.dirname(os.path.abspath(json_path))
        cats = sorted(data.get("categories", []), key=lambda c: c["id"])
        if not cats:
            raise ValueError(f"{json_path}: no categories")
        cat_to_idx = {c["id"]: i for i, c in enumerate(cats)}
        by_image: dict = {im["id"]: im for im in data.get("images", [])}
        anns: dict = {im_id: [] for im_id in by_image}
        for a in data.get("annotations", []):
            if a.get("iscrowd"):
                continue
            if a["image_id"] not in by_image:
                raise ValueError(
                    f"{json_path}: annotation {a.get('id')} references "
                    f"unknown image_id {a['image_id']}"
                )
            anns[a["image_id"]].append(a)
        records = []
        for im_id in sorted(by_image):
            im = by_image[im_id]
            h, w = int(im["height"]), int(im["width"])
            boxes, classes = [], []
            for a in anns[im_id]:
                x, y, bw, bh = (float(v) for v in a["bbox"])
                if a["category_id"] not in cat_to_idx:
                    raise ValueError(
                        f"{json_path}: annotation {a.get('id')} has unknown "
                        f"category_id {a['category_id']}"
                    )
                boxes.append(((x + bw / 2) / w, (y + bh / 2) / h, bw / w, bh / h))
                classes.append(cat_to_idx[a["category_id"]])
            records.append(ImageRecord(
                path=os.path.join(root, im["file_name"]), hw=(h, w),
                boxes=np.asarray(boxes, np.float32).reshape(-1, 4),
                classes=np.asarray(classes, np.int64).reshape(-1),
            ))
        super().__init__(records, [c["name"] for c in cats])


class VocXmlSource(_FileDetectionSource):
    """VOC layout loader: ``<dir>/Annotations/*.xml`` (or ``<dir>/*.xml``)
    with images in ``<dir>/JPEGImages/`` (or next to the xmls). Class
    indices follow ``class_names`` when given, else the sorted set of
    object names found — pass ``class_names`` explicitly when the mapping
    must stay stable across differently-populated directories."""

    name = "voc"

    def __init__(self, root_dir: str, class_names: Optional[Sequence[str]] = None):
        ann_dir = os.path.join(root_dir, "Annotations")
        if not os.path.isdir(ann_dir):
            ann_dir = root_dir
        xmls = sorted(
            os.path.join(ann_dir, f) for f in os.listdir(ann_dir)
            if f.endswith(".xml")
        )
        img_dir = os.path.join(root_dir, "JPEGImages")
        if not os.path.isdir(img_dir):
            img_dir = ann_dir
        parsed = []
        names_seen: set = set()
        for xml_path in xmls:
            node = ET.parse(xml_path).getroot()
            size = node.find("size")
            h, w = int(size.find("height").text), int(size.find("width").text)
            objs = []
            for obj in node.findall("object"):
                name = obj.find("name").text.strip()
                bb = obj.find("bndbox")
                x0, y0, x1, y1 = (
                    float(bb.find(k).text) for k in ("xmin", "ymin", "xmax", "ymax")
                )
                names_seen.add(name)
                objs.append((name, ((x0 + x1) / 2 / w, (y0 + y1) / 2 / h,
                                    (x1 - x0) / w, (y1 - y0) / h)))
            parsed.append((xml_path, (h, w), node.findtext("filename"), objs))
        if class_names is None:
            class_names = sorted(names_seen)
        name_to_idx = {n: i for i, n in enumerate(class_names)}
        records = []
        for xml_path, hw, filename, objs in parsed:
            unknown = sorted({n for n, _ in objs if n not in name_to_idx})
            if unknown:
                raise ValueError(
                    f"{xml_path}: object classes {unknown} not in "
                    f"class_names {tuple(class_names)}"
                )
            records.append(ImageRecord(
                path=os.path.join(img_dir, filename), hw=hw,
                boxes=np.asarray([b for _, b in objs], np.float32).reshape(-1, 4),
                classes=np.asarray([name_to_idx[n] for n, _ in objs],
                                   np.int64).reshape(-1),
            ))
        super().__init__(records, class_names)


# -------------------------------------------------------------------- spec --


def parse_dataset_spec(spec: Optional[str]) -> DetectionSource:
    """``--dataset`` string -> source: ``synthetic`` (default),
    ``coco:<instances.json>``, or ``voc:<dir>``."""
    if spec is None or spec in ("", "synthetic"):
        return SyntheticSource()
    kind, _, arg = spec.partition(":")
    if kind == "coco" and arg:
        return CocoJsonSource(arg)
    if kind == "voc" and arg:
        return VocXmlSource(arg)
    raise ValueError(
        f"unknown dataset spec {spec!r} — expected 'synthetic', "
        "'coco:<instances.json>' or 'voc:<dir>'"
    )
