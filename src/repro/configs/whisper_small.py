"""whisper-small [audio] — 12L d_model=768 12H d_ff=3072 vocab=51865 —
enc-dec, conv frontend (stub).  [arXiv:2212.04356; unverified]

The conv audio frontend is a STUB per the assignment: input_specs()
supplies precomputed frame embeddings (B, 1500, d_model). Encoder
self-attn + decoder causal/cross attention are real. The decoder is
full-attention → long_500k skipped; decode_32k runs on the decoder KV.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    arch_id="whisper-small",
    family="audio",
    n_layers=12,  # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    rope_theta=0.0,  # whisper uses learned/sinusoidal absolute positions
    encoder_layers=12,
    encoder_seq=1500,
    frontend="audio_frames",
    skip_shapes=("long_500k",),
)
