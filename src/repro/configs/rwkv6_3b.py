"""rwkv6-3b [ssm] — 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536 —
Finch, data-dependent decay.  [arXiv:2404.05892; hf]

Attention-free linear recurrence → O(1) state decode, runs long_500k.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    arch_id="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,  # d_model / 64 time-mix heads (HEAD_DIM=64 in models/rwkv6.py)
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65_536,
    rope_theta=0.0,  # no RoPE — token-shift + decay carries position
)
