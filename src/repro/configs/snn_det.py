"""The paper's own architecture: sparse compressed SNN object detector
(TCSI 2022). 1024x576 RGB input, CSP backbone, YOLOv2 head, (1,3) mixed
time steps, 80% fine-grained pruning on 3x3 kernels, FXP8 weights,
32x18 block convolution."""
from repro.models.snn_yolo import SNNDetConfig

CONFIG = SNNDetConfig(
    arch_id="snn-det",
    input_hw=(576, 1024),
    num_classes=3,
    num_anchors=5,
    full_t=3,
    threshold=0.5,
    leak=0.25,
    mode="snn",
    weight_bits=8,
    use_block_conv=True,
    mixed_time=True,
)
