"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64e top-6 — 2 shared + 64 routed, fine-grained.
[arXiv:2401.06066; hf]"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    arch_id="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,  # fine-grained expert width
    vocab_size=102_400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    skip_shapes=("long_500k",),
)
