"""qwen1.5-0.5b [dense] — 24L d_model=1024 16H (GQA kv=16) d_ff=2816
vocab=151936, QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    arch_id="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151_936,
    qkv_bias=True,
    tie_embeddings=True,  # 0.5B ties lm_head to the embedding
    rope_theta=1_000_000.0,
    # full attention → no sub-quadratic path for 500k decode (DESIGN.md §4)
    skip_shapes=("long_500k",),
)
