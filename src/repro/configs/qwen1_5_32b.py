"""qwen1.5-32b [dense] — 64L d_model=5120 40H (GQA kv=40) d_ff=27392
vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    arch_id="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27_392,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    fsdp=True,  # 35B x (2B param + 4B grad + moments) needs the data axis too
    # 40 full-width KV heads x 64 layers: the bf16 decode_32k cache is
    # 21.5 GB/chip on 256 chips — int8 KV (paper's FXP8) brings it to 10.7
    kv_quant=True,
    skip_shapes=("long_500k",),
)
