"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256.  [arXiv:2407.21783; unverified]"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    arch_id="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16_384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53_248,
    vocab_size=128_256,
    rope_theta=500_000.0,
    fsdp=True,  # ZeRO-3 weight sharding over 'data' is mandatory at 405B
    skip_shapes=("long_500k",),
)
