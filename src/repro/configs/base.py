"""Config schema for every architecture + the shape sets assigned to this
paper (train_4k / prefill_32k / decode_32k / long_500k)."""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Literal, Optional

import jax
import jax.numpy as jnp

Family = Literal["dense", "moe", "hybrid", "ssm", "vlm", "audio", "snn-det"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


# the four assigned LM shapes (see assignment block)
SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class LMConfig:
    arch_id: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    attn_every: int = 0  # zamba2: one shared attn block every N mamba blocks
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1_500  # whisper audio frames after conv frontend (stub)
    # --- modality frontend stubs ---
    frontend: Literal["none", "patches", "audio_frames"] = "none"
    n_patches: int = 0  # llava anyres patch embeddings per image
    # --- numerics / paper technique ---
    dtype: str = "bfloat16"
    ffn_density: float = 1.0  # <1 → fine-grained-pruned FFN, bitmask format
    remat: bool = True
    scan_layers: bool = True
    fsdp: bool = False  # additionally shard weight d_model dim over 'data'
    # serve path: fori_loop with carry-aliased stacked KV cache (§Perf OPT1)
    # vs the naive scan that copies the cache per layer
    serve_fast: bool = True
    # int8 KV cache with per-(token, head) scales — the paper's FXP8
    # quantization applied to the cache (§Perf OPT3); halves KV bytes
    kv_quant: bool = False
    # which shapes this arch skips, with reason (DESIGN.md §4)
    skip_shapes: tuple = ()

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def n_params(self) -> int:
        """Analytic parameter count (embedding + layers)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv_heads
        attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        if self.qkv_bias:
            attn += (nh + 2 * nkv) * hd
        mlp = 3 * d * f
        if self.family == "moe":
            mlp = self.n_experts * 3 * d * f + self.n_shared_experts * 3 * d * f + d * self.n_experts
        per_layer = attn + mlp + 2 * d
        total = self.n_layers * per_layer + v * d + d
        if not self.tie_embeddings:
            total += d * v
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.family != "moe":
            return self.n_params()
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv_heads
        attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        mlp = (self.top_k + self.n_shared_experts) * 3 * d * f + d * self.n_experts
        total = self.n_layers * (attn + mlp + 2 * d) + v * d + d
        if not self.tie_embeddings:
            total += d * v
        return total


def smoke_config(cfg):
    """Reduced same-family config for CPU smoke tests: few layers, small
    width/vocab/experts — structure preserved. Dispatches on config type:
    LM cells shrink depth/width, the snn-det cell shrinks spatial extent
    (all macro layers and the (1, full_t) mixed schedule preserved)."""
    from repro.models.snn_yolo import SNNDetConfig  # lazy: avoid cycle

    if isinstance(cfg, SNNDetConfig):
        return replace(
            cfg,
            input_hw=(24, 32),
            stem_channels=8,
            conv_block_channels=8,
            stage_channels=((8, 8), (8, 8), (8, 16), (16, 16), (16, 16)),
            pooled_stages=1,
            block_hw=(6, 8),
        )
    return replace(
        cfg,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else cfg.ssm_head_dim,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=32 if cfg.encoder_layers else cfg.encoder_seq,
        n_patches=8 if cfg.n_patches else 0,
        dtype="float32",
        remat=False,
        fsdp=False,
        kv_quant=False,
    )
