"""zamba2-7b [hybrid] — 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks.
[arXiv:2411.15242; unverified]

81 layers = 13 super-layers x (6 mamba + 1 shared-attn application) + 3 tail
mamba layers (models/hybrid.py). Sub-quadratic (SSM state decode) → runs
long_500k.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14_336,
    vocab_size=32_000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
)
