"""Architecture registry: ``get_config("<arch-id>")`` resolves every
assigned architecture (plus the paper's own snn-det) to its exact
public-literature config. ``--arch`` flags in launch/ and benchmarks/
look up here."""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, LMConfig, ShapeSpec, smoke_config

# arch-id -> module holding CONFIG
_MODULES = {
    "qwen1.5-0.5b": "repro.configs.qwen1_5_0_5b",
    "qwen1.5-32b": "repro.configs.qwen1_5_32b",
    "qwen1.5-110b": "repro.configs.qwen1_5_110b",
    "llama3-405b": "repro.configs.llama3_405b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "llava-next-34b": "repro.configs.llava_next_34b",
    "whisper-small": "repro.configs.whisper_small",
    "snn-det": "repro.configs.snn_det",
}

ARCH_IDS = tuple(k for k in _MODULES if k != "snn-det")  # the 10 LM cells
ALL_IDS = tuple(_MODULES)


def get_config(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def cells(include_skipped: bool = False):
    """All assigned (arch, shape) cells. Skipped cells (sub-quadratic
    requirement unmet, see each config's skip_shapes) are excluded unless
    include_skipped."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            if s.name in cfg.skip_shapes and not include_skipped:
                continue
            out.append((a, s.name))
    return out


__all__ = [
    "ARCH_IDS",
    "ALL_IDS",
    "SHAPES",
    "LMConfig",
    "ShapeSpec",
    "cells",
    "get_config",
    "smoke_config",
]
