"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling.  [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The vision tower is a STUB per the assignment: input_specs() supplies
precomputed patch embeddings (B, n_patches, d_model) that are prepended to
the token embeddings (anyres: base 576 patches + up to 4 tiles x 576 →
we provision 2880 patch slots).
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    arch_id="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20_480,
    vocab_size=64_000,
    rope_theta=5_000_000.0,
    frontend="patches",
    n_patches=2880,  # anyres: (1 base + 4 tiles) x 24x24 patches
    fsdp=True,
    skip_shapes=("long_500k",),
)
