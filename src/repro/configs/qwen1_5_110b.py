"""qwen1.5-110b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    arch_id="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49_152,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    fsdp=True,  # 110B params do not fit replicated over 'model' alone
    skip_shapes=("long_500k",),
)
