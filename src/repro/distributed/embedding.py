"""shard_map distributed embedding lookup + lm_head (§Perf OPT4).

Why: under GSPMD, the VJP of a plain gather into a (vocab, d_model)-sharded
table is a scatter-add whose output the partitioner materializes REPLICATED
(then reshards) — on llama3-405b train_4k that is 2x 8.4 GB f32 of
replicated embedding/lm_head gradients living in the microbatch-loop state
(measured; EXPERIMENTS.md §Perf iteration 3). Writing the lookup/projection
as shard_map makes the gradients SHARDED BY CONSTRUCTION:

  lookup:  each vocab shard all-gathers its table slice's d_model shards
           (small: |V|/16 x D), serves the tokens it owns, psum over the
           vocab axis. Transpose: local scatter-add into the shard's rows +
           reduce-scatter of the d_model gather — grads arrive (V/16, D/16).
  lm_head: gather W's d_model shards -> local (D, V/16) matmul -> logits
           vocab-sharded, NO psum. Transpose reduce-scatters dW.

Falls back to plain gather/matmul when no mesh context is installed (CPU
smoke tests, single-device serving).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.distributed.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd


def _batch_spec(rules, mesh, batch_dim: int):
    """Batch spec, dropped to replicated when the batch doesn't divide the
    mesh axes (long_500k decodes with global_batch=1)."""
    b = rules.get("batch")
    if b is None:
        return None
    axes = (b,) if isinstance(b, str) else b
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return b if batch_dim % n == 0 else None


def embed_lookup(tokens: jax.Array, table: jax.Array) -> jax.Array:
    """tokens (B, S) int32, table (V, D) -> (B, S, D).

    Distributed path when a mesh context is installed: table sharded
    (vocab -> model, embed -> data|None per the rules)."""
    mesh = shd.current_mesh()
    rules = shd.current_rules()
    if mesh is None or rules is None or "model" not in mesh.axis_names:
        return table[tokens]
    v_axis = rules.get("vocab")
    d_axis = rules.get("embed")
    if v_axis is None:
        return table[tokens]
    b_axis = _batch_spec(rules, mesh, tokens.shape[0])
    V = table.shape[0]
    n_v = mesh.shape[v_axis] if isinstance(v_axis, str) else 1
    if V % n_v != 0:
        return table[tokens]
    v_shard = V // n_v

    def local(tok, tab):
        # tab: (V/nv, D/nd) -> gather D so each vocab shard holds full rows
        if d_axis is not None:
            tab = jax.lax.all_gather(tab, d_axis, axis=1, tiled=True)
        lo = jax.lax.axis_index(v_axis) * v_shard
        rel = tok - lo
        ok = (rel >= 0) & (rel < v_shard)
        x = tab[jnp.clip(rel, 0, v_shard - 1)] * ok[..., None].astype(tab.dtype)
        return jax.lax.psum(x, v_axis)

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(b_axis, None), P(v_axis, d_axis)),
        out_specs=P(b_axis, None, None),
        check_vma=False,
    )(tokens, table)


def lm_head(x: jax.Array, w: jax.Array) -> jax.Array:
    """x (B, S, D) @ w (D, V) -> logits (B, S, V) f32, vocab-sharded.

    Distributed path: w sharded (embed -> data|None, vocab -> model)."""
    mesh = shd.current_mesh()
    rules = shd.current_rules()
    if mesh is None or rules is None or "model" not in mesh.axis_names:
        return (x @ w).astype(jnp.float32)
    v_axis = rules.get("vocab")
    d_axis = rules.get("embed")
    if v_axis is None or w.shape[1] % mesh.shape[v_axis] != 0:
        return (x @ w).astype(jnp.float32)
    b_axis = _batch_spec(rules, mesh, x.shape[0])

    def local(xl, wl):
        if d_axis is not None:
            wl = jax.lax.all_gather(wl, d_axis, axis=0, tiled=True)
        return (xl @ wl).astype(jnp.float32)  # (B/., S, V/nv) — no psum

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(b_axis, None, None), P(d_axis, v_axis)),
        out_specs=P(b_axis, None, v_axis),
        check_vma=False,
    )(x, w)
