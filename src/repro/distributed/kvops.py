"""Sequence-sharded KV-cache writes (§Perf OPT5).

GSPMD lowers a dynamic_update_slice at a traced position into a
select(broadcast(pred)) over the ENTIRE buffer when the updated dim is
sharded (it cannot prove which shard owns the write) — measured on
zamba2-7b long_500k decode as a full-cache f32 copy + a full-cache pred
mask (+11 GB/chip on a 6 GB cache; EXPERIMENTS.md §Perf). This module
writes the token row with an ownership check INSIDE shard_map: each seq
shard compares the write position against its own range and does a local,
tiny read-modify-write. No masks, no full-buffer copies.

Falls back to plain indexed update when no mesh context is installed or
the seq dim is not sharded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.distributed.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd


def _b_axis(rules, mesh, b: int):
    ax = rules.get("batch")
    if ax is None:
        return None
    axes = (ax,) if isinstance(ax, str) else ax
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return ax if b % n == 0 else None


def cache_write(buf: jax.Array, val: jax.Array, layer: jax.Array, pos) -> jax.Array:
    """buf (L, B, S, ...) with S possibly sharded; val (B, s_new, ...);
    layer scalar i; pos scalar write offset. Returns updated buf."""
    mesh = shd.current_mesh()
    rules = shd.current_rules()
    s_new = val.shape[1]
    fallback = lambda: jax.lax.dynamic_update_slice(
        buf, val[None].astype(buf.dtype), (layer, 0, pos) + (0,) * (buf.ndim - 3)
    )
    if (
        mesh is None
        or rules is None
        or jnp.ndim(pos) != 0
        or rules.get("kv_seq") not in mesh.axis_names
    ):
        return fallback()
    seq_axis = rules["kv_seq"]
    n = mesh.shape[seq_axis]
    S = buf.shape[2]
    if S % n != 0 or S // n < s_new:
        return fallback()
    local_len = S // n
    b_ax = _b_axis(rules, mesh, buf.shape[1])

    def local(buf_l, val_l, i, p):
        lo = jax.lax.axis_index(seq_axis).astype(p.dtype) * local_len
        rel = p - lo
        ok = (rel >= 0) & (rel <= local_len - s_new)
        relc = jnp.clip(rel, 0, local_len - s_new)
        start = (i, 0, relc) + (0,) * (buf_l.ndim - 3)
        sizes = (1, val_l.shape[0], s_new) + buf_l.shape[3:]
        cur = jax.lax.dynamic_slice(buf_l, start, sizes)
        new = jnp.where(ok, val_l[None].astype(buf_l.dtype), cur)
        return jax.lax.dynamic_update_slice(buf_l, new, start)

    spec_buf = P(None, b_ax, seq_axis, *([None] * (buf.ndim - 3)))
    spec_val = P(b_ax, *([None] * (val.ndim - 1)))
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(spec_buf, spec_val, P(), P()),
        out_specs=spec_buf,
        check_vma=False,
    )(buf, val, layer, pos)
