"""Logical-axis sharding rules → concrete NamedSharding/PartitionSpec.

Every model annotates its params and activations with LOGICAL axis names;
one rules table per deployment maps them onto mesh axes. This is the single
place the mesh topology touches model code, so re-meshing (elastic restart
on a different device count) only swaps the rules.
"""
from __future__ import annotations

from typing import Any, Mapping, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes, or None = replicated)
# 'pod' composes with 'data' for the batch so the multi-pod mesh shards
# batch hierarchically (inter-pod gradient reduction happens over DCN).
def default_rules(mesh: Mesh, *, fsdp: bool = False, kv_seq_shard: bool = True) -> dict:
    """The one rules table. Notable choices (EXPERIMENTS.md §Perf discusses
    the alternatives):

    * ``kv_seq`` → 'model': decode-time KV caches are sharded along the
      SEQUENCE dim. KV-head sharding dies on GQA archs (8 kv heads cannot
      split 16 ways) while sequence sharding is universal and turns decode
      attention into a split-K reduction (XLA inserts the small combine
      all-reduce). It also divides the per-chip KV bytes — the decode
      roofline's memory term — by the model-axis size.
    * ``opt_state`` → everything: int8 moments are flat (nblocks, 256) and
      shard over ALL axes (ZeRO across the whole fleet).
    * ``embed`` under fsdp → 'data': ZeRO-3 weight sharding composed with
      the 'model' tensor sharding of the per-layer matrices.
    """
    axes = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in axes)
    batch_axis = batch if len(batch) > 1 else (batch[0] if batch else None)
    all_axes = tuple(a for a in ("pod", "data", "model") if a in axes)
    rules = {
        "batch": batch_axis,
        "seq": None,
        "embed": "data" if fsdp else None,  # ZeRO-3 weight shard over data
        "embed2": None,  # second d_model axis of square matrices
        "act_embed": None,
        "act_seq": "model",  # sequence-parallel activations (Megatron-SP)
        "heads": "model",
        # KV caches shard EITHER the sequence dim (universal; GQA-safe) or
        # the kv-head dim — never both (same mesh axis twice is invalid)
        "kv_heads": None if kv_seq_shard else "model",
        "kv_seq": "model" if kv_seq_shard else None,
        "qkv": "model",
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "expert_mlp": None,
        "expert_cap": None,
        "layers": None,
        "conv_k": None,
        "ssm_heads": "model",
        "ssm_state": None,
        "spatial_blocks": "model",  # detector: block-conv grid (paper C3/C4)
        "channels": None,
        "opt_state": all_axes,
    }
    return rules


def spec_for(axes: Sequence[Optional[str]], rules: Mapping[str, Any]) -> P:
    """Logical axis names -> PartitionSpec via the rules table."""
    parts = []
    for a in axes:
        if a is None:
            parts.append(None)
        else:
            parts.append(rules.get(a))
    return P(*parts)


def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)


def tree_shardings(mesh: Mesh, axes_tree: Any, rules: Mapping[str, Any]):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree_util.tree_map(
        lambda axes: NamedSharding(mesh, spec_for(axes, rules)),
        axes_tree,
        is_leaf=_is_axes,
    )


def _part_size(mesh: Mesh, part) -> int:
    if part is None:
        return 1
    if isinstance(part, str):
        return int(mesh.shape[part])
    return int(np.prod([mesh.shape[p] for p in part]))


def sanitize_spec(mesh: Mesh, spec: P, shape) -> P:
    """Drop partitions that do not evenly divide their dimension (jax
    rejects explicitly-given uneven in_shardings). Replicating a small dim
    is always legal; the big tensors keep their full sharding."""
    parts = []
    for i, part in enumerate(spec):
        if part is not None and (
            i >= len(shape) or shape[i] % _part_size(mesh, part) != 0
        ):
            parts.append(None)
        else:
            parts.append(part)
    return P(*parts)


def tree_shardings_for(mesh: Mesh, axes_tree: Any, shapes_tree: Any, rules: Mapping[str, Any]):
    """Like tree_shardings but shape-aware: per-leaf specs are sanitized
    against the leaf's global shape (shapes_tree: matching pytree of
    ShapeDtypeStructs / arrays)."""

    def f(axes, shp):
        spec = sanitize_spec(mesh, spec_for(axes, rules), shp.shape)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(f, axes_tree, shapes_tree, is_leaf=_is_axes)


def constrain(x: jax.Array, axes: Sequence[Optional[str]], rules: Mapping[str, Any]):
    """with_sharding_constraint by logical names (no-op outside a mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, spec_for(axes, rules))
    except (ValueError, RuntimeError):
        return x


# --------------------------------------------------- activation constraints --
# Model code calls constrain_act() at layer boundaries; it is a no-op unless
# the launcher installed rules via use_rules(). This is how sequence-parallel
# activation sharding (Megatron-SP: the remat stash is seq-sharded over
# 'model', cutting per-chip activation memory by the TP degree) reaches the
# model without the model importing mesh state.

import contextlib
import threading as _threading

_ACT = _threading.local()


@contextlib.contextmanager
def use_rules(rules: Mapping[str, Any], mesh: Optional[Mesh] = None):
    prev = getattr(_ACT, "rules", None)
    prev_mesh = getattr(_ACT, "mesh", None)
    _ACT.rules = rules
    _ACT.mesh = mesh
    try:
        yield
    finally:
        _ACT.rules = prev
        _ACT.mesh = prev_mesh


def current_rules():
    return getattr(_ACT, "rules", None)


def current_mesh() -> Optional[Mesh]:
    return getattr(_ACT, "mesh", None)


def constrain_act(x: jax.Array, axes: Sequence[Optional[str]]):
    rules = getattr(_ACT, "rules", None)
    if rules is None:
        return x
    # skip degenerate dims (decode s=1): dropping the constraint is always
    # legal, it is only a hint
    spec = spec_for(axes, rules)
    for dim, part in enumerate(spec):
        if part is not None and x.shape[dim] == 1:
            return x
    return jax.lax.with_sharding_constraint(x, spec)


def num_devices(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
