"""Multi-controller runtime: one context object owns the process topology.

Every layer that used to hardcode single-controller assumptions (eval
sharding, the training launcher, checkpointing, mesh construction) consumes
a :class:`DistributedContext` instead of calling ``jax.process_*`` or
``jax.local_devices()`` ad hoc. The context owns

* ``(host_id, n_hosts)`` — this process's coordinates,
* the **global mesh** accessors (:meth:`data_mesh` over every device in
  the job, :meth:`stripe_mesh` with exactly one device per host — the mesh
  the cross-host eval reduction runs over),
* the **local devices** this process can address,
* the **striping contract**: :meth:`owned_shards` makes process ``i`` own
  shards ``i, i+P, i+2P, ...`` — the same interleaving
  ``synthetic_detection.batches(host_id, n_hosts)`` and
  ``lm_data.batch_at(host_id, n_hosts)`` already use for data, so shard
  ownership and data ownership follow ONE contract.

Construction: :func:`initialize` wires ``jax.distributed.initialize`` when
launched as one process of a multi-process job (enabling the gloo CPU
collectives backend first, so ``JAX_PLATFORMS=cpu`` jobs get REAL
cross-process collectives); without a coordinator it degrades to the
single-host identity context ``(host_id=0, n_hosts=1)`` and every consumer
behaves exactly as before. :func:`get_context` returns the process-wide
context, deriving the identity context on first use if :func:`initialize`
was never called.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Sequence

import numpy as np


def _enable_cpu_collectives() -> None:
    """Select the gloo CPU collectives backend — REQUIRED before the first
    backend touch, or multi-process ``JAX_PLATFORMS=cpu`` jobs fail with
    "Multiprocess computations aren't implemented on the CPU backend".
    Harmless on accelerator backends; tolerated missing on jax versions
    that predate (or postdate) the option name."""
    import jax

    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:  # noqa: BLE001 — option unknown on this jax version
        pass


@dataclass(frozen=True)
class DistributedContext:
    """This process's coordinates in the job, plus mesh/ownership accessors.

    ``host_id``/``n_hosts`` mirror ``jax.process_index()`` /
    ``jax.process_count()``; the identity context is ``(0, 1)``.
    """

    host_id: int
    n_hosts: int

    def __post_init__(self):
        if not 0 <= self.host_id < self.n_hosts:
            raise ValueError(
                f"host_id {self.host_id} out of range for {self.n_hosts} hosts"
            )

    # ------------------------------------------------------------ devices --

    @property
    def is_multi_controller(self) -> bool:
        return self.n_hosts > 1

    @property
    def global_devices(self) -> tuple:
        """Every device in the job, across all hosts."""
        import jax

        return tuple(jax.devices())

    @property
    def local_devices(self) -> tuple:
        """The devices THIS process can address."""
        import jax

        return tuple(jax.local_devices())

    # ------------------------------------------------------------- meshes --

    def data_mesh(self, axis_name: str = "data"):
        """1-D mesh over ALL global devices — the batch axis of
        data-parallel training spans every host's devices."""
        import jax

        return jax.sharding.Mesh(np.asarray(self.global_devices), (axis_name,))

    def stripe_mesh(self, axis_name: str = "data"):
        """1-D mesh with exactly ONE device per host, ordered by host id —
        the mesh the cross-host eval-stat reduction runs over (each host
        contributes one padded row; the collective crosses process
        boundaries, unlike ``compat.local_device_mesh``'s local subset)."""
        per_host: dict = {}
        for d in self.global_devices:
            per_host.setdefault(d.process_index, d)
        missing = [h for h in range(self.n_hosts) if h not in per_host]
        if missing:
            raise RuntimeError(
                f"no devices visible for hosts {missing} — was "
                "jax.distributed.initialize called on every process?"
            )
        devs = [per_host[h] for h in sorted(per_host)]
        import jax

        return jax.sharding.Mesh(np.asarray(devs), (axis_name,))

    # ---------------------------------------------------------- ownership --

    def owned_shards(self, n_shards: int) -> list:
        """Shard ids THIS host walks: ``host_id, host_id+P, ...`` — the
        ``batches(host_id, n_hosts)`` striping contract applied to shard
        ownership. Single-controller: every shard."""
        return list(range(self.host_id, n_shards, self.n_hosts))

    def validate_shard_count(self, n_shards: int) -> None:
        """Reject shard counts that don't divide evenly across hosts.

        The striping itself never duplicates work, but ``n_shards %
        n_hosts != 0`` silently skews it — some hosts walk one shard more
        than others, and an ``n_shards < n_hosts`` launch leaves whole
        hosts idle while looking healthy. Refuse loudly instead."""
        if self.is_multi_controller and (
            n_shards < self.n_hosts or n_shards % self.n_hosts != 0
        ):
            raise ValueError(
                f"n_shards={n_shards} does not stripe evenly over "
                f"{self.n_hosts} hosts — pass a multiple of n_hosts so "
                "every host owns the same number of shards (shard s "
                "belongs to host s % n_hosts)"
            )

    # --------------------------------------------------------- data plane --

    def global_batch(self, batch: Any, sharding) -> Any:
        """Assemble per-host local batches into dim-0-sharded GLOBAL
        arrays: host ``h`` contributes its local rows, the global leading
        dim is ``local_rows * n_hosts``. ``sharding`` must be a
        ``NamedSharding`` that partitions dim 0 over a mesh spanning every
        host (e.g. ``data_mesh``). Single-controller: plain device put."""
        import jax
        import jax.numpy as jnp

        if not self.is_multi_controller:
            return jax.tree_util.tree_map(jnp.asarray, batch)

        def put(x):
            x = np.asarray(x)
            global_shape = (x.shape[0] * self.n_hosts,) + x.shape[1:]
            return jax.make_array_from_process_local_data(
                sharding, x, global_shape
            )

        return jax.tree_util.tree_map(put, batch)

    def barrier(self, tag: str) -> None:
        """Block until every host reaches ``tag`` (no-op single-host)."""
        if self.is_multi_controller:
            from jax.experimental import multihost_utils

            multihost_utils.sync_global_devices(tag)

    def describe(self) -> str:
        return f"host {self.host_id}/{self.n_hosts}"


# ------------------------------------------------------------ construction --

_CTX: Optional[DistributedContext] = None


def initialize(
    *,
    coordinator_address: Optional[str] = None,
    num_processes: Optional[int] = None,
    process_id: Optional[int] = None,
) -> DistributedContext:
    """Build (and install as process-wide) the runtime context.

    With a ``coordinator_address`` (``host:port``): enables the CPU
    collectives backend, calls ``jax.distributed.initialize`` and returns
    the real multi-controller context. Without one: the identity context.
    Call BEFORE any other jax backend use (device queries included) —
    jax.distributed can only initialize against an untouched backend.
    """
    global _CTX
    import jax

    if coordinator_address is not None:
        _enable_cpu_collectives()
        jax.distributed.initialize(
            coordinator_address=coordinator_address,
            num_processes=num_processes,
            process_id=process_id,
        )
    _CTX = DistributedContext(
        host_id=jax.process_index(), n_hosts=jax.process_count()
    )
    return _CTX


def get_context() -> DistributedContext:
    """The process-wide context; derives the live (usually identity)
    context from jax process state if :func:`initialize` was never called."""
    global _CTX
    if _CTX is None:
        import jax

        _CTX = DistributedContext(
            host_id=jax.process_index(), n_hosts=jax.process_count()
        )
    return _CTX
