"""jax version compatibility.

``shard_map`` graduated from ``jax.experimental.shard_map`` (where the
replication-check kwarg is ``check_rep``) to ``jax.shard_map`` (where it is
``check_vma``). This shim presents the modern signature on either version
so the distributed modules run on the jax baked into the container.
"""
from __future__ import annotations

import inspect

import jax

try:
    from jax import shard_map as _shard_map
except ImportError:  # pre-graduation jax
    from jax.experimental.shard_map import shard_map as _shard_map

# the kwarg rename did not necessarily coincide with the graduation to
# jax.shard_map — ask the actual signature which name it takes
_CHECK_KW = (
    "check_vma"
    if "check_vma" in inspect.signature(_shard_map).parameters
    else "check_rep"
)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **{_CHECK_KW: check_vma}
    )


def make_mesh(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis types where the installed
    jax has the explicit-sharding API (``jax.sharding.AxisType``), plain
    otherwise (older jax is Auto-only, so the meaning is unchanged)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)
