"""jax version compatibility.

``shard_map`` graduated from ``jax.experimental.shard_map`` (where the
replication-check kwarg is ``check_rep``) to ``jax.shard_map`` (where it is
``check_vma``). This shim presents the modern signature on either version
so the distributed modules run on the jax baked into the container.
"""
from __future__ import annotations

import inspect

import jax
import numpy as np

try:
    from jax import shard_map as _shard_map
except ImportError:  # pre-graduation jax
    from jax.experimental.shard_map import shard_map as _shard_map

# the kwarg rename did not necessarily coincide with the graduation to
# jax.shard_map — ask the actual signature which name it takes
_CHECK_KW = (
    "check_vma"
    if "check_vma" in inspect.signature(_shard_map).parameters
    else "check_rep"
)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **{_CHECK_KW: check_vma}
    )


def make_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` with explicit Auto axis types where the installed
    jax has the explicit-sharding API (``jax.sharding.AxisType``), plain
    otherwise (older jax is Auto-only, so the meaning is unchanged).

    ``devices``: explicit device sequence to build the mesh over — the
    multi-controller path passes ``DistributedContext.global_devices`` so
    mesh axes span EVERY host's devices, never just the local ones. Falls
    back to a direct ``Mesh`` construction on jax versions whose
    ``make_mesh`` lacks the kwarg."""
    kwargs = {}
    if devices is not None:
        need = int(np.prod(shape))
        if len(devices) < need:
            raise ValueError(
                f"mesh shape {tuple(shape)} needs {need} devices but the "
                f"context sees only {len(devices)}"
            )
        devices = tuple(devices)[:need]
        if "devices" not in inspect.signature(jax.make_mesh).parameters:
            return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)
        kwargs["devices"] = devices
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kwargs["axis_types"] = (axis_type.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kwargs)


def local_device_mesh(n: int, axis_name: str = "data"):
    """A 1-D mesh over the FIRST ``n`` local devices. ``jax.make_mesh``
    insists on consuming every device; evaluation sharding wants a subset
    (e.g. 4 eval shards under ``--xla_force_host_platform_device_count=8``),
    so this builds the Mesh directly — the plain constructor defaults to
    Auto axis types on every supported jax."""
    devs = jax.devices()
    if n > len(devs):
        raise ValueError(
            f"need {n} devices for a {n}-way mesh but only {len(devs)} are "
            "visible — lower n_shards or force more simulated devices "
            "(XLA_FLAGS=--xla_force_host_platform_device_count=N)"
        )
    return jax.sharding.Mesh(np.asarray(devs[:n]), (axis_name,))
