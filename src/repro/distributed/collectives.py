"""Collective-communication helpers for the 1000+ node posture.

Three tools, all shard_map-based so the HLO carries REAL collectives that
the roofline parser (benchmarks/roofline.py) can account:

* ``int8_psum`` — int8 error-feedback gradient all-reduce: quantize the
  local shard, reduce-scatter int8 payloads (4x fewer bytes on the wire
  than f32), dequantize + sum locally, all-gather int8 results. The
  paper's FXP8 philosophy applied to the DP collective.
* ``hierarchical_psum`` — reduce-scatter within the pod ('data'), then
  all-reduce the pod-partials over the 'pod' axis (DCN), then all-gather
  within the pod. Moves the slow inter-pod hop to 1/N_data of the bytes.
* ``overlap_allgather_matmul`` — the classic collective-matmul pattern:
  x sharded on the contraction dim, one shard's matmul is computed per
  step while the next shard is being collective-permuted in — compute
  hides the ICI latency. XLA's latency-hiding scheduler does this
  automatically for simple cases; the explicit version is for the §Perf
  loop where we control the schedule.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from repro.distributed.compat import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ------------------------------------------------------------- int8 psum --


def _q8(x, axis=-1):
    scale = jnp.max(jnp.abs(x), axis=axis, keepdims=True) / 127.0
    q = jnp.round(x / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def int8_psum(mesh: Mesh, axis_name: str = "data"):
    """Returns f(x_local) that all-reduces a REPLICATED-shape f32 array over
    `axis_name` while moving int8 on the wire. Inside shard_map:
    quantize → all_to_all (scatter blocks) → local f32 sum → quantize →
    all_gather. Error relative to exact psum is bounded by 2 rounding steps
    (~1e-2 relative; error feedback at the optimizer absorbs it)."""
    n = mesh.shape[axis_name]

    def inner(x):
        orig_shape = x.shape
        flat = x.reshape(-1)
        pad = (-flat.size) % n
        flat = jnp.pad(flat, (0, pad)).reshape(n, -1)
        q, s = _q8(flat)  # per-row scale
        # reduce_scatter: row i of every peer lands on peer i
        qs = jax.lax.all_to_all(q[:, None], axis_name, 0, 0)[:, 0]
        ss = jax.lax.all_to_all(s[:, None], axis_name, 0, 0)[:, 0]
        local = jnp.sum(qs.astype(jnp.float32) * ss, axis=0)  # exact f32 sum
        q2, s2 = _q8(local[None])
        qg = jax.lax.all_gather(q2[0], axis_name)
        sg = jax.lax.all_gather(s2[0], axis_name)
        out = (qg.astype(jnp.float32) * sg).reshape(-1)
        return out[: int(np.prod(orig_shape))].reshape(orig_shape)

    spec = P()  # replicated in/out; the wire format is the int8 payload
    other = tuple(a for a in mesh.axis_names if a != axis_name)
    return shard_map(
        inner, mesh=mesh, in_specs=spec, out_specs=spec,
        check_vma=False,
    )


# ------------------------------------------------------ hierarchical psum --


def hierarchical_psum(mesh: Mesh):
    """psum over ('pod', 'data') done as reduce_scatter(data) →
    psum(pod) → all_gather(data): the inter-pod (DCN) hop moves 1/N_data
    of the bytes. Input/output replicated over both axes."""
    assert "pod" in mesh.axis_names, "hierarchical psum needs a multi-pod mesh"
    nd = mesh.shape["data"]

    def inner(x):
        orig_shape = x.shape
        flat = x.reshape(-1)
        pad = (-flat.size) % nd
        flat = jnp.pad(flat, (0, pad)).reshape(nd, -1)
        mine = jax.lax.all_to_all(flat[:, None], "data", 0, 0)[:, 0]
        part = jnp.sum(mine, axis=0)  # my 1/nd slice, summed intra-pod
        part = jax.lax.psum(part, "pod")  # DCN hop on the slice only
        out = jax.lax.all_gather(part, "data").reshape(-1)
        return out[: int(np.prod(orig_shape))].reshape(orig_shape)

    return shard_map(inner, mesh=mesh, in_specs=P(), out_specs=P(), check_vma=False)


# ------------------------------------------------- eval-stat all-gather --


def eval_stats_allgather(mesh: Mesh, axis_name: str = "data"):
    """The sharded-mAP reduction: every shard holds one padded row of
    per-prediction match statistics (global image index, class, score, TP
    flag, valid mask — any dict of equal-leading-dim arrays) plus its local
    per-class ground-truth counts. Returns ``f(rows, counts) ->
    (gathered_rows, total_counts)`` where ``rows`` leaves are (k, cap)
    arrays sharded over ``axis_name`` (one shard per device), gathered back
    replicated, and ``counts`` is (k, C) sharded the same way and
    all-reduced with an exact integer psum.

    This is the collective `repro.eval.sharded` pools through before the AP
    sweep: all_gather moves the (score, TP) lists, psum moves the recall
    denominators — both exact (int / bit-preserved payloads), so the pooled
    PR curve is bit-identical to the single-host evaluation."""

    def inner(rows, counts):
        g = jax.tree_util.tree_map(
            lambda r: jax.lax.all_gather(r, axis_name, axis=0, tiled=True), rows
        )
        total = jax.lax.psum(counts, axis_name)[0]
        return g, total

    return shard_map(
        inner,
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name)),
        out_specs=(P(), P()),
        check_vma=False,
    )


# ------------------------------------------- all-gather/matmul overlapping --


def overlap_allgather_matmul(mesh: Mesh, axis_name: str = "model"):
    """y = x @ w with w row-sharded over `axis_name`: per step, matmul the
    resident shard while collective-permuting the next one in (bidirectional
    ring). Equivalent to all_gather(w) @ — but the gather is hidden behind
    the MXU. Returns f(x, w_sharded)->(y replicated)."""
    n = mesh.shape[axis_name]

    def inner(x, w):
        # x: (m, k_local * n) replicated; w: (k_local, out) local shard
        k_local = w.shape[0]
        idx = jax.lax.axis_index(axis_name)

        def step(carry, i):
            acc, w_cur = carry
            src = (idx - i) % n  # whose shard we hold at step i
            xs = jax.lax.dynamic_slice_in_dim(x, src * k_local, k_local, axis=1)
            acc = acc + xs @ w_cur
            w_nxt = jax.lax.ppermute(
                w_cur, axis_name, [(j, (j + 1) % n) for j in range(n)]
            )
            return (acc, w_nxt), None

        acc0 = jnp.zeros((x.shape[0], w.shape[1]), w.dtype)
        (acc, _), _ = jax.lax.scan(step, (acc0, w), jnp.arange(n))
        return jax.lax.psum(acc, axis_name) / n  # replicas agree; psum folds them
        # NB: every rank computed the FULL sum (each saw all shards), so the
        # psum/n is a consistency fold, not part of the math.

    return shard_map(
        inner,
        mesh=mesh,
        in_specs=(P(), P(axis_name, None)),
        out_specs=P(),
        check_vma=False,
    )
