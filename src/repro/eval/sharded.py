"""Mesh-sharded mAP evaluation: stripe the val split, reduce exactly.

``harness.evaluate_detector`` scores the whole split on one host. Full-scale
configs need the same treatment the training data already gets
(``synthetic_detection.batches`` host striping): split the images across
shards, run each shard's forward→decode→NMS through the compile-once
executor plan, and reduce the pooled per-class (score, TP) lists before the
AP sweep. The reduction is EXACT — the pooled precision-recall curve (and
therefore mAP) is bit-identical to the single-host evaluation:

* shard s of k owns global image indices s, s+k, s+2k, ... (the
  ``batches(host_id, n_hosts)`` striping contract, via
  ``synthetic_detection.eval_shard_indices``),
* VOC greedy matching is per-image, so it shards embarrassingly; each
  shard emits flat per-prediction records (global image index, class,
  score, TP flag) plus its per-class ground-truth counts,
* records are gathered — through a REAL device collective
  (``distributed.collectives.eval_stats_allgather``: all_gather for the
  lists, integer psum for the recall denominators) when a mesh is
  available, plain host concatenation otherwise — and re-sorted by global
  image index (stable), which reconstructs the single-host pooling order
  EXACTLY, so score ties resolve identically and the AP sweep
  (``detection_map.average_precision``) sees the same sequence bit for bit.

The same code runs on 1 CPU device (host gather), N simulated CPU devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — the
``sharded-eval-sim`` CI lane), a real single-process multi-device mesh,
and a multi-CONTROLLER job (one process per host, launched through
``distributed.runtime.initialize``): process ``i`` owns shards
``i, i+P, i+2P, ...`` per :meth:`DistributedContext.owned_shards`, walks
ONLY those stripes, and the per-host merged records reduce through the
same ``eval_stats_allgather`` collective — run over the context's
:meth:`~repro.distributed.runtime.DistributedContext.stripe_mesh` (one
device per host, crossing process boundaries) instead of
``local_device_mesh``'s local subset. The stable re-sort by global image
index makes host/shard interleaving invisible, so the multi-host report is
bit-identical to the single-host one (the ``distributed-smoke`` CI lane's
gate). ``n_shards`` must stripe evenly over the hosts
(``n_shards % n_hosts == 0``) — anything else skews ownership and is
refused loudly.

Scores travel as float32 — the detector's native dtype, so the device hop
is bit-preserving. (Hand-crafted float64 scores that are not
float32-representable would be rounded; detector outputs never are.)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Iterable, Mapping, Optional, Sequence

import numpy as np

from repro.data import synthetic_detection as sd
from repro.eval import detection_map as dm


@dataclass(frozen=True)
class ShardedEvalConfig:
    """How to split and reduce one evaluation.

    * ``n_shards`` — stripe count; shard s owns image indices s, s+k, ...
    * ``axis_name`` — mesh axis the reduction collective runs over.
    * ``batch`` — per-shard forward chunk size (outputs are bitwise
      invariant to batch grouping, so this only trades memory for speed).
    * ``use_device_mesh`` — None: use the device collective when
      ``n_shards`` devices are visible, else gather on host. True forces
      the collective (raises without enough devices); False forces host.
    """

    n_shards: int = 1
    axis_name: str = "data"
    batch: int = 8
    use_device_mesh: Optional[bool] = None

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")


@dataclass
class ShardStats:
    """One shard's flat match records + recall denominators.

    ``image_idx``/``cls``/``score``/``tp`` align per pooled prediction;
    within a shard they are appended in ascending global image order, and
    within one image in the (class-major, detection-order) order
    ``detection_map.evaluate_detections`` pools in — so a stable re-sort of
    the concatenated shards by ``image_idx`` IS the single-host order.
    """

    image_idx: np.ndarray  # (P,) int32 global image index per prediction
    cls: np.ndarray  # (P,) int32
    score: np.ndarray  # (P,) float32
    tp: np.ndarray  # (P,) bool
    n_gt: np.ndarray  # (C,) int32 per-class ground-truth count
    n_images: int

    @classmethod
    def empty(cls, num_classes: int) -> "ShardStats":
        return cls(
            image_idx=np.zeros(0, np.int32), cls=np.zeros(0, np.int32),
            score=np.zeros(0, np.float32), tp=np.zeros(0, bool),
            n_gt=np.zeros(num_classes, np.int32), n_images=0,
        )


def match_stats(
    predictions: Iterable,
    ground_truths: Iterable[Mapping[str, Any]],
    image_indices: Sequence[int],
    *,
    num_classes: int,
    iou_threshold: float = 0.5,
) -> ShardStats:
    """Greedy-match one shard's (predictions, ground_truths) — exactly the
    per-image half of ``detection_map.evaluate_detections`` — and record
    every pooled entry with its GLOBAL image index for the exact reduce."""
    idx_out: list = []
    cls_out: list = []
    score_out: list = []
    tp_out: list = []
    n_gt = np.zeros(num_classes, np.int32)
    n_images = 0
    preds = list(predictions)
    gts = list(ground_truths)
    if not len(preds) == len(gts) == len(image_indices):
        raise ValueError(
            f"pairing mismatch: {len(preds)} predictions, {len(gts)} "
            "ground truths, "
            f"{len(image_indices)} image indices — images align by position"
        )
    for pred, gt, g_idx in zip(preds, gts, image_indices):
        n_images += 1
        pred = dm._as_image_preds(pred)
        p_boxes = np.asarray(pred["boxes"], np.float64).reshape(-1, 4)
        p_scores = np.asarray(pred["scores"], np.float64).reshape(-1)
        p_cls = np.asarray(pred["classes"], np.int64).reshape(-1)
        g_boxes = np.asarray(gt["boxes"], np.float64).reshape(-1, 4)
        g_cls = np.asarray(gt["classes"], np.int64).reshape(-1)
        for c in range(num_classes):
            n_gt[c] += int(np.sum(g_cls == c))
            sel = p_cls == c
            if not np.any(sel):
                continue
            tp = dm.match_image(
                p_boxes[sel], p_scores[sel], g_boxes[g_cls == c],
                iou_threshold=iou_threshold,
            )
            k = int(np.sum(sel))
            idx_out.extend([int(g_idx)] * k)
            cls_out.extend([c] * k)
            score_out.extend(p_scores[sel].tolist())
            tp_out.extend(tp.tolist())
    return ShardStats(
        image_idx=np.asarray(idx_out, np.int32),
        cls=np.asarray(cls_out, np.int32),
        score=np.asarray(score_out, np.float32),
        tp=np.asarray(tp_out, bool),
        n_gt=n_gt,
        n_images=n_images,
    )


# ------------------------------------------------------------------ reduce --


def _gather_host(stats: Sequence[ShardStats]) -> ShardStats:
    """Reference reduction: plain concatenation + integer sum."""
    return ShardStats(
        image_idx=np.concatenate([s.image_idx for s in stats]),
        cls=np.concatenate([s.cls for s in stats]),
        score=np.concatenate([s.score for s in stats]),
        tp=np.concatenate([s.tp for s in stats]),
        n_gt=np.sum([s.n_gt for s in stats], axis=0).astype(np.int32),
        n_images=sum(s.n_images for s in stats),
    )


@functools.lru_cache(maxsize=None)
def _mesh_gather_fn(n_shards: int, axis_name: str):
    """(mesh row sharding, jitted gather) for an n_shards-way reduction —
    cached so repeated sharded evaluations (run_pipeline scores 5+ times)
    reuse one jit entry instead of recompiling the collective per call.
    The local device topology is fixed for the process lifetime, so the
    cache can never go stale."""
    import jax
    from jax.sharding import NamedSharding

    from repro.distributed import collectives as C
    from repro.distributed import sharding as shd
    from repro.distributed.compat import local_device_mesh

    mesh = local_device_mesh(n_shards, axis_name)
    rules = shd.default_rules(mesh)
    row_sharding = NamedSharding(mesh, shd.spec_for(("batch",), rules))
    return row_sharding, jax.jit(C.eval_stats_allgather(mesh, axis_name))


def _gather_mesh(stats: Sequence[ShardStats], axis_name: str) -> ShardStats:
    """The device reduction: pad each shard's records to a common capacity,
    place row s on device s (``distributed.sharding`` logical-batch rule),
    all-gather the rows / psum the counts through
    ``collectives.eval_stats_allgather``, and unpad with the gathered valid
    mask. Bit-preserving: int/bool payloads plus float32 scores."""
    import jax

    k = len(stats)
    cap = max(1, max(s.image_idx.size for s in stats))

    def pad(x, fill=0):
        out = np.full((cap,), fill, dtype=x.dtype)
        out[: x.size] = x
        return out

    rows = {
        "image_idx": np.stack([pad(s.image_idx) for s in stats]),
        "cls": np.stack([pad(s.cls) for s in stats]),
        "score": np.stack([pad(s.score) for s in stats]),
        "tp": np.stack([pad(s.tp) for s in stats]),
        "valid": np.stack(
            [pad(np.ones(s.image_idx.size, bool), fill=False) for s in stats]
        ),
        # n_images rides along so the reduce is self-describing even for
        # shards that produced zero predictions
        "n_images": np.asarray([[s.n_images] for s in stats], np.int32),
    }
    counts = np.stack([s.n_gt for s in stats]).astype(np.int32)

    row_sharding, gather_fn = _mesh_gather_fn(k, axis_name)
    rows_dev = {f: jax.device_put(v, row_sharding) for f, v in rows.items()}
    counts_dev = jax.device_put(counts, row_sharding)
    gathered, total_gt = gather_fn(rows_dev, counts_dev)
    g = {f: np.asarray(v) for f, v in gathered.items()}
    valid = g["valid"].astype(bool)
    return ShardStats(
        image_idx=np.concatenate([g["image_idx"][s][valid[s]] for s in range(k)]),
        cls=np.concatenate([g["cls"][s][valid[s]] for s in range(k)]),
        score=np.concatenate([g["score"][s][valid[s]] for s in range(k)]),
        tp=np.concatenate([g["tp"][s][valid[s]].astype(bool) for s in range(k)]),
        n_gt=np.asarray(total_gt, np.int32),
        n_images=int(g["n_images"].sum()),
    )


@functools.lru_cache(maxsize=None)
def _process_gather_fn(n_hosts: int, axis_name: str):
    """(stripe mesh, jitted gather) for the cross-host reduction — one
    device per host, cached per (n_hosts, axis) like :func:`_mesh_gather_fn`
    (the process topology is fixed for the process lifetime)."""
    import jax

    from repro.distributed import collectives as C
    from repro.distributed import runtime

    mesh = runtime.get_context().stripe_mesh(axis_name)
    return mesh, jax.jit(C.eval_stats_allgather(mesh, axis_name))


def _gather_process(local: ShardStats, ctx, axis_name: str) -> ShardStats:
    """The multi-controller reduction: every host contributes ONE row (its
    merged owned-shard records) to the ``eval_stats_allgather`` collective
    over the context's stripe mesh. Two phases: an int all-gather agrees on
    the padded row capacity (hosts own different record counts), then the
    padded rows gather and the GT counts psum — both exact, so this is the
    cross-process twin of :func:`_gather_mesh`."""
    import jax
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    mesh, gather_fn = _process_gather_fn(ctx.n_hosts, axis_name)
    sharding = NamedSharding(mesh, P(axis_name))
    n = ctx.n_hosts

    def to_global(arr):  # local (1, ...) row -> (n_hosts, ...) global array
        return jax.make_array_from_process_local_data(
            sharding, arr, (n,) + arr.shape[1:]
        )

    sizes, _ = gather_fn(
        {"n": to_global(np.array([[local.image_idx.size]], np.int32))},
        to_global(np.zeros((1, 1), np.int32)),
    )
    cap = max(1, int(np.asarray(sizes["n"]).max()))

    def pad(x, fill=0):
        out = np.full((1, cap), fill, dtype=x.dtype)
        out[0, : x.size] = x
        return out

    rows = {
        "image_idx": pad(local.image_idx),
        "cls": pad(local.cls),
        "score": pad(local.score),
        "tp": pad(local.tp),
        "valid": pad(np.ones(local.image_idx.size, bool), fill=False),
        "n_images": np.asarray([[local.n_images]], np.int32),
    }
    counts = local.n_gt[None].astype(np.int32)
    gathered, total_gt = gather_fn(
        {f: to_global(v) for f, v in rows.items()}, to_global(counts)
    )
    g = {f: np.asarray(v) for f, v in gathered.items()}
    valid = g["valid"].astype(bool)
    return ShardStats(
        image_idx=np.concatenate([g["image_idx"][h][valid[h]] for h in range(n)]),
        cls=np.concatenate([g["cls"][h][valid[h]] for h in range(n)]),
        score=np.concatenate([g["score"][h][valid[h]] for h in range(n)]),
        tp=np.concatenate([g["tp"][h][valid[h]].astype(bool) for h in range(n)]),
        n_gt=np.asarray(total_gt, np.int32),
        n_images=int(g["n_images"].sum()),
    )


def _pick_gather(eval_cfg: ShardedEvalConfig) -> str:
    if eval_cfg.n_shards == 1:
        return "host"  # nothing to reduce; no collective either way
    use = eval_cfg.use_device_mesh
    if use is None:
        import jax

        use = len(jax.devices()) >= eval_cfg.n_shards
    return "mesh" if use else "host"


def pool_stats(
    stats: Sequence[ShardStats],
    *,
    num_classes: int,
    iou_threshold: float = 0.5,
    eval_cfg: Optional[ShardedEvalConfig] = None,
    ctx=None,
) -> dict:
    """Reduce per-shard stats and sweep AP — the sharded back half of
    ``detection_map.evaluate_detections``, bit-identical to it.

    Single-controller (``stats`` holds EVERY shard): gathers via the device
    collective or on host per ``eval_cfg``. Multi-controller (``stats``
    holds only this host's owned shards): host-merges the local shards,
    then reduces across processes through :func:`_gather_process` over the
    context's stripe mesh. Either way the pooled records stable-sort by
    global image index: shards hold disjoint, internally-ascending index
    sets, so the re-sorted sequence is exactly the order the single-host
    evaluator pooled in (same tie resolution, same cumsum, same envelope).
    Returns the ``evaluate_detections`` report dict plus
    ``n_shards``/``n_hosts``/``gather``.
    """
    from repro.distributed import runtime

    ctx = ctx or runtime.get_context()
    if ctx.is_multi_controller:
        eval_cfg = eval_cfg or ShardedEvalConfig(n_shards=len(stats) * ctx.n_hosts)
        gather = "process"
        local = (
            _gather_host(stats) if stats else ShardStats.empty(num_classes)
        )
        merged = _gather_process(local, ctx, eval_cfg.axis_name)
        n_shards = eval_cfg.n_shards
    else:
        eval_cfg = eval_cfg or ShardedEvalConfig(n_shards=len(stats))
        gather = _pick_gather(eval_cfg)
        merged = (
            _gather_mesh(stats, eval_cfg.axis_name) if gather == "mesh"
            else _gather_host(stats)
        )
        n_shards = len(stats)
    order = np.argsort(merged.image_idx, kind="stable")
    cls = merged.cls[order]
    score = merged.score[order]
    tp = merged.tp[order]
    aps = []
    n_pred = []
    for c in range(num_classes):
        sel = cls == c
        n_pred.append(int(np.sum(sel)))
        aps.append(dm.average_precision(score[sel], tp[sel], int(merged.n_gt[c])))
    present = [a for a in aps if not np.isnan(a)]
    return {
        "map": float(np.mean(present)) if present else float("nan"),
        "per_class_ap": aps,
        "n_gt": merged.n_gt.astype(np.int64).tolist(),
        "n_pred": n_pred,
        "n_images": int(merged.n_images),
        "iou_threshold": float(iou_threshold),
        "n_shards": n_shards,
        "n_hosts": ctx.n_hosts,
        "gather": gather,
    }


def _same_ap(a: float, b: float) -> bool:
    return a == b or (np.isnan(a) and np.isnan(b))


def reports_identical(a: Mapping[str, Any], b: Mapping[str, Any]) -> bool:
    """The bit-identical contract, as one canonical predicate: NaN-aware
    exact equality of two evaluation reports on every shared key (mAP,
    per-class AP, GT/prediction counts, image count, IoU threshold) —
    sharded-only keys like ``n_shards``/``gather`` are ignored. Used by the
    ``benchmarks/eval_map.py --shards`` parity gate and the test suite."""
    return (
        _same_ap(a["map"], b["map"])
        and len(a["per_class_ap"]) == len(b["per_class_ap"])
        and all(_same_ap(x, y) for x, y in zip(a["per_class_ap"], b["per_class_ap"]))
        and a["n_gt"] == b["n_gt"]
        and a["n_pred"] == b["n_pred"]
        and a["n_images"] == b["n_images"]
        and a["iou_threshold"] == b["iou_threshold"]
    )


# ------------------------------------------------------------- evaluators --


def evaluate_predictions_sharded(
    predictions: Sequence,
    ground_truths: Sequence[Mapping[str, Any]],
    *,
    num_classes: int,
    iou_threshold: float = 0.5,
    eval_cfg: Optional[ShardedEvalConfig] = None,
    ctx=None,
) -> dict:
    """Sharded scoring of ALREADY-COMPUTED predictions (the serve
    ``--eval-map`` path and the shard-reduction property tests): stripe the
    paired lists across ``eval_cfg.n_shards``, match per shard, reduce.
    Bit-identical to ``detection_map.evaluate_detections`` on the same
    pairing for any shard count, including empty shards — PROVIDED scores
    are float32-representable (detector outputs always are; pooled scores
    travel as float32, so hand-computed float64 scores that differ only
    past float32 precision would collapse into ties here while the
    unsharded evaluator still ranks them apart).

    Multi-controller: this host matches only its OWNED shards
    (``ctx.owned_shards``) and the reduce crosses processes — every host
    must call with the SAME (predictions, ground_truths) pairing and
    returns the same full report."""
    from repro.distributed import runtime

    ctx = ctx or runtime.get_context()
    eval_cfg = eval_cfg or ShardedEvalConfig(n_shards=max(1, ctx.n_hosts))
    ctx.validate_shard_count(eval_cfg.n_shards)
    predictions = list(predictions)
    ground_truths = list(ground_truths)
    if len(predictions) != len(ground_truths):
        raise ValueError(
            f"{len(predictions)} predictions vs {len(ground_truths)} ground "
            "truths — the pairing aligns by position"
        )
    n = len(predictions)
    stats = []
    for s in ctx.owned_shards(eval_cfg.n_shards):
        idx = sd.eval_shard_indices(n, s, eval_cfg.n_shards)
        stats.append(
            match_stats(
                [predictions[i] for i in idx],
                [ground_truths[i] for i in idx],
                idx,
                num_classes=num_classes,
                iou_threshold=iou_threshold,
            )
        )
    return pool_stats(
        stats, num_classes=num_classes, iou_threshold=iou_threshold,
        eval_cfg=eval_cfg, ctx=ctx,
    )


def evaluate_detector_sharded(
    det,
    *,
    n_images: int = 32,
    split: str = "val",
    iou_threshold: float = 0.5,
    eval_cfg: Optional[ShardedEvalConfig] = None,
    source=None,
    ctx=None,
) -> dict:
    """Sharded ``harness.evaluate_detector``: each shard materializes only
    its stripe of the eval split (``source`` — any
    ``repro.data.detection_datasets.DetectionSource``; the synthetic
    generator by default. Both the generator and the file-backed loaders
    are deterministic per (split, index), so no shared filesystem is
    needed), runs forward→decode→NMS through the compile-once executor
    plan in ``eval_cfg.batch`` chunks, and the match stats reduce through
    ``pool_stats``. mAP is bit-identical to the single-host path for any
    shard count (per-image outputs are bitwise invariant to batch grouping:
    integer-domain conv accumulation plus elementwise float stages).

    Multi-controller: process ``i`` walks ONLY its owned shards
    ``i, i+P, ...`` (``ctx.owned_shards``) — forward work scales with
    1/n_hosts wall-clock — and the reduce crosses processes through the
    context's stripe mesh; every host returns the same full report.
    ``eval_cfg`` defaults to one shard per host; an uneven
    ``n_shards % n_hosts`` raises (``ctx.validate_shard_count``)."""
    import jax.numpy as jnp

    from repro.distributed import runtime

    ctx = ctx or runtime.get_context()
    eval_cfg = eval_cfg or ShardedEvalConfig(n_shards=max(1, ctx.n_hosts))
    ctx.validate_shard_count(eval_cfg.n_shards)
    cfg = det.cfg
    from repro.data import detection_datasets as dd
    from repro.eval.harness import grid_div

    source = source or dd.SyntheticSource()
    cap = source.num_eval_images(split)
    if cap is not None:
        n_images = min(n_images, cap)
    stats = []
    for s in ctx.owned_shards(eval_cfg.n_shards):
        images, gts = source.eval_set(
            n_images, split=split, hw=cfg.input_hw, grid_div=grid_div(cfg),
            num_anchors=cfg.num_anchors, num_classes=cfg.num_classes,
            shard_id=s, n_shards=eval_cfg.n_shards,
        )
        idx = sd.eval_shard_indices(n_images, s, eval_cfg.n_shards)
        preds: list = []
        for i in range(0, len(images), eval_cfg.batch):
            dets, _ = det.detect(jnp.asarray(images[i : i + eval_cfg.batch]))
            preds.extend(dm.detections_to_predictions(dets))
        stats.append(
            match_stats(
                preds, gts, idx,
                num_classes=cfg.num_classes, iou_threshold=iou_threshold,
            )
        )
    report = pool_stats(
        stats, num_classes=cfg.num_classes, iou_threshold=iou_threshold,
        eval_cfg=eval_cfg, ctx=ctx,
    )
    report["split"] = split
    return report
