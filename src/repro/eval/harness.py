"""Accuracy-evaluation harness: train → prune → QAT-finetune → evaluate.

The paper's Table I walks the detector through a compression pipeline
(train SNN-a float → fine-grained prune 80% → FXP8 quantize → fine-tune)
and Fig 15 shows the mixed (1, 3) time-step schedule costs almost no mAP
versus uniform T=3. This harness reproduces both at a trainable demo
scale on the synthetic IVS-3cls-like split:

  stage "trained"  float weights, fresh from ``train_steps``
  stage "pruned"   80% magnitude pruning on 3×3 kernels, no retraining
  stage "qat"      FXP8 fake-quant + mask-preserving fine-tune
  schedules        the final weights evaluated mixed (1, 3) vs uniform T=3

Note on the schedule comparison: at inference on a static frame the two
schedules are mathematically identical through the first two macro layers
(convolving one step and broadcasting equals convolving three identical
steps), so the mAP delta is exactly 0 while the op count drops — the
Fig 15 trend in its cleanest form. The delta is still measured, not
assumed.

Every evaluation also reports the worst-case conv accumulator magnitude
against the ASIC's 16-bit accumulator claim (``core.quant.ACC_BITS``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pruning, quant
from repro.data import detection_datasets as dd
from repro.eval import detection_map as dm
from repro.models import snn_yolo as sy
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt

# Evaluation-time postprocess settings: a LOW score threshold and a deep
# detection budget — mAP integrates the whole precision-recall curve, so
# the serving default (0.25) would clip the low-confidence tail and
# understate AP (COCO/VOC evaluators use ~0.001-0.05 for the same reason).
EVAL_SCORE_THRESHOLD = 0.01
EVAL_MAX_DETECTIONS = 64


def demo_config(*, conv_exec: str = "dense", weight_bits: int = 8) -> sy.SNNDetConfig:
    """The trainable-size detector used by the harness, the training
    example and BENCH_eval: 96×160 input, thinned channels, 3 CSP stages
    (grid /16), mixed (1, 3) time steps."""
    from repro.configs import get_config

    return dataclasses.replace(
        get_config("snn-det"),
        arch_id="snn-det-eval",
        input_hw=(96, 160), stem_channels=8, conv_block_channels=16,
        stage_channels=((16, 16), (16, 32), (32, 64)), pooled_stages=3,
        # plain SAME conv for CPU training speed; (6, 10) divides every
        # feature-map resolution (96×160 … 6×10), so flipping
        # use_block_conv=True for compressed-executor evaluation works
        use_block_conv=False, block_hw=(6, 10),
        weight_bits=weight_bits, conv_exec=conv_exec,
    )


def grid_div(cfg: sy.SNNDetConfig) -> int:
    """Dataset grid divisor matching the model's pooling depth."""
    return 2 ** (cfg.pooled_stages + 1)


# ---------------------------------------------------------------- evaluate --


def accumulator_report(det) -> dict:
    """Worst-case conv accumulator magnitude per layer (binary-spike
    inputs: max over output channels of Σ|w_q|) vs the 16-bit claim."""
    plan = det.plan
    if plan is None:  # float weights: no FXP accumulator to bound
        return {"acc_bits": quant.ACC_BITS, "layers": {}, "max_abs": 0,
                "within_16b": True}
    per_layer = {
        name: quant.conv_acc_worst_case(np.asarray(lp.w_q))
        for name, lp in plan.layers.items()
    }
    worst = max(per_layer.values())
    return {
        "acc_bits": quant.ACC_BITS,
        "layers": per_layer,
        "max_abs": int(worst),
        "within_16b": bool(worst < 2 ** (quant.ACC_BITS - 1)),
    }


def evaluate_detector(
    det,
    *,
    n_images: int = 32,
    split: str = "val",
    batch: int = 8,
    iou_threshold: float = 0.5,
    sharded=None,
    source: Optional[dd.DetectionSource] = None,
    ctx=None,
) -> dict:
    """mAP@iou of a :class:`~repro.serve.detector.CompiledDetector` on an
    eval split. ``source`` is any :class:`~repro.data.detection_datasets.
    DetectionSource` — the synthetic generator by default, or a COCO/VOC
    loader (``detection_datasets.parse_dataset_spec``) for real annotated
    frames; ``n_images`` clamps to a finite source's split size.

    The handle's own postprocess settings are respected — build the
    detector with :func:`compile_eval_detector` (low threshold, deep
    budget) unless you specifically want serving-threshold mAP.

    ``sharded``: a :class:`repro.eval.sharded.ShardedEvalConfig` (or a bare
    shard count) routes the evaluation through the mesh-sharded path —
    striped split, per-shard forward→decode→NMS, collective reduction of
    the pooled match stats. The result is bit-identical to this single-host
    path for any shard count (tests/test_sharded_eval.py).

    ``ctx``: a :class:`repro.distributed.runtime.DistributedContext` —
    under a multi-controller launch each host walks only the shards it owns
    and the pooled stats are gathered across hosts (requires ``sharded``).
    """
    source = source or dd.SyntheticSource()
    cap = source.num_eval_images(split)
    if cap is not None:
        n_images = min(n_images, cap)
    if sharded is not None:
        from repro.eval import sharded as se

        eval_cfg = (
            se.ShardedEvalConfig(n_shards=sharded, batch=batch)
            if isinstance(sharded, int) else sharded
        )
        return se.evaluate_detector_sharded(
            det, n_images=n_images, split=split, iou_threshold=iou_threshold,
            eval_cfg=eval_cfg, source=source, ctx=ctx,
        )
    cfg = det.cfg
    images, gts = source.eval_set(
        n_images, split=split, hw=cfg.input_hw, grid_div=grid_div(cfg),
        num_anchors=cfg.num_anchors, num_classes=cfg.num_classes,
    )
    preds = []
    for i in range(0, n_images, batch):
        dets, _ = det.detect(jnp.asarray(images[i : i + batch]))
        preds.extend(dm.detections_to_predictions(dets))
    report = dm.evaluate_detections(
        preds, gts, num_classes=cfg.num_classes, iou_threshold=iou_threshold
    )
    report["split"] = split
    return report


def compile_eval_detector(cfg, params, bn, **kw):
    """compile_detector with evaluation postprocess settings."""
    kw.setdefault("score_threshold", EVAL_SCORE_THRESHOLD)
    kw.setdefault("max_detections", EVAL_MAX_DETECTIONS)
    return sy.compile_detector(cfg, params, bn, **kw)


# ------------------------------------------------------------- checkpoints --

# sidecar inside each committed step dir; makes a detector checkpoint
# self-describing (restore rebuilds the matching SNNDetConfig from it)
DETECTOR_CONFIG_FILE = "detector_config.json"


def save_detector_checkpoint(root: str, step: int, params, bn, cfg, *,
                             extra_files=None) -> str:
    """Commit ``{"params", "bn"}`` plus the full config as an atomic
    detector checkpoint under ``root`` (``train/checkpoint.py`` layout).
    The config sidecar rides inside the step dir, so the rename-commit
    covers it too — a reader can never see weights without their config.

    ``extra_files``: additional {filename: bytes} sidecars committed
    atomically alongside (e.g. the ANN→SNN ``conversion_report.json``);
    the config sidecar name is reserved. Returns the committed directory."""
    blob = json.dumps(sy.config_to_dict(cfg), indent=1).encode()
    files = dict(extra_files or {})
    if DETECTOR_CONFIG_FILE in files:
        raise ValueError(f"extra_files may not shadow {DETECTOR_CONFIG_FILE!r}")
    files[DETECTOR_CONFIG_FILE] = blob
    return ckpt.save(root, step, {"params": params, "bn": bn},
                     extra_files=files)


def restore_detector_checkpoint(root: str, *, step: Optional[int] = None,
                                cfg: Optional[sy.SNNDetConfig] = None):
    """Restore (cfg, params, bn, step) from a detector checkpoint.

    ``step`` defaults to the latest committed step; ``cfg`` defaults to the
    checkpoint's own config sidecar. Pass ``cfg`` explicitly to restore a
    bare train-state checkpoint (e.g. ``ft.Supervisor``'s, which carries
    ``params``/``bn``/``opt`` but no sidecar — the extra optimizer leaves
    are ignored). A config/weights mismatch surfaces as
    ``train.checkpoint.restore``'s missing-vs-extra leaf-path ValueError.
    """
    step = step if step is not None else ckpt.latest_step(root)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {root}")
    if cfg is None:
        cfg_path = os.path.join(root, f"step_{step:09d}", DETECTOR_CONFIG_FILE)
        if not os.path.exists(cfg_path):
            raise FileNotFoundError(
                f"{cfg_path} missing — step {step} is not a detector "
                "checkpoint (train-state checkpoints from ft.Supervisor "
                "carry no config sidecar); pass cfg= to restore anyway"
            )
        with open(cfg_path) as f:
            cfg = sy.config_from_dict(json.load(f))
    p_shapes, bn_shapes = jax.eval_shape(
        lambda k: sy.init_params(k, cfg), jax.random.PRNGKey(0)
    )
    state, step = ckpt.restore(
        root, {"params": p_shapes, "bn": bn_shapes}, step=step
    )
    return cfg, state["params"], state["bn"], step


# ------------------------------------------------------------------- train --


def train_steps(
    cfg: sy.SNNDetConfig,
    *,
    steps: int,
    batch: int = 4,
    seed: int = 0,
    lr_peak: float = 2e-3,
    params=None,
    bn=None,
    opt_state=None,
    grad_mask=None,
    start_index: int = 0,
    log_every: int = 50,
    verbose: bool = True,
    source: Optional[dd.DetectionSource] = None,
):
    """Train (or fine-tune) the detector on a train split — synthetic by
    default, or any :class:`~repro.data.detection_datasets.DetectionSource`
    (COCO/VOC loaders letterbox to ``cfg.input_hw`` and encode the same
    grid targets, so the loss and decode stay consistent).

    ``grad_mask``: optional pytree of {0,1} masks (pruning.mask_tree
    layout) — masked entries get zero gradient AND are re-zeroed after
    the update, so fine-tuning preserves the pruned support exactly.
    ``start_index``: first dataset sample index — fine-tune stages pass
    the number of samples the previous stage consumed so they see fresh
    data. Returns (params, bn, opt_state, losses).
    """
    ocfg = opt.AdamWConfig(
        lr_peak=lr_peak, lr_init=lr_peak / 10, lr_final=lr_peak / 100,
        warmup_steps=max(steps // 15, 1), total_steps=steps, weight_decay=1e-3,
    )
    if params is None:
        params, bn = sy.init_params(jax.random.PRNGKey(seed), cfg)
    if opt_state is None:
        opt_state = opt.init_state(params, ocfg)

    def loss_fn(p, b, imgs, tgts):
        head, new_bn, _ = sy.forward(p, b, imgs, cfg, train=True)
        return sy.yolo_loss(head, tgts), new_bn

    @jax.jit
    def step(p, b, o, imgs, tgts):
        (loss, new_bn), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            p, b, imgs, tgts
        )
        if grad_mask is not None:
            grads = jax.tree_util.tree_map(lambda g, m: g * m, grads, grad_mask)
        new_p, new_o = opt.apply_updates(p, grads, o, ocfg)
        if grad_mask is not None:
            new_p = jax.tree_util.tree_map(lambda w, m: w * m, new_p, grad_mask)
        return new_p, new_bn, new_o, loss

    source = source or dd.SyntheticSource()
    stream = source.batches(batch, hw=cfg.input_hw, steps=steps,
                            grid_div=grid_div(cfg), num_anchors=cfg.num_anchors,
                            num_classes=cfg.num_classes, start_index=start_index)
    losses = []
    for k, b in enumerate(stream):
        params, bn, opt_state, loss = step(
            params, bn, opt_state, jnp.asarray(b["image"]), jnp.asarray(b["target"])
        )
        losses.append(float(loss))
        if verbose and k % log_every == 0:
            print(f"    step {k:4d} loss {losses[-1]:8.4f}")
    return params, bn, opt_state, losses


# ---------------------------------------------------------------- pipeline --


@dataclass
class EvalReport:
    """Pipeline output: per-stage mAP, schedule comparison, accumulator."""

    stages: dict  # stage name -> evaluate_detections report
    schedules: dict  # "mixed_1_3" / "uniform_t3" -> report
    accumulator: dict
    losses: dict  # stage -> loss curve
    wall_s: float
    # the final (qat-stage) compile_eval_detector handle, kept so callers
    # (benchmarks/eval_map.py sharded-parity check) can re-score the SAME
    # weights under a different shard count without retraining
    final_det: Optional[object] = None

    @property
    def map_by_stage(self) -> dict:
        return {k: v["map"] for k, v in self.stages.items()}

    @property
    def schedule_delta(self) -> float:
        """mAP(T=3) − mAP(mixed): Fig 15 says this stays small."""
        return self.schedules["uniform_t3"]["map"] - self.schedules["mixed_1_3"]["map"]

    def summary(self) -> dict:
        return {
            "map_by_stage": self.map_by_stage,
            "per_class_ap_final": self.stages["qat"]["per_class_ap"],
            "schedule_map": {k: v["map"] for k, v in self.schedules.items()},
            "schedule_delta_map": self.schedule_delta,
            "accumulator_max_abs": self.accumulator["max_abs"],
            "accumulator_within_16b": self.accumulator["within_16b"],
            "wall_s": self.wall_s,
        }


def run_pipeline(
    cfg: Optional[sy.SNNDetConfig] = None,
    *,
    steps: int = 400,
    finetune_steps: int = 80,
    batch: int = 4,
    eval_images: int = 32,
    prune_rate: float = 0.8,
    seed: int = 0,
    conv_exec: str = "dense",
    eval_shards: int = 1,
    source: Optional[dd.DetectionSource] = None,
    ckpt_dir: Optional[str] = None,
    verbose: bool = True,
    ctx=None,
) -> EvalReport:
    """The scaled-down Table I / Fig 15 reproduction.

    Trains float, prunes, QAT-fine-tunes under the pruning mask, and
    evaluates mAP@0.5 after each stage; then compares the mixed (1, 3)
    schedule against uniform T=3 on the final weights. ``conv_exec``
    selects the executor used for the final (quantized) evaluations; the
    executors agree bit-exactly (tests/conformance/) — but ONLY under
    ``use_block_conv=True``, since gated/pallas always use block-conv
    border semantics. A compressed conv_exec therefore requires a
    block-conv config, so per-stage deltas measure compression, never a
    border-semantics mismatch against the float stages.

    ``eval_shards > 1`` routes every stage evaluation through the
    mesh-sharded path (``repro.eval.sharded``); the reduction is exact, so
    the reported numbers are bit-identical to the single-host run.

    ``source`` swaps the dataset for BOTH training and evaluation (a
    COCO/VOC :class:`~repro.data.detection_datasets.DetectionSource`;
    synthetic by default). ``ckpt_dir`` commits a self-describing detector
    checkpoint after the float-train stage (step = ``steps``) and after
    the QAT stage (step = ``steps + finetune_steps``) via
    :func:`save_detector_checkpoint`, so ``launch/serve.py --arch snn-det
    --checkpoint <dir>`` restores the latest (QAT) weights and serves
    them — the end of the "real annotations in → trained weights restored
    → served mAP out" path.
    """
    t0 = time.time()
    base = cfg if cfg is not None else demo_config()
    if conv_exec != "dense" and not base.use_block_conv:
        raise ValueError(
            f"conv_exec={conv_exec!r} evaluates with block-conv border "
            "semantics, but the float training stages would use plain SAME "
            "conv (use_block_conv=False) — the stage deltas would mix "
            "executor semantics with compression effects. Pass a config "
            "with use_block_conv=True (demo_config's block_hw=(6, 10) "
            "divides every feature map) or keep conv_exec='dense'"
        )
    float_cfg = dataclasses.replace(base, weight_bits=0, conv_exec="dense")
    quant_cfg = dataclasses.replace(base, weight_bits=8, conv_exec=conv_exec)
    stages: dict = {}
    losses: dict = {}
    sharded_cfg = eval_shards if eval_shards > 1 else None
    source = source or dd.SyntheticSource()

    def _eval(tag, c, p, b):
        det = compile_eval_detector(c, p, b)
        stages[tag] = evaluate_detector(det, n_images=eval_images,
                                        sharded=sharded_cfg, source=source,
                                        ctx=ctx)
        if verbose:
            aps = ", ".join(f"{a:.3f}" for a in stages[tag]["per_class_ap"])
            print(f"  [{tag}] mAP@0.5 {stages[tag]['map']:.3f}  (per-class {aps})")
        return det

    if verbose:
        print(f"  train {steps} steps (float, mixed (1,{base.full_t}), "
              f"dataset {source.name})")
    params, bn, opt_state, losses["train"] = train_steps(
        float_cfg, steps=steps, batch=batch, seed=seed, verbose=verbose,
        source=source,
    )
    if ckpt_dir:
        save_detector_checkpoint(ckpt_dir, steps, params, bn, float_cfg)
        if verbose:
            print(f"  saved float checkpoint (step {steps}) to {ckpt_dir}")
    _eval("trained", float_cfg, params, bn)

    pruned = pruning.prune_tree(params, prune_rate)
    _eval("pruned", float_cfg, pruned, bn)

    # QAT fine-tune: STE fake-quant weights, gradients masked to the
    # pruned support (paper fine-tunes 5 epochs after prune+quantize)
    mask = pruning.mask_tree(params, prune_rate)
    qat_train_cfg = dataclasses.replace(base, weight_bits=8, conv_exec="dense")
    if verbose:
        print(f"  QAT fine-tune {finetune_steps} steps (FXP8, masked grads)")
    qp, qbn, _, losses["qat"] = train_steps(
        qat_train_cfg, steps=finetune_steps, batch=batch, params=pruned, bn=bn,
        grad_mask=mask, lr_peak=3e-4, start_index=steps * batch,
        verbose=verbose, source=source,
    )
    if ckpt_dir:
        save_detector_checkpoint(
            ckpt_dir, steps + finetune_steps, qp, qbn, quant_cfg
        )
        if verbose:
            print(f"  saved QAT checkpoint (step {steps + finetune_steps}) "
                  f"to {ckpt_dir}")
    det = _eval("qat", quant_cfg, qp, qbn)

    # Fig 15: the same final weights under both time-step schedules
    schedules = {
        "mixed_1_3": stages["qat"],
        "uniform_t3": evaluate_detector(
            compile_eval_detector(
                dataclasses.replace(quant_cfg, mixed_time=False), qp, qbn
            ),
            n_images=eval_images,
            sharded=sharded_cfg,
            source=source,
            ctx=ctx,
        ),
    }
    report = EvalReport(
        stages=stages,
        schedules=schedules,
        accumulator=accumulator_report(det),
        losses=losses,
        wall_s=time.time() - t0,
        final_det=det,
    )
    if verbose:
        s = report.summary()
        print(f"  schedules: mixed {s['schedule_map']['mixed_1_3']:.3f} vs "
              f"T=3 {s['schedule_map']['uniform_t3']:.3f} "
              f"(delta {s['schedule_delta_map']:+.3f})")
        print(f"  accumulator max |acc| {s['accumulator_max_abs']} "
              f"(16b ok: {s['accumulator_within_16b']})  "
              f"wall {s['wall_s']:.0f}s")
    return report
