"""VOC-style detection accuracy: per-class average precision and mAP@IoU.

Pure numpy — the metric runs on host-side arrays (the batched, fixed-size
:class:`repro.models.postprocess.Detections` the serving path already
returns) so it composes with every executor and with streamed sessions
without touching the jitted graph.

Conventions match the rest of the repo:

* boxes are (cx, cy, w, h) in [0, 1] normalized image coordinates
  (``snn_yolo.decode_head`` output and ``synthetic_detection.sample``
  ground truth both use this format),
* matching is the VOC greedy rule: within an image, predictions are
  visited in descending score order; a prediction is a true positive if
  its best-IoU *unmatched* ground-truth box of the same class clears the
  IoU threshold, otherwise a false positive (duplicate detections of an
  already-matched box are FPs),
* AP is the all-points interpolated area under the precision-recall
  curve (VOC 2010+ / "continuous" definition),
* classes with zero ground-truth boxes are excluded from the mean
  (their AP is reported as NaN), matching the VOC evaluator.
"""
from __future__ import annotations

from typing import Any, Iterable, Mapping

import numpy as np


def iou_matrix_xywh(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Pairwise IoU of center-format boxes: (P, 4) × (G, 4) → (P, G)."""
    a = np.asarray(a, np.float64).reshape(-1, 4)
    b = np.asarray(b, np.float64).reshape(-1, 4)
    ax0, ay0 = a[:, 0] - a[:, 2] / 2, a[:, 1] - a[:, 3] / 2
    ax1, ay1 = a[:, 0] + a[:, 2] / 2, a[:, 1] + a[:, 3] / 2
    bx0, by0 = b[:, 0] - b[:, 2] / 2, b[:, 1] - b[:, 3] / 2
    bx1, by1 = b[:, 0] + b[:, 2] / 2, b[:, 1] + b[:, 3] / 2
    iw = np.maximum(
        np.minimum(ax1[:, None], bx1[None, :]) - np.maximum(ax0[:, None], bx0[None, :]), 0.0
    )
    ih = np.maximum(
        np.minimum(ay1[:, None], by1[None, :]) - np.maximum(ay0[:, None], by0[None, :]), 0.0
    )
    inter = iw * ih
    union = (a[:, 2] * a[:, 3])[:, None] + (b[:, 2] * b[:, 3])[None, :] - inter
    return inter / np.maximum(union, 1e-12)


def match_image(
    pred_boxes: np.ndarray,
    pred_scores: np.ndarray,
    gt_boxes: np.ndarray,
    *,
    iou_threshold: float = 0.5,
) -> np.ndarray:
    """Greedy VOC matching for ONE image and ONE class.

    Returns a bool array over predictions (in the order given): True = the
    prediction matched a previously-unmatched ground-truth box at
    IoU >= threshold. Predictions are visited in descending score order;
    ties keep the input order (stable sort).
    """
    p = np.asarray(pred_boxes, np.float64).reshape(-1, 4)
    g = np.asarray(gt_boxes, np.float64).reshape(-1, 4)
    tp = np.zeros(len(p), bool)
    if len(p) == 0 or len(g) == 0:
        return tp
    order = np.argsort(-np.asarray(pred_scores, np.float64), kind="stable")
    iou = iou_matrix_xywh(p, g)
    taken = np.zeros(len(g), bool)
    for i in order:
        j = int(np.argmax(np.where(taken, -1.0, iou[i])))
        if not taken[j] and iou[i, j] >= iou_threshold:
            taken[j] = True
            tp[i] = True
    return tp


def average_precision(scores: np.ndarray, tp: np.ndarray, n_gt: int) -> float:
    """All-points interpolated AP from pooled per-prediction match flags.

    ``scores``/``tp`` pool every prediction of one class across the whole
    split; ``n_gt`` is that class's total ground-truth count. Returns NaN
    when n_gt == 0 (class absent from the split), 0.0 when there are no
    predictions for a present class.
    """
    if n_gt == 0:
        return float("nan")
    scores = np.asarray(scores, np.float64).reshape(-1)
    tp = np.asarray(tp, bool).reshape(-1)
    if scores.size == 0:
        return 0.0
    order = np.argsort(-scores, kind="stable")
    tp = tp[order]
    cum_tp = np.cumsum(tp)
    cum_fp = np.cumsum(~tp)
    recall = cum_tp / n_gt
    precision = cum_tp / np.maximum(cum_tp + cum_fp, 1)
    # precision envelope: max precision at any recall >= r
    env = np.maximum.accumulate(precision[::-1])[::-1]
    # integrate over the recall steps
    r_prev = 0.0
    ap = 0.0
    for r, p in zip(recall, env):
        if r > r_prev:
            ap += (r - r_prev) * p
            r_prev = r
    return float(ap)


def _as_image_preds(item: Any) -> Mapping[str, np.ndarray]:
    """Accept either a dict {boxes, scores, classes} or a Detections-like
    NamedTuple (boxes, scores, classes, valid) for one image."""
    if isinstance(item, Mapping):
        return item
    boxes = np.asarray(item.boxes)
    scores = np.asarray(item.scores)
    classes = np.asarray(item.classes)
    valid = np.asarray(item.valid).astype(bool)
    return {"boxes": boxes[valid], "scores": scores[valid], "classes": classes[valid]}


def detections_to_predictions(dets) -> list:
    """Batched :class:`~repro.models.postprocess.Detections` → list of
    per-image prediction dicts (padding rows dropped)."""
    boxes = np.asarray(dets.boxes)
    scores = np.asarray(dets.scores)
    classes = np.asarray(dets.classes)
    valid = np.asarray(dets.valid).astype(bool)
    out = []
    for i in range(boxes.shape[0]):
        v = valid[i]
        out.append(
            {"boxes": boxes[i][v], "scores": scores[i][v], "classes": classes[i][v]}
        )
    return out


def evaluate_detections(
    predictions: Iterable,
    ground_truths: Iterable[Mapping[str, Any]],
    *,
    num_classes: int,
    iou_threshold: float = 0.5,
) -> dict:
    """Per-class AP + mAP over a paired (predictions, ground_truths) split.

    ``predictions``: per image, a dict {boxes (P,4), scores (P,),
    classes (P,)} or a single-image Detections. ``ground_truths``: per
    image, a dict {boxes (G,4), classes (G,)}. Images align by position
    (a mismatched pairing raises — silent truncation would shrink the
    recall denominator and INFLATE mAP instead of surfacing the bug).

    Returns {"map": float, "per_class_ap": (C,) list (NaN = class absent),
    "n_gt": (C,) list, "n_pred": (C,) list, "iou_threshold": float}.

    This IS the one-shard case of the mesh-sharded evaluator: per-image
    matching and AP pooling live in ``repro.eval.sharded`` (match_stats /
    pool_stats), so the sharded path cannot drift from this one — they are
    the same code.
    """
    from repro.eval import sharded as se  # lazy: sharded imports this module

    preds = list(predictions)
    gts = list(ground_truths)
    stats = se.match_stats(
        preds, gts, range(len(preds)),
        num_classes=num_classes, iou_threshold=iou_threshold,
    )
    report = se.pool_stats(
        [stats], num_classes=num_classes, iou_threshold=iou_threshold
    )
    # single-host surface: no sharding metadata in the report
    del report["n_shards"], report["gather"]
    return report


def map50(
    predictions: Iterable,
    ground_truths: Iterable[Mapping[str, Any]],
    *,
    num_classes: int,
) -> float:
    """mAP at IoU 0.5 (the paper's headline metric on IVS 3cls)."""
    return evaluate_detections(
        predictions, ground_truths, num_classes=num_classes, iou_threshold=0.5
    )["map"]
