"""Accuracy evaluation for the SNN detector: VOC-style mAP plus the
train→prune→QAT→evaluate harness reproducing the paper's Table I /
Fig 15 accuracy story at demo scale."""
from repro.eval import detection_map, harness, sharded  # noqa: F401
from repro.eval.detection_map import evaluate_detections, map50  # noqa: F401
from repro.eval.harness import EvalReport, evaluate_detector, run_pipeline  # noqa: F401
from repro.eval.sharded import (  # noqa: F401
    ShardedEvalConfig,
    evaluate_detector_sharded,
    evaluate_predictions_sharded,
)
