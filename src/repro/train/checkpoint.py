"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step):
    <root>/step_000123.tmp/          # staging — never read
        manifest.json                # treedef, shapes, dtypes, leaf->file
        leaf_00000.npy ...
    <root>/step_000123/              # rename-commit: readers only ever see
                                     # complete checkpoints
Design points for the 1000+ node posture:
* **Atomic**: write to `.tmp`, fsync, then `os.rename` — a crash mid-save
  can never corrupt the latest checkpoint; restore always picks the newest
  committed step.
* **Async**: `save_async` snapshots device arrays to host (blocking only on
  D2H) then writes on a background thread — training resumes immediately.
* **Sharded/elastic**: each leaf is stored as the FULL logical array
  (restore re-shards with whatever mesh/sharding the new job uses, so a
  restart on a different device count re-lowers and carries on). On a real
  multi-host pod each host writes only its addressable shards and the
  manifest stitches them; single-process here, the full-array path is the
  degenerate case of that protocol.
* **Self-describing**: manifest carries the pytree structure, so restore
  needs no template (but validates against one when given).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(root: str, step: int, tree: Any) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    paths, leaves, _ = _flatten_with_paths(tree)
    host = [np.asarray(jax.device_get(l)) for l in leaves]
    return _write(root, step, paths, host)


_PENDING: list[threading.Thread] = []


def save_async(root: str, step: int, tree: Any) -> threading.Thread:
    """Snapshot to host, then commit on a background thread."""
    paths, leaves, _ = _flatten_with_paths(tree)
    host = [np.asarray(jax.device_get(l)) for l in leaves]  # D2H barrier only
    t = threading.Thread(target=_write, args=(root, step, paths, host), daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending():
    for t in _PENDING:
        t.join()
    _PENDING.clear()


def _write(root: str, step: int, paths, host_leaves) -> str:
    final = os.path.join(root, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    for i, (p, arr) in enumerate(zip(paths, host_leaves)):
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"path": p, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # commit
    return final


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(root)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(root, d, "manifest.json"))
    ]
    return max(steps) if steps else None


def restore(root: str, template: Any, *, step: Optional[int] = None, shardings: Any = None):
    """Restore into the structure of `template`. `shardings` (optional
    pytree of NamedSharding, same structure) re-shards for the CURRENT mesh
    — this is the elastic-restart path: any device count, any layout."""
    step = step if step is not None else latest_step(root)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {root}")
    d = os.path.join(root, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    paths, leaves, treedef = _flatten_with_paths(template)
    out = []
    flat_sh = treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves)
    for p, tmpl, sh in zip(paths, leaves, flat_sh):
        e = by_path[p]
        arr = np.load(os.path.join(d, e["file"]))
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"shape mismatch at {p}: ckpt {arr.shape} vs template {tmpl.shape}")
        arr = arr.astype(tmpl.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr))
    return treedef.unflatten(out), step


def gc_old(root: str, keep: int = 3):
    """Keep the newest `keep` committed checkpoints, drop the rest."""
    if not os.path.isdir(root):
        return
    steps = sorted(
        d for d in os.listdir(root) if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(root, d), ignore_errors=True)
