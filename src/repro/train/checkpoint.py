"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step):
    <root>/step_000123.tmp/          # staging — never read
        manifest.json                # treedef, shapes, dtypes, leaf->file
        leaf_00000.npy ...
    <root>/step_000123/              # rename-commit: readers only ever see
                                     # complete checkpoints
Design points for the 1000+ node posture:
* **Atomic**: write to `.tmp`, fsync, then `os.rename` — a crash mid-save
  can never corrupt the latest checkpoint; restore always picks the newest
  committed step.
* **Async**: `save_async` snapshots device arrays to host (blocking only on
  D2H) then writes on a background thread — training resumes immediately.
* **Sharded/elastic**: each leaf is stored as the FULL logical array
  (restore re-shards with whatever mesh/sharding the new job uses, so a
  restart on a different device count re-lowers and carries on).
* **Multi-controller**: ``save(..., ctx=)`` under a multi-process launch
  stripes LEAF OWNERSHIP over hosts (leaf i -> host i % n_hosts): every
  host writes only the leaves it owns into the shared staging dir, posts a
  token-stamped ``host_N.done`` receipt, and host 0 — after collecting
  every receipt — assembles the manifest, writes the
  ``shard_manifest.json`` sidecar recording who wrote what, and performs
  the single rename-commit (the commit barrier). Leaves stay FULL logical
  arrays (non-addressable ones are collectively replicated first, in
  identical order on every host so the collectives line up), which is what
  keeps restore topology-elastic: a checkpoint saved on 2 hosts restores
  bit-exact on 1 host and vice versa.
* **Self-describing**: manifest carries the pytree structure, so restore
  needs no template (but validates against one when given).
* **Failure-surfacing**: a background write that dies (disk full, perms)
  records its exception; ``wait_pending()`` re-raises the first one, and
  ``gc_old`` joins in-flight writers before deleting their steps so
  delete can't race a rename-commit.
"""
from __future__ import annotations

import json
import os
import secrets
import shutil
import threading
import time
from dataclasses import dataclass
from typing import Any, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

_RESERVED_FILES = ("manifest.json", "shard_manifest.json", "staging.json")

# how long one host waits on the others during a sharded save before
# declaring the job wedged (a crashed peer, not a slow disk)
_HANDSHAKE_TIMEOUT_S = float(os.environ.get("REPRO_CKPT_HANDSHAKE_TIMEOUT", "120"))


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def _full_host_array(leaf) -> np.ndarray:
    """The FULL logical value of a leaf as a host array.

    Non-fully-addressable global arrays (multi-controller shardings) are
    collectively replicated first — EVERY host must therefore walk the
    leaves in the same order, or the replication collectives desync. The
    addressability predicate is a pure function of the (identical) sharding,
    so the walk stays aligned without any extra coordination."""
    if isinstance(leaf, jax.Array) and not leaf.is_fully_addressable:
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()), ("_ckpt",))
        rep = jax.jit(
            lambda a: a,
            out_shardings=jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()
            ),
        )(leaf)
        return np.asarray(rep.addressable_data(0))
    return np.asarray(jax.device_get(leaf))


def save(root: str, step: int, tree: Any, *,
         extra_files: Optional[Mapping[str, bytes]] = None,
         ctx=None) -> str:
    """Synchronous atomic save. Returns the committed directory.

    ``extra_files``: {filename: bytes} sidecars (e.g. a config json) written
    into the staging dir before the rename — they commit atomically with
    the checkpoint, so a reader never sees a step dir missing its sidecar.

    ``ctx``: the :class:`repro.distributed.runtime.DistributedContext`.
    Multi-controller: EVERY host must call save with the same arguments;
    each writes only the leaves it owns (leaf i -> host i % n_hosts) and
    host 0 performs the commit. Single-controller (the default context):
    unchanged single-writer path.
    """
    from repro.distributed import runtime

    ctx = ctx or runtime.get_context()
    paths, leaves, _ = _flatten_with_paths(tree)
    host = [_full_host_array(l) for l in leaves]
    if ctx.is_multi_controller:
        return _write_sharded(root, step, paths, host, extra_files, ctx)
    return _write(root, step, paths, host, extra_files)


@dataclass
class _PendingSave:
    """Bookkeeping for one in-flight async write: which (root, step) the
    thread is committing, and the exception it died with (if any) — daemon
    threads swallow exceptions, so without this record a failed write
    (disk full, permissions) would silently lose the checkpoint."""

    root: str
    step: int
    thread: threading.Thread
    error: Optional[BaseException] = None


_PENDING: list[_PendingSave] = []


def save_async(root: str, step: int, tree: Any, *,
               extra_files: Optional[Mapping[str, bytes]] = None) -> threading.Thread:
    """Snapshot to host, then commit on a background thread.

    A write failure is recorded on the pending entry and re-raised by the
    next :func:`wait_pending` — call it before exit (ft.Supervisor.run and
    the training examples do) or the failure is lost with the process.

    Single-controller ONLY: the sharded protocol runs replication
    collectives and a cross-host handshake, neither of which may happen on
    a background thread (collectives issued off the main thread deadlock
    against the step loop). Multi-controller jobs use :func:`save`.
    """
    from repro.distributed import runtime

    if runtime.get_context().is_multi_controller:
        raise NotImplementedError(
            "save_async is single-controller only — multi-controller jobs "
            "must use the synchronous save(..., ctx=ctx) sharded protocol"
        )
    paths, leaves, _ = _flatten_with_paths(tree)
    host = [np.asarray(jax.device_get(l)) for l in leaves]  # D2H barrier only
    pending = _PendingSave(root=os.path.abspath(root), step=step, thread=None)

    def _run():
        try:
            _write(root, step, paths, host, extra_files)
        except BaseException as e:  # noqa: BLE001 — recorded, re-raised later
            pending.error = e

    t = threading.Thread(target=_run, daemon=True)
    pending.thread = t
    t.start()
    _PENDING.append(pending)
    return t


def wait_pending():
    """Join every in-flight async save; re-raise the FIRST write failure
    (in submission order) after all writers have stopped."""
    first: Optional[_PendingSave] = None
    for p in _PENDING:
        p.thread.join()
        if p.error is not None and first is None:
            first = p
    _PENDING.clear()
    if first is not None:
        raise first.error


def _write(root: str, step: int, paths, host_leaves, extra_files=None) -> str:
    final = os.path.join(root, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    for i, (p, arr) in enumerate(zip(paths, host_leaves)):
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"path": p, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    _write_extras(tmp, extra_files)
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # commit
    return final


def _write_extras(tmp: str, extra_files) -> None:
    for fname, blob in (extra_files or {}).items():
        if (fname in _RESERVED_FILES or fname.startswith("leaf_")
                or fname.startswith("host_")):
            raise ValueError(f"extra_files name {fname!r} collides with checkpoint layout")
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(blob)


def _json_atomic(path: str, obj) -> None:
    swap = path + ".swap"
    with open(swap, "w") as f:
        json.dump(obj, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(swap, path)


def _read_json(path: str):
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return None


def _wait_for(pred, desc: str, ctx) -> None:
    deadline = time.monotonic() + _HANDSHAKE_TIMEOUT_S
    while not pred():
        if time.monotonic() > deadline:
            raise RuntimeError(
                f"[{ctx.describe()}] sharded-checkpoint handshake timed out "
                f"after {_HANDSHAKE_TIMEOUT_S:.0f}s waiting for {desc}"
            )
        time.sleep(0.05)


def _write_sharded(root: str, step: int, paths, host_leaves, extra_files, ctx) -> str:
    """Multi-controller save over a SHARED filesystem.

    Protocol (token-stamped so a retried save can never consume a previous
    attempt's receipts):
      1. host 0 resets the staging dir and posts ``staging.json`` with a
         fresh token; peers wait for it.
      2. every host writes the leaves it OWNS (leaf i -> host i % n_hosts)
         and posts a ``host_N.done`` receipt echoing the token.
      3. host 0 collects all receipts, writes ``shard_manifest.json`` (who
         wrote what), the extra sidecars and ``manifest.json``, then
         rename-commits — the commit barrier.
      4. peers wait for the committed dir to carry their token.
    Any wait gives up after REPRO_CKPT_HANDSHAKE_TIMEOUT (default 120 s)
    with the waiting host's id in the error — a crashed peer, not a hang.
    """
    final = os.path.join(root, f"step_{step:09d}")
    tmp = final + ".tmp"
    h, n = ctx.host_id, ctx.n_hosts
    staging = os.path.join(tmp, "staging.json")

    if h == 0:
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        token = secrets.token_hex(8)
        _json_atomic(staging, {"step": step, "token": token, "n_hosts": n})
    else:
        _wait_for(
            lambda: (_read_json(staging) or {}).get("step") == step,
            f"host 0 to open staging for step {step}", ctx,
        )
        token = _read_json(staging)["token"]

    owned_files = []
    for i, arr in enumerate(host_leaves):
        if i % n != h:
            continue
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        owned_files.append(fname)
    _json_atomic(
        os.path.join(tmp, f"host_{h}.done"),
        {"token": token, "host": h, "files": owned_files},
    )

    if h == 0:
        def receipts():
            got = [_read_json(os.path.join(tmp, f"host_{p}.done")) for p in range(n)]
            return all(r is not None and r.get("token") == token for r in got)

        _wait_for(receipts, "peer hosts' leaf receipts", ctx)
        shard_manifest = {
            "token": token,
            "n_hosts": n,
            "striping": "leaf i -> host i % n_hosts",
            "hosts": {
                str(p): _read_json(os.path.join(tmp, f"host_{p}.done"))["files"]
                for p in range(n)
            },
        }
        _json_atomic(os.path.join(tmp, "shard_manifest.json"), shard_manifest)
        _write_extras(tmp, extra_files)
        manifest = {"step": step, "n_hosts": n, "leaves": []}
        for i, (p, arr) in enumerate(zip(paths, host_leaves)):
            manifest["leaves"].append(
                {"path": p, "file": f"leaf_{i:05d}.npy",
                 "shape": list(arr.shape), "dtype": str(arr.dtype),
                 "host": i % n}
            )
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # commit barrier: peers unblock on this
    else:
        _wait_for(
            lambda: (_read_json(os.path.join(final, "shard_manifest.json")) or {})
            .get("token") == token,
            f"host 0 to commit step {step}", ctx,
        )
    return final


def _committed_steps(root: str) -> list[int]:
    """Step numbers of COMMITTED checkpoints: a ``step_*`` dir that is not
    a ``.tmp`` staging dir, parses as a step, and holds a manifest. The ONE
    predicate shared by latest_step and gc_old — junk dirs (crashed
    writers, stray files) are invisible to both."""
    if not os.path.isdir(root):
        return []
    steps = []
    for d in os.listdir(root):
        if not d.startswith("step_") or d.endswith(".tmp"):
            continue
        try:
            step = int(d.split("_")[1])
        except (IndexError, ValueError):
            continue
        if os.path.exists(os.path.join(root, d, "manifest.json")):
            steps.append(step)
    return sorted(steps)


def latest_step(root: str) -> Optional[int]:
    steps = _committed_steps(root)
    return max(steps) if steps else None


def read_extra_file(root: str, fname: str, *, step: Optional[int] = None) -> bytes:
    """Read back an ``extra_files`` sidecar from a committed checkpoint
    (latest step by default). Raises FileNotFoundError if the step or the
    sidecar does not exist — a committed step dir can legally lack any
    given sidecar (only the manifest is guaranteed)."""
    step = step if step is not None else latest_step(root)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {root}")
    path = os.path.join(root, f"step_{step:09d}", fname)
    with open(path, "rb") as f:
        return f.read()


def restore(root: str, template: Any, *, step: Optional[int] = None, shardings: Any = None):
    """Restore into the structure of `template`. `shardings` (optional
    pytree of NamedSharding, same structure) re-shards for the CURRENT mesh
    — this is the elastic-restart path: any device count, any layout."""
    step = step if step is not None else latest_step(root)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {root}")
    d = os.path.join(root, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    paths, leaves, treedef = _flatten_with_paths(template)
    missing = [p for p in paths if p not in by_path]
    if missing:
        # a config/checkpoint mismatch, not a corrupt file: say exactly
        # which leaves each side has that the other doesn't
        extra = sorted(set(by_path) - set(paths))
        raise ValueError(
            f"checkpoint {d} does not match the restore template: "
            f"template leaves missing from the checkpoint: {missing}; "
            f"checkpoint leaves absent from the template: {extra or '[]'} "
            "— the config that built the template differs from the one "
            "that saved the checkpoint"
        )
    out = []
    flat_sh = treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves)
    for p, tmpl, sh in zip(paths, leaves, flat_sh):
        e = by_path[p]
        arr = np.load(os.path.join(d, e["file"]))
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"shape mismatch at {p}: ckpt {arr.shape} vs template {tmpl.shape}")
        arr = arr.astype(tmpl.dtype)
        if sh is not None and not getattr(sh, "is_fully_addressable", True):
            # multi-controller sharding: device_put cannot build an array
            # spanning other hosts' devices — materialize per-shard from
            # the full host copy (every host read the same file)
            out.append(
                jax.make_array_from_callback(arr.shape, sh, lambda idx, a=arr: a[idx])
            )
        elif sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return treedef.unflatten(out), step


def gc_old(root: str, keep: int = 3):
    """Keep the newest ``keep`` COMMITTED checkpoints, drop the rest.

    Only committed dirs (the :func:`latest_step` predicate) count toward
    ``keep`` and only committed dirs are deleted — an uncommitted junk dir
    (no manifest) used to consume a keep slot and evict a real checkpoint,
    and a live writer's ``.tmp`` staging dir must never be touched. Before
    deleting a step, any in-flight :func:`save_async` writer for that step
    is joined, so the delete cannot race the writer's rename-commit (which
    would resurrect a just-deleted step as a stale "newest" checkpoint).
    """
    committed = _committed_steps(root)
    doomed = committed[:-keep] if keep > 0 else committed
    if not doomed:
        return
    doomed_set = set(doomed)
    root_abs = os.path.abspath(root)
    for p in list(_PENDING):
        if p.root == root_abs and p.step in doomed_set:
            p.thread.join()
    for step in doomed:
        shutil.rmtree(os.path.join(root, f"step_{step:09d}"), ignore_errors=True)
