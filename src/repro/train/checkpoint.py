"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step):
    <root>/step_000123.tmp/          # staging — never read
        manifest.json                # treedef, shapes, dtypes, leaf->file
        leaf_00000.npy ...
    <root>/step_000123/              # rename-commit: readers only ever see
                                     # complete checkpoints
Design points for the 1000+ node posture:
* **Atomic**: write to `.tmp`, fsync, then `os.rename` — a crash mid-save
  can never corrupt the latest checkpoint; restore always picks the newest
  committed step.
* **Async**: `save_async` snapshots device arrays to host (blocking only on
  D2H) then writes on a background thread — training resumes immediately.
* **Sharded/elastic**: each leaf is stored as the FULL logical array
  (restore re-shards with whatever mesh/sharding the new job uses, so a
  restart on a different device count re-lowers and carries on). On a real
  multi-host pod each host writes only its addressable shards and the
  manifest stitches them; single-process here, the full-array path is the
  degenerate case of that protocol.
* **Self-describing**: manifest carries the pytree structure, so restore
  needs no template (but validates against one when given).
* **Failure-surfacing**: a background write that dies (disk full, perms)
  records its exception; ``wait_pending()`` re-raises the first one, and
  ``gc_old`` joins in-flight writers before deleting their steps so
  delete can't race a rename-commit.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from dataclasses import dataclass
from typing import Any, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

_RESERVED_FILES = ("manifest.json",)


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(root: str, step: int, tree: Any, *,
         extra_files: Optional[Mapping[str, bytes]] = None) -> str:
    """Synchronous atomic save. Returns the committed directory.

    ``extra_files``: {filename: bytes} sidecars (e.g. a config json) written
    into the staging dir before the rename — they commit atomically with
    the checkpoint, so a reader never sees a step dir missing its sidecar.
    """
    paths, leaves, _ = _flatten_with_paths(tree)
    host = [np.asarray(jax.device_get(l)) for l in leaves]
    return _write(root, step, paths, host, extra_files)


@dataclass
class _PendingSave:
    """Bookkeeping for one in-flight async write: which (root, step) the
    thread is committing, and the exception it died with (if any) — daemon
    threads swallow exceptions, so without this record a failed write
    (disk full, permissions) would silently lose the checkpoint."""

    root: str
    step: int
    thread: threading.Thread
    error: Optional[BaseException] = None


_PENDING: list[_PendingSave] = []


def save_async(root: str, step: int, tree: Any, *,
               extra_files: Optional[Mapping[str, bytes]] = None) -> threading.Thread:
    """Snapshot to host, then commit on a background thread.

    A write failure is recorded on the pending entry and re-raised by the
    next :func:`wait_pending` — call it before exit (ft.Supervisor.run and
    the training examples do) or the failure is lost with the process.
    """
    paths, leaves, _ = _flatten_with_paths(tree)
    host = [np.asarray(jax.device_get(l)) for l in leaves]  # D2H barrier only
    pending = _PendingSave(root=os.path.abspath(root), step=step, thread=None)

    def _run():
        try:
            _write(root, step, paths, host, extra_files)
        except BaseException as e:  # noqa: BLE001 — recorded, re-raised later
            pending.error = e

    t = threading.Thread(target=_run, daemon=True)
    pending.thread = t
    t.start()
    _PENDING.append(pending)
    return t


def wait_pending():
    """Join every in-flight async save; re-raise the FIRST write failure
    (in submission order) after all writers have stopped."""
    first: Optional[_PendingSave] = None
    for p in _PENDING:
        p.thread.join()
        if p.error is not None and first is None:
            first = p
    _PENDING.clear()
    if first is not None:
        raise first.error


def _write(root: str, step: int, paths, host_leaves, extra_files=None) -> str:
    final = os.path.join(root, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    for i, (p, arr) in enumerate(zip(paths, host_leaves)):
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"path": p, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    for fname, blob in (extra_files or {}).items():
        if fname in _RESERVED_FILES or fname.startswith("leaf_"):
            raise ValueError(f"extra_files name {fname!r} collides with checkpoint layout")
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(blob)
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # commit
    return final


def _committed_steps(root: str) -> list[int]:
    """Step numbers of COMMITTED checkpoints: a ``step_*`` dir that is not
    a ``.tmp`` staging dir, parses as a step, and holds a manifest. The ONE
    predicate shared by latest_step and gc_old — junk dirs (crashed
    writers, stray files) are invisible to both."""
    if not os.path.isdir(root):
        return []
    steps = []
    for d in os.listdir(root):
        if not d.startswith("step_") or d.endswith(".tmp"):
            continue
        try:
            step = int(d.split("_")[1])
        except (IndexError, ValueError):
            continue
        if os.path.exists(os.path.join(root, d, "manifest.json")):
            steps.append(step)
    return sorted(steps)


def latest_step(root: str) -> Optional[int]:
    steps = _committed_steps(root)
    return max(steps) if steps else None


def read_extra_file(root: str, fname: str, *, step: Optional[int] = None) -> bytes:
    """Read back an ``extra_files`` sidecar from a committed checkpoint
    (latest step by default). Raises FileNotFoundError if the step or the
    sidecar does not exist — a committed step dir can legally lack any
    given sidecar (only the manifest is guaranteed)."""
    step = step if step is not None else latest_step(root)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {root}")
    path = os.path.join(root, f"step_{step:09d}", fname)
    with open(path, "rb") as f:
        return f.read()


def restore(root: str, template: Any, *, step: Optional[int] = None, shardings: Any = None):
    """Restore into the structure of `template`. `shardings` (optional
    pytree of NamedSharding, same structure) re-shards for the CURRENT mesh
    — this is the elastic-restart path: any device count, any layout."""
    step = step if step is not None else latest_step(root)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {root}")
    d = os.path.join(root, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    paths, leaves, treedef = _flatten_with_paths(template)
    missing = [p for p in paths if p not in by_path]
    if missing:
        # a config/checkpoint mismatch, not a corrupt file: say exactly
        # which leaves each side has that the other doesn't
        extra = sorted(set(by_path) - set(paths))
        raise ValueError(
            f"checkpoint {d} does not match the restore template: "
            f"template leaves missing from the checkpoint: {missing}; "
            f"checkpoint leaves absent from the template: {extra or '[]'} "
            "— the config that built the template differs from the one "
            "that saved the checkpoint"
        )
    out = []
    flat_sh = treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves)
    for p, tmpl, sh in zip(paths, leaves, flat_sh):
        e = by_path[p]
        arr = np.load(os.path.join(d, e["file"]))
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"shape mismatch at {p}: ckpt {arr.shape} vs template {tmpl.shape}")
        arr = arr.astype(tmpl.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr))
    return treedef.unflatten(out), step


def gc_old(root: str, keep: int = 3):
    """Keep the newest ``keep`` COMMITTED checkpoints, drop the rest.

    Only committed dirs (the :func:`latest_step` predicate) count toward
    ``keep`` and only committed dirs are deleted — an uncommitted junk dir
    (no manifest) used to consume a keep slot and evict a real checkpoint,
    and a live writer's ``.tmp`` staging dir must never be touched. Before
    deleting a step, any in-flight :func:`save_async` writer for that step
    is joined, so the delete cannot race the writer's rename-commit (which
    would resurrect a just-deleted step as a stale "newest" checkpoint).
    """
    committed = _committed_steps(root)
    doomed = committed[:-keep] if keep > 0 else committed
    if not doomed:
        return
    doomed_set = set(doomed)
    root_abs = os.path.abspath(root)
    for p in list(_PENDING):
        if p.root == root_abs and p.step in doomed_set:
            p.thread.join()
    for step in doomed:
        shutil.rmtree(os.path.join(root, f"step_{step:09d}"), ignore_errors=True)
