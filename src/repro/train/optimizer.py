"""AdamW with the paper's training recipe (§IV-A: warmup 1e-5→1e-4 over the
first 5 epochs, decay to 1e-6, weight decay 1e-3) plus the distributed-
optimization features the large assigned archs need:

* **ZeRO sharding by axes**: optimizer moments carry the same logical axes
  as their parameters PLUS the 'opt_state' convention — the rules table maps
  them so m/v are sharded at least over 'data' (ZeRO-1); with cfg.fsdp the
  params themselves are ZeRO-3 sharded and moments follow.
* **Int8 moments** (block-wise scales) — the paper's FXP8 philosophy applied
  to optimizer state: m/v stored int8 with one f32 scale per 256-block,
  4x smaller than f32 moments. This is what lets llama3-405b fit the
  single-pod 256-chip mesh (napkin math in EXPERIMENTS.md §Dry-run).
* **Error-feedback int8 gradient compression** for the DP all-reduce
  (distributed/collectives.py applies it around the reduction).

Pure-pytree implementation (no optax dependency): state is a pytree with
the same structure as params, jit/shard-friendly.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

# ------------------------------------------------------- int8 moment codec --
# SHAPE-PRESERVING int8 with one f32 scale per last-dim row. An earlier
# design packed moments as flat (nblocks, 256) sharded over all mesh axes;
# the reshape from the parameter's (model/data)-sharded layout to the flat
# layout is exactly what GSPMD cannot repartition — it replicates the full
# f32 tensor as "involuntary full rematerialization" (measured: +1.8 TB/chip
# temps and 58 TB/chip of all-gathers on llama3-405b train_4k; EXPERIMENTS.md
# §Perf). Keeping the parameter's shape means the int8 payload inherits the
# parameter's sharding verbatim: zero resharding, ZeRO-sharded by
# construction wherever the param is.


def _q8_pack(x: jax.Array):
    """f32 leaf -> (int8 payload same shape, f32 scale per last-dim row)."""
    scale = jnp.max(jnp.abs(x), axis=-1) / 127.0 if x.ndim else jnp.abs(x) / 127.0
    q = jnp.round(x / jnp.maximum(scale[..., None] if x.ndim else scale, 1e-12))
    return q.astype(jnp.int8), scale


def _q8_unpack(q: jax.Array, scale: jax.Array, shape=None, dtype=jnp.float32):
    s = scale[..., None] if q.ndim else scale
    return q.astype(dtype) * s


class Q8Leaf(NamedTuple):
    q: jax.Array  # int8, same shape as the parameter
    scale: jax.Array  # f32, param shape minus the last dim


class AdamWState(NamedTuple):
    step: jax.Array  # i32 scalar
    m: Any  # pytree of f32 leaves or Q8Leaf
    v: Any


class AdamWConfig(NamedTuple):
    lr_peak: float = 1e-4
    lr_init: float = 1e-5
    lr_final: float = 1e-6
    warmup_steps: int = 500
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 1e-3
    grad_clip: float = 1.0
    int8_moments: bool = False


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Paper §IV-A: linear warmup lr_init→lr_peak, cosine decay →lr_final."""
    step = step.astype(jnp.float32)
    warm = cfg.lr_init + (cfg.lr_peak - cfg.lr_init) * step / max(cfg.warmup_steps, 1)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    decay = cfg.lr_final + 0.5 * (cfg.lr_peak - cfg.lr_final) * (1 + jnp.cos(np.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, decay)


def init_state(params: Any, cfg: AdamWConfig) -> AdamWState:
    def zero_like(p):
        if cfg.int8_moments:
            return Q8Leaf(
                jnp.zeros(p.shape, jnp.int8),
                jnp.zeros(p.shape[:-1] if p.ndim else p.shape, jnp.float32),
            )
        return jnp.zeros(p.shape, jnp.float32)

    leaves = jax.tree_util.tree_map(zero_like, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=leaves, v=leaves)


def state_axes(param_axes_tree: Any, cfg: AdamWConfig) -> AdamWState:
    """Logical axes for the optimizer state: moments mirror their
    parameter's axes exactly (int8 payload same shape; its per-row scale
    drops the last axis) — so the state is ZeRO-sharded wherever the param
    is, with no cross-shard reshapes."""

    def mom_axes(axes):
        if cfg.int8_moments:
            return Q8Leaf(q=axes, scale=axes[:-1] if len(axes) else axes)
        return axes

    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x
    )
    leaves = jax.tree_util.tree_map(mom_axes, param_axes_tree, is_leaf=is_axes)
    return AdamWState(step=(), m=leaves, v=leaves)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def apply_updates(params: Any, grads: Any, state: AdamWState, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state)."""
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    # int8 moments: the second moment is stored in the SQRT domain —
    # linear int8 with a per-row max scale flushes v < max_v/127 to zero,
    # and m/(sqrt(0)+1e-8) explodes (observed: smoke training diverged).
    # sqrt-domain storage keeps entries down to max_v/16129, and the eps
    # floor is raised to the quantization noise level.
    eps = max(cfg.eps, 1e-5) if cfg.int8_moments else cfg.eps

    def upd(p, g, m_leaf, v_leaf):
        g = g.astype(jnp.float32) * clip
        if cfg.int8_moments:
            m = _q8_unpack(m_leaf.q, m_leaf.scale)
            v = jnp.square(_q8_unpack(v_leaf.q, v_leaf.scale))
        else:
            m, v = m_leaf, v_leaf
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if cfg.int8_moments:
            return new_p, Q8Leaf(*_q8_pack(m)), Q8Leaf(*_q8_pack(jnp.sqrt(v)))
        return new_p, m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    is_mom = lambda x: isinstance(x, Q8Leaf) or not isinstance(x, (dict, list, tuple))
    flat_m = jax.tree_util.tree_leaves(state.m, is_leaf=lambda x: isinstance(x, Q8Leaf))
    flat_v = jax.tree_util.tree_leaves(state.v, is_leaf=lambda x: isinstance(x, Q8Leaf))
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    mom_def = jax.tree_util.tree_structure(
        state.m, is_leaf=lambda x: isinstance(x, Q8Leaf)
    )
    new_m = jax.tree_util.tree_unflatten(mom_def, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(mom_def, [o[2] for o in out])
    return new_params, AdamWState(step=step, m=new_m, v=new_v)


# --------------------------------------- int8 error-feedback grad compress --


class EFState(NamedTuple):
    """Error-feedback residual for int8 gradient compression."""

    residual: Any  # pytree like grads, f32


def ef_init(params: Any) -> EFState:
    return EFState(
        residual=jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    )


def compress_decompress(g: jax.Array, res: jax.Array):
    """Simulated int8 all-reduce payload with error feedback: the value that
    the collective actually moves is int8; the quantization error is carried
    to the next step. Returns (g_hat, new_res)."""
    x = g.astype(jnp.float32) + res
    q, scale = _q8_pack(x)
    x_hat = _q8_unpack(q, scale, x.shape)
    return x_hat, x - x_hat
