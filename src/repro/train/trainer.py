"""Train-step factory: value_and_grad + microbatch gradient accumulation +
AdamW, with logical-axis sharding applied at the jit boundary.

Gradient accumulation keeps the activation working set to ONE microbatch
(the scan's carry is only the f32 grad accumulator), which is what makes
train_4k at global_batch=256 fit for the 100B+ archs. The grads the DP
all-reduce moves can optionally be int8 error-feedback compressed.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.train import optimizer as opt


class TrainState(NamedTuple):
    params: Any
    opt: opt.AdamWState
    ef: Optional[opt.EFState]  # error-feedback residual (None = off)


def init_train_state(params, ocfg: opt.AdamWConfig, *, grad_compress: bool = False):
    return TrainState(
        params=params,
        opt=opt.init_state(params, ocfg),
        ef=opt.ef_init(params) if grad_compress else None,
    )


def train_state_axes(param_axes: Any, ocfg: opt.AdamWConfig, *, grad_compress: bool = False):
    return TrainState(
        params=param_axes,
        opt=opt.state_axes(param_axes, ocfg),
        ef=opt.EFState(residual=param_axes) if grad_compress else None,
    )


def _split_microbatches(batch: dict, n_mb: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % n_mb == 0, (b, n_mb)
        return x.reshape(n_mb, b // n_mb, *x.shape[1:])

    return jax.tree_util.tree_map(split, batch)


def make_train_step(
    loss_fn: Callable,
    ocfg: opt.AdamWConfig,
    *,
    n_microbatch: int = 1,
    grad_compress: bool = False,
    grad_shardings: Any = None,
    batch_sharding: Any = None,
):
    """Returns train_step(state, batch) -> (new_state, metrics).

    grad_shardings: optional pytree of NamedShardings (same structure as
    params). Without it XLA's sharding propagation tends to leave gradients
    REPLICATED over the data axis (the batch psum produces a replicated
    value), which for a ZeRO-3 405B config blows per-chip memory by the DP
    degree. Constraining each (accumulated) gradient to its parameter's
    sharding turns the psum into a reduce-scatter — ZeRO-2 gradient
    sharding. Measured effect in EXPERIMENTS.md §Perf (llama3-405b
    train_4k: 1731 GB/chip -> fits).

    batch_sharding: optional NamedSharding (applied to every batch leaf)
    pinning the batch's leading dim to the context's data axis. Under a
    multi-controller launch each host feeds its own stripe of the global
    batch; this constraint makes the gradient psum over the batch axis a
    REAL cross-host collective rather than whatever placement propagation
    guesses from the input arrays.
    """

    def constrain_b(batch):
        if batch_sharding is None:
            return batch
        return jax.tree_util.tree_map(
            lambda x: jax.lax.with_sharding_constraint(x, batch_sharding), batch
        )

    def constrain_g(grads):
        if grad_shardings is None:
            return grads
        return jax.tree_util.tree_map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads,
            grad_shardings,
        )

    def grads_of(params, batch):
        # Constraining params at the loss entry is a forward no-op (they
        # already carry this sharding) but its TRANSPOSE constrains each
        # parameter's cotangent AT THE POINT IT IS PRODUCED inside the
        # backward scan — without it XLA materializes replicated f32 layer
        # grads (405B: 1.6 TB/chip) before any outer reshard can help.
        def shloss(p, b):
            return loss_fn(constrain_g(p), b)

        return jax.value_and_grad(shloss)(params, batch)

    def train_step(state: TrainState, batch: dict):
        params = state.params
        batch = constrain_b(batch)
        if n_microbatch == 1:
            loss, grads = grads_of(params, batch)
            grads = constrain_g(grads)
        else:
            mbs = _split_microbatches(batch, n_microbatch)

            def body(acc, mb):
                g_acc, l_acc = acc
                l, g = grads_of(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (constrain_g(g_acc), l_acc + l), None

            g0 = constrain_g(
                jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
            )
            (grads, loss), _ = jax.lax.scan(body, (g0, jnp.zeros(())), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / n_microbatch, grads)
            loss = loss / n_microbatch

        new_ef = None
        if grad_compress and state.ef is not None:
            pairs = jax.tree_util.tree_map(
                opt.compress_decompress, grads, state.ef.residual
            )
            is_pair = lambda x: isinstance(x, tuple) and len(x) == 2 and hasattr(x[0], "shape")
            grads = jax.tree_util.tree_map(lambda pr: pr[0], pairs, is_leaf=is_pair)
            new_ef = opt.EFState(
                residual=jax.tree_util.tree_map(lambda pr: pr[1], pairs, is_leaf=is_pair)
            )

        new_params, new_opt = opt.apply_updates(params, grads, state.opt, ocfg)
        metrics = {
            "loss": loss.astype(jnp.float32),
            "grad_norm": opt.global_norm(grads),
            "lr": opt.lr_schedule(ocfg, new_opt.step),
            "step": new_opt.step,
        }
        return TrainState(params=new_params, opt=new_opt, ef=new_ef), metrics

    return train_step
