"""Fault tolerance for the training launcher: heartbeat-supervised step
loop, bounded-retry restart from the last committed checkpoint, and
straggler mitigation hooks.

On a real multi-pod deployment the supervisor runs per-host and the
coordinator aggregates heartbeats over the cluster fabric; the JAX side
stays identical (restore → re-lower → continue), which is what this module
demonstrates end-to-end on CPU. Elastic re-meshing is exercised by
restoring onto a different device count (tests/test_checkpoint.py).

Components:
* Heartbeat — a monotonic progress file (step + wall time) the supervisor
  watches; a stalled heartbeat == hung/dead worker.
* Supervisor.run — bounded-retry loop: run the step function; on ANY
  exception (simulated node failure) restore from the last good checkpoint
  and continue; give up after max_restarts.
* StragglerMonitor — per-step duration EWMA; steps slower than
  `threshold x` the EWMA are flagged. At scale the launcher uses this to
  request re-scheduling of the slow host (here: recorded + surfaced in
  metrics; the dry-run records the hook's existence, the policy is
  deployment-specific).
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.train import checkpoint as ckpt


class Heartbeat:
    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def beat(self, step: int, **extra):
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": time.time(), **extra}, f)
        os.replace(tmp, self.path)

    def last(self) -> Optional[dict]:
        try:
            with open(self.path) as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def stalled(self, timeout_s: float) -> bool:
        last = self.last()
        return last is None or (time.time() - last["time"]) > timeout_s


@dataclass
class StragglerMonitor:
    """EWMA step-time tracker; flags steps slower than threshold x EWMA."""

    alpha: float = 0.1
    threshold: float = 2.0
    ewma: Optional[float] = None
    flagged: list = field(default_factory=list)

    def observe(self, step: int, duration_s: float) -> bool:
        is_straggler = self.ewma is not None and duration_s > self.threshold * self.ewma
        self.ewma = (
            duration_s
            if self.ewma is None
            else (1 - self.alpha) * self.ewma + self.alpha * duration_s
        )
        if is_straggler:
            self.flagged.append((step, duration_s, self.ewma))
        return is_straggler


@dataclass
class Supervisor:
    """Bounded-retry restart-from-last-good training supervisor.

    ``ctx``: the :class:`repro.distributed.runtime.DistributedContext` —
    under a multi-controller launch checkpoint saves go through the sharded
    protocol, garbage collection runs on host 0 only, and a run that gives
    up re-raises with THIS host's id in the message so multi-process CI
    failures are attributable to their origin."""

    ckpt_root: str
    max_restarts: int = 3
    save_every: int = 50
    keep: int = 3
    heartbeat: Optional[Heartbeat] = None
    straggler: StragglerMonitor = field(default_factory=StragglerMonitor)
    restarts: int = 0
    ctx: Any = None

    def _context(self):
        if self.ctx is None:
            from repro.distributed import runtime

            self.ctx = runtime.get_context()
        return self.ctx

    def _give_up(self, e: Exception):
        """Re-raise with the failing host's coordinates prepended —
        keeping the original exception type when its constructor allows,
        so callers matching on type (tests, retry policies) still do."""
        msg = f"[{self._context().describe()}] training gave up after " \
              f"{self.restarts - 1} restarts: {e}"
        try:
            exc = type(e)(msg)
        except Exception:  # noqa: BLE001 — exotic exception signature
            exc = RuntimeError(msg)
        raise exc from e

    def run(
        self,
        *,
        init_state: Callable[[], Any],
        state_template: Callable[[], Any],
        step_fn: Callable[[Any, int], Any],  # (state, step) -> state
        n_steps: int,
        shardings: Any = None,
    ):
        """Run n_steps with checkpoint/restart. step_fn raising == node
        failure; we restore and continue until max_restarts is exhausted."""
        start = ckpt.latest_step(self.ckpt_root)
        if start is not None:
            state, start = ckpt.restore(
                self.ckpt_root, state_template(), shardings=shardings
            )
            start += 1
        else:
            state, start = init_state(), 0

        step = start
        while step < n_steps:
            try:
                t0 = time.time()
                state = step_fn(state, step)
                self.straggler.observe(step, time.time() - t0)
                if self.heartbeat:
                    self.heartbeat.beat(step)
                if (step + 1) % self.save_every == 0 or step + 1 == n_steps:
                    ctx = self._context()
                    ckpt.save(self.ckpt_root, step, state, ctx=ctx)
                    if ctx.host_id == 0:
                        ckpt.gc_old(self.ckpt_root, keep=self.keep)
                step += 1
            except Exception as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    self._give_up(e)
                # settle in-flight async saves before picking the restore
                # point; a failed write re-raises here instead of being
                # silently dropped by the restart
                ckpt.wait_pending()
                last = ckpt.latest_step(self.ckpt_root)
                if last is None:
                    state, step = init_state(), 0
                else:
                    state, last = ckpt.restore(
                        self.ckpt_root, state_template(), shardings=shardings
                    )
                    step = last + 1
        # joins every async writer AND re-raises the first failed write —
        # the run is not "done" until its checkpoints are durably committed
        ckpt.wait_pending()
        return state
