from repro.train import checkpoint, ft, optimizer, trainer  # noqa: F401
