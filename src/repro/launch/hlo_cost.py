"""Trip-count-aware cost accounting over optimized (per-device) HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts every ``while`` body
exactly ONCE, so anything under a ``lax.scan`` (layer scan, microbatch
accumulation, chunked attention) is undercounted by its trip count — for a
126-layer scanned model that is a 126x error. This module re-derives

    * flops            (dot / convolution exact; elementwise ~1 flop/elem)
    * hbm bytes        (per fusion/op: operands + result — the fusion
                         boundary is XLA's memory-traffic boundary)
    * collective bytes (all-gather / all-reduce / reduce-scatter /
                         all-to-all / collective-permute result bytes)

by walking the HLO computation graph with while-loop trip counts parsed
from each loop's condition (`compare(iv, constant), direction=LT`), and
multiplying nested loops through. Used by launch/dryrun.py for the roofline
terms; validated against unrolled references in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# "%name = TYPE op-name(operands...), attrs" | names may be unsuffixed with %
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_CALL_ATTR_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")

_ELEMWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "compare", "select", "and", "or", "xor", "not", "clamp", "floor",
    "ceil", "sign", "cosine", "sine", "logistic", "remainder", "atan2",
    "exponential-minus-one", "log-plus-one", "cbrt", "round-nearest-afz",
    "round-nearest-even", "erf",
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shapes_in(text: str) -> List[Tuple[str, List[int]]]:
    return [
        (dt, [int(d) for d in dims.split(",") if d])
        for dt, dims in _SHAPE_RE.findall(text)
        if dt in _DTYPE_BYTES
    ]


def _nbytes(shapes) -> int:
    return sum(
        _DTYPE_BYTES[dt] * (1 if not dims else _prod(dims)) for dt, dims in shapes
    )


def _nelems(shapes) -> int:
    return sum(1 if not dims else _prod(dims) for dt, dims in shapes)


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= x
    return out


@dataclass
class _Op:
    name: str
    kind: str
    result_shapes: list
    operand_names: list
    called: list  # computation names (body/cond/calls/to_apply)
    attrs: str


@dataclass
class _Computation:
    name: str
    ops: Dict[str, _Op] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.coll_bytes += other.coll_bytes
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(
            self.flops * m,
            self.bytes * m,
            self.coll_bytes * m,
            {k: v * m for k, v in self.coll_by_kind.items()},
        )


_OPNAME_RE = re.compile(r"^\s*([\w\-]+)\(")


def parse_module(hlo: str):
    """-> (computations dict, entry name)."""
    comps: Dict[str, _Computation] = {}
    entry = None
    cur: Optional[_Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        # computation header: "%name (args) -> type {" or "ENTRY %name ..."
        if stripped.endswith("{") and ("(" in stripped) and "=" not in stripped.split("(")[0]:
            m = re.match(r"(ENTRY\s+)?%?([\w\.\-]+)\s*(?:\()", stripped)
            if m:
                cur = _Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, rhs = dm.group(1), dm.group(2)
        # result type(s): everything before the op name token
        om = re.search(r"([\w\-]+)\(", rhs)
        if not om:
            continue
        # the op name is the LAST bare token before '(' that is not a type
        head = rhs[: om.start()]
        kind = om.group(1)
        result_shapes = _shapes_in(head)
        # operand names: %refs inside the first paren group
        args = rhs[om.end():]
        depth = 1
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    attrs = args[i + 1:]
                    args = args[:i]
                    break
        else:
            attrs = ""
        operands = re.findall(r"%([\w\.\-]+)", args)
        called = _CALL_ATTR_RE.findall(attrs)
        op = _Op(name, kind, result_shapes, operands, called, attrs)
        cur.ops[name] = op
        cur.order.append(name)
    return comps, entry


def _dot_flops(op: _Op, comp: _Computation) -> float:
    """2 x prod(result) x prod(contracting dims of lhs)."""
    out_elems = _nelems(op.result_shapes)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    lhs = comp.ops.get(op.operand_names[0]) if op.operand_names else None
    if m and lhs and lhs.result_shapes:
        dims = [int(d) for d in m.group(1).split(",") if d]
        lhs_shape = lhs.result_shapes[0][1]
        k = _prod([lhs_shape[d] for d in dims if d < len(lhs_shape)]) or 1
    else:
        k = 1
    return 2.0 * out_elems * k


def _conv_flops(op: _Op, comp: _Computation) -> float:
    out_elems = _nelems(op.result_shapes)
    rhs = comp.ops.get(op.operand_names[1]) if len(op.operand_names) > 1 else None
    if rhs and rhs.result_shapes:
        # kernel (spatial..., cin, cout): flops = 2*out*prod(kernel)/cout
        kshape = rhs.result_shapes[0][1]
        cout = kshape[-1] if kshape else 1
        k = _prod(kshape) // max(cout, 1)
    else:
        k = 1
    gm = re.search(r"feature_group_count=(\d+)", op.attrs)
    g = int(gm.group(1)) if gm else 1
    return 2.0 * out_elems * k / g


def _trip_count(cond: _Computation) -> int:
    """lax.scan lowers to while(iv < N): find the compare-LT constant."""
    const_vals = {}
    for name in cond.order:
        op = cond.ops[name]
        if op.kind == "constant":
            m = re.search(r"constant\((-?\d+)\)", op.attrs) or re.search(
                r"\((-?\d+)\)", op.name
            )
            # constant value lives in the original text; _Op doesn't keep it,
            # so re-derive from attrs if present
            if m:
                const_vals[name] = int(m.group(1))
    # constants print as: %c = s32[] constant(126) — the value landed in
    # `args` (operand slot) during parsing; fall back to attrs scan above.
    for name in cond.order:
        op = cond.ops[name]
        if op.kind == "compare" and "direction=LT" in op.attrs:
            for o in op.operand_names:
                if o in const_vals:
                    return max(const_vals[o], 1)
    return 1


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_module(hlo_text)
        # constants: re-parse values (parse_module drops them) — walk text once
        self._const_fix(hlo_text)
        self._memo: Dict[str, Cost] = {}

    def _const_fix(self, hlo: str):
        # record constant integer values as pseudo-attrs for trip counting
        for m in re.finditer(r"%?([\w\.\-]+)\s*=\s*s(?:32|64)\[\]\s*constant\((-?\d+)\)", hlo):
            name, val = m.group(1), m.group(2)
            for comp in self.comps.values():
                if name in comp.ops and comp.ops[name].kind == "constant":
                    comp.ops[name].attrs += f" constant({val})"

    # ------------------------------------------------------------------
    def cost(self, comp_name: Optional[str] = None) -> Cost:
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps[comp_name]
        total = Cost()
        for name in comp.order:
            op = comp.ops[name]
            total += self._op_cost(op, comp)
        self._memo[comp_name] = total
        return total

    def _op_cost(self, op: _Op, comp: _Computation) -> Cost:
        k = op.kind
        if k in ("parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "after-all", "iota", "partition-id", "replica-id"):
            return Cost()
        if k == "while":
            # XLA annotates statically-known loop bounds on the while op:
            #   backend_config={"known_trip_count":{"n":"126"}, ...}
            tm = re.search(r'known_trip_count[^}]*"n"\s*:\s*"(\d+)"', op.attrs)
            if tm:
                trips = int(tm.group(1))
            else:  # fallback: compare-LT constant in the condition
                cond_comp = None
                for cn in op.called:
                    if cn in self.comps and any(
                        o.kind == "compare" or "compare" in o.kind
                        for o in self.comps[cn].ops.values()
                    ):
                        cond_comp = self.comps[cn]
                trips = _trip_count(cond_comp) if cond_comp else 1
            inner = Cost()
            for cn in op.called:
                if cn in self.comps:
                    inner += self.cost(cn)
            return inner.scaled(trips)
        if k in ("fusion", "call", "custom-call", "map", "reduce", "reduce-window",
                 "scatter", "select-and-scatter", "sort"):
            inner = Cost()
            elementwise_only = True
            for cn in op.called:
                if cn in self.comps:
                    inner += self.cost(cn)
                    elementwise_only &= self._is_elementwise_comp(cn)
            # fusion/call bytes: operands + result crossing the boundary.
            # EXCEPT pure-elementwise fusions: the CPU backend wraps every
            # op in a singleton kLoop fusion, but on TPU those chains fuse
            # into their producers/consumers — 0 extra HBM traffic.
            if k == "fusion" and elementwise_only:
                return Cost(flops=inner.flops, bytes=0.0,
                            coll_bytes=inner.coll_bytes,
                            coll_by_kind=dict(inner.coll_by_kind))
            nbytes = _nbytes(op.result_shapes) + self._operand_bytes(op, comp)
            if k in ("reduce", "reduce-window", "scatter", "select-and-scatter", "sort", "map"):
                # applied computation runs per output element
                inner = inner.scaled(max(_nelems(op.result_shapes), 1))
            return Cost(flops=inner.flops, bytes=nbytes + inner.bytes if k == "call" else nbytes,
                        coll_bytes=inner.coll_bytes, coll_by_kind=dict(inner.coll_by_kind))
        if k == "dot":
            return Cost(flops=_dot_flops(op, comp),
                        bytes=_nbytes(op.result_shapes) + self._operand_bytes(op, comp))
        if k == "convolution":
            return Cost(flops=_conv_flops(op, comp),
                        bytes=_nbytes(op.result_shapes) + self._operand_bytes(op, comp))
        if any(k.startswith(c) for c in _COLLECTIVES):
            if k.endswith("-done"):
                return Cost()
            nb = _nbytes(op.result_shapes)
            kind = next(c for c in _COLLECTIVES if k.startswith(c))
            return Cost(bytes=_nbytes(op.result_shapes) + self._operand_bytes(op, comp),
                        coll_bytes=nb, coll_by_kind={kind: float(nb)})
        # ---- HBM traffic model: "perfect elementwise fusion" ----
        # The CPU backend fuses far less than the TPU backend, so counting
        # operand+result bytes for every elementwise op would inflate the
        # memory term ~10-50x vs what the same program moves on TPU. We model
        # what TPU XLA does: elementwise/broadcast/convert chains fuse into
        # their consumers (0 extra HBM traffic); physical data movement pays.
        if k in ("dynamic-update-slice",):
            # in-place update: read+write the UPDATED SLICE, not the buffer
            upd = comp.ops.get(op.operand_names[1]) if len(op.operand_names) > 1 else None
            nb = 2 * _nbytes(upd.result_shapes) if upd else _nbytes(op.result_shapes)
            return Cost(bytes=nb)
        if k in ("dynamic-slice", "gather", "slice", "concatenate", "pad",
                 "transpose", "copy", "reverse", "dynamic-reshape"):
            return Cost(bytes=2 * _nbytes(op.result_shapes))
        if k in ("rng", "rng-bit-generator"):
            return Cost(bytes=_nbytes(op.result_shapes))
        flops = float(_nelems(op.result_shapes)) if k in _ELEMWISE_FLOP_OPS else 0.0
        return Cost(flops=flops, bytes=0.0)

    _EW_FUSABLE = _ELEMWISE_FLOP_OPS | {
        "parameter", "constant", "broadcast", "convert", "tuple",
        "get-tuple-element", "iota", "bitcast", "reshape", "copy",
        "reduce-precision", "is-finite",
    }

    def _is_elementwise_comp(self, comp_name: str) -> bool:
        comp = self.comps[comp_name]
        return all(o.kind in self._EW_FUSABLE for o in comp.ops.values())

    def _operand_bytes(self, op: _Op, comp: _Computation) -> int:
        nb = 0
        for o in op.operand_names:
            src = comp.ops.get(o)
            if src is not None:
                nb += _nbytes(src.result_shapes)
        return nb


def analyze_text(hlo_text: str) -> dict:
    model = HloCostModel(hlo_text)
    c = model.cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_bytes": c.coll_bytes,
        "collective_by_kind": c.coll_by_kind,
    }
