"""Distributed training launcher: --arch <id> picks the architecture, the
mesh spans whatever devices exist (or the production mesh under the
dry-run env), and the Supervisor provides checkpoint/restart fault
tolerance. On CPU this runs the smoke-scale config end to end; on a real
pod the same file runs the full config — nothing here is CPU-specific.

Usage (single controller):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --steps 50 --batch 8 --seq 64 [--full-config] [--ckpt DIR]

Multi-controller (one invocation PER process, same coordinator):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --coordinator 127.0.0.1:9876 --num-processes 2 --process-id <i> ...

--batch is the GLOBAL batch; each host feeds batch/n_hosts rows striped by
the lm_data (host_id, n_hosts) contract, assembled into dim-0-sharded
global arrays, so the gradient psum over the mesh's data axis is a real
cross-host collective.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.data import lm_data
from repro.distributed import runtime
from repro.distributed.sharding import default_rules, tree_shardings_for, use_rules
from repro.launch.mesh import make_host_mesh
from repro.models import zoo
from repro.train import ft
from repro.train import optimizer as opt
from repro.train import trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8,
                    help="GLOBAL batch size (split across hosts)")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full-size config (needs real accelerators)")
    ap.add_argument("--int8-moments", action="store_true")
    ap.add_argument("--coordinator", default=None,
                    help="host:port of process 0 — enables multi-controller mode")
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    ap.add_argument("--losses-out", default=None,
                    help="host 0 writes the per-step loss series here (json)")
    args = ap.parse_args(argv)

    # must run before ANY backend touch (device queries included)
    if args.coordinator:
        ctx = runtime.initialize(coordinator_address=args.coordinator,
                                 num_processes=args.num_processes,
                                 process_id=args.process_id)
    else:
        ctx = runtime.get_context()
    if args.batch % ctx.n_hosts != 0:
        raise SystemExit(
            f"--batch {args.batch} is the GLOBAL batch and must divide over "
            f"{ctx.n_hosts} hosts")
    local_batch = args.batch // ctx.n_hosts
    lead = ctx.host_id == 0

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = smoke_config(cfg)
    api = zoo.get_api(cfg)
    n_dev = jax.device_count()
    mesh = make_host_mesh(n_data=n_dev, n_model=1, ctx=ctx)
    rules = default_rules(mesh, fsdp=cfg.fsdp)
    batch_sharding = NamedSharding(mesh, P("data"))

    ocfg = opt.AdamWConfig(total_steps=args.steps, warmup_steps=max(args.steps // 10, 1),
                           int8_moments=args.int8_moments)
    step_fn_raw = trainer.make_train_step(
        api.loss_fn, ocfg, n_microbatch=args.microbatch,
        batch_sharding=batch_sharding if ctx.is_multi_controller else None)

    def init_state():
        params = api.init_params(jax.random.PRNGKey(0))
        return trainer.init_train_state(params, ocfg)

    def template():
        return jax.eval_shape(init_state)

    with mesh, use_rules(rules):
        state_sh = tree_shardings_for(
            mesh, trainer.train_state_axes(api.param_axes(), ocfg),
            jax.eval_shape(init_state), rules,
        )
        step = jax.jit(step_fn_raw, in_shardings=(state_sh, None),
                       out_shardings=(state_sh, None), donate_argnums=(0,))
        # jit the init on BOTH paths: multi-controller needs GLOBAL arrays
        # with the training shardings (eager init leaves host-local arrays
        # the step jit cannot consume), and the jit's fresh output buffers
        # also keep donate_argnums sound — eager init can alias two state
        # leaves to one buffer, which Execute() rejects as a double donation
        make_state = jax.jit(init_state, out_shardings=state_sh)

        losses = []

        def run_step(state, t):
            local = lm_data.batch_at(t, batch_size=local_batch, seq_len=args.seq,
                                     vocab=cfg.vocab_size,
                                     host_id=ctx.host_id, n_hosts=ctx.n_hosts)
            batch = ctx.global_batch(local, batch_sharding)
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
            if lead and t % 10 == 0:
                print(f"step {t:5d} loss {losses[-1]:.4f} lr {float(m['lr']):.2e} "
                      f"gnorm {float(m['grad_norm']):.3f}")
            return state

        t0 = time.time()
        if args.ckpt:
            hb = (f"{args.ckpt}/hb_host{ctx.host_id}.json"
                  if ctx.is_multi_controller else args.ckpt + "/hb.json")
            sup = ft.Supervisor(ckpt_root=args.ckpt, save_every=20,
                                heartbeat=ft.Heartbeat(hb), ctx=ctx)
            state = sup.run(init_state=make_state, state_template=template,
                            step_fn=run_step, n_steps=args.steps,
                            shardings=state_sh if ctx.is_multi_controller else None)
        else:
            state = make_state()
            for t in range(args.steps):
                state = run_step(state, t)
        dt = time.time() - t0
        toks = args.steps * args.batch * args.seq
        if lead:
            print(f"{args.arch}: {args.steps} steps, loss {losses[0]:.3f} -> "
                  f"{losses[-1]:.3f}, {toks/dt:.0f} tok/s")
        if args.losses_out and lead:
            with open(args.losses_out, "w") as f:
                json.dump(losses, f)
        if losses[-1] >= losses[0]:
            raise SystemExit("loss did not decrease")


if __name__ == "__main__":
    main()
