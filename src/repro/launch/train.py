"""Distributed training launcher: --arch <id> picks the architecture, the
mesh spans whatever devices exist (or the production mesh under the
dry-run env), and the Supervisor provides checkpoint/restart fault
tolerance. On CPU this runs the smoke-scale config end to end; on a real
pod the same file runs the full config — nothing here is CPU-specific.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --steps 50 --batch 8 --seq 64 [--full-config] [--ckpt DIR]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.data import lm_data
from repro.distributed.sharding import default_rules, tree_shardings_for, use_rules
from repro.launch.mesh import make_host_mesh
from repro.models import zoo
from repro.train import checkpoint as ckpt
from repro.train import ft
from repro.train import optimizer as opt
from repro.train import trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full-size config (needs real accelerators)")
    ap.add_argument("--int8-moments", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = smoke_config(cfg)
    api = zoo.get_api(cfg)
    n_dev = jax.device_count()
    mesh = make_host_mesh(n_data=n_dev, n_model=1)
    rules = default_rules(mesh, fsdp=cfg.fsdp)

    ocfg = opt.AdamWConfig(total_steps=args.steps, warmup_steps=max(args.steps // 10, 1),
                           int8_moments=args.int8_moments)
    step_fn_raw = trainer.make_train_step(api.loss_fn, ocfg, n_microbatch=args.microbatch)

    def init_state():
        params = api.init_params(jax.random.PRNGKey(0))
        return trainer.init_train_state(params, ocfg)

    def template():
        return jax.eval_shape(init_state)

    with mesh, use_rules(rules):
        state_sh = tree_shardings_for(
            mesh, trainer.train_state_axes(api.param_axes(), ocfg),
            jax.eval_shape(init_state), rules,
        )
        step = jax.jit(step_fn_raw, in_shardings=(state_sh, None),
                       out_shardings=(state_sh, None), donate_argnums=(0,))

        losses = []

        def run_step(state, t):
            batch = jax.tree_util.tree_map(
                jnp.asarray,
                lm_data.batch_at(t, batch_size=args.batch, seq_len=args.seq,
                                 vocab=cfg.vocab_size),
            )
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
            if t % 10 == 0:
                print(f"step {t:5d} loss {losses[-1]:.4f} lr {float(m['lr']):.2e} "
                      f"gnorm {float(m['grad_norm']):.3f}")
            return state

        t0 = time.time()
        if args.ckpt:
            sup = ft.Supervisor(ckpt_root=args.ckpt, save_every=20,
                                heartbeat=ft.Heartbeat(args.ckpt + "/hb.json"))
            state = sup.run(init_state=init_state, state_template=template,
                            step_fn=run_step, n_steps=args.steps)
        else:
            state = init_state()
            for t in range(args.steps):
                state = run_step(state, t)
        dt = time.time() - t0
        toks = args.steps * args.batch * args.seq
        print(f"{args.arch}: {args.steps} steps, loss {losses[0]:.3f} -> "
              f"{losses[-1]:.3f}, {toks/dt:.0f} tok/s")
        if losses[-1] >= losses[0]:
            raise SystemExit("loss did not decrease")


if __name__ == "__main__":
    main()
