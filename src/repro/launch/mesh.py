"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (smoke tests and benches must keep seeing the
single real CPU device; only launch/dryrun.py requests 512 placeholder
host devices via XLA_FLAGS before any jax import).

Both constructors route through the :class:`DistributedContext`, so under
a multi-controller launch the mesh axes span EVERY host's devices — not
just ``jax.local_devices()`` — and shardings built on them address the
whole job."""
from __future__ import annotations

from repro.distributed import runtime
from repro.distributed.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False, ctx=None):
    """Single pod: (data=16, model=16) over 256 chips (one TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) over 512 chips — the 'pod' axis
    composes with 'data' for hierarchical gradient reduction (DCN hop)."""
    ctx = ctx or runtime.get_context()
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, devices=ctx.global_devices)


def make_host_mesh(n_data: int = 1, n_model: int = 1, *, ctx=None):
    """Tiny mesh over the job's devices (tests/examples). Multi-controller:
    the data axis crosses process boundaries, so a (n_hosts, 1) mesh from a
    2-process CPU launch really sees both hosts' devices."""
    ctx = ctx or runtime.get_context()
    return make_mesh((n_data, n_model), ("data", "model"), devices=ctx.global_devices)
