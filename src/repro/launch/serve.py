"""Serving launcher: --arch picks the architecture; the Engine provides
continuous batching over a fixed slot pool for BOTH workloads — LM token
requests and snn-det frame streams (compile-once detector + streaming
membrane sessions). Smoke-scale on CPU; the same driver shards
params/caches over the production mesh on real hardware (launch/dryrun.py
proves those shardings compile).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --requests 8 --slots 4 --max-new 16
  PYTHONPATH=src python -m repro.launch.serve --arch snn-det \
      --requests 8 --slots 4 --frames 3 [--conv-exec gated|pallas|dense]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import ALL_IDS, get_config, smoke_config
from repro.models import zoo
from repro.serve import Engine, FrameRequest, Request


def _serve_lm(cfg, args):
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, n_slots=args.slots, max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    total = 0
    for r in range(args.requests):
        plen = int(rng.integers(3, 32))
        total += args.max_new
        eng.submit(Request(rid=r, prompt=list(rng.integers(1, cfg.vocab_size, plen)),
                           max_new_tokens=args.max_new))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    assert len(done) == args.requests
    print(f"{args.arch}: served {args.requests} requests "
          f"({total} new tokens) in {dt:.1f}s — {total/dt:.1f} tok/s")
    for r in sorted(done, key=lambda r: r.rid)[:3]:
        print(f"  req {r.rid}: {r.out}")


def _serve_detector(cfg, args):
    from repro.models import snn_yolo as sy
    from repro.serve.detector import demo_weights, step_latency_ms, synth_streams

    cfg = dataclasses.replace(cfg, conv_exec=args.conv_exec)
    params, bn, rng = demo_weights(cfg)
    det = sy.compile_detector(cfg, params, bn)
    eng = Engine(det, n_slots=args.slots)
    gts = None
    if args.eval_map:
        # serve the synthetic val split (one frame per request — each
        # admission cold-starts its slot) and score the SERVED detections
        from repro.data import synthetic_detection as sd
        from repro.eval.harness import grid_div

        images, gts = sd.eval_set(
            args.requests, hw=cfg.input_hw, grid_div=grid_div(cfg),
            num_anchors=cfg.num_anchors, num_classes=cfg.num_classes,
        )
        streams = [img[None] for img in images]
        total_frames = args.requests
    else:
        streams = synth_streams(rng, args.requests, args.frames, cfg.input_hw)
        total_frames = args.requests * args.frames
    for r, frames in enumerate(streams):
        eng.submit(FrameRequest(rid=r, frames=frames))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    assert len(done) == args.requests
    lat = step_latency_ms(eng.core.step_wall)
    print(f"{args.arch}[{args.conv_exec}]: served {args.requests} streams "
          f"({total_frames} frames) in {dt:.1f}s — {total_frames/dt:.1f} frames/s, "
          f"step p50 {lat['step_p50_ms']:.1f}ms p95 {lat['step_p95_ms']:.1f}ms")
    for r in sorted(done, key=lambda r: r.rid)[:3]:
        counts = [int(d.count) for d in r.out]
        print(f"  req {r.rid}: {len(r.out)} frames, detections/frame {counts}")
    if gts is not None:
        from repro.eval import detection_map as dm

        preds = [r.out[0] for r in sorted(done, key=lambda r: r.rid)]
        if args.eval_shards > 1:
            # score the served detections through the mesh-sharded reduction
            # (striped match stats, collective gather) — bit-identical to
            # the single-host sweep below for any shard count
            from repro.eval import sharded as se

            rep = se.evaluate_predictions_sharded(
                preds, gts, num_classes=cfg.num_classes, iou_threshold=0.5,
                eval_cfg=se.ShardedEvalConfig(n_shards=args.eval_shards),
            )
            shard_note = f" ({rep['n_shards']} shards, {rep['gather']} gather)"
        else:
            rep = dm.evaluate_detections(
                preds, gts, num_classes=cfg.num_classes, iou_threshold=0.5
            )
            shard_note = ""
        print(f"  served-detections mAP@0.5 {rep['map']:.3f} over "
              f"{rep['n_images']} val frames{shard_note} at the serving "
              f"score threshold "
              f"({det.score_threshold}) — demo weights are random-calibrated; "
              "load a trained checkpoint for representative accuracy")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ALL_IDS), required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--frames", type=int, default=3,
                    help="frames per stream (snn-det requests)")
    ap.add_argument("--conv-exec", default="gated",
                    choices=["dense", "gated", "pallas"],
                    help="detector conv executor (snn-det only)")
    ap.add_argument("--eval-map", action="store_true",
                    help="serve the synthetic val split and report mAP@0.5 "
                         "of the SERVED detections (snn-det only)")
    ap.add_argument("--eval-shards", type=int, default=1,
                    help="score the served detections through the "
                         "mesh-sharded mAP reduction (with --eval-map)")
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = smoke_config(cfg)
    if args.arch == "snn-det":
        _serve_detector(cfg, args)
    else:
        _serve_lm(cfg, args)


if __name__ == "__main__":
    main()
