"""Serving launcher: --arch picks the architecture; the Engine provides
continuous batching over a fixed slot pool for BOTH workloads — LM token
requests and snn-det frame streams (compile-once detector + streaming
membrane sessions). Smoke-scale on CPU; the same driver shards
params/caches over the production mesh on real hardware (launch/dryrun.py
proves those shardings compile).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --requests 8 --slots 4 --max-new 16
  PYTHONPATH=src python -m repro.launch.serve --arch snn-det \
      --requests 8 --slots 4 --frames 3 [--conv-exec gated|pallas|dense] \
      [--max-queue 16 --on-full reject|shed-oldest]
  PYTHONPATH=src python -m repro.launch.serve --arch snn-det --eval-map \
      --checkpoint /tmp/snn_det_ckpt [--dataset coco:<instances.json>]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import ALL_IDS, get_config, smoke_config
from repro.models import zoo
from repro.serve import AdmissionPolicy, Engine, FrameRequest, Request


def _admission(args):
    if args.max_queue is None:
        return None
    return AdmissionPolicy(max_queue=args.max_queue, on_full=args.on_full)


def _report_rejections(eng):
    if eng.rejected:
        print(f"  rejected {len(eng.rejected)} requests at admission "
              f"(rids {[r.rid for r in eng.rejected]})")


def _serve_lm(cfg, args):
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, n_slots=args.slots, max_seq=args.max_seq,
                 admission=_admission(args))

    rng = np.random.default_rng(0)
    for r in range(args.requests):
        plen = int(rng.integers(3, 32))
        eng.submit(Request(rid=r, prompt=list(rng.integers(1, cfg.vocab_size, plen)),
                           max_new_tokens=args.max_new))
    _report_rejections(eng)
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    assert len(done) == args.requests - len(eng.rejected)
    total = args.max_new * len(done)
    print(f"{args.arch}: served {len(done)} requests "
          f"({total} new tokens) in {dt:.1f}s — {total/dt:.1f} tok/s")
    for r in sorted(done, key=lambda r: r.rid)[:3]:
        print(f"  req {r.rid}: {r.out}")


def _serve_detector(cfg, args):
    from repro.data import detection_datasets as dd
    from repro.eval import harness
    from repro.models import snn_yolo as sy
    from repro.serve.detector import demo_weights, step_latency_ms, synth_streams

    source = dd.parse_dataset_spec(args.dataset)
    if args.checkpoint:
        # trained weights: the checkpoint's config sidecar replaces the
        # --arch smoke config (input size / channels must match the saved
        # tree); --conv-exec still overrides the executor if given
        cfg, params, bn, step = harness.restore_detector_checkpoint(args.checkpoint)
        if args.conv_exec:
            cfg = dataclasses.replace(cfg, conv_exec=args.conv_exec)
        rng = np.random.default_rng(0)
        print(f"restored checkpoint step {step} from {args.checkpoint} "
              f"({cfg.arch_id}, input {cfg.input_hw}, "
              f"conv_exec {cfg.conv_exec}, weight_bits {cfg.weight_bits})")
    else:
        cfg = dataclasses.replace(cfg, conv_exec=args.conv_exec or "gated")
        params, bn, rng = demo_weights(cfg)
    if args.eval_map and args.checkpoint:
        # real weights + --eval-map: compile with EVALUATION postprocess
        # settings (low threshold, deep budget) so the reported number is
        # the same mAP the accuracy harness would report — and is checked
        # against it bit-exactly below
        det = harness.compile_eval_detector(cfg, params, bn)
    else:
        det = sy.compile_detector(cfg, params, bn)
    eng = Engine(det, n_slots=args.slots, admission=_admission(args))
    gts = None
    n_requests = args.requests
    if args.eval_map:
        # serve the val split (one frame per request — each admission
        # cold-starts its slot) and score the SERVED detections
        cap = source.num_eval_images("val")
        if cap is not None and cap < n_requests:
            print(f"  ({args.dataset} has {cap} val images; serving all of them)")
            n_requests = cap
        images, gts = source.eval_set(
            n_requests, hw=cfg.input_hw, grid_div=harness.grid_div(cfg),
            num_anchors=cfg.num_anchors, num_classes=cfg.num_classes,
        )
        streams = [img[None] for img in images]
    else:
        streams = synth_streams(rng, n_requests, args.frames, cfg.input_hw)
    for r, frames in enumerate(streams):
        eng.submit(FrameRequest(rid=r, frames=frames))
    _report_rejections(eng)
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    assert len(done) == n_requests - len(eng.rejected)
    total_frames = sum(len(r.out) for r in done)
    lat = step_latency_ms(eng.core.step_wall)
    print(f"{args.arch}[{cfg.conv_exec}]: served {len(done)} streams "
          f"({total_frames} frames) in {dt:.1f}s — {total_frames/dt:.1f} frames/s, "
          f"step p50 {lat['step_p50_ms']:.1f}ms p95 {lat['step_p95_ms']:.1f}ms "
          f"p99 {lat['step_p99_ms']:.1f}ms")
    for r in sorted(done, key=lambda r: r.rid)[:3]:
        counts = [int(d.count) for d in r.out]
        print(f"  req {r.rid}: {len(r.out)} frames, detections/frame {counts}")
    if gts is not None:
        from repro.eval import detection_map as dm
        from repro.eval import sharded as se

        if eng.rejected:
            raise SystemExit(
                "--eval-map scores every val image; don't bound --max-queue "
                "below --requests"
            )

        preds = [r.out[0] for r in sorted(done, key=lambda r: r.rid)]
        if args.eval_shards > 1:
            from repro.distributed import runtime

            # score the served detections through the mesh-sharded reduction
            # (striped match stats, collective gather) — bit-identical to
            # the single-host sweep below for any shard count; the context
            # routes shard ownership under a multi-controller launch
            rep = se.evaluate_predictions_sharded(
                preds, gts, num_classes=cfg.num_classes, iou_threshold=0.5,
                eval_cfg=se.ShardedEvalConfig(n_shards=args.eval_shards),
                ctx=runtime.get_context(),
            )
            shard_note = f" ({rep['n_shards']} shards, {rep['gather']} gather)"
        else:
            rep = dm.evaluate_detections(
                preds, gts, num_classes=cfg.num_classes, iou_threshold=0.5
            )
            shard_note = ""
        weights_note = (
            "restored trained weights" if args.checkpoint else
            f"at the serving score threshold ({det.score_threshold}) — demo "
            "weights are random-calibrated; pass --checkpoint <dir> for "
            "representative accuracy"
        )
        print(f"  served-detections mAP@0.5 {rep['map']:.3f} over "
              f"{rep['n_images']} val frames ({args.dataset})"
              f"{shard_note} — {weights_note}")
        if args.checkpoint:
            # the end-to-end contract: the mAP of detections that went
            # through admission/slot batching must equal the accuracy
            # harness scoring the same weights on the same split, bit for
            # bit (per-image outputs are batch-grouping invariant)
            ref = harness.evaluate_detector(det, n_images=n_requests,
                                            source=source)
            identical = se.reports_identical(rep, ref)
            print(f"  harness parity: served {rep['map']!r} vs harness "
                  f"{ref['map']!r} — "
                  f"{'BIT-IDENTICAL' if identical else 'MISMATCH'}")
            if not identical:
                raise SystemExit(
                    "served-detections mAP does not match "
                    "harness.evaluate_detector on the restored weights"
                )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ALL_IDS), required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--frames", type=int, default=3,
                    help="frames per stream (snn-det requests)")
    ap.add_argument("--conv-exec", default=None,
                    choices=["dense", "gated", "pallas"],
                    help="detector conv executor (snn-det only; default: "
                         "gated, or the checkpoint's own executor with "
                         "--checkpoint)")
    ap.add_argument("--checkpoint", default=None, metavar="DIR",
                    help="restore trained params/BN (and config) from a "
                         "detector checkpoint dir — written by "
                         "eval/harness.run_pipeline(ckpt_dir=...), "
                         "benchmarks/eval_map.py --ckpt-dir or "
                         "examples/train_snn_detector.py — instead of "
                         "random-calibrated demo weights (snn-det only)")
    ap.add_argument("--dataset", default="synthetic",
                    help="--eval-map split: synthetic | coco:<instances."
                         "json> | voc:<dir> (snn-det only)")
    ap.add_argument("--eval-map", action="store_true",
                    help="serve the val split and report mAP@0.5 of the "
                         "SERVED detections (snn-det only); with "
                         "--checkpoint the score uses evaluation "
                         "postprocess settings and is asserted bit-exact "
                         "against harness.evaluate_detector")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission control: bound the submit queue at this "
                         "many waiting requests (default: unbounded)")
    ap.add_argument("--on-full", default="reject",
                    choices=["reject", "shed-oldest"],
                    help="full-queue policy with --max-queue: refuse new "
                         "requests, or shed the oldest queued ones")
    ap.add_argument("--eval-shards", type=int, default=1,
                    help="score the served detections through the "
                         "mesh-sharded mAP reduction (with --eval-map)")
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = smoke_config(cfg)
    if args.arch == "snn-det":
        _serve_detector(cfg, args)
    else:
        _serve_lm(cfg, args)


if __name__ == "__main__":
    main()
