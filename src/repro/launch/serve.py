"""Serving launcher: --arch picks the architecture; the Engine provides
continuous batching over a fixed slot pool. Smoke-scale on CPU; the same
driver shards params/caches over the production mesh on real hardware
(launch/dryrun.py proves those shardings compile).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --requests 8 --slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models import zoo
from repro.serve import Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = smoke_config(cfg)
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, n_slots=args.slots, max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    total = 0
    for r in range(args.requests):
        plen = int(rng.integers(3, 32))
        total += args.max_new
        eng.submit(Request(rid=r, prompt=list(rng.integers(1, cfg.vocab_size, plen)),
                           max_new_tokens=args.max_new))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    assert len(done) == args.requests
    print(f"{args.arch}: served {args.requests} requests "
          f"({total} new tokens) in {dt:.1f}s — {total/dt:.1f} tok/s")
    for r in sorted(done, key=lambda r: r.rid)[:3]:
        print(f"  req {r.rid}: {r.out}")


if __name__ == "__main__":
    main()
