import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks the device count on first
# init). The dry-run — and ONLY the dry-run — sees 512 placeholder host
# devices so jax.make_mesh can build the production meshes; smoke tests and
# benches keep seeing one device.
"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell against the production meshes and record the compiled artifact's
roofline terms.

For each cell this driver:
  1. builds the (16,16) single-pod (and optionally (2,16,16) multi-pod) mesh,
  2. resolves the arch config + ShapeDtypeStruct input specs (no allocation),
  3. jits the right step (train_step / prefill / decode) with NamedShardings
     derived from the logical-axis rules,
  4. .lower().compile() — failures here are sharding bugs in the system,
  5. prints memory_analysis() (proves the cell fits per-chip HBM) and
     cost_analysis(), parses collective bytes from the per-device HLO, and
  6. writes artifacts/dryrun/<arch>__<shape>__<mesh>.json for
     benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--subprocess]
  ./scripts/run_dryrun.sh   # full sweep used for artifacts/
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.distributed.sharding import (
    default_rules,
    spec_for,
    tree_shardings_for,
    use_rules,
)
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.models import zoo
from repro.train import optimizer as opt
from repro.train import trainer

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

# per-arch training presets: microbatch count at global_batch=256 and
# whether optimizer moments are int8 (EXPERIMENTS.md §Dry-run napkin math).
# n_microbatch is the MINIMUM that fits per-chip HBM: every extra microbatch
# multiplies the ZeRO-3 parameter all-gathers and the gradient reductions
# (§Perf iteration 2: llama3-405b collective term scales ~1/n_mb when
# dropping 16 -> 4).
TRAIN_PRESETS = {
    "qwen1.5-0.5b": (2, False),
    "whisper-small": (2, False),
    "rwkv6-3b": (4, False),
    "olmoe-1b-7b": (4, True),
    "deepseek-moe-16b": (4, True),
    "zamba2-7b": (4, True),
    "qwen1.5-32b": (4, True),
    "llava-next-34b": (4, True),
    "qwen1.5-110b": (4, True),
    "llama3-405b": (4, True),
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum RESULT bytes of every collective op in the per-device HLO
    (optimized HLO printers omit operand type annotations, so the result
    shape — between '=' and the op name — is the reliable size signal;
    for all-reduce it equals the operand size, for all-gather it is the
    gathered size, i.e. an upper bound on per-link traffic).
    Returns {op_kind: bytes, ..., 'total': bytes, 'count': n}. `-done` ops
    are skipped (they alias the in-flight `-start`)."""
    out: dict = {}
    count = 0
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        head = line[: m.start()]
        eq = head.find("=")
        if eq < 0:
            continue
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(head[eq:]):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + nbytes
        count += 1
    out["total"] = sum(v for k, v in out.items() if k != "count")
    out["count"] = count
    return out


def exact_param_count(cfg) -> int:
    api = zoo.get_api(cfg)
    shapes = jax.eval_shape(api.init_params, jax.random.PRNGKey(0))
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes))


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); D = tokens the
    step processes (decode: one token per sequence)."""
    n = exact_param_count(cfg)
    if cfg.family == "moe":
        n = int(n * cfg.n_active_params() / max(cfg.n_params(), 1))
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch  # decode: one new token per seq


def build_lowered(cfg, shape, mesh, *, donate: bool = True):
    """Returns (lowered, meta) for this cell on this mesh."""
    api = zoo.get_api(cfg)
    rules = default_rules(mesh, fsdp=cfg.fsdp)
    specs = zoo.input_specs(cfg, shape)
    baxes = zoo.batch_axes(cfg, shape)
    params_shape = jax.eval_shape(api.init_params, jax.random.PRNGKey(0))
    param_sh = tree_shardings_for(mesh, api.param_axes(), params_shape, rules)

    with use_rules(rules, mesh=mesh):
        if shape.kind == "train":
            n_mb, int8 = TRAIN_PRESETS.get(cfg.arch_id, (8, False))
            n_mb = min(n_mb, shape.global_batch)
            ocfg = opt.AdamWConfig(int8_moments=int8)
            state_shape = jax.eval_shape(
                lambda p: trainer.init_train_state(p, ocfg), params_shape
            )
            state_ax = trainer.train_state_axes(api.param_axes(), ocfg)
            state_sh = tree_shardings_for(mesh, state_ax, state_shape, rules)
            batch_sh = tree_shardings_for(
                mesh, baxes["batch"], specs["batch"], rules
            )
            step = trainer.make_train_step(
                api.loss_fn, ocfg, n_microbatch=n_mb, grad_shardings=param_sh
            )
            jitted = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,) if donate else (),
            )
            lowered = jitted.lower(state_shape, specs["batch"])
            meta = {"step": "train_step", "n_microbatch": n_mb, "int8_moments": int8}
        elif shape.kind == "prefill":
            args_sh = tuple(
                tree_shardings_for(mesh, a, s, rules)
                for a, s in zip(baxes["args"], specs["args"])
            )
            out_shape = jax.eval_shape(api.prefill_fn, params_shape, *specs["args"])
            cache_sh = tree_shardings_for(
                mesh,
                api.cache_axes(shape.global_batch, shape.seq_len),
                out_shape[1],
                rules,
            )
            jitted = jax.jit(
                api.prefill_fn,
                in_shardings=(param_sh,) + args_sh,
                out_shardings=(None, cache_sh),
            )
            lowered = jitted.lower(params_shape, *specs["args"])
            meta = {"step": "prefill"}
        else:  # decode
            cache_sh = tree_shardings_for(
                mesh,
                api.cache_axes(shape.global_batch, shape.seq_len),
                specs["cache"],
                rules,
            )
            tok_sh = tree_shardings_for(mesh, ("batch",), specs["token"], rules)
            pos_sh = tree_shardings_for(mesh, (), specs["pos"], rules)
            jitted = jax.jit(
                api.decode_fn,
                in_shardings=(param_sh, cache_sh, tok_sh, pos_sh),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(
                params_shape, specs["cache"], specs["token"], specs["pos"]
            )
            meta = {"step": "serve_step(decode)"}
    return lowered, meta


def analyze(lowered, compiled, mesh, cfg, shape) -> dict:
    n_chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    hlo_text = compiled.as_text()
    # trip-count-aware accounting (launch/hlo_cost.py): XLA's built-in
    # cost_analysis counts while bodies ONCE — a 126x error for a scanned
    # 126-layer model. Both are recorded; the roofline uses the corrected one.
    acc = hlo_cost.analyze_text(hlo_text)
    xla_cost = compiled.cost_analysis() or {}
    flops_pd = float(acc["flops"])
    bytes_pd = float(acc["bytes"])
    coll = {k: float(v) for k, v in acc["collective_by_kind"].items()}
    coll["total"] = float(acc["collective_bytes"])
    coll["count"] = parse_collective_bytes(hlo_text)["count"]
    # everything is PER-DEVICE after SPMD partitioning, so the roofline
    # terms divide by per-chip peaks directly (equivalent to the
    # total/(chips×peak) formulation).
    compute_t = flops_pd / PEAK_FLOPS
    memory_t = bytes_pd / HBM_BW
    coll_t = coll["total"] / ICI_BW

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            if hasattr(ma, k):
                mem[k] = int(getattr(ma, k))
        if mem:
            mem["live_peak_bytes"] = (
                mem.get("argument_size_in_bytes", 0)
                + mem.get("output_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0)
                - mem.get("alias_size_in_bytes", 0)
            )
    except Exception as e:  # CPU backend may not expose it
        mem["error"] = str(e)

    mf = model_flops(cfg, shape)
    terms = {
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": coll_t,
    }
    dominant = max(terms, key=terms.get)
    return {
        "n_chips": n_chips,
        "flops_per_device": flops_pd,
        "hbm_bytes_per_device": bytes_pd,
        "collective_bytes_per_device": coll,
        "xla_cost_analysis_uncorrected": {
            "flops": float(xla_cost.get("flops", 0.0)),
            "bytes_accessed": float(xla_cost.get("bytes accessed", 0.0)),
        },
        "roofline": {
            **terms,
            "dominant": dominant,
            "bound_s": max(terms.values()),
            "model_flops_total": mf,
            "useful_flops_ratio": mf / max(flops_pd * n_chips, 1.0),
        },
        "memory_analysis": mem,
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             *, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name in cfg.skip_shapes:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "skipped",
               "reason": "full-attention arch: no sub-quadratic path for 500k decode"}
        _write(out_dir, rec)
        return rec
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    with mesh:
        lowered, meta = build_lowered(cfg, shape, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "mesh_shape": {a: int(mesh.shape[a]) for a in mesh.axis_names},
            "status": "ok", **meta,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            **analyze(lowered, compiled, mesh, cfg, shape),
        }
    if verbose:
        print(f"== {arch} x {shape_name} x {mesh_kind} ==")
        print(json.dumps(rec["memory_analysis"], indent=1))
        print(json.dumps({k: rec[k] for k in ("flops_per_device", "hbm_bytes_per_device")}, indent=1))
        print("collectives:", json.dumps(rec["collective_bytes_per_device"]))
        print("roofline:", json.dumps(rec["roofline"], indent=1))
    _write(out_dir, rec)
    return rec


def _write(out_dir: str, rec: dict):
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--subprocess", action="store_true",
                    help="run each cell in its own process (isolates failures)")
    args = ap.parse_args(argv)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        for mk in meshes:
            if args.subprocess and args.all:
                r = subprocess.run(
                    [sys.executable, "-m", "repro.launch.dryrun",
                     "--arch", arch, "--shape", shape, "--mesh", mk, "--out", args.out],
                    capture_output=True, text=True,
                )
                status = "ok" if r.returncode == 0 else "FAIL"
                print(f"[{status}] {arch} x {shape} x {mk}")
                if r.returncode != 0:
                    print(r.stdout[-2000:], r.stderr[-2000:])
                    failures.append((arch, shape, mk))
            else:
                try:
                    run_cell(arch, shape, mk, args.out)
                except Exception:
                    traceback.print_exc()
                    failures.append((arch, shape, mk))
                finally:
                    jax.clear_caches()
    if failures:
        print("FAILED cells:", failures)
        sys.exit(1)
    print(f"all {len(cells) * len(meshes)} cells passed")


if __name__ == "__main__":
    main()
