"""Jitted wrappers around the Pallas kernels: host-side packing (bitmask
compression, block layout) + dispatch + unpacking.

These are the public entry points; `ref.py` holds the pure-jnp oracles each
wrapper is tested against (interpret mode on CPU, real TPU lowering on HW).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import gated_one_to_all as g2a
from . import spike_lif as sl
from . import bitmask_matmul as bmm
from .backend import auto_interpret


# ---------------------------------------------------------------------------
# Packing for the gated one-to-all kernel
# ---------------------------------------------------------------------------


class PackedConvWeights(NamedTuple):
    maskp: jax.Array  # (KB, taps, C8, KBLK) uint8 bit-packed over C
    vals: jax.Array  # (KB, VPAD) int8
    tap_any: jax.Array  # (KB, taps) int32
    kh: int
    kw: int
    cin: int  # padded input channels
    kout: int  # true output channels
    kblk: int

    @property
    def compressed_bytes(self) -> int:
        """HBM bytes the kernel actually reads for weights (the Fig 17
        accounting): packed mask bits + padded nonzero values."""
        return self.maskp.size + self.vals.size


def pack_conv_weights(
    w_int8: np.ndarray, *, kblk: int = 128, vpad: int | None = None
) -> PackedConvWeights:
    """w_int8: (kh, kw, Cin, K) int8 (zeros = pruned). Host-side pack.

    ``vpad`` fixes the padded length of each K-block's packed-value vector
    (useful to give every layer of a plan the same VPAD). The kernel's
    decode clips gather indices into ``vals`` — an nnz that exceeds VPAD
    would silently read garbage — so an insufficient ``vpad`` raises here,
    at pack time, instead.
    """
    w = np.asarray(w_int8)
    kh, kw, cin, k = w.shape
    taps = kh * kw
    cin_p = int(np.ceil(cin / 8)) * 8
    k_p = int(np.ceil(k / kblk)) * kblk
    wp = np.zeros((kh, kw, cin_p, k_p), np.int8)
    wp[:, :, :cin, :k] = w
    kb_total = k_p // kblk

    maskp = np.zeros((kb_total, taps, cin_p // 8, kblk), np.uint8)
    vals_list = []
    tap_any = np.zeros((kb_total, taps), np.int32)
    for kb in range(kb_total):
        wb = wp[:, :, :, kb * kblk : (kb + 1) * kblk].reshape(taps, cin_p, kblk)
        mask = (wb != 0).astype(np.uint8)
        tap_any[kb] = mask.reshape(taps, -1).any(axis=1).astype(np.int32)
        # pack bits along C: bit c -> word c//8, position c%8
        m = mask.reshape(taps, cin_p // 8, 8, kblk)
        for b in range(8):
            maskp[kb] |= (m[:, :, b, :] << b).astype(np.uint8)
        vals_list.append(wb[wb != 0].ravel())
    max_nnz = max((v.size for v in vals_list), default=0)
    if vpad is None:
        vpad = max(max_nnz, 1)
    elif vpad < max_nnz:
        raise ValueError(
            f"vpad={vpad} < max per-K-block nnz={max_nnz}: the kernel's "
            "clipped gather would silently read garbage values"
        )
    vpad = max(vpad, 1)
    vals = np.zeros((kb_total, vpad), np.int8)
    for kb, v in enumerate(vals_list):
        vals[kb, : v.size] = v
    return PackedConvWeights(
        maskp=jnp.asarray(maskp),
        vals=jnp.asarray(vals),
        tap_any=jnp.asarray(tap_any),
        kh=kh,
        kw=kw,
        cin=cin_p,
        kout=k,
        kblk=kblk,
    )


def unpack_conv_weights(pw: PackedConvWeights) -> np.ndarray:
    """Inverse of :func:`pack_conv_weights`: reconstruct the dense int8
    kernel (kh, kw, cin_padded, kout) from {maskp, vals}. Host-side; used
    by the pack→unpack round-trip property tests — the compressed form
    must be information-preserving for every sparsity pattern, or the
    kernel is silently computing with a different model."""
    maskp = np.asarray(pw.maskp)
    vals = np.asarray(pw.vals)
    kb_total, taps, c8, kblk = maskp.shape
    cin_p = c8 * 8
    w = np.zeros((taps, cin_p, kb_total * kblk), np.int8)
    for kb in range(kb_total):
        # unpack bit c%8 of word c//8 back to channel c (pack order)
        bits = np.stack(
            [(maskp[kb] >> b) & 1 for b in range(8)], axis=2
        )  # (taps, C8, 8, KBLK)
        mask = bits.reshape(taps, cin_p, kblk).astype(bool)
        block = np.zeros((taps, cin_p, kblk), np.int8)
        block[mask] = vals[kb, : int(mask.sum())]  # C-order, matching pack
        w[:, :, kb * kblk : (kb + 1) * kblk] = block
    return w.reshape(pw.kh, pw.kw, cin_p, kb_total * kblk)[..., : pw.kout]


def validate_packed(pw: PackedConvWeights) -> None:
    """Check that every K-block's nonzero count fits the packed-value
    buffer. The kernel clips gather indices into ``vals`` (it cannot
    bounds-check inside the grid), so an overflowing block silently reads
    the last value — validate host-side and raise instead."""
    maskp = np.asarray(pw.maskp)
    vpad = int(pw.vals.shape[1])
    nnz_per_kb = np.unpackbits(maskp.reshape(maskp.shape[0], -1), axis=1).sum(axis=1)
    worst = int(nnz_per_kb.max()) if nnz_per_kb.size else 0
    if worst > vpad:
        raise ValueError(
            f"packed weights invalid: K-block nnz={worst} exceeds VPAD={vpad}; "
            "repack with a larger vpad (kernel would silently read garbage)"
        )


def _block_layout(spikes: jax.Array, *, bh: int, bw: int, pad: int, cin_p: int) -> jax.Array:
    """NHWC int8 spikes → (N*nbh*nbw, bh+2p, bw+2p, Cp) replicate-padded
    independent blocks (block convolution, paper §II-B)."""
    n, h, w, c = spikes.shape
    if h % bh or w % bw:
        raise ValueError(f"({h},{w}) not divisible by block ({bh},{bw})")
    x = spikes
    if c < cin_p:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, cin_p - c)))
    x = x.reshape(n, h // bh, bh, w // bw, bw, cin_p).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(-1, bh, bw, cin_p)
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)), mode="edge")
    return x


@functools.partial(
    jax.jit,
    static_argnames=(
        "kh",
        "kw",
        "kblk",
        "bh",
        "bw",
        "interpret",
        "out_h",
        "out_w",
        "batch",
        "kout",
    ),
)
def _dispatch(spike_blocks, pw_maskp, pw_vals, pw_tap_any, *, kh, kw, kblk, bh, bw, out_h, out_w, batch, kout, interpret):
    out = g2a.gated_one_to_all_pallas(
        spike_blocks,
        pw_maskp,
        pw_vals,
        pw_tap_any,
        kh=kh,
        kw=kw,
        bh=bh,
        bw=bw,
        kblk=kblk,
        interpret=interpret,
    )
    nbh, nbw = out_h // bh, out_w // bw
    out = out.reshape(batch, nbh, nbw, bh, bw, -1).transpose(0, 1, 3, 2, 4, 5)
    out = out.reshape(batch, out_h, out_w, -1)
    return out[..., :kout]


def gated_conv(
    spikes: jax.Array,
    pw: PackedConvWeights,
    *,
    bh: int = g2a.BLOCK_H,
    bw: int = g2a.BLOCK_W,
    interpret: bool | None = None,
) -> jax.Array:
    """Sparse-compressed block convolution of int8 spikes. NHWC → NHWK int32.

    The leading axis is a plain batch: callers fold extra grid dimensions
    (e.g. SNN time steps, bit-serial planes) into it so the whole T·N·blocks
    volume runs through ONE pallas_call."""
    interpret = auto_interpret(interpret)
    n, h, w, _ = spikes.shape
    pad = (pw.kh - 1) // 2
    blocks = _block_layout(spikes.astype(jnp.int8), bh=bh, bw=bw, pad=pad, cin_p=pw.cin)
    return _dispatch(
        blocks,
        pw.maskp,
        pw.vals,
        pw.tap_any,
        kh=pw.kh,
        kw=pw.kw,
        kblk=pw.kblk,
        bh=bh,
        bw=bw,
        out_h=h,
        out_w=w,
        batch=n,
        kout=pw.kout,
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Fused LIF
# ---------------------------------------------------------------------------


def fused_lif(
    psum_t: jax.Array,  # (T, M, C) f32 synaptic inputs
    *,
    threshold: float = 0.5,
    leak: float = 0.25,
    mblk: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """LIF over T fully fused in VMEM (no HBM round-trip of the membrane
    potential between steps). Returns int8 spikes (T, M, C)."""
    return sl.fused_lif_pallas(
        psum_t, threshold=threshold, leak=leak, mblk=mblk, interpret=auto_interpret(interpret)
    )


# ---------------------------------------------------------------------------
# Bitmask sparse matmul (paper's format applied to LM FFN weights)
# ---------------------------------------------------------------------------


def pack_matmul_weights(w: np.ndarray, *, kblk: int = 512, nblk: int = 256):
    return bmm.pack_weights(w, kblk=kblk, nblk=nblk)


def bitmask_matmul(
    x: jax.Array,
    packed,
    *,
    mblk: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """x (M, K) f32/bf16 × bitmask-compressed W (K, N) → (M, N) f32."""
    return bmm.bitmask_matmul_pallas(x, packed, mblk=mblk, interpret=auto_interpret(interpret))
