"""Jitted wrappers around the Pallas kernels: host-side packing (bitmask
compression, block layout) + dispatch + unpacking.

These are the public entry points; `ref.py` holds the pure-jnp oracles each
wrapper is tested against (interpret mode on CPU, real TPU lowering on HW).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import fused_pipeline as fp
from . import gated_one_to_all as g2a
from . import spike_lif as sl
from . import bitmask_matmul as bmm
from .backend import auto_interpret


# ---------------------------------------------------------------------------
# Packing for the gated one-to-all kernel
# ---------------------------------------------------------------------------


class PackedConvWeights(NamedTuple):
    maskp: jax.Array  # (KB, taps, C8, KBLK) uint8 bit-packed over C
    vals: jax.Array  # (KB, VPAD) int8
    tap_any: jax.Array  # (KB, taps) int32
    kh: int
    kw: int
    cin: int  # padded input channels
    kout: int  # true output channels
    kblk: int
    # taps with ANY nonzero weight across ALL K-blocks, as a static tuple —
    # known at pack time, so the fused kernel skips dead taps at TRACE time
    # (no per-tap runtime cond; a pruned 3×3 often kills whole taps)
    tap_alive: tuple = ()

    @property
    def compressed_bytes(self) -> int:
        """HBM bytes the kernel actually reads for weights (the Fig 17
        accounting): packed mask bits + padded nonzero values."""
        return self.maskp.size + self.vals.size


def pack_conv_weights(
    w_int8: np.ndarray, *, kblk: int = 128, vpad: int | None = None
) -> PackedConvWeights:
    """w_int8: (kh, kw, Cin, K) int8 (zeros = pruned). Host-side pack.

    ``vpad`` fixes the padded length of each K-block's packed-value vector
    (useful to give every layer of a plan the same VPAD). The kernel's
    decode clips gather indices into ``vals`` — an nnz that exceeds VPAD
    would silently read garbage — so an insufficient ``vpad`` raises here,
    at pack time, instead.
    """
    w = np.asarray(w_int8)
    kh, kw, cin, k = w.shape
    taps = kh * kw
    cin_p = int(np.ceil(cin / 8)) * 8
    k_p = int(np.ceil(k / kblk)) * kblk
    wp = np.zeros((kh, kw, cin_p, k_p), np.int8)
    wp[:, :, :cin, :k] = w
    kb_total = k_p // kblk

    maskp = np.zeros((kb_total, taps, cin_p // 8, kblk), np.uint8)
    vals_list = []
    tap_any = np.zeros((kb_total, taps), np.int32)
    for kb in range(kb_total):
        wb = wp[:, :, :, kb * kblk : (kb + 1) * kblk].reshape(taps, cin_p, kblk)
        mask = (wb != 0).astype(np.uint8)
        tap_any[kb] = mask.reshape(taps, -1).any(axis=1).astype(np.int32)
        # pack bits along C: bit c -> word c//8, position c%8
        m = mask.reshape(taps, cin_p // 8, 8, kblk)
        for b in range(8):
            maskp[kb] |= (m[:, :, b, :] << b).astype(np.uint8)
        vals_list.append(wb[wb != 0].ravel())
    max_nnz = max((v.size for v in vals_list), default=0)
    if vpad is None:
        vpad = max(max_nnz, 1)
    elif vpad < max_nnz:
        raise ValueError(
            f"vpad={vpad} < max per-K-block nnz={max_nnz}: the kernel's "
            "clipped gather would silently read garbage values"
        )
    vpad = max(vpad, 1)
    vals = np.zeros((kb_total, vpad), np.int8)
    for kb, v in enumerate(vals_list):
        vals[kb, : v.size] = v
    return PackedConvWeights(
        maskp=jnp.asarray(maskp),
        vals=jnp.asarray(vals),
        tap_any=jnp.asarray(tap_any),
        kh=kh,
        kw=kw,
        cin=cin_p,
        kout=k,
        kblk=kblk,
        tap_alive=tuple(int(t) for t in np.flatnonzero(tap_any.any(axis=0))),
    )


def unpack_conv_weights(pw: PackedConvWeights) -> np.ndarray:
    """Inverse of :func:`pack_conv_weights`: reconstruct the dense int8
    kernel (kh, kw, cin_padded, kout) from {maskp, vals}. Host-side; used
    by the pack→unpack round-trip property tests — the compressed form
    must be information-preserving for every sparsity pattern, or the
    kernel is silently computing with a different model."""
    maskp = np.asarray(pw.maskp)
    vals = np.asarray(pw.vals)
    kb_total, taps, c8, kblk = maskp.shape
    cin_p = c8 * 8
    w = np.zeros((taps, cin_p, kb_total * kblk), np.int8)
    for kb in range(kb_total):
        # unpack bit c%8 of word c//8 back to channel c (pack order)
        bits = np.stack(
            [(maskp[kb] >> b) & 1 for b in range(8)], axis=2
        )  # (taps, C8, 8, KBLK)
        mask = bits.reshape(taps, cin_p, kblk).astype(bool)
        block = np.zeros((taps, cin_p, kblk), np.int8)
        block[mask] = vals[kb, : int(mask.sum())]  # C-order, matching pack
        w[:, :, kb * kblk : (kb + 1) * kblk] = block
    return w.reshape(pw.kh, pw.kw, cin_p, kb_total * kblk)[..., : pw.kout]


def validate_packed(pw: PackedConvWeights) -> None:
    """Check that every K-block's nonzero count fits the packed-value
    buffer. The kernel clips gather indices into ``vals`` (it cannot
    bounds-check inside the grid), so an overflowing block silently reads
    the last value — validate host-side and raise instead."""
    maskp = np.asarray(pw.maskp)
    vpad = int(pw.vals.shape[1])
    nnz_per_kb = np.unpackbits(maskp.reshape(maskp.shape[0], -1), axis=1).sum(axis=1)
    worst = int(nnz_per_kb.max()) if nnz_per_kb.size else 0
    if worst > vpad:
        raise ValueError(
            f"packed weights invalid: K-block nnz={worst} exceeds VPAD={vpad}; "
            "repack with a larger vpad (kernel would silently read garbage)"
        )


def _macro_grid(nbh: int, nbw: int, mr: int, mc: int) -> tuple[int, int]:
    """Macro-tile grid (GH, GW): how many mr×mc block groups cover an
    nbh×nbw block grid (ragged edges round UP — the layout zero-pads)."""
    return -(-nbh // mr), -(-nbw // mc)


def _block_layout(
    spikes: jax.Array, *, bh: int, bw: int, pad: int, cin_p: int,
    mr: int = 1, mc: int = 1,
) -> jax.Array:
    """NHWC int8 spikes → (N*GH*GW*mr*mc, bh+2p, bw+2p, Cp) replicate-padded
    independent blocks (block convolution, paper §II-B), ordered so every
    mr×mc MACRO-TILE of the block grid is contiguous along the block axis —
    the fused kernel's grid step then covers one macro group with a single
    dynamic slice. Ragged block grids (nbh % mr or nbw % mc nonzero) are
    zero-padded with whole garbage blocks that ``_unblock`` strips; each
    block still carries its OWN replicate-padded halo, so the macro
    ordering never changes numerics."""
    n, h, w, c = spikes.shape
    if h % bh or w % bw:
        raise ValueError(f"({h},{w}) not divisible by block ({bh},{bw})")
    x = spikes
    if c < cin_p:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, cin_p - c)))
    nbh, nbw = h // bh, w // bw
    x = x.reshape(n, nbh, bh, nbw, bw, cin_p).transpose(0, 1, 3, 2, 4, 5)
    if mr > 1 or mc > 1:
        gh, gw = _macro_grid(nbh, nbw, mr, mc)
        if (gh * mr, gw * mc) != (nbh, nbw):
            x = jnp.pad(
                x,
                ((0, 0), (0, gh * mr - nbh), (0, gw * mc - nbw))
                + ((0, 0),) * 3,
            )
        x = x.reshape(n, gh, mr, gw, mc, bh, bw, cin_p)
        x = x.transpose(0, 1, 3, 2, 4, 5, 6, 7)  # groups outer, tile inner
    x = x.reshape(-1, bh, bw, cin_p)
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)), mode="edge")
    return x


@functools.partial(
    jax.jit,
    static_argnames=(
        "kh",
        "kw",
        "kblk",
        "bh",
        "bw",
        "interpret",
        "out_h",
        "out_w",
        "batch",
        "kout",
    ),
)
def _dispatch(spike_blocks, pw_maskp, pw_vals, pw_tap_any, *, kh, kw, kblk, bh, bw, out_h, out_w, batch, kout, interpret):
    out = g2a.gated_one_to_all_pallas(
        spike_blocks,
        pw_maskp,
        pw_vals,
        pw_tap_any,
        kh=kh,
        kw=kw,
        bh=bh,
        bw=bw,
        kblk=kblk,
        interpret=interpret,
    )
    nbh, nbw = out_h // bh, out_w // bw
    out = out.reshape(batch, nbh, nbw, bh, bw, -1).transpose(0, 1, 3, 2, 4, 5)
    out = out.reshape(batch, out_h, out_w, -1)
    return out[..., :kout]


def gated_conv(
    spikes: jax.Array,
    pw: PackedConvWeights,
    *,
    bh: int = g2a.BLOCK_H,
    bw: int = g2a.BLOCK_W,
    interpret: bool | None = None,
) -> jax.Array:
    """Sparse-compressed block convolution of int8 spikes. NHWC → NHWK int32.

    The leading axis is a plain batch: callers fold extra grid dimensions
    (e.g. SNN time steps, bit-serial planes) into it so the whole T·N·blocks
    volume runs through ONE pallas_call."""
    interpret = auto_interpret(interpret)
    n, h, w, _ = spikes.shape
    pad = (pw.kh - 1) // 2
    blocks = _block_layout(spikes.astype(jnp.int8), bh=bh, bw=bw, pad=pad, cin_p=pw.cin)
    return _dispatch(
        blocks,
        pw.maskp,
        pw.vals,
        pw.tap_any,
        kh=pw.kh,
        kw=pw.kw,
        kblk=pw.kblk,
        bh=bh,
        bw=bw,
        out_h=h,
        out_w=w,
        batch=n,
        kout=pw.kout,
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Fused layer pipeline: conv → FXP rescale → tdBN affine → LIF, one dispatch
# ---------------------------------------------------------------------------


def _block_layout_nohalo(
    x: jax.Array, *, bh: int, bw: int, cpad: int, mr: int = 1, mc: int = 1
) -> jax.Array:
    """NHWC f32 → (N*GH*GW*mr*mc, bh, bw, Cp) independent blocks, channel-
    padded, macro-ordered like :func:`_block_layout` (the membrane layout —
    no conv halo)."""
    return _block_layout(x, bh=bh, bw=bw, pad=0, cin_p=cpad, mr=mr, mc=mc)


def _unblock(
    xb: jax.Array, *, n: int, h: int, w: int, mr: int = 1, mc: int = 1
) -> jax.Array:
    """(N*GH*GW*mr*mc, bh, bw, C) macro-ordered blocks → NHWC (leading axes
    preserved). Inverts :func:`_block_layout`: undoes the macro grouping,
    then strips the zero-padded ragged-edge blocks by slicing to (h, w)."""
    bh, bw = xb.shape[-3], xb.shape[-2]
    lead = xb.shape[:-4]
    L = len(lead)
    nbh, nbw = h // bh, w // bw
    gh, gw = _macro_grid(nbh, nbw, mr, mc)
    cc = xb.shape[-1]
    xb = xb.reshape(lead + (n, gh, gw, mr, mc, bh, bw, cc))
    # (..., n, gh, gw, mr, mc, bh, bw, C) → (..., n, gh, mr, bh, gw, mc, bw, C)
    perm = tuple(range(L)) + tuple(L + i for i in (0, 1, 3, 5, 2, 4, 6, 7))
    xb = xb.transpose(perm)
    xb = xb.reshape(lead + (n, gh * mr * bh, gw * mc * bw, cc))
    return xb[..., :h, :w, :]


def affine_bundle(
    pw: PackedConvWeights,
    scale: jax.Array,  # () f32 — FXP dequant scale (per-tensor)
    mean: jax.Array,  # (C,) f32 — tdBN running mean
    var: jax.Array,  # (C,) f32 — tdBN running var
    gamma: jax.Array,
    beta: jax.Array,
    *,
    eps: float = 1e-5,
) -> jax.Array:
    """Pack the per-channel pipeline constants into the kernel's
    (KB, 5, KBLK) bundle: [FXP scale, mean, rsqrt(var+eps), gamma, beta].

    ``rsqrt(var+eps)`` is precomputed here — it is a deterministic
    element-wise function, so the kernel multiplying by it is bit-identical
    to ``tdbn_apply`` computing it inline. Channels padded past the true
    layer width get (mean 0, var 1, gamma 0, beta 0): their outputs are
    garbage-free zeros and are stripped by the caller anyway."""
    kb_total = pw.maskp.shape[0]
    kblk = pw.kblk
    kp = kb_total * kblk
    kout = mean.shape[0]

    def padc(v, fill):
        return jnp.concatenate([v, jnp.full((kp - kout,), fill, v.dtype)]) if kp > kout else v

    rinv = jax.lax.rsqrt(var + eps)
    rows = jnp.stack(
        [
            jnp.broadcast_to(scale.astype(jnp.float32), (kp,)),
            padc(mean.astype(jnp.float32), 0.0),
            padc(rinv.astype(jnp.float32), 1.0),
            padc(gamma.astype(jnp.float32), 0.0),
            padc(beta.astype(jnp.float32), 0.0),
        ]
    )  # (5, KP)
    return rows.reshape(fp.AFFINE_ROWS, kb_total, kblk).transpose(1, 0, 2)


@functools.partial(
    jax.jit,
    static_argnames=(
        "kh",
        "kw",
        "kblk",
        "bh",
        "bw",
        "nbt",
        "mr",
        "mc",
        "t_out",
        "in_bits",
        "tap_alive",
        "bn_scale",
        "threshold",
        "leak",
        "reset",
        "out_h",
        "out_w",
        "batch",
        "kout",
        "interpret",
    ),
)
def _dispatch_fused(
    spike_blocks,
    maskp,
    vals,
    affine,
    v0_blocks,
    wdense,
    *,
    kh,
    kw,
    kblk,
    bh,
    bw,
    nbt,
    mr,
    mc,
    t_out,
    in_bits,
    tap_alive,
    bn_scale,
    threshold,
    leak,
    reset,
    out_h,
    out_w,
    batch,
    kout,
    interpret,
):
    spk, mem = fp.fused_pipeline_pallas(
        spike_blocks,
        maskp,
        vals,
        affine,
        v0_blocks,
        kh=kh,
        kw=kw,
        bh=bh,
        bw=bw,
        kblk=kblk,
        nbt=nbt,
        bpg=mr * mc,
        t_out=t_out,
        in_bits=in_bits,
        tap_alive=tap_alive,
        bn_scale=bn_scale,
        threshold=threshold,
        leak=leak,
        reset=reset,
        wdense=wdense,
        interpret=interpret,
    )
    spk = _unblock(spk.astype(jnp.float32), n=batch, h=out_h, w=out_w,
                   mr=mr, mc=mc)
    mem = _unblock(mem, n=batch, h=out_h, w=out_w, mr=mr, mc=mc)
    return spk[..., :kout], mem[..., :kout]


def _normalize_tiling(
    nbt: int, mrows: int, mcols: int, nbh: int, nbw: int
) -> tuple[int, int, int]:
    """Clamp a requested (nbt, mrows×mcols) tiling to a layer's nbh×nbw
    block grid. A bare ``nbt`` with no macro shape (the legacy flat-group
    form, still used by direct callers) maps to a 1×nbt row macro-tile;
    macro axes clamp to the grid, and nbt drops to the largest divisor of
    the macro size. Pure dispatch shaping — never affects numerics."""
    if mrows * mcols == 1 and nbt > 1:
        mrows, mcols = 1, nbt
    mrows = max(1, min(mrows, nbh))
    mcols = max(1, min(mcols, nbw))
    bpg = mrows * mcols
    nbt = max(1, min(nbt, bpg))
    while bpg % nbt:
        nbt -= 1
    return nbt, mrows, mcols


def fused_conv_bn_lif(
    x_t: jax.Array,  # (t_in, N, H, W, C): int8 {0,1} spikes, or u8-valued f32
    pw: PackedConvWeights,
    affine: jax.Array,  # (KB, 5, KBLK) from affine_bundle
    *,
    v0: jax.Array | None,  # (N, H, W, Kout) f32 initial membrane, None=cold
    out_t: int,
    in_bits: int,
    bn_scale: float,
    threshold: float,
    leak: float,
    reset: str = "hard",
    v_init: float = 0.0,
    bh: int = g2a.BLOCK_H,
    bw: int = g2a.BLOCK_W,
    nbt: int = 1,
    mrows: int = 1,
    mcols: int = 1,
    predecode: bool = True,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """The whole per-layer pipeline (conv → FXP rescale → tdBN affine → LIF
    over ``out_t`` steps) in ONE Pallas dispatch. Returns
    (spikes (out_t, N, H, W, Kout) f32 {0,1}, final membrane (N, H, W, Kout) f32).

    ``in_bits=8`` runs the encoding layer: ``x_t`` then carries the u8-grid
    pixel VALUES (as f32) — the exact fold of the 8 bit-serial planes, so
    encode is one dispatch of the same kernel (see fused_pipeline.py).

    ``mrows``/``mcols`` select the MACRO-TILE: each grid step processes an
    mrows×mcols group of spatial blocks (whole block-rows, or r×c groups),
    with ``nbt`` blocks stacked per MXU dot inside the group — the grid
    shrinks by mrows·mcols, amortizing per-step overhead at large inputs.
    Ragged block grids zero-pad whole blocks that are stripped on the way
    out. Passing only ``nbt`` (no macro shape) keeps the legacy flat
    grouping as a 1×nbt macro-tile. Tiling NEVER changes numerics.

    ``predecode=True`` (default) runs the bitmask decoder stage host-side at
    trace time — inference weights are static, so the decode is paid once
    per compile instead of once per frame — and hands the kernel the dense
    per-K-block weights. ``predecode=False`` keeps the decoder inside the
    kernel (once per K-block per call, the paper's on-chip decode for
    streaming weights); both are bit-identical and tested against each
    other.
    """
    interpret = auto_interpret(interpret)
    wdense = None
    if predecode:
        kb_total = pw.maskp.shape[0]
        kp_tot = kb_total * pw.kblk
        wd = unpack_conv_weights(pw).reshape(pw.kh * pw.kw, pw.cin, pw.kout)
        wd = np.pad(wd, ((0, 0), (0, 0), (0, kp_tot - pw.kout)))
        wdense = jnp.asarray(
            wd.reshape(pw.kh * pw.kw, pw.cin, kb_total, pw.kblk).transpose(2, 0, 1, 3)
        )
    t_in, n, h, w, _ = x_t.shape
    nbt, mrows, mcols = _normalize_tiling(nbt, mrows, mcols, h // bh, w // bw)
    pad = (pw.kh - 1) // 2
    in_dtype = jnp.float32 if in_bits == 8 else jnp.int8
    flat = _block_layout(
        x_t.reshape((t_in * n,) + x_t.shape[2:]).astype(in_dtype),
        bh=bh,
        bw=bw,
        pad=pad,
        cin_p=pw.cin,
        mr=mrows,
        mc=mcols,
    )
    nb = flat.shape[0] // t_in
    blocks = flat.reshape((t_in, nb) + flat.shape[1:])
    kp = pw.maskp.shape[0] * pw.kblk
    if v0 is None:
        # cold start at v_init (conversion's θ/2 rounding trick); padded
        # channels/blocks get it too but are sliced away on the way out
        v0b = jnp.full((nb, bh, bw, kp), v_init, jnp.float32)
    else:
        v0b = _block_layout_nohalo(
            v0.astype(jnp.float32), bh=bh, bw=bw, cpad=kp, mr=mrows, mc=mcols
        )
    return _dispatch_fused(
        blocks,
        None if predecode else pw.maskp,
        None if predecode else pw.vals,
        affine,
        v0b,
        wdense,
        kh=pw.kh,
        kw=pw.kw,
        kblk=pw.kblk,
        bh=bh,
        bw=bw,
        nbt=nbt,
        mr=mrows,
        mc=mcols,
        t_out=out_t,
        in_bits=in_bits,
        tap_alive=tuple(pw.tap_alive),
        bn_scale=bn_scale,
        threshold=threshold,
        leak=leak,
        reset=reset,
        out_h=h,
        out_w=w,
        batch=n,
        kout=pw.kout,
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Fused LIF
# ---------------------------------------------------------------------------


def fused_lif(
    psum_t: jax.Array,  # (T, M, C) f32 synaptic inputs
    *,
    threshold: float = 0.5,
    leak: float = 0.25,
    mblk: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """LIF over T fully fused in VMEM (no HBM round-trip of the membrane
    potential between steps). Returns int8 spikes (T, M, C)."""
    return sl.fused_lif_pallas(
        psum_t, threshold=threshold, leak=leak, mblk=mblk, interpret=auto_interpret(interpret)
    )


# ---------------------------------------------------------------------------
# Bitmask sparse matmul (paper's format applied to LM FFN weights)
# ---------------------------------------------------------------------------


def pack_matmul_weights(w: np.ndarray, *, kblk: int = 512, nblk: int = 256):
    return bmm.pack_weights(w, kblk=kblk, nblk=nblk)


def bitmask_matmul(
    x: jax.Array,
    packed,
    *,
    mblk: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """x (M, K) f32/bf16 × bitmask-compressed W (K, N) → (M, N) f32."""
    return bmm.bitmask_matmul_pallas(x, packed, mblk=mblk, interpret=auto_interpret(interpret))
