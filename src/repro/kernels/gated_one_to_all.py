"""Pallas TPU kernel for the gated one-to-all product (paper §III-B.1).

TPU-native reformulation of the ASIC dataflow
---------------------------------------------
The ASIC walks nonzero weights one per cycle, broadcasting each against a
576-neuron spatial tile ("one-to-all") with clock-gated accumulates. On TPU
the same decomposition groups by kernel TAP (the (r,c) position in the 3×3
window): for each tap,

    out[(y,x), k] += spikes_shifted_by_tap[(y,x), c] @ W[tap][c, k]

is a (BH·BW, C) × (C, K_BLK) MXU matmul. The sparsity mechanisms map as:

  * zero-WEIGHT skipping  → a tap whose (C × K_BLK) weight block is entirely
    zero is skipped via ``pl.when`` (block-granular analogue of the per-
    weight cycle skip; TPU is SIMD so element-level skip cannot win).
  * bit-mask compression  → weights live in HBM as {bit-packed mask,
    packed nonzero int8 values}; the kernel decodes them ONCE per K-block
    into VMEM scratch (grid order: K outer / spatial-block inner — the
    paper's KTBC order!) and reuses the decoded block across every spatial
    tile. HBM weight traffic is the COMPRESSED size, the paper's −59.1%.
  * zero-ACTIVATION gating → spikes are int8 {0,1}; the multiply itself
    gates, and activation storage is 1 byte (the ASIC used 1 bit; int8 is
    the TPU-native gateable width).
  * spatial parallelism   → one grid step computes an entire 32×18 block-
    convolution tile (576 outputs = the paper's 576 PEs), lanes/sublanes
    replacing the PE array.

Block convolution (paper §II-B) is inherited from the host-side layout: each
spatial tile arrives replicate-padded and independent, so the kernel never
communicates across tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import auto_interpret

# paper tile: 32 wide × 18 tall = 576 PEs
BLOCK_H = 18
BLOCK_W = 32


def _kernel(
    tap_any_ref,  # SMEM (1, taps) int32 — any nonzero weight at tap?
    spikes_ref,  # VMEM (1, BH+2p, BW+2p, C) int8
    maskp_ref,  # VMEM (1, taps, C // 8, KBLK) uint8 — bit-packed over C
    vals_ref,  # VMEM (1, VPAD) int8 — packed nonzero weights, this K-block
    out_ref,  # VMEM (1, BH, BW, KBLK) int32
    wdense_ref,  # scratch VMEM (taps, C, KBLK) int8 — decoded weights
    acc_ref,  # scratch VMEM (BH*BW, KBLK) int32
    *,
    taps: int,
    kh: int,
    kw: int,
    bh: int,
    bw: int,
):
    nb = pl.program_id(1)  # spatial tile index (innermost — weight reuse)

    # ---- decode compressed weights once per K-block (paper: weights stay
    # resident on-chip and are reused across every tile and time step) ----
    @pl.when(nb == 0)
    def _decode():
        words = maskp_ref[0]  # (taps, C//8, KBLK) uint8
        c8 = words.shape[1]
        kblk = words.shape[2]
        # unpack bits along the C axis: bit c lives in word c//8 at position c%8
        expanded = jnp.repeat(words, 8, axis=1)  # (taps, C, KBLK)
        shifts = (jax.lax.broadcasted_iota(jnp.int32, (taps, c8 * 8, kblk), 1) % 8).astype(
            jnp.uint8
        )
        bits = ((expanded >> shifts) & 1).astype(jnp.int32)
        flat = bits.reshape(-1)
        idx = jnp.cumsum(flat) - 1  # position into packed values
        vals = vals_ref[0]
        gathered = jnp.take(vals, jnp.clip(idx, 0, vals.shape[0] - 1), axis=0)
        dense = jnp.where(flat > 0, gathered.astype(jnp.int32), 0)
        wdense_ref[...] = dense.reshape(taps, c8 * 8, kblk).astype(jnp.int8)

    acc_ref[...] = jnp.zeros_like(acc_ref)

    # ---- per-tap gated one-to-all accumulation ----
    for tap in range(taps):
        r, c = tap // kw, tap % kw

        @pl.when(tap_any_ref[0, tap] > 0)  # zero-weight tap: skip entirely
        def _tap(tap=tap, r=r, c=c):
            window = spikes_ref[0, r : r + bh, c : c + bw, :]  # (BH, BW, C)
            s = window.reshape(bh * bw, window.shape[-1])
            w = wdense_ref[tap]  # (C, KBLK) int8
            acc_ref[...] += jax.lax.dot_general(
                s,
                w,
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )

    out_ref[0] = acc_ref[...].reshape(bh, bw, acc_ref.shape[-1])


def gated_one_to_all_pallas(
    spike_blocks: jax.Array,  # (NB, BH+2p, BW+2p, C) int8, replicate-padded
    maskp: jax.Array,  # (KB, taps, C//8, KBLK) uint8
    vals: jax.Array,  # (KB, VPAD) int8
    tap_any: jax.Array,  # (KB, taps) int32
    *,
    kh: int,
    kw: int,
    bh: int = BLOCK_H,
    bw: int = BLOCK_W,
    kblk: int,
    interpret: bool | None = None,
) -> jax.Array:
    """Run the kernel. Returns (NB, BH, BW, KB*KBLK) int32 partial sums.

    ``interpret=None`` auto-detects: compiled Mosaic lowering on TPU,
    interpreter mode on CPU/GPU backends."""
    interpret = auto_interpret(interpret)
    nb_total, ph, pw, cin = spike_blocks.shape
    kb_total, taps, c8, kblk_ = maskp.shape
    assert kblk_ == kblk and taps == kh * kw and c8 * 8 == cin
    assert ph == bh + kh - 1 and pw == bw + kw - 1

    grid = (kb_total, nb_total)  # K outer, spatial inner → KTBC order
    out = pl.pallas_call(
        functools.partial(_kernel, taps=taps, kh=kh, kw=kw, bh=bh, bw=bw),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, taps), lambda kb, nb: (kb, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, ph, pw, cin), lambda kb, nb: (nb, 0, 0, 0)),
            pl.BlockSpec((1, taps, c8, kblk), lambda kb, nb: (kb, 0, 0, 0)),
            pl.BlockSpec((1, vals.shape[1]), lambda kb, nb: (kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, bh, bw, kblk), lambda kb, nb: (nb, 0, 0, kb)),
        out_shape=jax.ShapeDtypeStruct((nb_total, bh, bw, kb_total * kblk), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((taps, cin, kblk), jnp.int8),
            pltpu.VMEM((bh * bw, kblk), jnp.int32),
        ],
        interpret=interpret,
    )(tap_any, spike_blocks, maskp, vals)
    return out
