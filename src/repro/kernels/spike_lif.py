"""Fused LIF Pallas kernel: the whole T loop runs with the membrane
potential resident in VMEM (the ASIC keeps Vmem in PE registers across the
time loop — same insight, TPU memory hierarchy).

Without fusion, T LIF steps cost 2·T HBM round-trips of the potential; fused
they cost one read of the synaptic inputs and one write of the spikes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import auto_interpret


def _kernel(x_ref, out_ref, *, threshold: float, leak: float, t: int):
    v = jnp.zeros(x_ref.shape[1:], jnp.float32)
    for step in range(t):  # T is small (≤4): unrolled, v stays in VREGs
        v = v * leak + x_ref[step].astype(jnp.float32)
        s = (v >= threshold).astype(jnp.float32)
        out_ref[step] = s.astype(jnp.int8)
        v = v * (1.0 - s)  # hard reset


def fused_lif_pallas(
    psum_t: jax.Array,  # (T, M, C)
    *,
    threshold: float,
    leak: float,
    mblk: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    interpret = auto_interpret(interpret)
    t, m, c = psum_t.shape
    m_p = (m + mblk - 1) // mblk * mblk
    if m_p != m:
        psum_t = jnp.pad(psum_t, ((0, 0), (0, m_p - m), (0, 0)))
    grid = (m_p // mblk,)
    out = pl.pallas_call(
        functools.partial(_kernel, threshold=threshold, leak=leak, t=t),
        grid=grid,
        in_specs=[pl.BlockSpec((t, mblk, c), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((t, mblk, c), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, m_p, c), jnp.int8),
        interpret=interpret,
    )(psum_t)
    return out[:, :m, :]
