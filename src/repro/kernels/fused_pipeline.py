"""Fused layer-pipeline Pallas kernel (the whole per-layer dataflow in one
dispatch): gated one-to-all conv → FXP rescale → tdBN (inference affine) →
LIF spike/reset, for ALL T time steps, with the membrane accumulator
resident in VMEM scratch across the T loop.

Why fusion is the paper's real speedup
--------------------------------------
The ASIC never materializes per-time-step activations off-chip: spikes flow
PE→PE and the membrane potential lives in PE registers for the whole T loop.
The unfused executor pipeline pays exactly that cost in software — every
layer round-trips (T, N, H, W, C) activations and LIF membranes through HBM
between a conv `pallas_call`, an XLA tdBN, and an XLA LIF scan. This kernel
collapses the full per-layer pipeline into ONE `pallas_call`:

    for t in range(T):                      # static unrolled, T ≤ 4
        acc   = Σ_tap spikes_t ⋆ W[tap]     # int MXU dots, per-tap skip
        y     = acc * fxp_scale             # FXP8 dequant (once, exact)
        y     = c·((y − μ)·rsqrt(σ²+ε))·γ+β # tdBN inference affine
        v     = v·leak + y                  # LIF — v NEVER leaves VMEM
        s_t   = v ≥ θ ; v *= (1 − s_t)      # spike + hard reset

Bit-exactness contract: every float op above is the SAME op in the SAME
order as the unfused `core.plan` → `core.lif.tdbn_apply` → `core.lif.
lif_over_time` pipeline (integer conv accumulation is order-independent;
the affine/LIF chain is element-wise), so fused output is BIT-IDENTICAL to
the dense oracle — tests/conformance/ asserts it against the goldens.

Mixed time steps: a layer with in_T=1, out_T=T (the paper's §II-A mixed
schedule, e.g. conv_block) computes the conv ONCE and reuses the rescaled+
normalized drive for every LIF step — the membrane loop is the only per-T
work.

Bit-serial encode in one dispatch: the 8-bit encoding layer folds its 8 bit
planes *into the input values* — Σ_b 2^b·conv(plane_b, W) = conv(Σ_b 2^b·
plane_b, W) = conv(u8, W) by linearity over exact integers — so encode is
ONE dispatch of this same kernel (in_bits=8 switches the dot to f32, exact
for |acc| < 2^24). This is the TPU-native form of the paper's §III-C.2
bit-serial support: same datapath for both layer types, B folded above the
channel loop. `benchmarks/kernel_bench.py` asserts the single-dispatch
property by counting pallas_call equations in the trace and checks parity
against the literal 8-plane bit-serial reference.

Grid/tiling: grid = (K-blocks, spatial macro-tiles) — K outer, spatial
inner, the paper's KTBC order, so compressed weights are decoded once per
K-block and reused across every spatial tile and time step. Each grid step
processes a MACRO-TILE of ``bpg = mrows·mcols`` spatial blocks (a whole
row of blocks, or an r×c block group — the host layout in ops.py makes
the group contiguous along the block axis): the gated product runs as
``bpg//nbt`` MXU dots of ``nbt`` stacked blocks each, and the FXP rescale,
tdBN affine and LIF update are vectorized across the WHOLE macro-tile.
Large inputs are won here: at 96×128 a per-block grid is 256 steps whose
per-step overhead (block fetch, interpret-loop iteration) dwarfs the
arithmetic — macro-tiles collapse it to a handful of steps per K-block.
Blocks stay independent (each carries its own replicate-padded halo), so
any macro shape is bit-exact with the one-block-per-step dispatch.
``(kblk, nbt, mrows×mcols)`` are the per-layer-shape autotuning knobs
swept by `kernels/autotune.py`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import auto_interpret

# rows of the per-K-block affine parameter bundle (see _affine_bundle in
# ops.py): FXP scale, tdBN mean, rsqrt(var+eps), gamma, beta
AFFINE_ROWS = 5


def _rounded(x: jax.Array) -> jax.Array:
    """Mark ``x`` as a value whose rounded f32 bit pattern the reference
    chain materializes (a product that feeds an add/sub).

    Inside one fused computation XLA/LLVM contracts ``a*b + c`` into an FMA
    (single rounding). On the CPU backend this happens at codegen, below
    HLO, and is measured to survive EVERY in-graph barrier — a bitcast
    round-trip, even ``optimization_barrier`` — so this marker cannot (and
    does not need to) pin eager per-op rounding. What keeps the executors
    bit-identical is that the production dense/gated references are jitted
    graphs of the same ops, so XLA contracts them the same way; the
    conformance suite asserts that end-to-end parity at 0.0. The bitcast
    round-trip is kept because on an actual TPU lowering (Mosaic, not
    interpret mode) the integer view does force materialization, keeping
    the kernel's rounding aligned with its jitted references there too."""
    return jax.lax.bitcast_convert_type(
        jax.lax.bitcast_convert_type(x, jnp.int32), jnp.float32
    )


def _kernel(
    spikes_ref,  # VMEM (t_in, bpg, BH+2p, BW+2p, C) int8 (f32 for in_bits=8)
    *refs,  # packed mode: maskp, vals, affine, v0, spk, mem, wdense scratch
    #         predecoded mode: wdense, affine, v0, spk, mem (no scratch)
    taps: int,
    kh: int,
    kw: int,
    bh: int,
    bw: int,
    bpg: int,  # spatial blocks per grid step (the macro-tile, mrows·mcols)
    nbt: int,  # blocks stacked per MXU dot; divides bpg
    t_in: int,
    t_out: int,
    in_bits: int,
    tap_alive: tuple,  # taps with any nonzero weight (static, pack-time)
    bn_scale: float,  # alpha * threshold (tdBN), a trace-time constant
    threshold: float,
    leak: float,
    reset: str,
    predecode: bool,
    conv_body: bool,  # interpret mode: one lax.conv instead of im2col ops
):
    if predecode:
        # decoder stage already ran (static weights decode once, at plan/
        # trace time — see fused_conv_bn_lif); the kernel consumes the
        # VMEM-resident dense K-block directly
        wdense_ref, affine_ref, v0_ref, spk_ref, mem_ref = refs
    else:
        maskp_ref, vals_ref, affine_ref, v0_ref, spk_ref, mem_ref, wdense_ref = refs
        nbg = pl.program_id(1)  # spatial group index (innermost)

        # ---- decode compressed weights once per K-block (paper: weights
        # stay resident on-chip, reused across tiles and time steps) ----
        @pl.when(nbg == 0)
        def _decode():
            words = maskp_ref[0]  # (taps, C//8, KBLK) uint8
            c8 = words.shape[1]
            kblk = words.shape[2]
            expanded = jnp.repeat(words, 8, axis=1)  # (taps, C, KBLK)
            shifts = (
                jax.lax.broadcasted_iota(jnp.int32, (taps, c8 * 8, kblk), 1) % 8
            ).astype(jnp.uint8)
            bits = ((expanded >> shifts) & 1).astype(jnp.int32)
            flat = bits.reshape(-1)
            idx = jnp.cumsum(flat) - 1  # position into packed values
            vals = vals_ref[0]
            gathered = jnp.take(vals, jnp.clip(idx, 0, vals.shape[0] - 1), axis=0)
            dense = jnp.where(flat > 0, gathered.astype(jnp.int32), 0)
            wdense_ref[...] = dense.reshape(taps, c8 * 8, kblk).astype(jnp.int8)

    kblk = wdense_ref.shape[-1]
    m = bpg * bh * bw
    acc_dtype = jnp.float32 if in_bits == 8 else jnp.int32

    # ---- conv over the macro-tile: bpg//nbt MXU dots, each one
    # (t_in·nbt·bh·bw, live·C)×(live·C, KBLK), covering every live tap and
    # every input time step. The per-block im2col stacks the live taps'
    # shifted windows along a patch axis; dead taps (every weight pruned —
    # common for the 80%-pruned 3×3 kernels) are dropped from BOTH the
    # patch matrix and the weight rows at TRACE time via ``tap_alive``
    # (liveness is a pack-time property, so no runtime cond). Integer
    # accumulation is order-independent, so folding the tap loop into the
    # dot's reduction axis — and splitting the macro-tile into dot groups —
    # is bit-exact with any per-tap, per-block summation. ----
    spk_all = spikes_ref[...]  # one ref read; taps/groups slice the value
    # predecoded input carries a leading (1,) K-block axis; scratch doesn't
    wall = wdense_ref[0] if predecode else wdense_ref[...]
    cin = spk_all.shape[-1]
    ph_, pw_ = spk_all.shape[2], spk_all.shape[3]
    if not tap_alive:
        acc = jnp.zeros((t_in, m, kblk), acc_dtype)
    elif conv_body:
        # interpret mode runs the kernel body as XLA ops on CPU, where one
        # native VALID conv over the WHOLE macro-tile beats the hand im2col
        # (9 slices + stack + dot) by a wide margin — and is where the
        # macro-tile pays off: one conv op per grid step regardless of bpg.
        # Zero (pruned) taps contribute exact zeros, and integer-valued f32
        # accumulation is order-independent, so this is bit-identical to
        # the tap-sliced MXU dots used on hardware.
        if kh == 1 and kw == 1:
            # pointwise: no halo (ph == bh), the conv IS one channel dot —
            # skip the conv op's window machinery entirely
            acc = jax.lax.dot_general(
                spk_all.reshape(t_in * m, cin).astype(jnp.float32),
                wall.reshape(cin, kblk).astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ).reshape(t_in, m, kblk)
        else:
            x = spk_all.reshape(t_in * bpg, ph_, pw_, cin)
            acc = jax.lax.conv_general_dilated(
                x.astype(jnp.float32),
                wall.reshape(kh, kw, cin, kblk).astype(jnp.float32),
                window_strides=(1, 1),
                padding="VALID",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            ).reshape(t_in, m, kblk)
    else:
        w = wall if len(tap_alive) == taps else jnp.stack([wall[t] for t in tap_alive])
        w = w.reshape(len(tap_alive) * cin, kblk)
        if in_bits == 8:
            # multibit u8 input: f32 MXU dot — exact while live·C·255·127
            # < 2^24 (the u8 encode layer has C≤8, far inside the bound)
            w = w.astype(jnp.float32)
        groups = []
        for g0 in range(0, bpg, nbt):  # static unroll: bpg//nbt dot groups
            blk = jax.lax.slice(
                spk_all, (0, g0, 0, 0, 0), (t_in, g0 + nbt, ph_, pw_, cin)
            )
            wins = [
                jax.lax.slice(
                    blk,
                    (0, 0, tap // kw, tap % kw, 0),
                    (t_in, nbt, tap // kw + bh, tap % kw + bw, cin),
                )
                for tap in tap_alive
            ]
            # (t_in, nbt, bh, bw, live, C) → rows ordered exactly like the
            # membrane/output layout, cols ordered [tap, c] like wdense rows
            patches = jnp.stack(wins, axis=-2)
            s = patches.reshape(t_in, nbt * bh * bw, len(tap_alive) * cin)
            groups.append(
                jax.lax.dot_general(
                    s,
                    w,
                    (((2,), (0,)), ((), ())),
                    preferred_element_type=acc_dtype,
                )
            )
        acc = groups[0] if len(groups) == 1 else jnp.concatenate(groups, axis=1)

    scale = affine_ref[0, 0]  # (KBLK,) — FXP scale (scalar, row-broadcast)
    mean = affine_ref[0, 1]
    rinv = affine_ref[0, 2]  # rsqrt(var + eps), precomputed (deterministic)
    gamma = affine_ref[0, 3]
    beta = affine_ref[0, 4]

    # FXP rescale then the tdBN inference affine — op-for-op the unfused
    # core.plan executor + core.lif.tdbn_apply(training=False); element-wise,
    # so applying it to the stacked (t_in·m, KBLK) drive is bit-identical.
    # _rounded pins every product that feeds an add/sub — see its docstring:
    # without it XLA contracts mul+add into FMAs, a silent 1-ulp drift that
    # can flip spikes sitting exactly at threshold.
    # vectorized across the whole macro-tile: one element-wise chain over
    # (t_in, bpg·bh·bw, KBLK), however many dot groups produced the drive
    y_all = _rounded(acc.astype(jnp.float32) * scale)
    x_hat = _rounded((y_all - mean) * rinv)
    drives = _rounded((bn_scale * x_hat) * gamma) + beta

    v = v0_ref[...].reshape(m, kblk)
    for t in range(t_out):  # T ≤ 4: unrolled, v stays in VREGs/VMEM
        # mixed time steps (in_T=1 → out_T=T): one conv drive, T LIF steps
        y = drives[0] if t_in == 1 else drives[t]
        v = _rounded(v * leak) + y
        spiked = v >= threshold
        spk_ref[t] = spiked.reshape(bpg, bh, bw, kblk).astype(jnp.int8)
        if reset == "soft":
            # reset by subtraction: where(s, v−θ, v) ≡ v − s·θ for
            # s ∈ {0,1} (s·θ is exactly 0 or θ, so one subtraction either
            # way — bit-identical to core.lif.lif_step's soft branch)
            v = jnp.where(spiked, v - threshold, v)
        else:
            # hard reset: where(s, 0, v) ≡ v·(1−s) for s ∈ {0,1} (no
            # arithmetic → no rounding, so no _rounded barrier needed;
            # ±0.0 both propagate as exact zero through v·leak + y)
            v = jnp.where(spiked, 0.0, v)
    mem_ref[...] = v.reshape(bpg, bh, bw, kblk)


def fused_pipeline_pallas(
    spike_blocks: jax.Array,  # (t_in, NB, BH+2p, BW+2p, C) int8 (f32 if in_bits=8)
    maskp: jax.Array | None,  # (KB, taps, C//8, KBLK) uint8 (packed mode)
    vals: jax.Array | None,  # (KB, VPAD) int8 (packed mode)
    affine: jax.Array,  # (KB, AFFINE_ROWS, KBLK) f32
    v0_blocks: jax.Array,  # (NB, BH, BW, KB*KBLK) f32
    *,
    kh: int,
    kw: int,
    bh: int,
    bw: int,
    kblk: int,
    nbt: int,
    t_out: int,
    in_bits: int,
    tap_alive: tuple,
    bn_scale: float,
    threshold: float,
    leak: float,
    reset: str = "hard",
    bpg: int | None = None,  # macro-tile: blocks per grid step (default nbt)
    wdense: jax.Array | None = None,  # (KB, taps, C, KBLK) int8 (predecoded)
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """One fused dispatch for a whole layer. Returns
    (spikes (t_out, NB, BH, BW, KB*KBLK) int8, membrane (NB, BH, BW, KB*KBLK) f32).

    Weights arrive either compressed (``maskp``/``vals`` — the kernel runs
    the bitmask decoder once per K-block, the paper's on-chip decode) or
    predecoded (``wdense`` — the decoder stage ran ahead of the kernel; for
    static inference weights it then runs once per COMPILE, not per frame).
    Both modes compute bit-identically.

    ``bpg`` spatial blocks — the macro-tile, e.g. mrows·mcols contiguous
    blocks of the block grid (callers order/pad the block axis so each
    macro group is contiguous and bpg divides NB) — are processed per grid
    step; within a step the conv runs as ``bpg//nbt`` MXU dots of ``nbt``
    stacked blocks each. Grid order is K-blocks outer / macro-tiles inner
    so the decoded weight block is reused across every spatial tile and
    time step.
    """
    interpret = auto_interpret(interpret)
    predecode = wdense is not None
    t_in, nb_total, ph, pw, cin = spike_blocks.shape
    if bpg is None:
        bpg = nbt
    if predecode:
        kb_total, taps, cin_, kblk_ = wdense.shape
        assert cin_ == cin, (cin_, cin)
    else:
        kb_total, taps, c8, kblk_ = maskp.shape
        assert c8 * 8 == cin
    assert kblk_ == kblk and taps == kh * kw
    assert ph == bh + kh - 1 and pw == bw + kw - 1
    assert bpg % nbt == 0, (bpg, nbt)
    assert nb_total % bpg == 0, (nb_total, bpg)
    assert t_in == t_out or t_in == 1, (t_in, t_out)
    assert affine.shape == (kb_total, AFFINE_ROWS, kblk)

    if predecode:
        w_specs = [pl.BlockSpec((1, taps, cin, kblk), lambda kb, nb: (kb, 0, 0, 0))]
        w_inputs = (wdense,)
        scratch = []
    else:
        w_specs = [
            pl.BlockSpec((1, taps, cin // 8, kblk), lambda kb, nb: (kb, 0, 0, 0)),
            pl.BlockSpec((1, vals.shape[1]), lambda kb, nb: (kb, 0)),
        ]
        w_inputs = (maskp, vals)
        scratch = [pltpu.VMEM((taps, cin, kblk), jnp.int8)]

    grid = (kb_total, nb_total // bpg)  # K outer, macro inner → KTBC order
    spk, mem = pl.pallas_call(
        functools.partial(
            _kernel,
            taps=taps,
            kh=kh,
            kw=kw,
            bh=bh,
            bw=bw,
            bpg=bpg,
            nbt=nbt,
            t_in=t_in,
            t_out=t_out,
            in_bits=in_bits,
            tap_alive=tuple(tap_alive),
            bn_scale=bn_scale,
            threshold=threshold,
            leak=leak,
            reset=reset,
            predecode=predecode,
            conv_body=bool(interpret),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_in, bpg, ph, pw, cin), lambda kb, nb: (0, nb, 0, 0, 0)),
            *w_specs,
            pl.BlockSpec((1, AFFINE_ROWS, kblk), lambda kb, nb: (kb, 0, 0)),
            pl.BlockSpec((bpg, bh, bw, kblk), lambda kb, nb: (nb, 0, 0, kb)),
        ],
        out_specs=[
            pl.BlockSpec((t_out, bpg, bh, bw, kblk), lambda kb, nb: (0, nb, 0, 0, kb)),
            pl.BlockSpec((bpg, bh, bw, kblk), lambda kb, nb: (nb, 0, 0, kb)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t_out, nb_total, bh, bw, kb_total * kblk), jnp.int8),
            jax.ShapeDtypeStruct((nb_total, bh, bw, kb_total * kblk), jnp.float32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(spike_blocks, *w_inputs, affine, v0_blocks)
    return spk, mem
