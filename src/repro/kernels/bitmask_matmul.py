"""Bitmask-compressed sparse-weight matmul — the paper's weight format
(§III-B.2) applied to transformer FFN layers.

W (K, N) with fine-grained pruning is stored in HBM as {bit-packed mask,
packed nonzero values}; the kernel decodes each (KBLK, NBLK) tile in VMEM
and feeds the MXU. HBM weight traffic = compressed bytes — for a
memory-bound decode/serving step this directly shrinks the roofline memory
term by (1 − density) · 8/9-ish, mirroring the paper's −59.1% DRAM claim.

Grid (n, m, k): k innermost so the f32 accumulator tile stays in VMEM
scratch until the K reduction completes.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import auto_interpret


class PackedMatmulWeights(NamedTuple):
    maskp: jax.Array  # (KB, NB, KBLK//8, NBLK) uint8, bits packed over K
    vals: jax.Array  # (KB, NB, VPAD) — same dtype as original weights
    shape: tuple  # (K, N) original
    kblk: int
    nblk: int

    @property
    def compressed_bytes(self) -> int:
        return self.maskp.size + self.vals.size * self.vals.dtype.itemsize


def pack_weights(w: np.ndarray, *, kblk: int = 512, nblk: int = 256) -> PackedMatmulWeights:
    w = np.asarray(w)
    k, n = w.shape
    k_p = (k + kblk - 1) // kblk * kblk
    n_p = (n + nblk - 1) // nblk * nblk
    wp = np.zeros((k_p, n_p), w.dtype)
    wp[:k, :n] = w
    kb_t, nb_t = k_p // kblk, n_p // nblk

    maskp = np.zeros((kb_t, nb_t, kblk // 8, nblk), np.uint8)
    vals_list = {}
    vpad = 1
    for kb in range(kb_t):
        for nb in range(nb_t):
            blk = wp[kb * kblk : (kb + 1) * kblk, nb * nblk : (nb + 1) * nblk]
            mask = (blk != 0).astype(np.uint8).reshape(kblk // 8, 8, nblk)
            for b in range(8):
                maskp[kb, nb] |= (mask[:, b, :] << b).astype(np.uint8)
            v = blk[blk != 0].ravel()
            vals_list[(kb, nb)] = v
            vpad = max(vpad, v.size)
    vals = np.zeros((kb_t, nb_t, vpad), w.dtype)
    for (kb, nb), v in vals_list.items():
        vals[kb, nb, : v.size] = v
    return PackedMatmulWeights(
        maskp=jnp.asarray(maskp), vals=jnp.asarray(vals), shape=(k, n), kblk=kblk, nblk=nblk
    )


def _kernel(x_ref, maskp_ref, vals_ref, out_ref, acc_ref, *, kb_total: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # decode this (KBLK, NBLK) weight tile from the compressed form
    words = maskp_ref[0, 0]  # (KBLK//8, NBLK) uint8
    k8, nblk = words.shape
    expanded = jnp.repeat(words, 8, axis=0)  # (KBLK, NBLK)
    shifts = (jax.lax.broadcasted_iota(jnp.int32, (k8 * 8, nblk), 0) % 8).astype(jnp.uint8)
    bits = ((expanded >> shifts) & 1).astype(jnp.int32)
    flat = bits.reshape(-1)
    idx = jnp.cumsum(flat) - 1
    vals = vals_ref[0, 0]
    gathered = jnp.take(vals, jnp.clip(idx, 0, vals.shape[0] - 1))
    dense = jnp.where(flat > 0, gathered.astype(jnp.float32), 0.0).reshape(k8 * 8, nblk)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), dense, preferred_element_type=jnp.float32
    )

    @pl.when(kb == kb_total - 1)
    def _store():
        out_ref[...] = acc_ref[...]


def bitmask_matmul_pallas(
    x: jax.Array, packed: PackedMatmulWeights, *, mblk: int = 256, interpret: bool | None = None
) -> jax.Array:
    interpret = auto_interpret(interpret)
    m, k = x.shape
    k_orig, n_orig = packed.shape
    assert k == k_orig, (k, k_orig)
    kblk, nblk = packed.kblk, packed.nblk
    kb_t = packed.maskp.shape[0]
    nb_t = packed.maskp.shape[1]
    m_p = (m + mblk - 1) // mblk * mblk
    k_p = kb_t * kblk
    if (m_p, k_p) != (m, k):
        x = jnp.pad(x, ((0, m_p - m), (0, k_p - k)))

    grid = (nb_t, m_p // mblk, kb_t)
    out = pl.pallas_call(
        functools.partial(_kernel, kb_total=kb_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((mblk, kblk), lambda nb, mb, kb: (mb, kb)),
            pl.BlockSpec((1, 1, kblk // 8, nblk), lambda nb, mb, kb: (kb, nb, 0, 0)),
            pl.BlockSpec((1, 1, packed.vals.shape[-1]), lambda nb, mb, kb: (kb, nb, 0)),
        ],
        out_specs=pl.BlockSpec((mblk, nblk), lambda nb, mb, kb: (mb, nb)),
        out_shape=jax.ShapeDtypeStruct((m_p, nb_t * nblk), jnp.float32),
        scratch_shapes=[pltpu.VMEM((mblk, nblk), jnp.float32)],
        interpret=interpret,
    )(x, packed.maskp, packed.vals)
    return out[:m, :n_orig]
