"""Backend detection shared by every Pallas kernel wrapper (leaf module so
kernel files can use it without importing ops and creating a cycle)."""
from __future__ import annotations

import jax


def auto_interpret(interpret: bool | None = None) -> bool:
    """Resolve the Pallas ``interpret`` flag: explicit bool wins; ``None``
    auto-detects the backend (compiled Mosaic lowering on TPU, interpreter
    elsewhere — CPU/GPU have no lowering for these kernels)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret
