"""Backend detection shared by every Pallas kernel wrapper (leaf module so
kernel files can use it without importing ops and creating a cycle)."""
from __future__ import annotations

import jax


def auto_interpret(interpret: bool | None = None) -> bool:
    """Resolve the Pallas ``interpret`` flag: explicit bool wins; ``None``
    auto-detects the backend (compiled Mosaic lowering on TPU, interpreter
    elsewhere — CPU/GPU have no lowering for these kernels)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def count_pallas_calls(fn, *args, **kwargs) -> int:
    """Number of ``pallas_call`` equations in ``fn``'s jaxpr (recursing into
    nested sub-jaxprs: pjit, scan, cond bodies). This is the DISPATCH COUNT
    of one traced execution — the verifiable form of "bit-serial encode
    executes as one dispatch" that kernel_bench and the conformance suite
    assert, independent of wall-clock noise."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)

    def walk(jp) -> int:
        n = 0
        for eqn in jp.eqns:
            if eqn.primitive.name == "pallas_call":
                n += 1
            for key in ("jaxpr", "call_jaxpr", "cond_jaxpr", "body_jaxpr",
                        "branches"):
                sub = eqn.params.get(key)
                if sub is None:
                    continue
                subs = sub if isinstance(sub, (tuple, list)) else [sub]
                for s in subs:
                    inner = getattr(s, "jaxpr", s)
                    if hasattr(inner, "eqns"):
                        n += walk(inner)
        return n

    return walk(closed.jaxpr)
