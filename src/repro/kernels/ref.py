"""Pure-jnp oracles for every Pallas kernel (allclose-tested per shape/dtype
sweep in tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import block_conv as bc
from repro.core import lif as lifm


def gated_conv_ref(spikes: jax.Array, w_dense: jax.Array, *, bh: int = 18, bw: int = 32):
    """Block convolution (replicate-padded independent tiles) with dense
    weights — the semantics the gated one-to-all kernel must reproduce.
    spikes NHWC (any int/float), w HWIO. Returns f32."""
    return bc.block_conv2d(
        spikes.astype(jnp.float32), w_dense.astype(jnp.float32), block_h=bh, block_w=bw
    )


def fused_lif_ref(psum_t: jax.Array, *, threshold: float = 0.5, leak: float = 0.25):
    """Scan-based LIF oracle. psum_t (T, M, C) → int8 spikes."""
    spikes, _ = lifm.lif_over_time(
        psum_t.astype(jnp.float32), threshold=threshold, leak=leak, reset="hard"
    )
    return spikes.astype(jnp.int8)


def bitmask_matmul_ref(x: jax.Array, w_dense: jax.Array):
    return jnp.dot(x.astype(jnp.float32), w_dense.astype(jnp.float32))
