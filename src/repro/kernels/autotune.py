"""Per-layer block/grid-shape autotuning for the fused pipeline kernel.

SpikeX-style (arXiv 2505.12292) insight: sparse-SNN speedups come from
block/tiling-shape co-optimization, not arithmetic — the same fused kernel
can be dispatched with different K-block widths (``kblk``, the packed
weight-block granularity), macro-tile shapes (``mrows × mcols``, how many
spatial conv blocks one grid step owns — whole rows of blocks or r×c
groups, collapsing the grid at large inputs), and MXU dot granularities
(``nbt``, how many of the macro-tile's blocks each dot stacks; divides
``mrows·mcols``). None of these knobs changes numerics (integer
accumulation is order-independent, the affine/LIF chain is element-wise),
so tiling is a pure wall-clock search problem.

This module sweeps candidate :class:`TileConfig` s per LAYER SHAPE,
measures the fused dispatch with the same median-of-k wall-clock harness
the kernel benchmarks use (``measure``), and persists the winners in a
deterministic shape→config JSON cache that ``core/plan.py`` consults at
compile time:

    python -m repro.kernels.autotune            # retune the default shapes
    python -m repro.kernels.autotune --input-hw 96x128

Cache contract (tests/test_autotune.py):
  * deterministic — the same entries serialize to byte-identical files
    (sorted keys, fixed separators, no timestamps or wall-clock values);
  * safe — a missing, stale (version-bumped) or corrupt cache silently
    falls back to :data:`DEFAULT_TILE`, and tile choice NEVER changes
    numerics, only speed.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time
import warnings
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_CACHE_PATH = os.path.join(os.path.dirname(__file__), "autotune_cache.json")
CACHE_ENV_VAR = "REPRO_AUTOTUNE_CACHE"
CACHE_VERSION = 2  # v2: macro-tile axis (mrows/mcols) joined the search

KBLK_CANDIDATES = (32, 64, 128)
NBT_CANDIDATES = (1, 2, 4, 8, 16)
# macro-tile edge lengths tried along each block-grid axis (must divide
# the grid edge to be enumerated — ragged macros are legal but waste pad)
MACRO_CANDIDATES = (1, 2, 4, 8, 16, 32)
# dots-per-grid-step granularities tried inside a macro-tile
DOT_GROUP_CANDIDATES = (1, 2, 4)
# candidate tilings must keep (spikes + weights + scratch) under VMEM
VMEM_BUDGET_BYTES = 12 * 2**20
# walls within this fraction of the fastest candidate count as a tie —
# break toward the LARGEST macro-tile (fewest grid steps): per-step
# overhead amortization is the monotone effect the sweep exists to
# exploit, and sub-noise argmin would otherwise pick shapes at random
TIE_MARGIN = 0.05


class TileConfig(NamedTuple):
    """One fused-kernel dispatch shape. ``kblk``: packed K-block width
    (output channels decoded/computed per grid step); ``mrows × mcols``:
    macro-tile of spatial conv blocks each grid step owns; ``nbt``:
    blocks stacked per MXU dot (divides ``mrows·mcols``)."""

    kblk: int = 128
    nbt: int = 1
    mrows: int = 1
    mcols: int = 1


DEFAULT_TILE = TileConfig()


class LayerShape(NamedTuple):
    """Everything the tuner needs to reconstruct a layer's dispatch —
    and the cache key. Batch-agnostic: tuned at N=1; ``nbt`` stays valid
    for larger batches (the block axis only grows)."""

    kh: int
    kw: int
    cin: int  # true (unpadded) input channels
    kout: int  # true output channels
    in_bits: int  # 1 = binary spikes, 8 = u8 encode input
    t_in: int
    t_out: int
    h: int  # feature-map resolution the layer runs at
    w: int
    bh: int  # conv block (grid tile) shape
    bw: int

    @property
    def key(self) -> str:
        return (
            f"conv{self.kh}x{self.kw}_ci{self.cin}_co{self.kout}"
            f"_ib{self.in_bits}_t{self.t_in}-{self.t_out}"
            f"_hw{self.h}x{self.w}_blk{self.bh}x{self.bw}"
        )

    @property
    def n_blocks(self) -> int:
        return (self.h // self.bh) * (self.w // self.bw)


# ------------------------------------------------------------------ cache --


def cache_path(path: str | None = None) -> str:
    return path or os.environ.get(CACHE_ENV_VAR) or DEFAULT_CACHE_PATH


# Paths already complained about — a stale/corrupt cache is consulted once
# per LAYER at plan-build time, so an unguarded warn would fire ~27× per
# detector compile. One warning per cache path per process is enough.
_warned_paths: set[str] = set()


def _warn_once(path: str, detail: str) -> None:
    if path in _warned_paths:
        return
    _warned_paths.add(path)
    warnings.warn(
        f"autotune cache {path!r} ignored ({detail}); all layers fall back "
        f"to the default tiling {tuple(DEFAULT_TILE)} — numerics are "
        "unaffected, only speed. Regenerate with `python -m "
        "repro.kernels.autotune`.",
        RuntimeWarning,
        stacklevel=3,
    )


def load_cache(path: str | None = None) -> dict[str, TileConfig]:
    """Load the shape→tile cache. A missing, corrupt, or version-stale file
    yields {} — callers then run every layer at :data:`DEFAULT_TILE`, which
    is always numerically identical, just untuned. A cache file that EXISTS
    but can't be used (corrupt JSON, version mismatch) warns once per
    process with the path and the found-vs-expected version; a simply
    missing file stays silent (the untuned default is a supported state)."""
    p = cache_path(path)
    try:
        with open(p) as f:
            raw = json.load(f)
    except FileNotFoundError:
        return {}
    except (OSError, json.JSONDecodeError) as e:
        _warn_once(p, f"corrupt: {e}")
        return {}
    if not isinstance(raw, dict) or raw.get("version") != CACHE_VERSION:
        found = raw.get("version") if isinstance(raw, dict) else type(raw).__name__
        _warn_once(
            p, f"version mismatch: found {found!r}, expected {CACHE_VERSION!r}"
        )
        return {}
    out = {}
    for key, cfgd in raw.get("entries", {}).items():
        try:
            out[key] = TileConfig(
                kblk=int(cfgd["kblk"]),
                nbt=int(cfgd["nbt"]),
                mrows=int(cfgd.get("mrows", 1)),
                mcols=int(cfgd.get("mcols", 1)),
            )
        except (KeyError, TypeError, ValueError):
            continue  # one bad entry falls back; the rest stay usable
    return out


def save_cache(entries: dict[str, TileConfig], path: str | None = None) -> str:
    """Serialize deterministically: sorted keys, fixed separators, ONLY the
    chosen configs (never wall-clock samples) — so identical shape sets
    always produce byte-identical cache files."""
    p = cache_path(path)
    payload = {
        "version": CACHE_VERSION,
        "entries": {
            key: {
                "kblk": int(t.kblk),
                "nbt": int(t.nbt),
                "mrows": int(t.mrows),
                "mcols": int(t.mcols),
            }
            for key, t in sorted(entries.items())
        },
    }
    blob = json.dumps(payload, indent=1, sort_keys=True) + "\n"
    with open(p, "w") as f:
        f.write(blob)
    return p


@functools.lru_cache(maxsize=4)
def _default_cache_cached(path: str, mtime: float) -> tuple:
    return tuple(load_cache(path).items())


def lookup(shape: LayerShape, cache: dict[str, TileConfig] | None = None) -> TileConfig:
    """Resolve a layer shape to its tuned tile; DEFAULT_TILE when untuned.
    ``cache=None`` loads the default cache file (mtime-invalidated)."""
    if cache is None:
        p = cache_path()
        try:
            mtime = os.path.getmtime(p)
        except OSError:
            return DEFAULT_TILE
        cache = dict(_default_cache_cached(p, mtime))
    return cache.get(shape.key, DEFAULT_TILE)


# -------------------------------------------------------------- measuring --


def measure(fn: Callable[[], jax.Array], *, iters: int = 5, warmup: int = 1) -> float:
    """Median wall-clock of ``fn`` (which must return a jax array to block
    on) — the same median-of-k discipline as benchmarks/e2e_detector.py,
    shared here so kernel_bench and the tuner time dispatches identically."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    walls = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        walls.append(time.perf_counter() - t0)
    return float(np.median(walls))


def _macro_shapes(nbh: int, nbw: int) -> list[tuple[int, int]]:
    """Macro-tile shapes tried for an nbh×nbw block grid: grow along the
    row first (contiguous blocks), then stack whole rows — i.e. (1, c)
    for c | nbw, then (r, nbw) for r | nbh. This chain covers everything
    from single-block to whole-grid without a quadratic sweep."""
    mcs = [m for m in MACRO_CANDIDATES if m <= nbw and nbw % m == 0]
    mrs = [m for m in MACRO_CANDIDATES if m <= nbh and nbh % m == 0]
    shapes = [(1, mc) for mc in mcs]
    shapes += [(mr, nbw) for mr in mrs if mr > 1 and nbw in mcs]
    return shapes


def candidates(shape: LayerShape) -> list[TileConfig]:
    """Legal tile configs for a layer shape: kblk clipped to the padded
    output width (one tight block minimum, matching build_layer_plan),
    macro-tile shapes from :func:`_macro_shapes` (row-first chain up to
    the whole block grid), nbt a divisor of the macro-tile size keeping
    the per-step dot count small, all capped by a crude VMEM model."""
    kout8 = -(-shape.kout // 8) * 8
    kblks = sorted({min(kb, kout8) for kb in KBLK_CANDIDATES})
    nbh, nbw = shape.h // shape.bh, shape.w // shape.bw
    out = []
    cin_p = -(-shape.cin // 8) * 8
    ph, pw = shape.bh + shape.kh - 1, shape.bw + shape.kw - 1
    in_bytes = 4 if shape.in_bits == 8 else 1
    for kblk in kblks:
        for mr, mc in _macro_shapes(nbh, nbw):
            bpg = mr * mc
            vmem = (
                shape.t_in * bpg * ph * pw * cin_p * in_bytes  # spike tile
                + shape.kh * shape.kw * cin_p * kblk * 2  # maskp+decoded w
                + bpg * shape.bh * shape.bw * kblk * (4 + 4 + shape.t_out)
            )
            if vmem > VMEM_BUDGET_BYTES:
                continue
            nbts = sorted({bpg // g for g in DOT_GROUP_CANDIDATES if bpg % g == 0})
            for nbt in nbts:
                out.append(TileConfig(kblk=kblk, nbt=nbt, mrows=mr, mcols=mc))
    return out or [DEFAULT_TILE]


def _synthetic_layer(shape: LayerShape, rng: np.random.Generator):
    """Deterministic synthetic weights + activations at the layer's shape
    and the paper's sparsity regime (~80% pruned 3×3 kernels)."""
    w = rng.integers(-127, 128, (shape.kh, shape.kw, shape.cin, shape.kout))
    density = 0.2 if shape.kh > 1 else 0.6
    w[rng.random(w.shape) > density] = 0
    w = w.astype(np.int8)
    if shape.in_bits == 8:
        x = rng.integers(0, 256, (shape.t_in, 1, shape.h, shape.w, shape.cin))
        x_t = jnp.asarray(x, jnp.float32)
    else:
        x = rng.random((shape.t_in, 1, shape.h, shape.w, shape.cin)) < 0.25
        x_t = jnp.asarray(x, jnp.float32)
    return w, x_t


def tune_layer(
    shape: LayerShape,
    *,
    threshold: float = 0.5,
    leak: float = 0.25,
    measure_fn: Callable | None = None,
    iters: int = 5,
) -> tuple[TileConfig, dict[str, float]]:
    """Sweep candidate tilings for one layer shape; return (winner, record
    of wall-clock per candidate). ``measure_fn(tile, run) -> seconds`` is
    injectable so tests can drive selection deterministically."""
    from . import ops  # lazy: ops imports nothing from here

    rng = np.random.default_rng(0)
    w, x_t = _synthetic_layer(shape, rng)
    record: dict[str, float] = {}
    walls_by_tile: list[tuple[TileConfig, float]] = []
    for tile in candidates(shape):
        packed = ops.pack_conv_weights(w, kblk=tile.kblk)
        kp = packed.maskp.shape[0] * packed.kblk
        affine = ops.affine_bundle(
            packed,
            jnp.float32(1.0 / 128),
            jnp.zeros((shape.kout,)),
            jnp.ones((shape.kout,)),
            jnp.ones((shape.kout,)),
            jnp.zeros((shape.kout,)),
        )

        # measure the JITTED dispatch: production plans run fused layers
        # inside one jitted detector graph, so the eager python/layout
        # overhead of a bare call (~1ms, constant across tiles) would
        # otherwise drown the real per-tile differences in a shared floor
        @functools.partial(jax.jit, static_argnums=())
        def _fused(x, packed=packed, affine=affine, tile=tile):
            spk, mem = ops.fused_conv_bn_lif(
                x,
                packed,
                affine,
                v0=None,
                out_t=shape.t_out,
                in_bits=shape.in_bits,
                bn_scale=threshold,
                threshold=threshold,
                leak=leak,
                bh=shape.bh,
                bw=shape.bw,
                nbt=tile.nbt,
                mrows=tile.mrows,
                mcols=tile.mcols,
            )
            return mem

        def run():
            return _fused(x_t)

        wall = (
            measure_fn(tile, run)
            if measure_fn is not None
            else measure(run, iters=iters)
        )
        record[f"kblk{tile.kblk}_nbt{tile.nbt}_mt{tile.mrows}x{tile.mcols}"] = wall
        walls_by_tile.append((tile, wall))
    if not walls_by_tile:
        return DEFAULT_TILE, record
    best_wall = min(w for _, w in walls_by_tile)
    # noise-aware winner: among walls within TIE_MARGIN of the fastest,
    # take the largest macro-tile (then coarsest dots, then widest kblk)
    near = [(t, w) for t, w in walls_by_tile if w <= best_wall * (1 + TIE_MARGIN)]
    best = max(near, key=lambda tw: (tw[0].mrows * tw[0].mcols, tw[0].nbt,
                                     tw[0].kblk))[0]
    return best, record


def detector_layer_shapes(cfg) -> dict[str, LayerShape]:
    """Every fused-eligible conv layer of an ``SNNDetConfig`` as
    :class:`LayerShape` s (the head has no tdBN/LIF and is not fused)."""
    from repro.models import snn_yolo as sy  # lazy: avoid import cycle

    bh, bw = cfg.block_hw
    out = {}
    for spec in sy.layer_specs(cfg):
        if spec.name == "head":
            continue
        out[spec.name] = LayerShape(
            kh=spec.k,
            kw=spec.k,
            cin=spec.cin,
            kout=spec.cout,
            in_bits=spec.bits_in,
            t_in=spec.t_in,
            t_out=spec.t_out,
            h=spec.h,
            w=spec.w,
            bh=bh,
            bw=bw,
        )
    return out


def tune_detector(
    cfg,
    *,
    measure_fn: Callable | None = None,
    iters: int = 5,
    verbose: bool = True,
) -> dict[str, TileConfig]:
    """Tune every distinct fused layer shape of a detector config; returns
    cache entries (key → TileConfig)."""
    entries: dict[str, TileConfig] = {}
    for name, shape in sorted(detector_layer_shapes(cfg).items()):
        if shape.key in entries:
            continue
        tile, record = tune_layer(
            shape,
            threshold=cfg.threshold,
            leak=cfg.leak,
            measure_fn=measure_fn,
            iters=iters,
        )
        entries[shape.key] = tile
        if verbose:
            walls = ", ".join(f"{k}={v*1e3:.2f}ms" for k, v in sorted(record.items()))
            print(
                f"  {name:20s} {shape.key}\n    -> kblk={tile.kblk} "
                f"nbt={tile.nbt} macro={tile.mrows}x{tile.mcols}   ({walls})"
            )
    return entries


def check_cache(cfgs, path: str | None = None) -> list[str]:
    """Return the cache keys required by ``cfgs`` that the committed cache
    is MISSING (empty list = fully covered). A stale or corrupt cache
    loads as {} and therefore reports every key missing — exactly the
    state `make check-autotune` exists to catch, since lookup() would
    silently fall back to DEFAULT_TILE for all of them."""
    cache = load_cache(path)
    missing = []
    for cfg in cfgs:
        for name, shape in sorted(detector_layer_shapes(cfg).items()):
            if shape.key not in cache and shape.key not in missing:
                missing.append(shape.key)
    return missing


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--input-hw", default=None,
                    help="HxW override for the tuned config (e.g. 96x128)")
    ap.add_argument("--out", default=None, help="cache path (default: packaged)")
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument(
        "--check", action="store_true",
        help="don't tune: fail (exit 1) if the committed cache is missing "
        "entries for the benchmarked configs (default + --input-hw)",
    )
    args = ap.parse_args(argv)

    import dataclasses

    from benchmarks.e2e_detector import reduced_config

    cfgs = [reduced_config()]
    if args.input_hw:
        h, w = (int(v) for v in args.input_hw.lower().split("x"))
        cfgs.append(dataclasses.replace(cfgs[0], input_hw=(h, w)))

    if args.check:
        missing = check_cache(cfgs, args.out)
        if missing:
            print(f"autotune cache {cache_path(args.out)} is missing "
                  f"{len(missing)} entr{'y' if len(missing) == 1 else 'ies'}:")
            for key in missing:
                print(f"  {key}")
            print("regenerate with: python -m repro.kernels.autotune"
                  + (f" --input-hw {args.input_hw}" if args.input_hw else ""))
            return 1
        print(f"autotune cache covers all {len(cfgs)} benchmarked config(s)")
        return 0

    entries = load_cache(args.out)
    for cfg in cfgs:
        print(f"tuning {cfg.arch_id} @ {cfg.input_hw[0]}x{cfg.input_hw[1]}")
        entries.update(tune_detector(cfg, iters=args.iters))
    path = save_cache(entries, args.out)
    print(f"wrote {len(entries)} entries -> {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
