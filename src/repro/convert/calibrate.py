"""Channel-wise threshold calibration for ANN→SNN conversion.

Spiking-YOLO's channel-norm insight (arXiv 1903.06530): a rate-coded SNN
neuron can only represent activations in ``[0, λ]`` per time window, so
each channel needs its own normalization constant ``λ_c`` — a PERCENTILE
of its observed post-ReLU activations (the max is an outlier magnet and
starves the channel's firing rate).

This module runs the imported ANN over a calibration split with the exact
conv semantics of the SNN target (u8-quantized input grid, block conv)
and collects per layer:

  * ``lam`` — per-channel percentile of the post-ReLU activation,
  * ``mean``/``var`` — per-channel statistics of the BIAS-FREE conv
    output (what the SNN executor computes), re-derived tdBN running
    statistics come from these,
  * for the encode layer (fires ONCE, in_T=1): the spike-conditional mean
    activation ``spike_value`` — a 1-step binary spike carries this value
    into the next layer, not ``λ``.

The reference forward here is intentionally standalone (pure conv→folded
BN→ReLU) and is pinned against ``snn_yolo.forward(mode="ann")`` by
tests/test_convert.py — drift between the two is a test failure.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.convert import importer as imp
from repro.core import block_conv as bc
from repro.models import snn_yolo as sy


def quantize_images_u8(images) -> jnp.ndarray:
    """Snap [0,1] images to the u8 grid the compressed encode layer
    consumes (``core/plan._quantize_input_u8``: bit-serial 8-bit input) —
    calibration must see the same pixels the SNN will."""
    x = jnp.clip(jnp.asarray(images, jnp.float32), 0.0, 1.0)
    return jnp.round(x * 255.0) / 255.0


@dataclasses.dataclass(frozen=True)
class LayerStats:
    """Per-channel calibration results for one conv+BN layer."""

    lam: np.ndarray  # (C,) percentile of relu(conv + b̃)
    mean: np.ndarray  # (C,) mean of the bias-free conv output
    var: np.ndarray  # (C,)
    spike_value: Optional[np.ndarray] = None  # (C,) encode only
    spike_frac: Optional[np.ndarray] = None  # (C,) encode duty realized


@dataclasses.dataclass(frozen=True)
class CalibrationStats:
    layers: dict  # name -> LayerStats
    head: np.ndarray  # (N, gh, gw, A, 5+C) ANN head outputs (readout fit)
    n_images: int
    percentile: float


def _maxpool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _conv(x, w, *, use_block_conv: bool, block_hw):
    if use_block_conv and w.shape[0] > 1:
        bh, bw = block_hw
        return bc.block_conv2d(x, w, block_h=bh, block_w=bw)
    return bc.conv2d(x, w)


def ann_reference_forward(
    ann: imp.AnnDetector,
    images,
    *,
    taps: Optional[dict] = None,
    use_block_conv: Optional[bool] = None,
    block_hw=None,
    quantize_input: bool = True,
):
    """Plain conv→folded-BN→ReLU forward of the imported ANN.

    ``taps``, when given, collects each layer's BIAS-FREE conv output
    (``conv(a_in, w_tilde)``, shape (N, H, W, C)) under its name plus the
    raw head conv output under "head". Conv semantics (block vs SAME,
    input u8 grid) default to the ANN config but should be overridden to
    the CONVERSION TARGET's settings during calibration.

    Returns the head predictions reshaped to (N, gh, gw, A, 5+C) — the
    same contract as ``snn_yolo.forward``.
    """
    cfg = ann.cfg
    ubc = cfg.use_block_conv if use_block_conv is None else use_block_conv
    bhw = tuple(block_hw or cfg.block_hw)

    a = (
        quantize_images_u8(images)
        if quantize_input
        else jnp.asarray(images, jnp.float32)
    )

    def layer(a_in, name):
        w_t, b_t = ann.folded(name)
        c = _conv(a_in, jnp.asarray(w_t), use_block_conv=ubc, block_hw=bhw)
        if taps is not None:
            taps[name] = c
        return jax.nn.relu(c + jnp.asarray(b_t))

    a = _maxpool(layer(a, "encode"))
    a = _maxpool(layer(a, "conv_block"))
    for i in range(len(cfg.stage_channels)):
        short = layer(a, f"stage{i}/shortcut")
        m = layer(a, f"stage{i}/main_in")
        m = layer(m, f"stage{i}/main_a")
        m = layer(m, f"stage{i}/main_b")
        a = layer(jnp.concatenate([m, short], axis=-1), f"stage{i}/agg")
        if i < cfg.pooled_stages - 1:
            a = _maxpool(a)
    head = _conv(a, jnp.asarray(ann.head_w), use_block_conv=ubc, block_hw=bhw)
    if taps is not None:
        taps["head"] = head
    n, gh, gw, _ = head.shape
    return head.reshape(n, gh, gw, cfg.num_anchors, 5 + cfg.num_classes)


def calibrate(
    ann: imp.AnnDetector,
    images,
    *,
    percentile: float = 99.7,
    encode_duty: float = 0.5,
    batch: int = 8,
    use_block_conv: bool = True,
    block_hw=None,
) -> CalibrationStats:
    """Collect per-channel λ / conv statistics over a calibration set.

    ``percentile`` ∈ (0, 100]: coverage of the activation distribution one
    full-rate spike train represents (λ is monotone non-decreasing in it —
    property-tested). ``encode_duty``: the duty point τ of the 1-step
    encode layer — a channel spikes iff its activation ≥ τ·λ_c; the
    recorded ``spike_value`` is the conditional mean activation above that
    point (what the single spike is worth downstream).
    """
    images = np.asarray(images)
    n = images.shape[0]
    fwd = jax.jit(
        lambda imgs: _tapped(ann, imgs, use_block_conv, block_hw)
    )
    names = imp.conv_bn_layer_names(ann.cfg)
    acc: dict[str, list] = {name: [] for name in names}
    heads = []
    for i in range(0, n, batch):
        taps, head = fwd(jnp.asarray(images[i : i + batch]))
        for name in names:
            c = np.asarray(taps[name])
            acc[name].append(c.reshape(-1, c.shape[-1]))
        heads.append(np.asarray(head))

    layers = {}
    for name in names:
        c = np.concatenate(acc[name], axis=0)  # (samples, C)
        _, b_t = ann.folded(name)
        act = np.maximum(c + b_t, 0.0)  # post-ReLU, ANN units
        lam = np.percentile(act, percentile, axis=0).astype(np.float32)
        stats = dict(
            lam=lam,
            mean=c.mean(axis=0).astype(np.float32),
            var=c.var(axis=0).astype(np.float32),
        )
        if name == "encode":
            thresh = encode_duty * lam  # (C,)
            fired = act >= np.maximum(thresh, 1e-12)
            cnt = fired.sum(axis=0)
            total = np.where(fired, act, 0.0).sum(axis=0)
            stats["spike_value"] = np.where(
                cnt > 0, total / np.maximum(cnt, 1), thresh
            ).astype(np.float32)
            stats["spike_frac"] = (cnt / act.shape[0]).astype(np.float32)
        layers[name] = LayerStats(**stats)
    return CalibrationStats(
        layers=layers,
        head=np.concatenate(heads, axis=0),
        n_images=n,
        percentile=percentile,
    )


def _tapped(ann, imgs, use_block_conv, block_hw):
    taps: dict = {}
    head = ann_reference_forward(
        ann, imgs, taps=taps,
        use_block_conv=use_block_conv, block_hw=block_hw,
    )
    return taps, head
