"""ANN→SNN conversion front-end (Spiking-YOLO-style, arXiv 1903.06530).

Imports a pretrained dense conv+BN YOLO detector from an ``.npz`` bundle,
calibrates per-channel firing thresholds on a ``DetectionSource`` split,
and emits an ``SNNDetConfig`` + parameter tree that drops straight into
``core/plan.build_plan`` (prune→FXP8→bitmask-pack) and the self-describing
detector checkpoint format — no training steps anywhere.

    ann = convert.load_ann_npz("tests/fixtures/ann_detector/ann_tiny_yolo.npz")
    out = convert.convert_ann(ann)
    out.save("/tmp/converted")          # serve.py --checkpoint /tmp/converted
"""
from repro.convert.importer import (  # noqa: F401
    FORMAT,
    AnnConvBN,
    AnnDetector,
    conv_bn_layer_names,
    export_ann_npz,
    load_ann_npz,
)
from repro.convert.calibrate import (  # noqa: F401
    CalibrationStats,
    LayerStats,
    ann_reference_forward,
    calibrate,
    quantize_images_u8,
)
from repro.convert.emit import (  # noqa: F401
    ConvertConfig,
    ConvertedDetector,
    convert_ann,
    readout_scale,
)
