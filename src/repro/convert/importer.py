"""Dense ANN detector import/export: the ``.npz`` interchange format.

One file carries a full pretrained conv+BN YOLO detector with the repo's
topology (``snn_yolo.init_params`` layer plan):

  * ``__meta__`` — JSON blob: ``{"format": "repro-ann-detector/1",
    "config": snn_yolo.config_to_dict(cfg), "eps": <bn epsilon>}``. The
    embedded config makes the bundle self-describing — the importer
    rebuilds the exact ``SNNDetConfig`` (channel plan, input resolution)
    and validates every array against ``jax.eval_shape(init_params)``.
  * ``<layer>/w|gamma|beta|mean|var`` — STANDARD BatchNorm parameters per
    conv layer (``encode``, ``conv_block``, ``stage{i}/{shortcut,main_in,
    main_a,main_b,agg}``): ``y = gamma·(conv(x)+bias−mean)/sqrt(var+eps)+
    beta`` followed by ReLU. Any npz-exported tiny YOLO with matching
    layer shapes loads — the repo's own tdBN-trained ANN mode exports via
    :func:`export_ann_npz` (tdBN's ``alpha·threshold`` factor folds into
    the standard gamma).
  * ``<layer>/bias`` — optional conv bias (repo-trained ANNs have none).
  * ``head/w`` — the 1×1 YOLOv2 head kernel (no BN, no bias: the SNN head
    is a pure membrane-readout conv, so a biased head cannot convert).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

import jax
import numpy as np

from repro.models import snn_yolo as sy

FORMAT = "repro-ann-detector/1"
META_KEY = "__meta__"
BN_KEYS = ("w", "gamma", "beta", "mean", "var")


def conv_bn_layer_names(cfg: sy.SNNDetConfig) -> list[str]:
    """Conv+BN layer names in forward (topological) order — every layer of
    ``init_params`` except the BN-free head."""
    names = ["encode", "conv_block"]
    for i in range(len(cfg.stage_channels)):
        names += [
            f"stage{i}/shortcut", f"stage{i}/main_in",
            f"stage{i}/main_a", f"stage{i}/main_b", f"stage{i}/agg",
        ]
    return names


@dataclasses.dataclass(frozen=True)
class AnnConvBN:
    """One dense conv layer with standard (already-θ-folded) BatchNorm."""

    w: np.ndarray  # (kh, kw, cin, cout) HWIO
    gamma: np.ndarray  # (cout,)
    beta: np.ndarray
    mean: np.ndarray
    var: np.ndarray
    bias: Optional[np.ndarray] = None  # (cout,) conv bias, usually absent


@dataclasses.dataclass(frozen=True)
class AnnDetector:
    """A validated imported ANN detector, ready for calibration."""

    cfg: sy.SNNDetConfig  # the source architecture (mode forced to "ann")
    layers: dict  # name -> AnnConvBN, forward order
    head_w: np.ndarray  # (1, 1, cin, head_channels)
    eps: float = 1e-5

    def folded(self, name: str) -> tuple[np.ndarray, np.ndarray]:
        """BN folded into the conv: returns ``(w_tilde, b_tilde)`` with
        ``BN(conv(x, w) + bias) == conv(x, w_tilde) + b_tilde`` exactly
        (eval-mode running statistics)."""
        l = self.layers[name]
        s = l.gamma / np.sqrt(l.var + self.eps)  # (cout,)
        w_tilde = (l.w * s).astype(np.float32)
        bias = l.bias if l.bias is not None else 0.0
        b_tilde = (l.beta + s * (bias - l.mean)).astype(np.float32)
        return w_tilde, b_tilde


def export_ann_npz(path: str, params, bn_state, cfg: sy.SNNDetConfig, *,
                   eps: float = 1e-5) -> str:
    """Export a repo-trained ANN-mode detector (``snn_yolo`` trees) as a
    format-v1 npz bundle.

    The repo's ANN mode normalizes with tdBN, whose eval-time affine is
    ``y = θ·γ·(x−mean)·rsqrt(var+eps) + β`` (alpha=1) — a standard BN with
    ``gamma_std = θ·γ``; the threshold factor folds in here so importers
    see plain BatchNorm semantics.
    """
    arrays: dict[str, np.ndarray] = {}
    for name in conv_bn_layer_names(cfg):
        arrays[f"{name}/w"] = np.asarray(params[name]["w"], np.float32)
        arrays[f"{name}/gamma"] = np.asarray(
            cfg.threshold * params[name]["gamma"], np.float32
        )
        arrays[f"{name}/beta"] = np.asarray(params[name]["beta"], np.float32)
        arrays[f"{name}/mean"] = np.asarray(bn_state[name]["mean"], np.float32)
        arrays[f"{name}/var"] = np.asarray(bn_state[name]["var"], np.float32)
    arrays["head/w"] = np.asarray(params["head"]["w"], np.float32)
    meta = {
        "format": FORMAT,
        "config": sy.config_to_dict(dataclasses.replace(cfg, mode="ann")),
        "eps": eps,
    }
    arrays[META_KEY] = np.frombuffer(
        json.dumps(meta, sort_keys=True).encode(), dtype=np.uint8
    )
    np.savez(path, **arrays)
    return path


def load_ann_npz(path: str) -> AnnDetector:
    """Load + validate a format-v1 bundle into an :class:`AnnDetector`.

    Raises ``ValueError`` with the full missing-vs-unexpected key lists or
    the first shape mismatch — a bundle either loads completely or not at
    all (no partially-imported detectors).
    """
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    if META_KEY not in arrays:
        raise ValueError(f"{path}: not an ANN detector bundle ({META_KEY} missing)")
    meta = json.loads(arrays.pop(META_KEY).astype(np.uint8).tobytes())
    if meta.get("format") != FORMAT:
        raise ValueError(
            f"{path}: format {meta.get('format')!r}, expected {FORMAT!r}"
        )
    cfg = dataclasses.replace(
        sy.config_from_dict(meta["config"]), mode="ann"
    )
    eps = float(meta.get("eps", 1e-5))

    names = conv_bn_layer_names(cfg)
    expected = {f"{n}/{k}" for n in names for k in BN_KEYS} | {"head/w"}
    optional = {f"{n}/bias" for n in names}
    got = set(arrays)
    missing = sorted(expected - got)
    unexpected = sorted(got - expected - optional)
    if missing or unexpected:
        raise ValueError(
            f"{path}: bad key set — missing {missing or 'none'}, "
            f"unexpected {unexpected or 'none'}"
        )

    # shape-check every array against the architecture the meta declares
    p_shapes, bn_shapes = jax.eval_shape(
        lambda k: sy.init_params(k, cfg), jax.random.PRNGKey(0)
    )
    def check(key, want):
        have = arrays[key].shape
        if tuple(have) != tuple(want):
            raise ValueError(
                f"{path}: {key} has shape {tuple(have)}, "
                f"config expects {tuple(want)}"
            )
    layers = {}
    for n in names:
        check(f"{n}/w", p_shapes[n]["w"].shape)
        for k in ("gamma", "beta"):
            check(f"{n}/{k}", p_shapes[n][k].shape)
        for k in ("mean", "var"):
            check(f"{n}/{k}", bn_shapes[n][k].shape)
        bias = arrays.get(f"{n}/bias")
        if bias is not None:
            check(f"{n}/bias", p_shapes[n]["beta"].shape)
        layers[n] = AnnConvBN(
            w=np.asarray(arrays[f"{n}/w"], np.float32),
            gamma=np.asarray(arrays[f"{n}/gamma"], np.float32),
            beta=np.asarray(arrays[f"{n}/beta"], np.float32),
            mean=np.asarray(arrays[f"{n}/mean"], np.float32),
            var=np.asarray(arrays[f"{n}/var"], np.float32),
            bias=None if bias is None else np.asarray(bias, np.float32),
        )
    check("head/w", p_shapes["head"]["w"].shape)
    return AnnDetector(
        cfg=cfg, layers=layers,
        head_w=np.asarray(arrays["head/w"], np.float32), eps=eps,
    )
