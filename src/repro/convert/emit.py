"""Emit a converted SNN detector from an imported ANN + calibration stats.

The rescale (channel-norm, Spiking-YOLO arXiv 1903.06530, adapted to this
repo's tdBN+LIF stack):

Per hidden conv layer, with folded ANN weights ``w̃/b̃`` and per-channel
norm ``λ_c``, the SNN should drive each LIF step with

    y_c = (θ·g / λ_c) · (a_conv_c + b̃_c)            (g = drive gain)

so a channel at its λ-covered activation fires at full rate. Three pieces
realize that EXACTLY inside the existing executor + tdBN machinery, with
the LIF threshold untouched at the paper's fixed θ=0.5:

  1. **Input scaling** — spikes are worth ``in_value_c`` ANN units (the
     producing layer's λ_c/g; the 1-step encode's conditional mean), so
     the ANN-unit conv output is ``conv(spikes, w̃ · in_value)``.
  2. **Per-output-channel conditioning** — the plan quantizes FXP8
     per-TENSOR; stored weights are pre-scaled by ``d_c = max|W|/max|W_c|``
     so every output channel spans the full int8 range (per-channel
     resolution for free), and ``d_c`` is divided back out in the affine.
     Dead channels (``max|W_c| = 0``) keep ``d_c = 1`` — the S1 quantize
     guard covers the all-zero slices this produces.
  3. **tdBN as the affine carrier** — with re-derived running statistics
     set to the calibrated conv-output stats (μ_c, σ²_c in EXECUTOR
     units), tdBN's eval-time transform ``θ·γ_c·(x−μ_c)·rsqrt(σ²_c+eps)+
     β_c`` equals the target affine when

         γ_c = g·sqrt(σ²_c+eps) / (λ_c·d_c)
         β_c = θ·g·(mean_c + b̃_c) / λ_c

     (mean_c in ANN units). The stats are REAL statistics of the layer's
     conv output, so downstream consumers (finetuning, bn recalibration)
     see a well-formed tdBN state, and the identity holds to float
     rounding — property-tested in tests/test_convert.py.

The encode layer fires once (in_T=1): it spikes iff activation ≥ τ·λ_c
(duty point τ = ``ConvertConfig.encode_duty``), realized as the same
affine with ``λ_c → τ·λ_c``; its downstream ``in_value`` is the
calibrated spike-conditional mean. The BN-free head is rescaled by its
input values and divided by the membrane-readout gain ρ(T, leak) —
analytic, or least-squares-fitted against the ANN head on the
calibration set (default).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.convert import importer as imp
from repro.convert.calibrate import (
    LayerStats,
    ann_reference_forward,
    calibrate as _calibrate,
)
from repro.core import plan as cplan
from repro.models import snn_yolo as sy


@dataclasses.dataclass(frozen=True)
class ConvertConfig:
    """Knobs of the conversion. Defaults are the fixture-tuned settings
    (examples/convert_ann_detector.py sweeps them)."""

    percentile: float = 99.7  # λ coverage of the activation distribution
    calib_images: int = 32
    calib_batch: int = 8
    split: str = "train"  # calibration split (never the eval split)
    # rate-code resolution of the converted net. Accuracy climbs steadily
    # with T (the rate code quantizes every activation to T levels and
    # deep layers compound the rounding): the committed fixture scores
    # mAP 0.30 at T=64 and 0.39 at T=128 on the 48-image synthetic eval
    # split. 128 is the accuracy default; drop it for latency experiments.
    full_t: int = 128
    leak: float = 1.0  # 1.0 = pure integrate-and-fire (classic conversion)
    # LIF reset of the CONVERTED net: "soft" (reset by subtraction) makes
    # the realized rate track clamp(drive/θ) with O(1/T) error; the
    # training default "hard" loses the overshoot on every spike — an O(1)
    # per-layer attenuation that compounds through depth and is the main
    # reason classic hard-reset conversion needs T in the hundreds.
    reset: str = "soft"
    # cold-start membrane as a fraction of θ: 0.5 turns the spike count
    # floor(T·y/θ) into round(T·y/θ) — an unbiased rate code, which helps
    # at small T. At the T=128 default the floor bias is negligible and
    # the round-UP of near-zero drives instead seeds a background spike
    # noise floor (0.393 at 0.0 vs 0.377 at 0.5 on the fixture), so the
    # default is 0; set 0.25–0.5 when running at T ≤ 64.
    v_init_frac: float = 0.0
    # pool tdBN drives instead of OR-ing spike trains (snn_yolo.
    # SNNDetConfig.pool_drive). Only sound when drives are constant over
    # the T loop: with spiking inputs the per-step max switches winners
    # and Σ_t max_i y > max_i Σ_t y, inflating the background noise floor
    # — measurably WORSE than the OR gate on the fixture, so off by
    # default; kept as a knob for constant-drive topologies.
    pool_drive: bool = False
    # rate-coded encode (snn_yolo.SNNDetConfig.rate_encode): the encode
    # layer emits a spike TRAIN over full_t instead of the paper's 1-step
    # binary plane. Required for useful converted accuracy — a 1-bit
    # front-end destroys what the pretrained ANN expects to see; the duty-
    # point path below stays for the paper-faithful (1, T) topology.
    rate_encode: bool = True
    encode_duty: float = 0.5  # τ: 1-step encode spikes iff act ≥ τ·λ_c
    gain: float = 1.0  # hidden-layer drive gain (hard-reset compensation)
    # value-calibration passes: re-run the CONVERTED net on the calibration
    # images (taps= capture of every layer's LIF drive), reconstruct the
    # spike trains, and least-squares refit each channel's spike value
    # v_c = Σ(a·r)/Σ(r²) against the clipped ANN activation. This absorbs
    # the two systematic rate-coding losses the analytic λ/g value cannot
    # see — hard-reset overshoot (rate ≈ 1/ceil(θ/y) < y/θ) and the
    # OR-gate inflation of max-pooling spike trains. EXPERIMENTAL, off by
    # default: the joint per-layer refit chases the pool inflation it
    # itself changes between passes and can diverge; with soft reset +
    # v_init the analytic values are already near-unbiased.
    calib_passes: int = 0
    # spike max-pool of the converted net (snn_yolo.SNNDetConfig.
    # pool_mode): "rate" = rate-gated pooling — each 2×2 window passes
    # the current spike of the input with the highest running spike
    # count, so the pooled rate tracks the ANN's max instead of the OR
    # gate's union rate (which inflates every pooled layer's input).
    pool_mode: str = "rate"
    # head readout (snn_yolo.SNNDetConfig.head_readout): "final" = final
    # membrane / T, weighting every step equally. The paper's "mean"
    # readout weights a step-t spike by (T−t+1)/T — under rate coding
    # low-rate neurons fire LATE, so "mean" systematically crushes
    # exactly the small activations the detection head discriminates on.
    head_readout: str = "final"
    conv_exec: str = "gated"
    head_scale: str = "empirical"  # "empirical" | "analytic"
    dead_eps: float = 1e-6  # λ below this (ANN units) = dead channel


def readout_scale(full_t: int, leak: float, mode: str = "mean") -> float:
    """Gain of the spiking head readout for a CONSTANT per-step input y:
    out = ρ·y. ``mode="mean"`` is ``membrane_readout``'s time-averaged
    membrane, ρ = (1/T)·Σ_{k=1..T} Σ_{j=0..k-1} leak^j; ``mode="final"``
    is final membrane / T, ρ = (1/T)·Σ_{j=0..T-1} leak^j (= 1 at
    leak=1)."""
    vs, v = [], 0.0
    for _ in range(full_t):
        v = v * leak + 1.0
        vs.append(v)
    if mode == "final":
        return float(vs[-1] / full_t)
    return float(np.mean(vs))


@dataclasses.dataclass(frozen=True)
class ConvertedDetector:
    """The emitted bundle: drops into ``compile_detector`` / the detector
    checkpoint format with zero special-casing."""

    cfg: sy.SNNDetConfig
    params: dict
    bn_state: dict
    report: dict

    REPORT_FILE = "conversion_report.json"

    def save(self, root: str, *, step: int = 0) -> str:
        """Commit as a self-describing detector checkpoint; the conversion
        report rides along as an atomic sidecar."""
        from repro.eval import harness

        blob = json.dumps(self.report, indent=1, sort_keys=True).encode()
        return harness.save_detector_checkpoint(
            root, step, self.params, self.bn_state, self.cfg,
            extra_files={self.REPORT_FILE: blob},
        )


def target_config(ann_cfg: sy.SNNDetConfig, cc: ConvertConfig) -> sy.SNNDetConfig:
    return dataclasses.replace(
        ann_cfg,
        arch_id=f"{ann_cfg.arch_id}-converted",
        mode="snn",
        weight_bits=8,
        use_block_conv=True,
        mixed_time=True,
        full_t=cc.full_t,
        leak=cc.leak,
        reset=cc.reset,
        v_init=cc.v_init_frac * ann_cfg.threshold,
        pool_drive=cc.pool_drive,
        pool_mode=cc.pool_mode,
        head_readout=cc.head_readout,
        conv_exec=cc.conv_exec,
        rate_encode=cc.rate_encode,
    )


def _condition(w: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-output-channel conditioning for per-tensor FXP8: returns
    ``(w_scaled, d)`` with ``w_scaled[..., c] = w[..., c]·d_c`` and every
    live channel's max|w| equal to the tensor max."""
    m = np.abs(w).reshape(-1, w.shape[-1]).max(axis=0)  # (cout,)
    big = m.max()
    if big == 0.0:
        return w, np.ones_like(m)
    d = np.where(m > 0, big / np.where(m > 0, m, 1.0), 1.0)
    return (w * d).astype(np.float32), d.astype(np.float32)


def _emit_layer(
    w_tilde: np.ndarray,
    b_tilde: np.ndarray,
    stats: LayerStats,
    in_value: np.ndarray,
    *,
    lam_target: np.ndarray,
    gain: float,
    dead_eps: float,
    threshold: float = 0.5,
):
    """Rescale one conv+BN layer. Returns (layer_params, layer_bn, info).

    ``lam_target``: the λ the affine divides by (τ·λ for encode, λ for
    hidden layers); ``in_value``: ANN-units worth of one input spike.
    γ is derived against tdBN's OWN epsilon (``lif.tdbn_apply`` default) —
    the source ANN's BN eps was already consumed by ``AnnDetector.folded``.
    """
    eps = 1e-5  # lif.tdbn_apply default — the affine must invert exactly it
    w_in = (w_tilde * in_value[None, None, :, None]).astype(np.float32)
    dead = lam_target <= dead_eps
    w_s, d = _condition(w_in)

    # calibrated stats are ANN-unit conv outputs; executor units are ×d
    mean_x = (d * stats.mean).astype(np.float32)
    var_x = (d * d * stats.var).astype(np.float32)
    lam_safe = np.where(dead, 1.0, lam_target)
    gamma = (gain * np.sqrt(var_x + eps) / (lam_safe * d)).astype(np.float32)
    beta = (threshold * gain * (stats.mean + b_tilde) / lam_safe).astype(
        np.float32
    )
    gamma = np.where(dead, 0.0, gamma).astype(np.float32)
    beta = np.where(dead, 0.0, beta).astype(np.float32)

    layer_p = {
        "w": jnp.asarray(w_s),
        "gamma": jnp.asarray(gamma),
        "beta": jnp.asarray(beta),
    }
    layer_s = {
        "mean": jnp.asarray(mean_x),
        "var": jnp.asarray(var_x),
        "count": jnp.ones((), jnp.int32),
    }
    info = {
        "lam_min": float(lam_target.min()),
        "lam_max": float(lam_target.max()),
        "dead_channels": int(dead.sum()),
        "cond_max": float(d.max()),
    }
    return layer_p, layer_s, info, dead


def convert_ann(
    ann: imp.AnnDetector,
    *,
    source=None,
    cc: ConvertConfig = ConvertConfig(),
) -> ConvertedDetector:
    """Full pipeline: calibrate → rescale → (optional) head fit → bundle.

    ``source``: any :class:`repro.data.detection_datasets.DetectionSource`
    for the calibration split (synthetic generator by default). NO
    training happens anywhere in here.
    """
    from repro.data import detection_datasets as dd
    from repro.eval import harness

    cfg = target_config(ann.cfg, cc)
    source = source or dd.SyntheticSource()
    images, _ = source.eval_set(
        cc.calib_images, split=cc.split, hw=cfg.input_hw,
        grid_div=harness.grid_div(cfg), num_anchors=cfg.num_anchors,
        num_classes=cfg.num_classes,
    )
    stats = _calibrate(
        ann, images,
        percentile=cc.percentile, encode_duty=cc.encode_duty,
        batch=cc.calib_batch, use_block_conv=cfg.use_block_conv,
        block_hw=cfg.block_hw,
    )

    names = imp.conv_bn_layer_names(ann.cfg)
    folded = {n: ann.folded(n) for n in names}
    live = {
        n: np.asarray(stats.layers[n].lam > cc.dead_eps) for n in names
    }
    rho = readout_scale(cfg.full_t, cfg.leak, mode=cc.head_readout)

    # --- initial per-layer OUTPUT spike values: the analytic λ/g (a spike
    # at full rate reconstructs the λ-covered activation); 1-step encode
    # carries the calibrated spike-conditional mean instead
    values: dict = {}
    for n in names:
        st = stats.layers[n]
        values[n] = np.where(live[n], st.lam / cc.gain, 0.0).astype(np.float32)
    if not cc.rate_encode:
        st = stats.layers["encode"]
        values["encode"] = np.where(live["encode"], st.spike_value, 0.0).astype(
            np.float32
        )

    def in_values(vals):
        """Chain output values into each consumer's in_value, matching the
        forward-topology wiring (agg consumes cat=[main_b, shortcut])."""
        iv = {"encode": np.ones(3, np.float32), "conv_block": vals["encode"]}
        prev = "conv_block"
        for i in range(len(ann.cfg.stage_channels)):
            iv[f"stage{i}/shortcut"] = vals[prev]
            iv[f"stage{i}/main_in"] = vals[prev]
            iv[f"stage{i}/main_a"] = vals[f"stage{i}/main_in"]
            iv[f"stage{i}/main_b"] = vals[f"stage{i}/main_a"]
            iv[f"stage{i}/agg"] = np.concatenate(
                [vals[f"stage{i}/main_b"], vals[f"stage{i}/shortcut"]]
            )
            prev = f"stage{i}/agg"
        return iv, vals[prev]

    def emit_all(vals):
        iv, head_in = in_values(vals)
        params: dict = {}
        bn: dict = {}
        rep: dict = {}
        for n in names:
            st = stats.layers[n]
            lam_target, gain = st.lam, cc.gain
            if n == "encode" and not cc.rate_encode:
                # paper-faithful (1, T) topology: encode fires once at
                # duty point τ (spike iff act ≥ τ·λ)
                lam_target, gain = cc.encode_duty * st.lam, 1.0
            p, s, info, _ = _emit_layer(
                folded[n][0], folded[n][1], st,
                np.asarray(iv[n], np.float32),
                lam_target=np.asarray(lam_target, np.float32),
                gain=gain, dead_eps=cc.dead_eps, threshold=cfg.threshold,
            )
            params[n], bn[n] = p, s
            info["value_mean"] = float(np.asarray(vals[n]).mean())
            rep[n] = info
        # head: input scaling / readout gain; no BN to carry an affine
        head_w = (
            ann.head_w * head_in[None, None, :, None] / rho
        ).astype(np.float32)
        params["head"] = {"w": jnp.asarray(head_w)}
        return params, bn, rep, head_w

    if cc.calib_passes > 0:
        # fit targets: per-sample ANN activations, clipped at λ (a spike
        # train cannot reconstruct past rate 1, so chasing the clipped
        # tail would only inflate every in-coverage pixel)
        taps: dict = {}
        ann_reference_forward(
            ann, jnp.asarray(images), taps=taps,
            use_block_conv=cfg.use_block_conv, block_hw=cfg.block_hw,
        )
        ann_acts = {
            n: np.minimum(
                np.maximum(np.asarray(taps[n]) + folded[n][1], 0.0),
                np.maximum(np.asarray(stats.layers[n].lam), 1e-12),
            ).astype(np.float32)
            for n in names
        }
        for _ in range(int(cc.calib_passes)):
            params, bn, _, _ = emit_all(values)
            rates = _realized_rates(
                cfg, params, bn, images, names, batch=cc.calib_batch
            )
            values = _refit_values(values, rates, ann_acts, live)

    params, bn, report_layers, head_w = emit_all(values)

    alpha = 1.0
    if cc.head_scale == "empirical":
        alpha = _fit_head_scale(cfg, params, bn, images, stats.head)
        params["head"] = {"w": jnp.asarray(head_w * alpha)}
    elif cc.head_scale != "analytic":
        raise ValueError(f"head_scale {cc.head_scale!r}")

    plan = cplan.build_plan(params, cfg)
    report = {
        "convert_config": dataclasses.asdict(cc),
        "source_arch_id": ann.cfg.arch_id,
        "calib_images": int(stats.n_images),
        "readout_scale": rho,
        "head_scale_fit": float(alpha),
        "layers": report_layers,
        "plan_summary": plan.summary(),
    }
    return ConvertedDetector(cfg=cfg, params=params, bn_state=bn, report=report)


def _realized_rates(cfg, params, bn, images, names, *, batch: int) -> dict:
    """Run the CONVERTED detector on the calibration images with drive
    taps and reconstruct each layer's firing rates. The taps ARE the real
    per-step LIF drives of the run, so applying the same LIF dynamics to
    them reproduces the executor's spike trains exactly."""
    import jax

    from repro.core import lif as lifm

    fit_cfg = dataclasses.replace(cfg, conv_exec="dense")
    plan = cplan.build_plan(params, fit_cfg)

    def _tapped(imgs):
        t: dict = {}
        sy.forward(params, bn, imgs, fit_cfg, train=False, plan=plan, taps=t)
        out = {}
        for n in names:
            init = None
            if fit_cfg.v_init:
                init = lifm.LIFState(
                    v=jnp.full(t[n].shape[1:], fit_cfg.v_init, t[n].dtype)
                )
            s, _ = lifm.lif_over_time(
                t[n], threshold=fit_cfg.threshold, leak=fit_cfg.leak,
                reset=fit_cfg.reset, init=init,
            )
            out[n] = s.mean(axis=0)  # (N, H, W, C) firing rate
        return out

    f = jax.jit(_tapped)
    accum: dict = {n: [] for n in names}
    for i in range(0, images.shape[0], batch):
        r = f(jnp.asarray(images[i : i + batch]))
        for n in names:
            accum[n].append(np.asarray(r[n]))
    return {n: np.concatenate(accum[n], axis=0) for n in names}


def _refit_values(values, rates, ann_acts, live) -> dict:
    """Per-channel least squares v_c = Σ(a·r)/Σ(r²): the spike value that
    best reconstructs the clipped ANN activation from the REALIZED rates.
    Channels that never fired on the calibration set keep their previous
    value (nothing to fit against)."""
    out = {}
    for n, v in values.items():
        c = v.shape[0]
        r = rates[n].reshape(-1, c).astype(np.float64)
        a = ann_acts[n].reshape(-1, c).astype(np.float64)
        num = (a * r).sum(axis=0)
        den = (r * r).sum(axis=0)
        ok = live[n] & (den > 1e-8)
        fit = num / np.where(den > 0.0, den, 1.0)
        out[n] = np.where(ok, fit, v).astype(np.float32)
    return out


def _fit_head_scale(cfg, params, bn, images, head_ann) -> float:
    """Least-squares scalar α minimizing ‖α·head_snn − head_ann‖² on the
    calibration images, run through the REAL executor plan (so the fit
    sees FXP quantization). Falls back to 1.0 on a silent head."""
    import jax

    fit_cfg = dataclasses.replace(cfg, conv_exec="dense")
    plan = cplan.build_plan(params, fit_cfg)
    fwd = jax.jit(
        lambda imgs: sy.forward(
            params, bn, imgs, fit_cfg, train=False, plan=plan
        )[0]
    )
    outs = []
    for i in range(0, images.shape[0], 8):
        outs.append(np.asarray(fwd(jnp.asarray(images[i : i + 8]))))
    head_snn = np.concatenate(outs, axis=0)
    num = float((head_snn * head_ann).sum())
    den = float((head_snn * head_snn).sum())
    if den <= 0.0:
        return 1.0
    return num / den
