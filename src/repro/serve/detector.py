"""Compile-once detector serving: handles, streaming sessions, slot core.

The paper's accelerator is a compile-once pipeline — weights are pruned,
FXP8-quantized and bitmask-compressed offline, then frames stream through.
This module is that shape as an API:

* :class:`CompiledDetector` — the compile-once handle. Owns the
  :class:`~repro.core.plan.DetectorPlan` (built exactly once, staleness-
  checked on every call), the jitted executor-backed forward, and the
  postprocess stage (``decode_head`` → score threshold → class-aware NMS),
  so callers go ``det = compile_detector(cfg, params); dets = det(frames)``
  with zero plan plumbing.

* :class:`DetectorSession` — a streaming handle over consecutive video
  frames. Carries every LIF membrane potential (and the head accumulator)
  across frames — warm-starting temporal state instead of re-zeroing per
  frame — with an explicit ``reset()``/``state`` contract. One session
  object vectorizes a whole batch of independent streams (row i of the
  batch is stream i; ``reset(i)`` cold-starts just that row), which is what
  the serve Engine's slot pool runs on.

* :class:`FrameRequest` + :class:`DetectorEngineCore` — the detector
  backend for the Engine's slot/admission loop (``EngineAPI``): continuous
  batching of frame streams over detector slots, one batched session step
  per engine tick.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as cplan
from repro.core import pruning
from repro.models import snn_yolo as sy
from repro.models.postprocess import Detections, postprocess


class StalePlanError(RuntimeError):
    """The handle's params changed after compile — its plan (and the jitted
    closure over it) no longer describe the weights. Re-run
    ``compile_detector`` on the new params."""


def _weight_leaves(params) -> tuple:
    return tuple(layer_p["w"] for layer_p in params.values())


def _affine_input_leaves(params, bn_state) -> tuple:
    """Every leaf the precomputed fused-kernel affine bundles were built
    from (gamma/beta + calibrated BN mean/var) — fingerprinted alongside
    the weights so a post-compile swap of any of them is refused instead of
    silently serving stale normalization constants."""
    leaves = []
    for name in sorted(params):
        p = params[name]
        if "gamma" not in p or name not in (bn_state or {}):
            continue
        st = bn_state[name]
        leaves += [p["gamma"], p["beta"], st["mean"], st["var"]]
    return tuple(leaves)


class SessionStep(NamedTuple):
    """One streamed frame's outputs: postprocessed detections + raw head."""

    detections: Detections
    head: jax.Array  # (N, gh, gw, A, 5+C) raw predictions


class CompiledDetector:
    """Compile-once handle around the detector.

    Build through :func:`repro.models.snn_yolo.compile_detector`. The
    constructor prunes (optionally), builds the compression plan ONCE, and
    jits a single step function — forward through the configured conv
    executor plus the full postprocess — that every call and every session
    reuses. ``__call__`` is stateless (cold membrane per frame);
    :meth:`new_session` returns the streaming handle.
    """

    def __init__(
        self,
        cfg: sy.SNNDetConfig,
        params,
        bn_state=None,
        *,
        anchors=sy.DEFAULT_ANCHORS,
        score_threshold: float = 0.25,
        iou_threshold: float = 0.5,
        max_detections: int = 32,
        prune_rate: float | None = None,
    ):
        if prune_rate is not None:
            params = pruning.prune_tree(params, prune_rate)
        self.cfg = cfg
        self.params = params
        self.bn_state = bn_state if bn_state is not None else sy.default_bn_state(params)
        self.anchors = tuple(anchors)
        self.score_threshold = float(score_threshold)
        self.iou_threshold = float(iou_threshold)
        self.max_detections = int(max_detections)
        if cfg.conv_exec != "dense" and not cfg.weight_bits:
            raise ValueError(
                f"conv_exec={cfg.conv_exec!r} requires weight_bits > 0; "
                "float weights only run through the dense oracle"
            )
        # the compile step: one pass over the tree. The plan is the handle's
        # owned artifact, built for EVERY quantized handle — dense included:
        # the dense executor consumes the plan's w_q/scale so all three
        # executors run the same integer-domain accumulate-then-scale math
        # and agree bit-exactly (tests/conformance/ asserts it). Only
        # weight_bits=0 (float) handles have nothing to pack and keep the
        # legacy fake-quant float path.
        self._plan = cplan.build_plan(params, cfg) if cfg.weight_bits else None
        # staleness fingerprint: identity of every weight leaf at compile
        # time. A swapped/mutated leaf means the packed plan and the jitted
        # constants are lying about the model -> refuse loudly.
        self._compiled_leaves = _weight_leaves(params)

        # compile-once affine hoist (pallas executor): the fused kernel's
        # per-layer parameter bundle depends only on weights + calibrated BN
        # stats, so build the whole set here instead of re-deriving it from
        # gamma/beta/mean/var on EVERY frame — those ops sit right before a
        # pallas_call and can't fuse into it. The bundle inputs join the
        # staleness fingerprint (check_plan) so a post-compile swap of
        # bn_state or gamma/beta fails loudly rather than serving stale
        # constants.
        self._affines = None
        self._affine_leaves: tuple = ()
        if self._plan is not None and cfg.conv_exec == "pallas" and cfg.mode == "snn":
            self._affines = cplan.precompute_affines(
                self._plan, params, self.bn_state, cfg
            )
            self._affine_leaves = _affine_input_leaves(params, self.bn_state)

        cfg_, plan_, affines_ = cfg, self._plan, self._affines

        def _step(params, bn, frames, mem):
            head, _, aux = sy.forward(
                params, bn, frames, cfg_, train=False, plan=plan_, membrane=mem,
                affines=affines_,
            )
            dets = postprocess(
                head,
                self.anchors,
                score_threshold=self.score_threshold,
                iou_threshold=self.iou_threshold,
                max_detections=self.max_detections,
            )
            return head, aux["membrane"], dets

        def _masked(params, bn, frames, mem, active, cold):
            # masked cold-start reset: rows joining this tick start from a
            # zero membrane INSIDE the jitted step — admission never issues
            # eager per-leaf device scatters
            def blank(v):
                m = cold.reshape((-1,) + (1,) * (v.ndim - 1))
                return jnp.where(m, jnp.zeros((), v.dtype), v)

            mem0 = jax.tree_util.tree_map(blank, mem)
            head, new_mem, dets = _step(params, bn, frames, mem0)

            # inactive rows are dead lanes in the megabatch: their compute
            # is discarded and their membrane must NOT evolve between
            # occupants — keep the old state wherever active is False
            def keep(new, old):
                m = active.reshape((-1,) + (1,) * (new.ndim - 1))
                return jnp.where(m, new, old)

            return head, jax.tree_util.tree_map(keep, new_mem, mem0), dets

        self._step = jax.jit(_step)
        self._masked_step_fn = jax.jit(_masked)

    @property
    def plan(self):
        """The owned DetectorPlan, built exactly once at compile time.
        None only when weight_bits=0 (float weights: nothing to compress,
        and the forward runs the legacy fake-quant path)."""
        return self._plan

    # ------------------------------------------------------------- checks --
    def check_plan(self) -> None:
        """Raise :class:`StalePlanError` if params changed after compile."""
        now = _weight_leaves(self.params)
        if len(now) != len(self._compiled_leaves) or any(
            a is not b for a, b in zip(now, self._compiled_leaves)
        ):
            raise StalePlanError(
                "detector params changed after compile: the owned plan/jit "
                "no longer match the weights — call "
                "snn_yolo.compile_detector(cfg, params) again"
            )
        if self._affines is not None:
            now_aff = _affine_input_leaves(self.params, self.bn_state)
            if len(now_aff) != len(self._affine_leaves) or any(
                a is not b for a, b in zip(now_aff, self._affine_leaves)
            ):
                raise StalePlanError(
                    "detector BN/affine parameters changed after compile: "
                    "the precomputed fused-kernel affine bundles no longer "
                    "match gamma/beta/mean/var — call "
                    "snn_yolo.compile_detector(cfg, params, bn_state) again"
                )

    # -------------------------------------------------------------- calls --
    def __call__(self, frames) -> Detections:
        """frames: (N, H, W, 3) in [0, 1] -> batched Detections (cold
        membrane state — use a session for streaming video)."""
        dets, _ = self.detect(frames)
        return dets

    def detect(self, frames) -> tuple[Detections, jax.Array]:
        """Like ``__call__`` but also returns the raw head volume."""
        self.check_plan()
        head, _, dets = self._step(
            self.params, self.bn_state, jnp.asarray(frames), None
        )
        return dets, head

    def masked_step(self, frames, mem, active, cold=None):
        """One megabatched serving tick over a capacity bucket of streams.

        ``frames``: (C, H, W, 3); ``mem``: membrane pytree with C rows;
        ``active``: (C,) bool — rows where it is False are padding lanes
        whose outputs are discarded and whose membrane stays EXACTLY as it
        was (bit-identical active-row outputs regardless of what the dead
        lanes hold); ``cold``: (C,) bool — rows joining this tick, whose
        membrane is zeroed INSIDE the step (masked cold-start reset) so
        admission never touches device state eagerly. Returns ``(head,
        new_mem, detections)``. Jitted once per capacity bucket, never per
        occupancy.
        """
        self.check_plan()
        if cold is None:
            cold = jnp.zeros(jnp.shape(active), bool)
        return self._masked_step_fn(
            self.params, self.bn_state, frames, mem, active, cold
        )

    # ----------------------------------------------------------- sessions --
    def zero_state(self, batch: int):
        """Cold-start membrane pytree for a ``batch``-stream session."""
        if self.cfg.mode != "snn":
            raise ValueError(
                f"sessions stream LIF membrane state; mode={self.cfg.mode!r} "
                "has no temporal state to carry"
            )
        h, w = self.cfg.input_hw
        frames = jax.ShapeDtypeStruct((batch, h, w, 3), jnp.float32)
        _, mem_shapes, _ = jax.eval_shape(
            self._step, self.params, self.bn_state, frames, None
        )
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), mem_shapes
        )

    def new_session(self, batch: int = 1) -> "DetectorSession":
        return DetectorSession(self, batch)


class DetectorSession:
    """Streaming handle: membrane potentials persist across ``step`` calls.

    The session vectorizes ``batch`` independent streams — feed it a
    (batch, H, W, 3) frame stack per step; row i's state only ever mixes
    with row i's frames. Contract:

    * ``step(frames)`` — advance every stream by one frame; returns
      :class:`SessionStep` (postprocessed detections + raw head).
    * ``state`` — the current membrane pytree ({layer: v, ..., "head": v}).
      A fresh or just-reset session's state is all zeros, and outputs from
      it are bit-identical to the stateless ``detector(frames)`` path.
    * ``reset()`` / ``reset(i)`` — cold-start every stream / only stream i.
    """

    def __init__(self, det: CompiledDetector, batch: int = 1):
        self.det = det
        self.batch = int(batch)
        self._mem = det.zero_state(self.batch)
        self.frames_seen = 0

    @property
    def state(self):
        return self._mem

    def step(self, frames) -> SessionStep:
        frames = jnp.asarray(frames)
        if frames.ndim != 4 or frames.shape[0] != self.batch:
            raise ValueError(
                f"session batch is {self.batch}; got frames {frames.shape} "
                "(want (batch, H, W, 3))"
            )
        self.det.check_plan()
        head, self._mem, dets = self.det._step(
            self.det.params, self.det.bn_state, frames, self._mem
        )
        self.frames_seen += 1
        return SessionStep(detections=dets, head=head)

    def reset(self, index: int | None = None) -> None:
        """Zero the membrane state of every stream, or of stream ``index``."""
        if index is None:
            self._mem = jax.tree_util.tree_map(jnp.zeros_like, self._mem)
            self.frames_seen = 0
            return
        if not -self.batch <= index < self.batch:
            # JAX drops out-of-bounds scatter indices silently — a typo'd
            # stream index would "reset" nothing without this check
            raise IndexError(f"stream index {index} out of range for batch {self.batch}")
        self._mem = jax.tree_util.tree_map(
            lambda v: v.at[index].set(0.0), self._mem
        )


# ------------------------------------------------- demo / benchmark setup --


def demo_weights(cfg: sy.SNNDetConfig, *, prune_rate: float = 0.8, seed: int = 0,
                 calib_batch: int = 2):
    """Pruned + tdBN-calibrated random weights for serving demos, smoke CI
    and benchmarks (real deployments load trained checkpoints instead).
    Returns (params, bn_state, rng) — the rng continues the same stream so
    callers generate matching synthetic frames."""
    params, bn = sy.init_params(jax.random.PRNGKey(seed), cfg)
    params = pruning.prune_tree(params, prune_rate)
    rng = np.random.default_rng(seed)
    h, w = cfg.input_hw
    calib = (rng.integers(0, 256, (calib_batch, h, w, 3)) / 255.0).astype(np.float32)
    bn = sy.calibrate_bn_state(params, bn, calib, cfg)
    return params, bn, rng


def synth_streams(rng, n_streams: int, n_frames: int, hw) -> list:
    """Uint8-grid synthetic frame streams (exact under the bit-serial
    8-bit encode path): n_streams arrays of (n_frames, H, W, 3)."""
    h, w = hw
    return [
        (rng.integers(0, 256, (n_frames, h, w, 3)) / 255.0).astype(np.float32)
        for _ in range(n_streams)
    ]


def step_latency_ms(step_wall: list) -> dict:
    """p50/p95/p99 of the engine's per-tick session-step latency, first
    tick (jit warmup) excluded."""
    wall = np.asarray(step_wall[1:] or step_wall)
    return {
        "step_p50_ms": float(np.percentile(wall, 50) * 1e3),
        "step_p95_ms": float(np.percentile(wall, 95) * 1e3),
        "step_p99_ms": float(np.percentile(wall, 99) * 1e3),
    }


# ------------------------------------------------------------ engine core --


@dataclass
class FrameRequest:
    """A video-clip detection request: F consecutive frames of one stream."""

    rid: int
    frames: Any  # (F, H, W, 3) float array in [0, 1]
    out: list = field(default_factory=list)  # per-frame Detections (numpy)
    heads: list = field(default_factory=list)  # per-frame raw head (numpy)
    done: bool = False


def _pow2(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


class DetectorEngineCore:
    """EngineAPI backend: megabatched continuous-stream detector serving.

    Every engine tick advances ALL active streams as ONE device-resident
    megabatch:

    * Membrane/accumulator state lives on device across ticks (threaded
      through ``forward(membrane=)`` inside the compile-once handle), never
      staged through the host.
    * The pool is sized in power-of-two CAPACITY BUCKETS: the masked step
      jits once per bucket shape, so a 1000-stream workload compiles
      O(log n_slots) step functions total — never one per occupancy.
    * Join/leave remaps slot rows without recompiling OR eager device work:
      admission claims the lowest free row and marks it for a masked
      cold-start reset applied INSIDE the next jitted step; retirement just
      frees the row (the stale membrane is invisible behind the active
      mask). The only per-leaf device ops left are the rare bucket
      grow/shrink events — shrink compacts surviving rows below the new
      capacity with one gather.
    * Inactive bucket lanes are masked out of the step — their membrane is
      bit-frozen between occupants instead of evolving under blank frames —
      and a fully drained pool dispatches nothing at all.
    * Postprocess/NMS runs batched inside the same jitted step, and the
      next tick's frame upload double-buffers against this tick's compute
      (async dispatch; steady-state only, since a finishing stream remaps
      the batch layout).
    """

    def __init__(self, det: CompiledDetector, *, n_slots: int = 8,
                 min_bucket: int = 8):
        self.det = det
        self.n_slots = n_slots
        self.min_bucket = min(min_bucket, n_slots)
        h, w = det.cfg.input_hw
        self._hw = (h, w)
        # row table over the capacity bucket: _rows[row] -> engine slot or
        # None (free lane), _row_of[slot] -> row, _cursor[slot] -> next
        # frame index, _cold -> rows whose membrane must be zeroed by the
        # next step's masked cold-start reset.
        self._row_of: dict[int, int] = {}
        self._cursor: dict[int, int] = {}
        self._cold: set[int] = set()
        self.cap = self._bucket_for(0)
        self._rows: list[Optional[int]] = [None] * self.cap
        self._mem = det.zero_state(self.cap)  # device-resident across ticks
        self._staged = None  # (device frames, signature): double-buffered upload
        self.step_wall: list[float] = []  # per-tick latency (BENCH_serve)

    def _bucket_for(self, n: int) -> int:
        return min(self.n_slots, max(self.min_bucket, _pow2(max(n, 1))))

    # ---------------------------------------------------------- admission --
    def validate(self, req: FrameRequest) -> Optional[str]:
        """None if ``req`` is servable, else the rejection reason — checked
        by ``Engine.submit`` (typed rejection) and again by :meth:`admit`
        BEFORE any slot/membrane state is touched."""
        frames = np.asarray(req.frames)
        h, w = self._hw
        if frames.ndim != 4 or frames.shape[0] < 1:
            return (
                f"FrameRequest.frames must be (F, H, W, 3) with F >= 1; "
                f"got {frames.shape}"
            )
        if frames.shape[1:] != (h, w, 3):
            return (
                f"FrameRequest.frames must be (F, {h}, {w}, 3) to match "
                f"the compiled detector's cfg.input_hw={self._hw}; "
                f"got {frames.shape}"
            )
        return None

    def admit(self, req: FrameRequest, slot_idx: int) -> None:
        req.frames = np.asarray(req.frames, np.float32)
        err = self.validate(req)
        if err is not None:  # reject BEFORE touching any session state
            raise ValueError(err)
        if len(self._row_of) == self.cap:  # bucket full: grow, don't re-jit
            self._grow(self._bucket_for(len(self._row_of) + 1))
        row = self._rows.index(None)  # lowest free lane
        self._rows[row] = slot_idx
        self._row_of[slot_idx] = row
        self._cursor[slot_idx] = 0
        # masked cold-start reset: the row is zeroed inside the NEXT jitted
        # step — join issues zero device ops and never recompiles
        self._cold.add(row)

    # --------------------------------------------------------- row plumbing --
    def _grow(self, new_cap: int) -> None:
        pad = new_cap - self.cap
        self._mem = jax.tree_util.tree_map(
            lambda v: jnp.concatenate(
                [v, jnp.zeros((pad,) + v.shape[1:], v.dtype)]
            ),
            self._mem,
        )
        self._rows.extend([None] * pad)
        self.cap = new_cap

    def _shrink(self, new_cap: int) -> None:
        """Compact surviving rows below ``new_cap`` with ONE gather per
        membrane leaf, then slice the bucket. Only called on the rare
        occupancy-halved events — per-tick join/leave is pure bookkeeping."""
        perm = list(range(new_cap))
        free = [r for r in range(new_cap) if self._rows[r] is None]
        for r in range(new_cap, self.cap):
            slot = self._rows[r]
            if slot is None:
                continue
            dst = free.pop(0)
            perm[dst] = r
            self._rows[dst] = slot
            self._row_of[slot] = dst
        idx = jnp.asarray(perm)
        self._mem = jax.tree_util.tree_map(lambda v: v[idx], self._mem)
        self._rows = self._rows[:new_cap]
        self.cap = new_cap

    def _retire(self, slot: int) -> None:
        """Free ``slot``'s row. No device work: the stale membrane left in
        the lane is invisible behind the active mask, and a future occupant
        cold-starts it inside the step."""
        row = self._row_of.pop(slot)
        self._rows[row] = None
        self._cold.discard(row)
        del self._cursor[slot]

    def _occupied(self):
        return [(r, s) for r, s in enumerate(self._rows) if s is not None]

    def _signature(self, cursor_offset: int = 0):
        """Identity of one tick's frame batch: capacity + (row, slot, frame
        index) per occupied lane. The staged (double-buffered) upload is
        only used when its signature matches the tick it was staged for —
        any admission, retirement or remap misses and reassembles."""
        return (
            self.cap,
            tuple((r, s, self._cursor[s] + cursor_offset)
                  for r, s in self._occupied()),
        )

    def _assemble(self, active: dict[int, FrameRequest], offset: int = 0):
        h, w = self._hw
        batch = np.zeros((self.cap, h, w, 3), np.float32)
        for row, slot in self._occupied():
            batch[row] = active[slot].frames[self._cursor[slot] + offset]
        return batch

    # --------------------------------------------------------------- tick --
    def step(self, active: dict[int, FrameRequest]) -> list[int]:
        if not self._row_of:  # fully drained pool: zero-cost skip
            return []
        t0 = time.perf_counter()
        sig = self._signature()
        if self._staged is not None and self._staged[1] == sig:
            frames_dev = self._staged[0]  # pre-uploaded last tick
        else:
            frames_dev = jnp.asarray(self._assemble(active))
        self._staged = None
        mask = np.zeros((self.cap,), bool)
        cold = np.zeros((self.cap,), bool)
        for row, _ in self._occupied():
            mask[row] = True
        for row in self._cold:
            cold[row] = True
        self._cold.clear()
        head, new_mem, dets = self.det.masked_step(
            frames_dev, self._mem, jnp.asarray(mask), jnp.asarray(cold)
        )
        # double-buffer: while the device chews on this tick, stage the
        # NEXT tick's upload. Steady state only — a finishing stream would
        # remap rows and invalidate the layout (the signature check above
        # would reject it anyway; skipping saves the wasted copy).
        if all(
            self._cursor[s] + 1 < len(active[s].frames) for s in self._row_of
        ):
            self._staged = (
                jax.device_put(jnp.asarray(self._assemble(active, offset=1))),
                self._signature(cursor_offset=1),
            )
        jax.block_until_ready(head)
        self.step_wall.append(time.perf_counter() - t0)

        head_np = np.asarray(head)
        dets_np = jax.tree_util.tree_map(np.asarray, dets)  # one transfer/field
        self._mem = new_mem
        finished = []
        for row, slot in self._occupied():
            req = active[slot]
            req.out.append(dets_np.row(row))
            req.heads.append(head_np[row])
            self._cursor[slot] += 1
            if self._cursor[slot] >= len(req.frames):
                finished.append(slot)
        for slot in finished:
            self._retire(slot)
        new_cap = self._bucket_for(len(self._row_of))
        if new_cap < self.cap:
            self._shrink(new_cap)
        return finished
