"""Batched serving engine with continuous batching (slot-based).

The engine holds a fixed pool of B slots. Requests are admitted into free
slots; each step advances EVERY active slot together; finished slots are
retired and refilled from the queue, vLLM-style, without ever re-lowering.

The slot/admission loop itself is workload-agnostic: :class:`Engine` owns
the queue, the slot occupancy, and the run loop, and delegates the actual
model work to an :class:`EngineAPI` backend:

* :class:`LMEngineCore` — LM token serving. One shared KV cache over the
  pool; prefill per-request at bucketed lengths, scattered into the slot's
  rows; each step decodes one token for every active slot (per-slot cache
  positions — the vectorized cache_pos path in models/layers.py). Works
  for every KV-cache family (dense/moe/vlm/audio); recurrent families
  (ssm/hybrid) serve through the same API with their O(1) state as the
  "cache".

* :class:`repro.serve.detector.DetectorEngineCore` — detection serving.
  Slot i is stream i of a vectorized streaming
  :class:`~repro.serve.detector.DetectorSession`; each step advances all
  active frame streams by one frame through the compile-once detector.

``Engine(cfg, params)`` dispatches on the config type (LMConfig vs
SNNDetConfig), so ``launch/serve.py --arch`` drives both workloads through
one loop.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models import zoo


def _bucket(n: int) -> int:
    b = 16
    while b < n:
        b *= 2
    return b


@dataclass
class Request:
    rid: int
    prompt: list  # token ids
    max_new_tokens: int = 32
    eos_id: int = -1  # -1 = never
    out: list = field(default_factory=list)
    done: bool = False


# ------------------------------------------------------ admission control --


@dataclass(frozen=True)
class AdmissionPolicy:
    """Backpressure contract for :meth:`Engine.submit`.

    ``max_queue`` bounds the number of QUEUED (not yet admitted) requests;
    ``None`` keeps the legacy unbounded queue. When the queue is full,
    ``on_full`` picks the policy:

    * ``"reject"`` — refuse the new request (it never enters the queue).
    * ``"shed-oldest"`` — evict queued requests from the FRONT until the
      new one fits (freshest traffic wins; a camera fleet cares about the
      latest frames, not a stale backlog).

    Either way the caller gets a typed :class:`SubmitResult` instead of
    silent queue growth, and every refused/evicted request lands in
    ``Engine.rejected`` with ``done=False``.
    """

    max_queue: Optional[int] = None
    on_full: str = "reject"

    def __post_init__(self):
        if self.on_full not in ("reject", "shed-oldest"):
            raise ValueError(
                f"on_full={self.on_full!r}: want 'reject' or 'shed-oldest'"
            )
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")


@dataclass(frozen=True)
class SubmitResult:
    """Typed outcome of one :meth:`Engine.submit` call. Truthy iff the
    request was accepted; ``reason`` explains a rejection (``"queue-full"``
    / ``"invalid: ..."``); ``shed`` lists requests evicted to make room
    under the shed-oldest policy."""

    accepted: bool
    reason: Optional[str] = None
    shed: tuple = ()

    def __bool__(self) -> bool:
        return self.accepted


class EngineRunResult(list):
    """`Engine.run`'s return value: the finished-request list (it IS a
    list, so existing callers keep working) plus the drain status.

    * ``status`` — ``"drained"`` (queue and slots empty) or ``"truncated"``
      (``max_steps`` exhausted with work left).
    * ``pending`` — requests that did NOT finish: in-flight slot occupants
      first, then the still-queued tail, every one with ``done=False``.
    """

    def __init__(self, finished, status: str, pending):
        super().__init__(finished)
        self.status = status
        self.pending = list(pending)

    @property
    def drained(self) -> bool:
        return self.status == "drained"


@runtime_checkable
class EngineAPI(Protocol):
    """Backend contract for the slot/admission loop.

    The Engine owns queue + slot occupancy; a backend only ever sees
    (request, slot index) pairs. ``admit`` loads one request's state into a
    slot (prefill / session reset); ``step`` advances every active slot by
    one unit of work (a token, a frame) and returns the slot indices that
    finished this step. Backends expose ``n_slots`` so the Engine can size
    its pool to match.
    """

    n_slots: int

    def admit(self, req: Any, slot_idx: int) -> None: ...

    def step(self, active: dict[int, Any]) -> list[int]: ...


class LMEngineCore:
    """EngineAPI backend for LM token serving over one shared KV cache."""

    def __init__(self, cfg: LMConfig, params, *, n_slots: int = 8,
                 max_seq: int = 512, greedy: bool = True):
        self.cfg = cfg
        self.api = zoo.get_api(cfg)
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.greedy = greedy
        self.pos = [0] * n_slots  # next cache write position per slot
        self.cache = self.api.init_cache(n_slots, max_seq)
        self._decode = jax.jit(self.api.decode_fn)
        self._prefill_cache = {}
        # bucketed prefill (pad + valid_len mask) holds for families whose
        # prefill cache is positionally sliceable — the causal mask keeps
        # positions < plen blind to the pad, and _scatter_kv only ever
        # copies rows [:plen] into the shared cache. Recurrent-state
        # families (ssm/hybrid) fold the whole padded sequence into their
        # O(1) state, so they keep exact-length prefill.
        self._bucketed = (
            getattr(cfg, "family", None) in ("dense", "moe")
            and not getattr(cfg, "kv_quant", False)
        )

    # ------------------------------------------------------------ prefill --
    def _prefill_fn(self, length: int):
        # one jit entry per BUCKET (pad + valid_len mask): the compile
        # cache is O(log max-prompt-len) under varied traffic instead of
        # one entry per exact prompt length. Non-bucketable families key
        # by exact length (their traffic decides the cache size).
        if length not in self._prefill_cache:
            self._prefill_cache[length] = jax.jit(self.api.prefill_fn)
        return self._prefill_cache[length]

    def admit(self, req: Request, slot_idx: int):
        plen = len(req.prompt)
        if self._bucketed:
            blen = _bucket(plen)
            padded = np.zeros((1, blen), np.int32)
            padded[0, :plen] = np.asarray(req.prompt, np.int32)
            logits, pcache = self._prefill_fn(blen)(
                self.params, jnp.asarray(padded), valid_len=jnp.int32(plen)
            )
        else:
            toks = jnp.asarray(np.asarray(req.prompt, np.int32)[None])
            logits, pcache = self._prefill_fn(plen)(self.params, toks)
        tok = int(jnp.argmax(logits[0]))
        req.out.append(tok)
        self._scatter_kv(pcache, slot_idx, plen)
        self.pos[slot_idx] = plen

    def _scatter_kv(self, pcache, slot_idx: int, plen: int):
        """Copy the request's prefilled KV rows into the shared cache."""
        def put_kv(dst, src):
            """(L, B, S_max, kv, hd) <- (L, 1, plen, kv, hd) rows."""
            return dst.at[:, slot_idx, :plen].set(src[:, 0, :plen].astype(dst.dtype))

        def put_state(dst, src):
            """Recurrent state: copy the slot along whichever axis matches
            the pool size (no seq dim)."""
            for ax in range(dst.ndim):
                if dst.shape[ax] == self.n_slots and src.shape[ax] == 1:
                    idx = [slice(None)] * dst.ndim
                    idx[ax] = slot_idx
                    src_idx = [slice(None)] * src.ndim
                    src_idx[ax] = 0
                    return dst.at[tuple(idx)].set(src[tuple(src_idx)].astype(dst.dtype))
            return dst

        if hasattr(self.cache, "k"):  # dense KVCache
            self.cache = type(self.cache)(
                put_kv(self.cache.k, pcache.k), put_kv(self.cache.v, pcache.v)
            )
        elif hasattr(self.cache, "self_k"):  # whisper
            c = self.cache
            self.cache = type(c)(
                self_k=put_kv(c.self_k, pcache.self_k),
                self_v=put_kv(c.self_v, pcache.self_v),
                cross_k=c.cross_k.at[:, slot_idx].set(pcache.cross_k[:, 0].astype(c.cross_k.dtype)),
                cross_v=c.cross_v.at[:, slot_idx].set(pcache.cross_v[:, 0].astype(c.cross_v.dtype)),
            )
        elif hasattr(self.cache, "attn_k"):  # hybrid: KV + stacked states
            c = self.cache
            self.cache = type(c)(
                mamba=jax.tree_util.tree_map(put_state, c.mamba, pcache.mamba),
                tail=(
                    jax.tree_util.tree_map(put_state, c.tail, pcache.tail)
                    if c.tail is not None
                    else None
                ),
                attn_k=put_kv(c.attn_k, pcache.attn_k),
                attn_v=put_kv(c.attn_v, pcache.attn_v),
            )
        else:  # pure recurrent state pytrees (ssm)
            self.cache = jax.tree_util.tree_map(put_state, self.cache, pcache)

    # ------------------------------------------------------------- decode --
    def step(self, active: dict[int, Request]) -> list[int]:
        toks = np.zeros((self.n_slots,), np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        for i, req in active.items():
            toks[i] = req.out[-1]
            pos[i] = self.pos[i]
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(pos)
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        finished = []
        for i, req in active.items():
            self.pos[i] += 1
            tok = int(nxt[i])
            req.out.append(tok)
            if (
                tok == req.eos_id
                or len(req.out) >= req.max_new_tokens
                or self.pos[i] + 1 >= self.max_seq
            ):
                finished.append(i)
        return finished


def _resolve_core(cfg, params, *, n_slots, max_seq, greedy) -> EngineAPI:
    if isinstance(cfg, LMConfig):
        return LMEngineCore(cfg, params, n_slots=n_slots, max_seq=max_seq,
                            greedy=greedy)
    from repro.models.snn_yolo import SNNDetConfig, compile_detector
    from repro.serve.detector import CompiledDetector, DetectorEngineCore

    if isinstance(cfg, CompiledDetector):  # a pre-compiled handle
        return DetectorEngineCore(cfg, n_slots=n_slots)
    if isinstance(cfg, SNNDetConfig):
        if isinstance(params, tuple):  # (params, bn_state) as init_params returns
            p, bn = params
        else:
            p, bn = params, None
        return DetectorEngineCore(compile_detector(cfg, p, bn), n_slots=n_slots)
    raise TypeError(
        f"don't know how to serve {type(cfg).__name__}: pass an LMConfig, an "
        "SNNDetConfig, a CompiledDetector, or an explicit core="
    )


class Engine:
    """The workload-agnostic slot/admission loop over an EngineAPI core.

    ``admission`` bounds the queue (:class:`AdmissionPolicy`); ``submit``
    returns a typed :class:`SubmitResult` so callers see rejection/shedding
    instead of silent growth, and ``run`` reports whether the loop drained
    or truncated (:class:`EngineRunResult`).
    """

    def __init__(self, cfg=None, params=None, *, n_slots: int = 8,
                 max_seq: int = 512, greedy: bool = True,
                 core: Optional[EngineAPI] = None,
                 admission: Optional[AdmissionPolicy] = None):
        self.core = core if core is not None else _resolve_core(
            cfg, params, n_slots=n_slots, max_seq=max_seq, greedy=greedy
        )
        self.cfg = cfg
        self.admission = admission if admission is not None else AdmissionPolicy()
        self.n_slots = self.core.n_slots
        self.slots: list[Optional[Any]] = [None] * self.n_slots
        self.queue: list[Any] = []
        self.finished: list[Any] = []
        self.rejected: list[Any] = []  # refused/evicted requests (done=False)

    def submit(self, req) -> SubmitResult:
        # reject malformed requests BEFORE they enter the queue: a bad
        # request discovered mid-run would otherwise abort the whole loop
        # (cores still validate again at admit time for direct-admit users)
        validate = getattr(self.core, "validate", None)
        if validate is not None:
            err = validate(req)
            if err is not None:
                self.rejected.append(req)
                return SubmitResult(False, reason=f"invalid: {err}")
        pol = self.admission
        if pol.max_queue is not None and len(self.queue) >= pol.max_queue:
            if pol.on_full == "reject":
                self.rejected.append(req)
                return SubmitResult(False, reason="queue-full")
            shed = []  # shed-oldest: evict the stale front, keep the fresh
            while len(self.queue) >= pol.max_queue:
                shed.append(self.queue.pop(0))
            self.rejected.extend(shed)
            self.queue.append(req)
            return SubmitResult(True, reason="shed-oldest", shed=tuple(shed))
        self.queue.append(req)
        return SubmitResult(True)

    def _active(self) -> dict[int, Any]:
        return {i: r for i, r in enumerate(self.slots) if r is not None}

    def run(self, max_steps: int = 10_000) -> EngineRunResult:
        """Continuous-batching loop: admit from queue into free slots, then
        step all active slots together; repeat until drained (or until
        ``max_steps``, in which case the result's ``status`` is
        ``"truncated"`` and ``pending`` lists every undone request —
        in-flight occupants keep their slot state, so a later ``run()``
        resumes them)."""
        steps = 0
        while (self.queue or any(r is not None for r in self.slots)) and steps < max_steps:
            for i in range(self.n_slots):
                if self.slots[i] is None and self.queue:
                    req = self.queue.pop(0)
                    self.core.admit(req, i)
                    self.slots[i] = req
            active = self._active()
            if active:
                for i in self.core.step(active):
                    self.slots[i].done = True
                    self.finished.append(self.slots[i])
                    self.slots[i] = None
            steps += 1
        pending = [r for r in self.slots if r is not None] + list(self.queue)
        return EngineRunResult(
            self.finished, "truncated" if pending else "drained", pending
        )
