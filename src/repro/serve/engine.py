"""Batched serving engine with continuous batching (slot-based).

The engine holds a fixed pool of B decode slots over one shared KV cache.
Requests are admitted into free slots; each decode step advances EVERY
active slot by one token (per-slot cache positions — the vectorized
cache_pos path in models/layers.py). Finished slots (EOS or max_tokens) are
retired and refilled from the queue, vLLM-style, without ever re-lowering.

Prefill runs per-request at bucketed lengths (powers of two) so the jit
cache stays small; the prefilled KV is scattered into the slot's rows.

Works for every KV-cache family (dense/moe/vlm/audio). Recurrent families
(ssm/hybrid) serve through the same API with their O(1) state as the
"cache"; positions are ignored by their decode fns.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models import zoo


def _bucket(n: int) -> int:
    b = 16
    while b < n:
        b *= 2
    return b


@dataclass
class Request:
    rid: int
    prompt: list  # token ids
    max_new_tokens: int = 32
    eos_id: int = -1  # -1 = never
    out: list = field(default_factory=list)
    done: bool = False


@dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0  # next cache write position

    @property
    def free(self):
        return self.req is None


class Engine:
    def __init__(self, cfg: LMConfig, params, *, n_slots: int = 8, max_seq: int = 512,
                 greedy: bool = True):
        self.cfg = cfg
        self.api = zoo.get_api(cfg)
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.greedy = greedy
        self.slots = [_Slot() for _ in range(n_slots)]
        self.cache = self.api.init_cache(n_slots, max_seq)
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._decode = jax.jit(self.api.decode_fn)
        self._prefill_cache = {}

    # ------------------------------------------------------------ prefill --
    def _prefill_fn(self, plen: int):
        # one jit entry per distinct prompt length; production would bucket
        # (pad + mask) — exact-length keeps the first-token logits trivially
        # correct and the test/examples workload has few distinct lengths.
        if plen not in self._prefill_cache:
            self._prefill_cache[plen] = jax.jit(self.api.prefill_fn)
        return self._prefill_cache[plen]

    def _admit(self, req: Request, slot_idx: int):
        plen = len(req.prompt)
        toks = jnp.asarray(np.asarray(req.prompt, np.int32)[None])
        logits, pcache = self._prefill_fn(plen)(self.params, toks)
        tok = int(jnp.argmax(logits[0]))
        req.out.append(tok)
        self._scatter_kv(pcache, slot_idx, plen)
        self.slots[slot_idx] = _Slot(req=req, pos=plen)

    def _scatter_kv(self, pcache, slot_idx: int, plen: int):
        """Copy the request's prefilled KV rows into the shared cache."""
        def put_kv(dst, src):
            """(L, B, S_max, kv, hd) <- (L, 1, plen, kv, hd) rows."""
            return dst.at[:, slot_idx, :plen].set(src[:, 0, :plen].astype(dst.dtype))

        def put_state(dst, src):
            """Recurrent state: copy the slot along whichever axis matches
            the pool size (no seq dim)."""
            for ax in range(dst.ndim):
                if dst.shape[ax] == self.n_slots and src.shape[ax] == 1:
                    idx = [slice(None)] * dst.ndim
                    idx[ax] = slot_idx
                    src_idx = [slice(None)] * src.ndim
                    src_idx[ax] = 0
                    return dst.at[tuple(idx)].set(src[tuple(src_idx)].astype(dst.dtype))
            return dst

        if hasattr(self.cache, "k"):  # dense KVCache
            self.cache = type(self.cache)(
                put_kv(self.cache.k, pcache.k), put_kv(self.cache.v, pcache.v)
            )
        elif hasattr(self.cache, "self_k"):  # whisper
            c = self.cache
            self.cache = type(c)(
                self_k=put_kv(c.self_k, pcache.self_k),
                self_v=put_kv(c.self_v, pcache.self_v),
                cross_k=c.cross_k.at[:, slot_idx].set(pcache.cross_k[:, 0].astype(c.cross_k.dtype)),
                cross_v=c.cross_v.at[:, slot_idx].set(pcache.cross_v[:, 0].astype(c.cross_v.dtype)),
            )
        elif hasattr(self.cache, "attn_k"):  # hybrid: KV + stacked states
            c = self.cache
            self.cache = type(c)(
                mamba=jax.tree_util.tree_map(put_state, c.mamba, pcache.mamba),
                tail=(
                    jax.tree_util.tree_map(put_state, c.tail, pcache.tail)
                    if c.tail is not None
                    else None
                ),
                attn_k=put_kv(c.attn_k, pcache.attn_k),
                attn_v=put_kv(c.attn_v, pcache.attn_v),
            )
        else:  # pure recurrent state pytrees (ssm)
            self.cache = jax.tree_util.tree_map(put_state, self.cache, pcache)

    # ------------------------------------------------------------- decode --
    def _step(self):
        active = [i for i, s in enumerate(self.slots) if not s.free]
        if not active:
            return
        toks = np.zeros((self.n_slots,), np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        for i in active:
            toks[i] = self.slots[i].req.out[-1]
            pos[i] = self.slots[i].pos
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(pos)
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i in active:
            slot = self.slots[i]
            req = slot.req
            slot.pos += 1
            tok = int(nxt[i])
            req.out.append(tok)
            if tok == req.eos_id or len(req.out) >= req.max_new_tokens or slot.pos + 1 >= self.max_seq:
                req.done = True
                self.finished.append(req)
                self.slots[i] = _Slot()

    # --------------------------------------------------------------- API --
    def submit(self, req: Request):
        self.queue.append(req)

    def run(self, max_steps: int = 10_000):
        """Continuous-batching loop: admit from queue into free slots, then
        decode all active slots together; repeat until drained."""
        steps = 0
        while (self.queue or any(not s.free for s in self.slots)) and steps < max_steps:
            for i, s in enumerate(self.slots):
                if s.free and self.queue:
                    self._admit(self.queue.pop(0), i)
            self._step()
            steps += 1
        return self.finished
