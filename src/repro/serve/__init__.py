from repro.serve.engine import Engine, EngineAPI, LMEngineCore, Request  # noqa: F401
from repro.serve.detector import (  # noqa: F401
    CompiledDetector,
    DetectorEngineCore,
    DetectorSession,
    FrameRequest,
    SessionStep,
    StalePlanError,
)
