from repro.serve.engine import (  # noqa: F401
    AdmissionPolicy,
    Engine,
    EngineAPI,
    EngineRunResult,
    LMEngineCore,
    Request,
    SubmitResult,
)
from repro.serve.detector import (  # noqa: F401
    CompiledDetector,
    DetectorEngineCore,
    DetectorSession,
    FrameRequest,
    SessionStep,
    StalePlanError,
)
