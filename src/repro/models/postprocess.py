"""Pure-JAX detection postprocess: YOLO decode → score threshold → NMS.

Everything here is shape-static and jit/vmap-safe — the serve path runs the
whole stage inside the :class:`repro.serve.detector.CompiledDetector`'s
jitted postprocess, so per-frame detection serving never leaves the device.
Suppression is greedy class-aware NMS: a fixed budget of ``max_out`` picks,
each pick suppressing same-class boxes above the IoU threshold (boxes of
OTHER classes are never suppressed by a pick — per-class independence).

Boxes are (cx, cy, w, h) in [0, 1] normalized image coordinates, matching
``snn_yolo.decode_head``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.snn_yolo import DEFAULT_ANCHORS, decode_head


class Detections(NamedTuple):
    """Fixed-size (padded) per-image detection set.

    All fields share the leading ``(..., max_out)`` shape; ``valid`` marks
    the live entries (invalid rows are zero-filled padding).
    """

    boxes: jax.Array  # (..., max_out, 4) xywh, [0, 1] normalized
    scores: jax.Array  # (..., max_out) obj * best-class probability
    classes: jax.Array  # (..., max_out) int32 class id
    valid: jax.Array  # (..., max_out) bool

    @property
    def count(self) -> jax.Array:
        """Number of live detections per image: (...,) int32."""
        return jnp.sum(self.valid.astype(jnp.int32), axis=-1)

    def row(self, i: int) -> "Detections":
        """Slice one image out of a batched Detections."""
        return Detections(*(f[i] for f in self))


def iou_xywh(a: jax.Array, b: jax.Array) -> jax.Array:
    """IoU of center-format boxes; broadcasts over leading dims.
    a: (..., 4), b: (..., 4) -> (...,)."""
    ax0, ay0 = a[..., 0] - a[..., 2] / 2, a[..., 1] - a[..., 3] / 2
    ax1, ay1 = a[..., 0] + a[..., 2] / 2, a[..., 1] + a[..., 3] / 2
    bx0, by0 = b[..., 0] - b[..., 2] / 2, b[..., 1] - b[..., 3] / 2
    bx1, by1 = b[..., 0] + b[..., 2] / 2, b[..., 1] + b[..., 3] / 2
    iw = jnp.maximum(jnp.minimum(ax1, bx1) - jnp.maximum(ax0, bx0), 0.0)
    ih = jnp.maximum(jnp.minimum(ay1, by1) - jnp.maximum(ay0, by0), 0.0)
    inter = iw * ih
    union = a[..., 2] * a[..., 3] + b[..., 2] * b[..., 3] - inter
    return inter / jnp.maximum(union, 1e-9)


def nms(
    boxes: jax.Array,
    scores: jax.Array,
    classes: Optional[jax.Array] = None,
    *,
    iou_threshold: float = 0.5,
    max_out: int = 32,
) -> tuple[jax.Array, jax.Array]:
    """Greedy NMS over one image. boxes (M, 4), scores (M,), classes (M,)
    optional int — when given, suppression is class-aware (a pick only
    suppresses boxes of ITS class). Entries with score <= 0 are dead on
    arrival (the score threshold zeroes them upstream).

    Returns (indices (max_out,) int32, valid (max_out,) bool).
    """
    m = boxes.shape[0]
    idx0 = jnp.zeros((max_out,), jnp.int32)
    ok0 = jnp.zeros((max_out,), bool)
    if m == 0:  # empty candidate set: argmax over 0 entries is undefined
        return idx0, ok0
    live = jnp.where(scores > 0.0, scores, -jnp.inf)

    def body(k, carry):
        live, idx, ok = carry
        i = jnp.argmax(live)
        picked = live[i] > 0.0
        same = jnp.ones((m,), bool) if classes is None else classes == classes[i]
        overlap = iou_xywh(boxes, boxes[i]) >= iou_threshold
        # the pick itself has IoU 1 with itself, so it dies here too
        live = jnp.where(picked & same & overlap, -jnp.inf, live)
        idx = idx.at[k].set(i.astype(jnp.int32))
        ok = ok.at[k].set(picked)
        return live, idx, ok

    _, idx, ok = jax.lax.fori_loop(0, min(max_out, m), body, (live, idx0, ok0))
    return idx, ok


def class_aware_nms(boxes, scores, classes, *, iou_threshold=0.5, max_out=32):
    """Per-class greedy NMS (thin alias: ``nms`` with classes required)."""
    return nms(
        boxes, scores, classes, iou_threshold=iou_threshold, max_out=max_out
    )


def postprocess(
    head: jax.Array,
    anchors=DEFAULT_ANCHORS,
    *,
    score_threshold: float = 0.25,
    iou_threshold: float = 0.5,
    max_detections: int = 32,
) -> Detections:
    """Full serving postprocess: ``decode_head`` (with its score threshold)
    → best-class scoring → class-aware NMS. head: (N, gh, gw, A, 5+C) raw
    predictions → batched fixed-size :class:`Detections`.

    ``score_threshold`` gates BOTH the objectness (via ``decode_head``) and
    the combined ``obj * best-class`` score, so every valid detection's
    reported score is >= the threshold.
    """
    boxes, obj, cls = decode_head(head, anchors, threshold=score_threshold)
    cls_id = jnp.argmax(cls, axis=-1).astype(jnp.int32)
    score = obj * jnp.max(cls, axis=-1)
    score = jnp.where(score >= score_threshold, score, 0.0)
    # sub-threshold entries are exactly 0 -> dead on arrival in NMS
    n = head.shape[0]
    flat = lambda x, d: x.reshape((n, -1) + x.shape[x.ndim - d :])  # noqa: E731
    boxes_f, score_f, cls_f = flat(boxes, 1), flat(score, 0), flat(cls_id, 0)

    def one(b, s, c):
        idx, ok = nms(
            b, s, c, iou_threshold=iou_threshold, max_out=max_detections
        )
        okf = ok.astype(b.dtype)
        return Detections(
            boxes=b[idx] * okf[:, None],
            scores=s[idx] * okf,
            classes=c[idx] * ok.astype(jnp.int32),
            valid=ok,
        )

    return jax.vmap(one)(boxes_f, score_f, cls_f)
