"""Shared LM building blocks: RMSNorm, RoPE, GQA attention (w/ KV cache),
SwiGLU MLP (dense or bitmask-sparse), embeddings. Pure functions over
explicit param dicts; every initializer has a parallel `*_axes` giving the
logical sharding axes of each leaf (distributed/sharding.py maps them to the
mesh)."""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig


def _init(key, shape, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ------------------------------------------------------------- norms/rope --


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------- attention --


def attn_init(key, cfg: LMConfig) -> dict:
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.param_dtype
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (d, nh * hd), dt),
        "wk": _init(ks[1], (d, nkv * hd), dt),
        "wv": _init(ks[2], (d, nkv * hd), dt),
        "wo": _init(ks[3], (nh * hd, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh * hd,), dt)
        p["bk"] = jnp.zeros((nkv * hd,), dt)
        p["bv"] = jnp.zeros((nkv * hd,), dt)
    return p


def attn_axes(cfg: LMConfig) -> dict:
    a = {
        "wq": ("embed", "qkv"),
        "wk": ("embed", "qkv"),
        "wv": ("embed", "qkv"),
        "wo": ("qkv", "embed"),
    }
    if cfg.qkv_bias:
        a |= {"bq": ("qkv",), "bk": ("qkv",), "bv": ("qkv",)}
    return a


class KVSlice(NamedTuple):
    k: jax.Array  # (B, S, n_kv, hd)
    v: jax.Array


def _qkv(x, p, cfg: LMConfig, positions):
    b, s, _ = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, nh, hd)
    k = k.reshape(b, s, nkv, hd)
    v = v.reshape(b, s, nkv, hd)
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, cfg: LMConfig):
    """Grouped-query scaled dot-product attention.
    q: (B,Sq,H,hd)  k,v: (B,Skv,KV,hd)  mask: (B,1,Sq,Skv) bool or None."""
    b, sq, nh, hd = q.shape
    nkv = k.shape[2]
    g = nh // nkv
    q = q.reshape(b, sq, nkv, g, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32) / np.sqrt(hd)
    if mask is not None:
        logits = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(b, sq, nh * hd)


# Above this many query positions, self-attention switches to the chunked
# online-softmax path (flash-style in pure XLA): peak memory goes from
# O(Sq·Skv) to O(q_chunk·kv_chunk) per step. Needed so 32k prefill lowers.
CHUNKED_ATTN_THRESHOLD = 8_192
# §Perf iteration (qwen1.5-0.5b x prefill_32k): bigger Q chunks amortize
# K/V re-reads (memory term -8%); 8192x2048 keeps the f32 score tile at
# 67 MB (inside a v5e core's ~128 MB VMEM) and KV_CHUNK == 32k/16 stays
# aligned with the kv_seq shard so no cross-shard collectives appear.
Q_CHUNK = 8_192
KV_CHUNK = 2_048


def _chunked_sdpa(q, k, v, cfg: LMConfig, *, causal: bool, q_chunk=None, kv_chunk=None):
    q_chunk = q_chunk or Q_CHUNK  # resolved at call time (perf-tunable)
    kv_chunk = kv_chunk or KV_CHUNK
    """Blockwise attention with online softmax (Rabe & Staats / FlashAttention
    recurrence) in pure lax — the TPU kernel is structurally identical but
    this version lowers on any backend and keeps the O(S^2) score matrix out
    of HBM. q (B,S,H,hd), k/v (B,S,KV,hd); S divisible by chunk sizes
    (callers pad)."""
    b, s, nh, hd = q.shape
    nkv = k.shape[2]
    g = nh // nkv
    scale = 1.0 / np.sqrt(hd)
    s_real = s
    pad = (-s) % max(q_chunk, kv_chunk)
    if pad:  # pad keys get masked below; padded queries are sliced away
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nq, nk = s // q_chunk, s // kv_chunk
    qc = q.reshape(b, nq, q_chunk, nkv, g, hd)
    kc = k.reshape(b, nk, kv_chunk, nkv, hd)
    vc = v.reshape(b, nk, kv_chunk, nkv, hd)

    def q_block(qi):
        qb = qc[:, qi]  # (B, qc, KV, G, hd)

        def kv_step(carry, ki):
            acc, m, l = carry
            kb = kc[:, ki]
            vb = vc[:, ki]
            logits = jnp.einsum("bqkgh,bskh->bkgqs", qb, kb).astype(jnp.float32) * scale
            qpos = qi * q_chunk + jnp.arange(q_chunk)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            mask = kpos[None, :] < s_real  # padded keys never attended
            if causal:
                mask = mask & (qpos[:, None] >= kpos[None, :])
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p_ = jnp.exp(logits - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p_, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p_.astype(vb.dtype), vb
            ).astype(jnp.float32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, nkv, g, q_chunk, hd), jnp.float32)
        m0 = jnp.full((b, nkv, g, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, nkv, g, q_chunk), jnp.float32)
        # causal: block (qi, ki) is all-masked when ki*kvc > (qi+1)*qc — skip
        # via masked scan bounds is not static; rely on the mask (XLA still
        # executes but the result is exact).
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)  # (B, KV, G, qc, hd)

    outs = jax.lax.map(q_block, jnp.arange(nq))  # (nq, B, KV, G, qc, hd)
    out = jnp.moveaxis(outs, 0, 3)  # (B, KV, G, nq, qc, hd)
    out = out.reshape(b, nkv, g, s, hd).transpose(0, 3, 1, 2, 4).reshape(b, s, nh * hd)
    return out[:, :s_real]


def attention(
    x: jax.Array,
    p: dict,
    cfg: LMConfig,
    *,
    positions: jax.Array,
    causal: bool = True,
    kv_cache: Optional[KVSlice] = None,
    cache_pos: Optional[jax.Array] = None,
    cross_kv: Optional[KVSlice] = None,
):
    """Returns (out, new_kv). Modes:
      * full (train/prefill): causal self-attention over x.
      * decode: kv_cache + cache_pos given, x is (B, 1, D).
      * cross: cross_kv given (whisper decoder) — keys from the encoder.
    """
    b, s, _ = x.shape
    if cross_kv is not None:
        nh, hd = cfg.n_heads, cfg.hd
        q = (x @ p["wq"]).reshape(b, s, nh, hd)
        if cfg.qkv_bias:
            q = q + p["bq"].reshape(nh, hd)
        out = _sdpa(q, cross_kv.k, cross_kv.v, None, cfg)
        return out @ p["wo"], None

    q, k, v = _qkv(x, p, cfg, positions)

    if kv_cache is not None:  # decode: append one step at cache_pos
        if jnp.ndim(cache_pos) == 0:  # uniform position across the batch
            k_all = jax.lax.dynamic_update_slice_in_dim(kv_cache.k, k.astype(kv_cache.k.dtype), cache_pos, axis=1)
            v_all = jax.lax.dynamic_update_slice_in_dim(kv_cache.v, v.astype(kv_cache.v.dtype), cache_pos, axis=1)
            pos_b = cache_pos[None]
        else:  # per-slot positions (continuous batching, s == 1)
            rows = jnp.arange(b)[:, None]
            cols = cache_pos[:, None] + jnp.arange(s)[None]
            k_all = kv_cache.k.at[rows, cols].set(k.astype(kv_cache.k.dtype))
            v_all = kv_cache.v.at[rows, cols].set(v.astype(kv_cache.v.dtype))
            pos_b = cache_pos
        skv = k_all.shape[1]
        # position j is visible to query step i iff j <= cache_pos + i
        valid = jnp.arange(skv)[None, None, :] <= (
            pos_b[:, None, None] + jnp.arange(s)[None, :, None]
        )
        out = _sdpa(q, k_all, v_all, valid[:, None], cfg)  # (B, 1, Sq, Skv)
        return out @ p["wo"], KVSlice(k_all, v_all)

    if s > CHUNKED_ATTN_THRESHOLD:
        out = _chunked_sdpa(q, k, v, cfg, causal=causal)
    else:
        mask = None
        if causal:
            mask = jnp.tril(jnp.ones((s, s), bool))[None, None]
        out = _sdpa(q, k, v, mask, cfg)
    return out @ p["wo"], KVSlice(k, v)


# -------------------------------------------------------------------- MLP --


def mlp_init(key, cfg: LMConfig, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.param_dtype
    ks = jax.random.split(key, 3)
    return {
        "wi": _init(ks[0], (d, f), dt),
        "wg": _init(ks[1], (d, f), dt),
        "wo": _init(ks[2], (f, d), dt),
    }


def mlp_axes(cfg: LMConfig) -> dict:
    return {"wi": ("embed", "mlp"), "wg": ("embed", "mlp"), "wo": ("mlp", "embed")}


def mlp(x: jax.Array, p: dict) -> jax.Array:
    """SwiGLU."""
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]


# -------------------------------------------------------------- embeddings --


def embed_init(key, cfg: LMConfig) -> dict:
    dt = cfg.param_dtype
    ks = jax.random.split(key, 3)
    p = {
        "tok": _init(ks[0], (cfg.vocab_size, cfg.d_model), dt, scale=1.0),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = _init(ks[1], (cfg.d_model, cfg.vocab_size), dt)
    return p


def embed_axes(cfg: LMConfig) -> dict:
    a = {"tok": ("vocab", "embed"), "final_norm": (None,)}
    if not cfg.tie_embeddings:
        a["lm_head"] = ("embed", "vocab")
    return a


def embed_tokens(tokens: jax.Array, p: dict) -> jax.Array:
    """Distributed-aware embedding lookup (see distributed/embedding.py)."""
    from repro.distributed import embedding as de

    return de.embed_lookup(tokens, p["tok"])


def logits_fn(x: jax.Array, p: dict, cfg: LMConfig) -> jax.Array:
    from repro.distributed import embedding as de

    x = rmsnorm(x, p["final_norm"], cfg.norm_eps)
    w = p["tok"].T if cfg.tie_embeddings else p["lm_head"]
    return de.lm_head(x, w)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits (..., V) f32, labels (...) int32. Mean NLL."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
