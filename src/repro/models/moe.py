"""Mixture-of-Experts layers (deepseek-moe-16b: 2 shared + 64 routed top-6
fine-grained; olmoe-1b-7b: 64 routed top-8).

Dispatch uses sort-based grouping with a fixed per-expert capacity
(dropped-token MoE): static shapes for jit, experts sharded over the
'experts' logical axis (→ 'model' mesh axis). The router's top-k mask is the
paper's "enable map" at tile granularity — routing IS activation gating
(DESIGN.md §4): experts only compute on tokens whose gate is nonzero, the
MoE analogue of the gated one-to-all product's zero-activation gating.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models import layers as L


def moe_init(key, cfg: LMConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.param_dtype
    ks = jax.random.split(key, 5)
    p = {
        "router": L._init(ks[0], (d, e), jnp.float32),  # router math in f32
        "experts": {
            "wi": L._init(ks[1], (e, d, f), dt),
            "wg": L._init(ks[2], (e, d, f), dt),
            "wo": L._init(ks[3], (e, f, d), dt),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = L.mlp_init(ks[4], cfg, d_ff=f * cfg.n_shared_experts)
    return p


def moe_axes(cfg: LMConfig) -> dict:
    a = {
        "router": ("embed", None),
        # experts shard over 'model'; the per-expert FFN dims get their own
        # logical axis (expert_mlp -> replicated) — two dims of one tensor
        # cannot both land on the 'model' mesh axis
        "experts": {
            "wi": ("experts", "embed", "expert_mlp"),
            "wg": ("experts", "embed", "expert_mlp"),
            "wo": ("experts", "expert_mlp", "embed"),
        },
    }
    if cfg.n_shared_experts:
        a["shared"] = L.mlp_axes(cfg)
    return a


def _capacity(n_tokens: int, cfg: LMConfig) -> int:
    cap = int(np.ceil(cfg.top_k * n_tokens / cfg.n_experts * cfg.capacity_factor))
    return max(cap, 8)


def route(x2d: jax.Array, router_w: jax.Array, cfg: LMConfig):
    """x2d (T, D) → (expert_ids (T,k), gates (T,k), aux_loss)."""
    logits = (x2d.astype(jnp.float32) @ router_w).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32), axis=1), axis=0
    ) / cfg.top_k
    aux = cfg.n_experts * jnp.sum(me * ce)
    return ids, gates, aux


def dispatch_group(ids: jax.Array, n_tokens: int, cfg: LMConfig):
    """Sort-based grouping. ids (T, k) → per-slot token index (E*C,) and a
    validity/gate-slot map back to (T, k)."""
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(n_tokens, cfg)
    flat_e = ids.reshape(-1)  # (T*k,)
    flat_tok = jnp.repeat(jnp.arange(n_tokens), k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]
    # rank of each entry within its expert group
    group_start = jnp.searchsorted(sorted_e, jnp.arange(E))
    rank = jnp.arange(n_tokens * k) - group_start[sorted_e]
    keep = rank < C
    slot = sorted_e * C + rank  # destination slot in (E*C)
    slot = jnp.where(keep, slot, E * C)  # overflow bucket
    slot_token = jnp.full((E * C + 1,), n_tokens, jnp.int32)  # n_tokens = pad row
    slot_token = slot_token.at[slot].set(sorted_tok.astype(jnp.int32))
    # map back: for each (token, k) entry, which slot served it (or -1)
    entry_slot = jnp.full((n_tokens * k,), -1, jnp.int32)
    entry_slot = entry_slot.at[order].set(jnp.where(keep, slot, -1).astype(jnp.int32))
    return slot_token[: E * C], entry_slot.reshape(n_tokens, k), C


def moe_mlp(x: jax.Array, p: dict, cfg: LMConfig):
    """x (B, S, D) → (out, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    ids, gates, aux = route(x2d, p["router"], cfg)
    slot_token, entry_slot, C = dispatch_group(ids, t, cfg)

    x_pad = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
    grouped = x_pad[slot_token].reshape(cfg.n_experts, C, d)  # (E, C, D)

    ew = p["experts"]
    hg = jnp.einsum("ecd,edf->ecf", grouped, ew["wg"])
    hi = jnp.einsum("ecd,edf->ecf", grouped, ew["wi"])
    ho = jnp.einsum("ecf,efd->ecd", jax.nn.silu(hg) * hi, ew["wo"])  # (E, C, D)
    ho_flat = ho.reshape(cfg.n_experts * C, d)

    # combine: each (token, k) entry pulls its slot's output, scaled by gate
    safe_slot = jnp.maximum(entry_slot, 0)
    pulled = ho_flat[safe_slot]  # (T, k, D)
    valid = (entry_slot >= 0).astype(pulled.dtype)[..., None]
    out = jnp.sum(pulled * valid * gates[..., None].astype(pulled.dtype), axis=1)

    if cfg.n_shared_experts:
        out = out + L.mlp(x2d, p["shared"])
    return out.reshape(b, s, d).astype(x.dtype), aux


def moe_layer_init(key, cfg: LMConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "attn": L.attn_init(k1, cfg),
        "moe": moe_init(k2, cfg),
        "ln1": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "ln2": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }


def moe_layer_axes(cfg: LMConfig) -> dict:
    return {
        "attn": L.attn_axes(cfg),
        "moe": moe_axes(cfg),
        "ln1": (None,),
        "ln2": (None,),
    }


def moe_block(x, lp, cfg: LMConfig, *, positions, kv=None, cache_pos=None, causal=True):
    h, new_kv = L.attention(
        L.rmsnorm(x, lp["ln1"], cfg.norm_eps),
        lp["attn"],
        cfg,
        positions=positions,
        causal=causal,
        kv_cache=kv,
        cache_pos=cache_pos,
    )
    x = x + h
    mo, _aux = moe_mlp(L.rmsnorm(x, lp["ln2"], cfg.norm_eps), lp["moe"], cfg)
    return x + mo, new_kv


# ----------------------------------------- expert parallelism (§Perf OPT6) --
# The jnp-level moe_mlp above lets GSPMD distribute the dispatch gather,
# which materializes an all-gather of EVERY token on EVERY expert shard
# (T x D bytes x model-axis). But with tokens sharded over 'data' and
# experts over 'model', each device ALREADY holds (its tokens x its
# experts): the only communication MoE fundamentally needs is the combine
# reduction over the expert axis. This shard_map version does exactly
# that — local routing, local dispatch restricted to the shard's experts,
# local expert FFNs, then one psum('model') of the (T_local, D) output:
# per-device collective bytes drop from T*D (gather) to T_local*D (psum).


def _dispatch_group_masked(ids, keep_entry, n_tokens: int, n_experts: int,
                           top_k: int, capacity: int):
    """dispatch_group over a LOCAL expert range: entries with
    keep_entry=False (expert lives on another shard) are dropped."""
    E, k, C = n_experts, top_k, capacity
    flat_e = jnp.where(keep_entry.reshape(-1), ids.reshape(-1), E)  # E = drop
    flat_tok = jnp.repeat(jnp.arange(n_tokens), k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]
    group_start = jnp.searchsorted(sorted_e, jnp.arange(E))
    rank = jnp.arange(n_tokens * k) - group_start[jnp.clip(sorted_e, 0, E - 1)]
    keep = (rank < C) & (sorted_e < E)
    slot = jnp.where(keep, sorted_e * C + rank, E * C)
    slot_token = jnp.full((E * C + 1,), n_tokens, jnp.int32)
    slot_token = slot_token.at[slot].set(sorted_tok.astype(jnp.int32))
    entry_slot = jnp.full((n_tokens * k,), -1, jnp.int32)
    entry_slot = entry_slot.at[order].set(jnp.where(keep, slot, -1).astype(jnp.int32))
    return slot_token[: E * C], entry_slot.reshape(n_tokens, k)


def moe_mlp_ep(x: jax.Array, p: dict, cfg: LMConfig):
    """Expert-parallel moe_mlp. Falls back to moe_mlp when no mesh context
    (CPU tests / single device) or the shapes don't divide the mesh."""
    from repro.distributed import sharding as shd

    mesh = shd.current_mesh()
    rules = shd.current_rules()
    b, s, d = x.shape
    t = b * s
    e_axis = rules.get("experts") if rules else None
    if (
        mesh is None
        or e_axis not in getattr(mesh, "axis_names", ())
        or cfg.n_experts % mesh.shape[e_axis] != 0
    ):
        return moe_mlp(x, p, cfg)
    batch_rule = rules.get("batch")
    b_axes = (batch_rule,) if isinstance(batch_rule, str) else (batch_rule or ())
    n_data = 1
    for a in b_axes:
        n_data *= mesh.shape[a]
    if t % max(n_data, 1) != 0:
        return moe_mlp(x, p, cfg)
    M = mesh.shape[e_axis]
    E_l = cfg.n_experts // M
    t_l = t // max(n_data, 1)
    C = max(int(np.ceil(cfg.top_k * t_l / cfg.n_experts * cfg.capacity_factor)), 8)

    from repro.distributed.compat import shard_map
    from jax.sharding import PartitionSpec as P

    def local(x2d, router_w, wi, wg, wo, shared):
        ids, gates, aux = route(x2d, router_w, cfg)  # local tokens, all E
        m_idx = jax.lax.axis_index(e_axis)
        lo = m_idx * E_l
        keep = (ids >= lo) & (ids < lo + E_l)
        slot_token, entry_slot = _dispatch_group_masked(
            ids - lo, keep, x2d.shape[0], E_l, cfg.top_k, C
        )
        x_pad = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
        grouped = x_pad[slot_token].reshape(E_l, C, d)
        hg = jnp.einsum("ecd,edf->ecf", grouped, wg)
        hi = jnp.einsum("ecd,edf->ecf", grouped, wi)
        ho = jnp.einsum("ecf,efd->ecd", jax.nn.silu(hg) * hi, wo)
        ho_flat = ho.reshape(E_l * C, d)
        safe = jnp.maximum(entry_slot, 0)
        pulled = ho_flat[safe]
        valid = (entry_slot >= 0).astype(pulled.dtype)[..., None]
        out = jnp.sum(pulled * valid * gates[..., None].astype(pulled.dtype), axis=1)
        out = jax.lax.psum(out, e_axis)  # combine across expert shards
        if cfg.n_shared_experts:
            out = out + L.mlp(x2d, shared)
        aux = jax.lax.pmean(aux, e_axis)
        return out, aux

    bspec = batch_rule
    shared_p = p.get("shared")
    shared_specs = jax.tree_util.tree_map(lambda _: P(None, None), shared_p) if shared_p else None
    out2d, aux = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(bspec, None), P(None, None), P(e_axis, None, None),
                  P(e_axis, None, None), P(e_axis, None, None), shared_specs),
        out_specs=(P(bspec, None), P()),
        check_vma=False,
    )(x.reshape(t, d), p["router"], p["experts"]["wi"], p["experts"]["wg"],
      p["experts"]["wo"], shared_p)
    return out2d.reshape(b, s, d).astype(x.dtype), aux


def moe_block_ep(x, lp, cfg: LMConfig, *, positions, kv=None, cache_pos=None, causal=True):
    h, new_kv = L.attention(
        L.rmsnorm(x, lp["ln1"], cfg.norm_eps),
        lp["attn"],
        cfg,
        positions=positions,
        causal=causal,
        kv_cache=kv,
        cache_pos=cache_pos,
    )
    x = x + h
    mo, _aux = moe_mlp_ep(L.rmsnorm(x, lp["ln2"], cfg.norm_eps), lp["moe"], cfg)
    return x + mo, new_kv
