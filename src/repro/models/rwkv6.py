"""RWKV6 "Finch" (attention-free, data-dependent decay) — rwkv6-3b.

Time-mix recurrence per head (state S ∈ R^{hd×hd}):
    y_t = r_t · (diag(u)·k_t v_tᵀ + S_t)
    S_{t+1} = diag(w_t) · S_t + k_t v_tᵀ
with data-dependent per-channel decay w_t = exp(-exp(w0 + tanh(x W_a) W_b)).

The decay-accumulate structure is again the paper's leaky-integrator family
(LIF without threshold; DESIGN.md §4). Training runs a CHUNKED scan: within
a chunk the contribution is an attention-like masked matmul with decay
weights; the state hops chunk to chunk — same skeleton as mamba2's SSD, so
long-context decode stays O(1) memory in sequence length.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.distributed import sharding as shd
from repro.models import layers as L

HEAD_DIM = 64
LORA_R = 64


def n_heads(cfg: LMConfig) -> int:
    return cfg.d_model // HEAD_DIM


def rwkv_init(key, cfg: LMConfig) -> dict:
    d = cfg.d_model
    dt = cfg.param_dtype
    ks = jax.random.split(key, 12)
    return {
        "ln1": jnp.ones((d,), dt),
        "ln2": jnp.ones((d,), dt),
        # time-mix interpolation factors (token shift)
        "mu_r": jnp.full((d,), 0.5, dt),
        "mu_k": jnp.full((d,), 0.5, dt),
        "mu_v": jnp.full((d,), 0.5, dt),
        "mu_g": jnp.full((d,), 0.5, dt),
        "mu_w": jnp.full((d,), 0.5, dt),
        "w_r": L._init(ks[0], (d, d), dt),
        "w_k": L._init(ks[1], (d, d), dt),
        "w_v": L._init(ks[2], (d, d), dt),
        "w_g": L._init(ks[3], (d, d), dt),
        "w_o": L._init(ks[4], (d, d), dt),
        # data-dependent decay LoRA
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "w_a": L._init(ks[5], (d, LORA_R), dt),
        "w_b": L._init(ks[6], (LORA_R, d), dt),
        "u": jnp.zeros((d,), jnp.float32),  # bonus for current token
        "gn": jnp.ones((d,), dt),  # group-norm weight on the head outputs
        # channel-mix
        "mu_cr": jnp.full((d,), 0.5, dt),
        "mu_ck": jnp.full((d,), 0.5, dt),
        "c_r": L._init(ks[7], (d, d), dt),
        "c_k": L._init(ks[8], (d, cfg.d_ff), dt),
        "c_v": L._init(ks[9], (cfg.d_ff, d), dt),
    }


def rwkv_axes(cfg: LMConfig) -> dict:
    vec = (None,)
    mat = ("embed", "heads")
    return {
        "ln1": vec, "ln2": vec,
        "mu_r": vec, "mu_k": vec, "mu_v": vec, "mu_g": vec, "mu_w": vec,
        "w_r": mat, "w_k": mat, "w_v": mat, "w_g": mat, "w_o": ("heads", "embed"),
        "w0": vec, "w_a": ("embed", None), "w_b": (None, "heads"), "u": vec, "gn": vec,
        "mu_cr": vec, "mu_ck": vec,
        "c_r": ("embed", "heads"), "c_k": ("embed", "mlp"), "c_v": ("mlp", "embed"),
    }


class RWKVState(NamedTuple):
    s: jax.Array  # (B, H, hd, hd) wkv state
    x_tm: jax.Array  # (B, D) last token (time-mix shift)
    x_cm: jax.Array  # (B, D) last token (channel-mix shift)


def init_state(cfg: LMConfig, batch: int) -> RWKVState:
    h = n_heads(cfg)
    return RWKVState(
        s=jnp.zeros((batch, h, HEAD_DIM, HEAD_DIM), jnp.float32),
        x_tm=jnp.zeros((batch, cfg.d_model), jnp.float32),
        x_cm=jnp.zeros((batch, cfg.d_model), jnp.float32),
    )


def _shift(x, last):
    """Token shift: x_{t-1} with carried boundary. x (B,T,D), last (B,D)."""
    return jnp.concatenate([last[:, None].astype(x.dtype), x[:, :-1]], axis=1)


def _wkv_chunked(r, k, v, w, u, s0, *, chunk: int = 64):
    """Chunked WKV6. r/k/v (B,T,H,hd), w (B,T,H,hd) decay in (0,1),
    s0 (B,H,hd,hd). Returns (y (B,T,H,hd), s_final).

    Recurrence: S_t = diag(w_t)·S_{t-1} + k_t v_tᵀ ;
                y_t = rᵀ_t (S_{t-1} + diag(u)·k_t v_tᵀ).
    (state BEFORE this token's injection + a 'bonus' diagonal term.)
    """
    B, T, H, hd = r.shape
    nc = T // chunk
    assert T % chunk == 0
    rc = r.reshape(B, nc, chunk, H, hd)
    kc = k.reshape(B, nc, chunk, H, hd)
    vc = v.reshape(B, nc, chunk, H, hd)
    logw = jnp.log(jnp.clip(w, 1e-8, 1.0)).reshape(B, nc, chunk, H, hd)
    sw = jnp.cumsum(logw, axis=2)  # inclusive cumulative log decay

    # intra-chunk: the decay applied to an injection at i, observed at t
    # (t > i), is prod_{j=i+1..t} w_j = e^{sw_t - sw_i}. Factor it as
    # (r_t ∘ e^{sw_t}) · (k_i ∘ e^{-sw_i}) so the contraction over hd is a
    # matmul and only the (t, i, H) score tensor is materialized.
    r_tilde = rc * jnp.exp(jnp.clip(sw, -60.0, 0.0))
    k_tilde = kc * jnp.exp(jnp.clip(-sw, 0.0, 60.0))
    scores = jnp.einsum("bnthd,bnihd->bntih", r_tilde, k_tilde)  # (B,nc,t,i,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)[None, None, :, :, None]
    scores = jnp.where(tri, scores, 0.0)
    y_intra = jnp.einsum("bntih,bnihd->bnthd", scores, vc)
    # current-token bonus: y_t += (r_t ∘ u ∘ k_t)·v_t
    bonus = jnp.sum(rc * u[None, None, None] * kc, axis=-1)  # (B,nc,t,H)
    y_intra = y_intra + bonus[..., None] * vc

    # chunk state: S' = diag(e^{sw_last}) S + Σ_i diag(e^{sw_last - sw_i}) k_i v_iᵀ
    sw_last = sw[:, :, -1:]  # (B,nc,1,H,hd)
    rdec = jnp.exp(jnp.clip(sw_last - sw, -60.0, 0.0))  # (B,nc,chunk,H,hd)
    inj = jnp.einsum("bnthd,bntho->bnhdo", kc * rdec, vc)  # (B,nc,H,hd,hd)
    cdec = jnp.exp(jnp.clip(sw_last[:, :, 0], -60.0, 0.0))  # (B,nc,H,hd)

    def scan_fn(s, inp):
        cd, ic = inp  # cd (B,H,hd), ic (B,H,hd,hd)
        s_new = s * cd[..., None] + ic
        return s_new, s

    s_final, s_prevs = jax.lax.scan(
        scan_fn, s0, (cdec.transpose(1, 0, 2, 3), inj.transpose(1, 0, 2, 3, 4))
    )
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)  # (B,nc,H,hd,hd)

    # inter-chunk: y_t += (r_t ∘ e^{sw_{t-1}+logw_t ... }) — decay from chunk
    # start to t applied to the carried state: prod_{j<=t} w_j = e^{sw_t}
    esw = jnp.exp(jnp.clip(sw, -60.0, 0.0))  # (B,nc,t,H,hd)
    y_inter = jnp.einsum("bnthd,bnhdo->bntho", rc * esw, s_prevs)

    y = (y_intra + y_inter).reshape(B, T, H, hd)
    return y, s_final


def time_mix(x, p, cfg: LMConfig, state: Optional[RWKVState], *, chunk: int = 64):
    b, t, d = x.shape
    H = n_heads(cfg)
    last = state.x_tm if state is not None else jnp.zeros((b, d))
    xs = _shift(x, last)

    def lerp(mu):
        return x + (xs - x) * mu[None, None]

    r = (lerp(p["mu_r"]) @ p["w_r"]).reshape(b, t, H, HEAD_DIM).astype(jnp.float32)
    k = (lerp(p["mu_k"]) @ p["w_k"]).reshape(b, t, H, HEAD_DIM).astype(jnp.float32)
    v = (lerp(p["mu_v"]) @ p["w_v"]).reshape(b, t, H, HEAD_DIM).astype(jnp.float32)
    g = jax.nn.silu(lerp(p["mu_g"]) @ p["w_g"])
    wln = p["w0"][None, None] + jnp.tanh(
        lerp(p["mu_w"]).astype(jnp.float32) @ p["w_a"].astype(jnp.float32)
    ) @ p["w_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wln)).reshape(b, t, H, HEAD_DIM)  # decay ∈ (0,1)
    u = p["u"].reshape(H, HEAD_DIM)

    s0 = state.s if state is not None else jnp.zeros((b, H, HEAD_DIM, HEAD_DIM))
    if t == 1:  # decode recurrence
        r1, k1, v1, w1 = r[:, 0], k[:, 0], v[:, 0], w[:, 0]
        y = jnp.einsum("bhd,bhdo->bho", r1, s0) + jnp.sum(
            r1 * u[None] * k1, axis=-1, keepdims=True
        ) * v1
        s_final = s0 * w1[..., None] + jnp.einsum("bhd,bho->bhdo", k1, v1)
        y = y[:, None]  # (B,1,H,hd)
    else:
        pad = (-t) % chunk
        if pad:
            r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        y, s_final = _wkv_chunked(r, k, v, w, u, s0, chunk=chunk)
        y = y[:, :t]

    y = y.reshape(b, t, d)
    y = L.rmsnorm(y.astype(x.dtype), p["gn"], cfg.norm_eps) * g
    out = y @ p["w_o"]
    return out, s_final, x[:, -1].astype(jnp.float32)


def channel_mix(x, p, state: Optional[RWKVState]):
    b, t, d = x.shape
    last = state.x_cm if state is not None else jnp.zeros((b, d))
    xs = _shift(x, last)
    xr = x + (xs - x) * p["mu_cr"][None, None]
    xk = x + (xs - x) * p["mu_ck"][None, None]
    rr = jax.nn.sigmoid(xr @ p["c_r"])
    kk = jnp.square(jax.nn.relu(xk @ p["c_k"]))
    return rr * (kk @ p["c_v"]), x[:, -1].astype(jnp.float32)


def rwkv_block(x, lp, cfg: LMConfig, *, state: Optional[RWKVState] = None, chunk: int = 64):
    """Full RWKV6 layer. Returns (x, new_state)."""
    h, s_new, tm_last = time_mix(L.rmsnorm(x, lp["ln1"], cfg.norm_eps), lp, cfg, state, chunk=chunk)
    x = x + h
    h2, cm_last = channel_mix(L.rmsnorm(x, lp["ln2"], cfg.norm_eps), lp, state)
    x = x + h2
    return x, RWKVState(s=s_new, x_tm=tm_last, x_cm=cm_last)


# ------------------------------------------------------------- full model --


def init_params(key, cfg: LMConfig) -> dict:
    ke, kl = jax.random.split(key)
    keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: rwkv_init(k, cfg))(keys)
    return {"embed": L.embed_init(ke, cfg), "layers": layers}


def param_axes(cfg: LMConfig) -> dict:
    lx = jax.tree_util.tree_map(
        lambda axes: ("layers",) + axes,
        rwkv_axes(cfg),
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(a, (str, type(None))) for a in x),
    )
    return {"embed": L.embed_axes(cfg), "layers": lx}


def init_cache(cfg: LMConfig, batch: int) -> RWKVState:
    """Stacked-over-layers recurrent state — O(1) in sequence length, which
    is why rwkv6 runs the long_500k shape."""
    st = init_state(cfg, batch)
    L_ = cfg.n_layers
    return RWKVState(
        s=jnp.zeros((L_,) + st.s.shape, jnp.float32),
        x_tm=jnp.zeros((L_,) + st.x_tm.shape, jnp.float32),
        x_cm=jnp.zeros((L_,) + st.x_cm.shape, jnp.float32),
    )


def forward(
    params,
    tokens,
    cfg: LMConfig,
    *,
    state: Optional[RWKVState] = None,
    collect_state: bool = False,
    chunk: int = 64,
):
    """tokens (B, T) → (logits, new_state|None). Scan over stacked layers."""
    collect_state = collect_state or state is not None
    x = L.embed_tokens(tokens, params["embed"])

    def body(h, xs):
        if state is not None:
            lp, st_l = xs
            st = RWKVState(*st_l)
        else:
            lp, st = xs, None
        h, ns = rwkv_block(h, lp, cfg, state=st, chunk=chunk)
        h = shd.constrain_act(h, ("batch", "act_seq", None))  # SP stash
        return h, (tuple(ns) if collect_state else None)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    xs = (params["layers"], tuple(state)) if state is not None else params["layers"]
    x, ns = jax.lax.scan(body, x, xs)
    logits = L.logits_fn(x, params["embed"], cfg)
    new_state = RWKVState(*ns) if ns is not None else None
    return logits, new_state
