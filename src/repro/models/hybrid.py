"""zamba2-style hybrid: stacks of Mamba2 layers with a SHARED attention
block (one parameter set, applied every `attn_every` layers — zamba2's
parameter-sharing trick).

Layout: n_super super-layers, each = `attn_every` mamba layers + one
application of the shared attention block; `n_tail` trailing mamba layers
make up the remainder (81 = 13·6 + 3 for zamba2-7b with attn_every=6).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.distributed import sharding as shd
from repro.models import layers as L
from repro.models import mamba2
from repro.models import transformer as tfm


def split_layers(cfg: LMConfig) -> tuple[int, int]:
    n_super = cfg.n_layers // cfg.attn_every
    n_tail = cfg.n_layers - n_super * cfg.attn_every
    return n_super, n_tail


def init_params(key, cfg: LMConfig) -> dict:
    n_super, n_tail = split_layers(cfg)
    ke, km, kt, ka = jax.random.split(key, 4)
    mk = jax.random.split(km, n_super * cfg.attn_every).reshape(n_super, cfg.attn_every, 2)
    stack = jax.vmap(jax.vmap(lambda k: mamba2.mamba_init(k, cfg)))(mk)
    p = {
        "embed": L.embed_init(ke, cfg),
        "mamba": stack,  # (n_super, attn_every, ...)
        "shared_attn": tfm.layer_init(ka, cfg),  # ONE block, reused
    }
    if n_tail:
        tk = jax.random.split(kt, n_tail)
        p["tail"] = jax.vmap(lambda k: mamba2.mamba_init(k, cfg))(tk)
    return p


def param_axes(cfg: LMConfig) -> dict:
    n_super, n_tail = split_layers(cfg)
    m_axes = jax.tree_util.tree_map(
        lambda axes: ("layers", None) + axes,
        mamba2.mamba_axes(cfg),
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )
    p = {
        "embed": L.embed_axes(cfg),
        "mamba": m_axes,
        "shared_attn": tfm.layer_axes(cfg),
    }
    if n_tail:
        p["tail"] = jax.tree_util.tree_map(
            lambda axes: ("layers",) + axes,
            mamba2.mamba_axes(cfg),
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(a, (str, type(None))) for a in x),
        )
    return p


class HybridCache(NamedTuple):
    mamba: mamba2.MambaState  # stacked (n_super, attn_every, ...)
    tail: Optional[mamba2.MambaState]  # stacked (n_tail, ...)
    attn_k: jax.Array  # (n_super, B, S, kv, hd)
    attn_v: jax.Array


def init_cache(cfg: LMConfig, batch: int, max_seq: int, dtype=jnp.bfloat16) -> HybridCache:
    n_super, n_tail = split_layers(cfg)

    def stacked_state(*lead):
        st = mamba2.init_state(cfg, batch)
        return mamba2.MambaState(
            h=jnp.zeros(lead + st.h.shape, jnp.float32),
            conv=jnp.zeros(lead + st.conv.shape, jnp.float32),
        )

    kv_shape = (n_super, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    return HybridCache(
        mamba=stacked_state(n_super, cfg.attn_every),
        tail=stacked_state(n_tail) if n_tail else None,
        attn_k=jnp.zeros(kv_shape, dtype),
        attn_v=jnp.zeros(kv_shape, dtype),
    )


def forward(
    params,
    tokens,
    cfg: LMConfig,
    *,
    cache: Optional[HybridCache] = None,
    cache_pos=None,
    collect_kv: bool = False,
):
    """Returns (logits, new_cache | None)."""
    collect_kv = collect_kv or cache is not None
    x = L.embed_tokens(tokens, params["embed"])
    b, s, _ = x.shape
    base = cache_pos if cache_pos is not None else 0
    if cache_pos is not None and jnp.ndim(cache_pos) == 1:
        base = cache_pos[:, None]  # per-slot positions (continuous batching)
    positions = base + jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    n_super, n_tail = split_layers(cfg)
    shared = params["shared_attn"]

    def super_body(carry, xs):
        h = carry
        mp, mstate, kv_l = xs
        new_states = []
        for j in range(cfg.attn_every):
            lp_j = jax.tree_util.tree_map(lambda a: a[j], mp)
            st_j = (
                mamba2.MambaState(h=mstate.h[j], conv=mstate.conv[j])
                if mstate is not None
                else None
            )
            h, ns = mamba2.mamba_forward(h, lp_j, cfg, state=st_j)
            new_states.append(ns)
        kv = tfm.KVSlice_or_none(kv_l)
        h, new_kv = tfm.dense_block(
            h, shared, cfg, positions=positions, kv=kv, cache_pos=cache_pos
        )
        stacked = mamba2.MambaState(
            h=jnp.stack([st.h for st in new_states]),
            conv=jnp.stack([st.conv for st in new_states]),
        )
        h = shd.constrain_act(h, ("batch", "act_seq", None))  # SP stash
        out = (stacked, new_kv if collect_kv else None)
        return h, out

    if cfg.remat:
        super_body = jax.checkpoint(super_body, prevent_cse=False)

    mstates = cache.mamba if cache is not None else None
    kv_in = (cache.attn_k, cache.attn_v) if cache is not None else None
    x, (new_mstates, new_kv) = jax.lax.scan(
        super_body, x, (params["mamba"], mstates, kv_in)
    )

    new_tail = None
    if n_tail:
        tail_states = []
        for j in range(n_tail):
            lp_j = jax.tree_util.tree_map(lambda a: a[j], params["tail"])
            st_j = (
                mamba2.MambaState(h=cache.tail.h[j], conv=cache.tail.conv[j])
                if cache is not None
                else None
            )
            x, ns = mamba2.mamba_forward(x, lp_j, cfg, state=st_j)
            tail_states.append(ns)
        new_tail = mamba2.MambaState(
            h=jnp.stack([t.h for t in tail_states]),
            conv=jnp.stack([t.conv for t in tail_states]),
        )

    logits = L.logits_fn(x, params["embed"], cfg)
    new_cache = None
    if collect_kv:
        new_cache = HybridCache(
            mamba=new_mstates,
            tail=new_tail,
            attn_k=new_kv.k if new_kv is not None else None,
            attn_v=new_kv.v if new_kv is not None else None,
        )
    return logits, new_cache


# ------------------------------------------------- serve fast path (§Perf) --
# Same carry-aliased trick as transformer.cached_forward: the decode step
# carries the whole HybridCache through a fori_loop over super-layers and
# updates states/KV in place (token-granular for the shared-attention KV),
# instead of scan-stacking new caches (which copies the full per-super KV
# every super-layer — 4x the true traffic at long_500k).


def cached_decode(params, token, cfg: LMConfig, cache: HybridCache, pos):
    """token (B,) int32, pos scalar/(B,). Returns (logits (B,V), cache)."""
    x = L.embed_tokens(token[:, None], params["embed"])
    b = x.shape[0]
    base = pos[:, None] if jnp.ndim(pos) == 1 else pos
    positions = jnp.broadcast_to(base + jnp.zeros((b, 1), jnp.int32), (b, 1))
    n_super, n_tail = split_layers(cfg)
    shared = params["shared_attn"]
    s_max = cache.attn_k.shape[2]

    def super_body(i, carry):
        x, mh, mconv, kc, vc = carry
        mp_i = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            params["mamba"],
        )
        for j in range(cfg.attn_every):
            lp_j = jax.tree_util.tree_map(lambda a: a[j], mp_i)
            st_j = mamba2.MambaState(
                h=jax.lax.dynamic_index_in_dim(mh, i, 0, keepdims=False)[j],
                conv=jax.lax.dynamic_index_in_dim(mconv, i, 0, keepdims=False)[j],
            )
            x, ns = mamba2.mamba_forward(x, lp_j, cfg, state=st_j)
            mh = mh.at[i, j].set(ns.h.astype(mh.dtype))
            mconv = mconv.at[i, j].set(ns.conv.astype(mconv.dtype))

        # shared attention block over the carried KV (token-granular write)
        h = L.rmsnorm(x, shared["ln1"], cfg.norm_eps)
        q, k, v = L._qkv(h, shared["attn"], cfg, positions)
        if jnp.ndim(pos) == 0:
            from repro.distributed import kvops

            kc = kvops.cache_write(kc, k, i, pos)
            vc = kvops.cache_write(vc, v, i, pos)
        else:
            rows = jnp.arange(b)[:, None]
            cols = pos[:, None]
            kc = kc.at[i, rows, cols].set(k.astype(kc.dtype))
            vc = vc.at[i, rows, cols].set(v.astype(vc.dtype))
        kv_axes = ("layers", "batch", "kv_seq", "kv_heads", None)
        kc = shd.constrain_act(kc, kv_axes)
        vc = shd.constrain_act(vc, kv_axes)
        k_l = jax.lax.dynamic_index_in_dim(kc, i, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(vc, i, 0, keepdims=False)
        off = pos[:, None, None] if jnp.ndim(pos) == 1 else pos
        valid = jnp.arange(s_max)[None, None, :] <= (off + jnp.zeros((1, 1, 1), jnp.int32))
        att = L._sdpa(q, k_l, v_l, valid[:, None], cfg)
        x = x + att @ shared["attn"]["wo"]
        x = x + L.mlp(L.rmsnorm(x, shared["ln2"], cfg.norm_eps), shared["mlp"])
        return (x, mh, mconv, kc, vc)

    x, mh, mconv, kc, vc = jax.lax.fori_loop(
        0, n_super, super_body,
        (x, cache.mamba.h, cache.mamba.conv, cache.attn_k, cache.attn_v),
    )

    new_tail = cache.tail
    if n_tail:
        th, tconv = cache.tail.h, cache.tail.conv
        for j in range(n_tail):
            lp_j = jax.tree_util.tree_map(lambda a: a[j], params["tail"])
            st_j = mamba2.MambaState(h=th[j], conv=tconv[j])
            x, ns = mamba2.mamba_forward(x, lp_j, cfg, state=st_j)
            th = th.at[j].set(ns.h.astype(th.dtype))
            tconv = tconv.at[j].set(ns.conv.astype(tconv.dtype))
        new_tail = mamba2.MambaState(h=th, conv=tconv)

    logits = L.logits_fn(x, params["embed"], cfg)
    new_cache = HybridCache(
        mamba=mamba2.MambaState(h=mh, conv=mconv), tail=new_tail,
        attn_k=kc, attn_v=vc,
    )
    return logits[:, 0], new_cache
