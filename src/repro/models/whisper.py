"""Whisper-style encoder–decoder backbone (whisper-small). The conv audio
frontend is a STUB per the assignment: `input_specs()` supplies precomputed
frame embeddings (B, encoder_seq, d_model); everything downstream (encoder
self-attention, decoder causal + cross attention) is real.

MLPs are 2-matrix GELU (whisper convention) rather than SwiGLU.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.distributed import sharding as shd
from repro.models import layers as L
from repro.models import transformer as tfm


def gelu_mlp_init(key, cfg: LMConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "wi": L._init(k1, (cfg.d_model, cfg.d_ff), cfg.param_dtype),
        "wo": L._init(k2, (cfg.d_ff, cfg.d_model), cfg.param_dtype),
    }


def gelu_mlp_axes(cfg):
    return {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}


def gelu_mlp(x, p):
    return jax.nn.gelu(x @ p["wi"]) @ p["wo"]


def enc_layer_init(key, cfg: LMConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "attn": L.attn_init(k1, cfg),
        "mlp": gelu_mlp_init(k2, cfg),
        "ln1": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "ln2": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }


def enc_layer_axes(cfg):
    return {
        "attn": L.attn_axes(cfg),
        "mlp": gelu_mlp_axes(cfg),
        "ln1": (None,),
        "ln2": (None,),
    }


def dec_layer_init(key, cfg: LMConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_attn": L.attn_init(k1, cfg),
        "cross_attn": L.attn_init(k2, cfg),
        "mlp": gelu_mlp_init(k3, cfg),
        "ln1": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "ln_x": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "ln2": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }


def dec_layer_axes(cfg):
    return {
        "self_attn": L.attn_axes(cfg),
        "cross_attn": L.attn_axes(cfg),
        "mlp": gelu_mlp_axes(cfg),
        "ln1": (None,),
        "ln_x": (None,),
        "ln2": (None,),
    }


def init_params(key, cfg: LMConfig) -> dict:
    ke, kenc, kdec = jax.random.split(key, 3)
    enc_keys = jax.random.split(kenc, cfg.encoder_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": L.embed_init(ke, cfg),
        "encoder": jax.vmap(lambda k: enc_layer_init(k, cfg))(enc_keys),
        "decoder": jax.vmap(lambda k: dec_layer_init(k, cfg))(dec_keys),
    }


def param_axes(cfg: LMConfig) -> dict:
    def stack(tree):
        return jax.tree_util.tree_map(
            lambda axes: ("layers",) + axes,
            tree,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(a, (str, type(None))) for a in x),
        )

    return {
        "embed": L.embed_axes(cfg),
        "encoder": stack(enc_layer_axes(cfg)),
        "decoder": stack(dec_layer_axes(cfg)),
    }


def encode(params, frames, cfg: LMConfig):
    """frames: (B, S_enc, D) stub frontend output → encoder states."""
    b, s, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(h, lp):
        a, _ = L.attention(
            L.rmsnorm(h, lp["ln1"], cfg.norm_eps),
            lp["attn"],
            cfg,
            positions=positions,
            causal=False,
        )
        h = h + a
        h = h + gelu_mlp(L.rmsnorm(h, lp["ln2"], cfg.norm_eps), lp["mlp"])
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, frames.astype(cfg.param_dtype), params["encoder"])
    return h


class WhisperCache(NamedTuple):
    self_k: jax.Array  # (L, B, S_max, kv, hd)
    self_v: jax.Array
    cross_k: jax.Array  # (L, B, S_enc, kv, hd) — fixed after prefill
    cross_v: jax.Array


def cross_kv(params, enc_out, cfg: LMConfig):
    """Precompute per-decoder-layer cross-attention K/V from encoder out."""
    b, s, _ = enc_out.shape

    def body(_, lp):
        p = lp["cross_attn"]
        k = (enc_out @ p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
        v = (enc_out @ p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
        if cfg.qkv_bias:
            k = k + p["bk"].reshape(cfg.n_kv_heads, cfg.hd)
            v = v + p["bv"].reshape(cfg.n_kv_heads, cfg.hd)
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(body, None, params["decoder"])
    return ks, vs


def decoder_forward(
    params,
    tokens,
    cfg: LMConfig,
    cross: tuple,  # (L, B, S_enc, kv, hd) ×2
    *,
    cache: Optional[WhisperCache] = None,
    cache_pos=None,
    collect_kv: bool = False,
):
    collect_kv = collect_kv or cache is not None
    x = L.embed_tokens(tokens, params["embed"])
    b, s, _ = x.shape
    base = cache_pos if cache_pos is not None else 0
    if cache_pos is not None and jnp.ndim(cache_pos) == 1:
        base = cache_pos[:, None]  # per-slot positions (continuous batching)
    positions = base + jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(h, xs):
        lp, kv_l, (ck, cv) = xs
        kv = tfm.KVSlice_or_none(kv_l)
        a, new_kv = L.attention(
            L.rmsnorm(h, lp["ln1"], cfg.norm_eps),
            lp["self_attn"],
            cfg,
            positions=positions,
            causal=True,
            kv_cache=kv,
            cache_pos=cache_pos,
        )
        h = h + a
        c, _ = L.attention(
            L.rmsnorm(h, lp["ln_x"], cfg.norm_eps),
            lp["cross_attn"],
            cfg,
            positions=positions,
            cross_kv=L.KVSlice(ck, cv),
        )
        h = h + c
        h = h + gelu_mlp(L.rmsnorm(h, lp["ln2"], cfg.norm_eps), lp["mlp"])
        h = shd.constrain_act(h, ("batch", "act_seq", None))  # SP stash
        return h, (new_kv if collect_kv else None)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    kv_in = (cache.self_k, cache.self_v) if cache is not None else None
    x, new_kv = jax.lax.scan(body, x, (params["decoder"], kv_in, cross))
    logits = L.logits_fn(x, params["embed"], cfg)
    new_cache = None
    if collect_kv and new_kv is not None:
        # scan stacked the per-layer KVSlice → fields are (L, B, S, kv, hd)
        new_cache = WhisperCache(
            self_k=new_kv.k, self_v=new_kv.v, cross_k=cross[0], cross_v=cross[1]
        )
    return logits, new_cache
