"""The paper's SNN object-detection network (§II, Fig 1/2) + ANN/QNN/BNN
baselines (Table II).

Topology (inferred — Fig 1 gives the block diagram but not channel counts;
our channel plan reproduces Table I's 3.17M parameters within 0.5% and
Fig 15's operation counts within ~20%, see benchmarks/table1_ablation.py):

  encode conv 3×3   3→16   @1024×576  (ANN encoding layer, in_T=1, out_T=1)
  maxpool
  conv block  3×3  16→32   @512×288   (in_T=1, out_T=3 — mixed time steps)
  maxpool
  basic block  32→32       @256×144   (CSP, Fig 2b)
  maxpool
  basic block  32→64       @128×72
  maxpool
  basic block  64→128      @64×36
  maxpool
  basic block 128→256      @32×18
  basic block 256→256      @32×18
  output conv 1×1 256→40   @32×18     (no-reset membrane accumulation,
                                       averaged over T; YOLOv2 head:
                                       5 anchors × (5 + 3 classes))

Basic block (Fig 2b, CSPNet-style):
  shortcut: 1×1 cin→cout/2                      (tdBN + LIF)
  main:     1×1 cin→cout → 3×3 cout→cout ×2     (tdBN + LIF each)
  concat(main, shortcut) → 1×1 1.5·cout→cout    (tdBN + LIF)

LIF: threshold 0.5, leak 0.25, hard reset. All tensors NHWC; time leads:
(T, N, H, W, C).
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, field
from typing import Any, Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import block_conv as bc
from repro.core import energy as en
from repro.core import lif as lifm
from repro.core import plan as cplan
from repro.core import pruning, quant
from repro.core import spike_conv as sc

Mode = Literal["snn", "ann", "qnn", "bnn"]
ConvExec = Literal["dense", "gated", "pallas"]


@dataclass(frozen=True)
class SNNDetConfig:
    arch_id: str = "snn-det"
    input_hw: tuple = (576, 1024)
    num_classes: int = 3
    num_anchors: int = 5
    stem_channels: int = 16
    conv_block_channels: int = 32
    # basic blocks: (cin, cout) pairs; pooling before each of the first 3
    stage_channels: tuple = ((32, 32), (32, 64), (64, 128), (128, 256), (256, 256))
    # how many stages have a maxpool in front (the rest run at final res)
    pooled_stages: int = 4
    full_t: int = 3
    threshold: float = 0.5
    leak: float = 0.25
    # LIF reset mode (core.lif.ResetMode): "hard" — the paper's v·(1−s)
    # (training default); "soft" — reset by subtraction, v −= θ on spike.
    # ANN→SNN conversion (repro.convert) emits "soft": with it the firing
    # rate tracks clamp(drive/θ) with O(1/T) error instead of the hard
    # reset's systematic overshoot loss, which compounds through depth.
    reset: str = "hard"
    # cold-start membrane potential of every spiking layer (streaming
    # sessions that carry v across frames override it). Conversion sets
    # θ/2: the spike count becomes round(T·y/θ) instead of floor(·) — an
    # UNBIASED rate code, killing the per-layer undercount that otherwise
    # compounds through depth.
    v_init: float = 0.0
    # pool the tdBN DRIVES (pre-LIF) instead of the spike trains at every
    # max-pool site (snn mode only). OR-ing spike trains overestimates the
    # ANN's max-pool (union rate ≥ max rate); pooling the drive commutes
    # with the monotone tdBN→LIF chain, so the converted net's pooled
    # firing rate tracks exactly the ANN's pooled activation. Training
    # keeps the paper's spike OR gate (False).
    pool_drive: bool = False
    # spike max-pool semantics (snn mode): "or" — the paper's OR gate
    # (union of the window's spike trains; its rate OVERESTIMATES the
    # ANN's max, union rate ≥ max rate); "rate" — rate-gated pooling
    # (Rueckauer et al. 2017): each window passes the CURRENT spike of
    # the input with the highest running spike count, so the pooled rate
    # tracks the max input rate. Conversion emits "rate"; training keeps
    # the paper's "or".
    pool_mode: str = "or"
    # spiking head readout: "mean" — the paper's no-reset membrane
    # averaged over T, which weights a spike at step t by (T−t+1)/T so
    # LATE spikes count less (low-rate neurons fire late under rate
    # coding and get systematically crushed); "final" — final membrane
    # divided by T, weighting every step equally (timing-free for
    # leak=1, what conversion needs).
    head_readout: str = "mean"
    mode: Mode = "snn"
    act_bits: int = 4  # QNN activation precision (Table II sweeps 2/3/4)
    weight_bits: int = 8  # 0 = float weights
    use_block_conv: bool = False
    # in_T per LIF-producing macro layer: encode, conv_block, stages...
    mixed_time: bool = True
    # rate-coded encoding: the encode layer's conv result (computed ONCE —
    # in_T stays 1) drives its LIF for full_t steps, emitting a spike TRAIN
    # instead of the paper's single binary plane. The paper's trained nets
    # learn around the 1-bit encode; ANN→SNN conversion (repro.convert)
    # cannot, so converted configs flip this on. Executor plans and the
    # fused kernel handle it unchanged (same broadcast path as conv_block).
    rate_encode: bool = False
    # which conv executor runs every layer (core/plan.py registry):
    # "dense" oracle, "gated" shift-accumulate reference, "pallas" kernel
    conv_exec: str = "dense"
    # spatial block for block conv AND the Pallas grid; every feature-map
    # resolution in the net must divide it (paper: 18×32)
    block_hw: tuple = (18, 32)
    # Pallas interpret override: None = auto-detect backend
    kernel_interpret: bool | None = None

    @property
    def head_channels(self) -> int:
        return self.num_anchors * (5 + self.num_classes)

    @property
    def grid_hw(self) -> tuple:
        # one maxpool after encode, one after conv_block, pooled_stages-1
        # between stages (the paper's 5 pools ⇒ //32 at pooled_stages=4)
        f = 2 ** (self.pooled_stages + 1)
        return (self.input_hw[0] // f, self.input_hw[1] // f)


def config_to_dict(cfg: "SNNDetConfig") -> dict:
    """JSON-serializable dict of the full config — the self-describing
    sidecar detector checkpoints carry (``harness.save_detector_checkpoint``)
    so a restore needs no out-of-band knowledge of the architecture."""
    return dataclasses.asdict(cfg)


def config_from_dict(d: dict) -> "SNNDetConfig":
    """Inverse of :func:`config_to_dict` — JSON round-trips tuples as
    lists, so the tuple-typed fields are re-tupled before construction."""
    d = dict(d)
    unknown = set(d) - {f.name for f in dataclasses.fields(SNNDetConfig)}
    if unknown:
        raise ValueError(f"unknown SNNDetConfig fields {sorted(unknown)} — "
                         "checkpoint written by an incompatible version?")
    for k in ("input_hw", "block_hw"):
        if k in d:
            d[k] = tuple(d[k])
    if "stage_channels" in d:
        d["stage_channels"] = tuple(tuple(p) for p in d["stage_channels"])
    return SNNDetConfig(**d)


# ----------------------------------------------------------------- params --


def _conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout), dtype) * np.sqrt(2.0 / fan_in)
    return w


def _bn_init(c):
    return {"gamma": jnp.ones((c,)), "beta": jnp.zeros((c,))}


def _bn_state(c):
    return {"mean": jnp.zeros((c,)), "var": jnp.ones((c,)), "count": jnp.zeros((), jnp.int32)}


def init_params(key, cfg: SNNDetConfig):
    """Returns (params, bn_state) pytrees."""
    keys = iter(jax.random.split(key, 64))
    p: dict[str, Any] = {}
    s: dict[str, Any] = {}

    def conv_bn(name, kh, kw, cin, cout):
        p[name] = {"w": _conv_init(next(keys), kh, kw, cin, cout), **_bn_init(cout)}
        s[name] = _bn_state(cout)

    conv_bn("encode", 3, 3, 3, cfg.stem_channels)
    conv_bn("conv_block", 3, 3, cfg.stem_channels, cfg.conv_block_channels)
    for i, (cin, cout) in enumerate(cfg.stage_channels):
        half = cout // 2
        conv_bn(f"stage{i}/shortcut", 1, 1, cin, half)
        conv_bn(f"stage{i}/main_in", 1, 1, cin, cout)
        conv_bn(f"stage{i}/main_a", 3, 3, cout, cout)
        conv_bn(f"stage{i}/main_b", 3, 3, cout, cout)
        conv_bn(f"stage{i}/agg", 1, 1, cout + half, cout)
    p["head"] = {"w": _conv_init(next(keys), 1, 1, cfg.stage_channels[-1][1], cfg.head_channels)}
    return p, s


def param_count(params) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))


def calibrate_bn_state(params, bn_state, images, cfg: SNNDetConfig, *, iters: int = 25):
    """Move the tdBN running statistics onto real activation statistics by
    running train-mode forwards. Fresh stats (mean 0, var 1) silence every
    deep layer of an untrained net at eval time — serving demos, benchmarks
    and streaming-session tests calibrate first so spikes actually flow.
    Runs the dense path (no plan needed); returns the new bn_state."""
    dense_cfg = cfg if cfg.conv_exec == "dense" else dataclasses.replace(cfg, conv_exec="dense")
    step = jax.jit(lambda bn: forward(params, bn, images, dense_cfg, train=True)[1])
    for _ in range(iters):
        bn_state = step(bn_state)
    return bn_state


def default_bn_state(params):
    """Fresh inference-time bn_state (mean 0, var 1) matching ``params`` —
    what ``compile_detector`` uses when no trained statistics are given."""
    return {
        name: _bn_state(lp["w"].shape[-1])
        for name, lp in params.items()
        if "gamma" in lp
    }


# ---------------------------------------------------------------- forward --


def _conv(x, w, cfg: SNNDetConfig):
    if cfg.use_block_conv and w.shape[0] > 1:
        bh, bw = cfg.block_hw
        return bc.block_conv2d(x, w, block_h=bh, block_w=bw)
    return bc.conv2d(x, w)


def _conv_t(x_t, layer_p, cfg: SNNDetConfig, *, name=None, plan=None):
    """Run one conv layer over the (T, N, H, W, C) volume.

    With a compiled plan the layer dispatches through the pluggable
    executor registry (dense / gated / pallas — ``cfg.conv_exec``), which
    folds T into the batch; without one it falls back to the legacy
    fake-quant float path (the differentiable training path)."""
    if plan is not None and name is not None and name in plan.layers:
        return cplan.run_conv(x_t, plan.layers[name], cfg)
    w = _maybe_quant_w(layer_p["w"], cfg)
    return jax.vmap(lambda x: _conv(x, w, cfg))(x_t)


def _maybe_quant_w(w, cfg: SNNDetConfig):
    if cfg.weight_bits and cfg.mode != "bnn":
        return quant.fake_quant_tensor(w, cfg.weight_bits)
    if cfg.mode == "bnn":
        # binary weights, scaled by mean magnitude (XNOR-style)
        scale = jnp.mean(jnp.abs(w))
        return jnp.sign(w) * scale
    return w


def _tdbn(x_t, layer_p, layer_s, cfg, train):
    """x_t: (T, N, H, W, C) — tdBN pools stats over (T, N, H, W)."""
    params = lifm.TdBNParams(gamma=layer_p["gamma"], beta=layer_p["beta"])
    state = lifm.TdBNState(mean=layer_s["mean"], var=layer_s["var"], count=layer_s["count"])
    y, new_state = lifm.tdbn_apply(
        params, state, x_t, threshold=cfg.threshold, training=train
    )
    return y, {"mean": new_state.mean, "var": new_state.var, "count": new_state.count}


def _activation(y_t, cfg: SNNDetConfig, *, v0=None):
    """Post-norm nonlinearity per model family. y_t: (T, N, H, W, C).

    Returns (act, v_final). ``v0`` warm-starts the LIF membrane (streaming
    sessions carry it across frames); v_final is None for stateless modes.
    """
    if cfg.mode == "snn":
        if v0 is None and cfg.v_init:
            v0 = jnp.full(y_t.shape[1:], cfg.v_init, y_t.dtype)
        init = None if v0 is None else lifm.LIFState(v=v0)
        spikes, final = lifm.lif_over_time(
            y_t, threshold=cfg.threshold, leak=cfg.leak, reset=cfg.reset,
            init=init,
        )
        return spikes, final.v
    if cfg.mode == "ann":
        return jax.nn.relu(y_t), None
    if cfg.mode == "qnn":
        act = jax.nn.relu(y_t)
        qmax = 2**cfg.act_bits - 1
        scale = jnp.maximum(jnp.max(act), 1e-6) / qmax
        return quant.fake_quant(act, scale), None
    if cfg.mode == "bnn":
        return lifm.spike_fn(y_t, 0.0), None  # sign-ish binary activation w/ STE
    raise ValueError(cfg.mode)


def _conv_bn_act(
    x_t, layer_p, layer_s, cfg, train, *, out_t=None, name=None, plan=None, v0=None,
    affine=None, taps=None, pool=False,
):
    """Conv (per time step) → tdBN → activation.

    Mixed time steps: if out_t > x_t.shape[0] == 1, the conv result is
    computed ONCE and broadcast to out_t steps before the LIF (paper §II-A).
    Returns (act, new_bn_state, v_final).

    ``pool``: this layer's output feeds a 2×2 max-pool. With
    ``cfg.pool_drive`` (snn mode) the pool runs HERE, on the tdBN drive
    before the LIF — the caller must then skip its own ``_maxpool_t`` —
    so the pooled firing rate tracks the ANN's pooled activation instead
    of the OR-gate union. Forces the unfused path (the fused kernel's
    conv→affine→LIF chain has no pool stage between affine and LIF).

    At eval time on the pallas executor the whole chain collapses into ONE
    fused dispatch per layer (``plan.run_fused``: conv → FXP rescale → tdBN
    affine → LIF with the membrane resident in VMEM across T) — bit-exact
    with the unfused path, so this is purely a dataflow change. When
    ``taps`` is given the chain stays unfused so the tdBN output can be
    recorded — numerics are identical either way (PR 6 conformance).
    """
    t_out = out_t or x_t.shape[0]
    pool_inside = pool and cfg.pool_drive and cfg.mode == "snn"
    if (
        not train
        and taps is None
        and not pool_inside
        and cfg.mode == "snn"
        and cfg.conv_exec == "pallas"
        and plan is not None
        and name in plan.layers
        and "gamma" in layer_p
        and (x_t.shape[0] in (1, t_out))
    ):
        act, v_final = cplan.run_fused(
            x_t,
            plan.layers[name],
            cfg,
            gamma=layer_p["gamma"],
            beta=layer_p["beta"],
            mean=layer_s["mean"],
            var=layer_s["var"],
            v0=v0,
            out_t=t_out,
            affine=affine,
        )
        return act, layer_s, v_final  # eval-mode tdBN state is unchanged
    y_t = _conv_t(x_t, layer_p, cfg, name=name, plan=plan)
    if out_t is not None and out_t != y_t.shape[0]:
        assert y_t.shape[0] == 1, "can only broadcast from T=1"
        y_t = jnp.broadcast_to(y_t, (out_t,) + y_t.shape[1:])
    y_t, new_s = _tdbn(y_t, layer_p, layer_s, cfg, train)
    if taps is not None and name is not None:
        taps[name] = y_t  # tdBN output, PRE-pool (matches the ANN taps)
    if pool_inside:
        y_t = _maxpool_t(y_t)
    act, v_final = _activation(y_t, cfg, v0=v0)
    return act, new_s, v_final


def _maxpool_t(x_t):
    """2×2 spike max-pool == OR gate (paper's max-pooling module)."""
    return jax.vmap(
        lambda x: jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )
    )(x_t)


def _rate_gated_pool_t(s_t):
    """2×2 rate-gated spike pool (Rueckauer et al. 2017): each window
    emits the CURRENT spike of the input with the highest cumulative
    spike count, so the pooled rate converges to the max input rate —
    the OR gate's union rate systematically overestimates it. Counts are
    encoded into the max-reduce key as 2·count + spike (count ≤ T ≪ 2²³
    so the f32 encoding is exact); ties break toward a spiking input,
    which makes the first steps degrade gracefully to the OR gate."""

    def step(c, s):
        c = c + s
        key = c * 2.0 + s
        m = jax.lax.reduce_window(
            key, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )
        return c, m % 2.0

    _, out = jax.lax.scan(step, jnp.zeros_like(s_t[0]), s_t)
    return out


def _pool_t(s_t, cfg: SNNDetConfig):
    """Pool a spike/activation volume per ``cfg.pool_mode`` (snn mode
    only — ann/qnn/bnn activations are real-valued, where max IS max)."""
    if cfg.mode == "snn" and cfg.pool_mode == "rate":
        return _rate_gated_pool_t(s_t)
    return _maxpool_t(s_t)


def forward(
    params,
    bn_state,
    images,
    cfg: SNNDetConfig,
    *,
    train: bool = False,
    plan=None,
    membrane=None,
    affines=None,
    taps=None,
):
    """images: (N, H, W, 3) in [0, 1]. Returns (head, new_bn_state, aux).

    head: (N, gh, gw, anchors, 5 + classes) raw predictions.
    aux["spikes"]: per-macro-layer spike tensors for mIoUT analysis.
    aux["membrane"]: final LIF membrane potential per layer (plus the head
    accumulator under "head") — the streaming state a
    :class:`repro.serve.detector.DetectorSession` threads across frames.

    ``plan``: a precompiled :class:`repro.core.plan.DetectorPlan`. Required
    for ``cfg.conv_exec`` other than "dense" — every conv layer then runs
    through the compressed executor. Plan ownership (build, cache, staleness
    checks) lives in :func:`compile_detector`; this free function is the
    internal core the handle wraps.

    ``membrane``: optional {layer_name: v} dict warm-starting every LIF
    membrane (cold start when None or when a layer key is missing).

    ``affines``: optional {layer_name: bundle} of precomputed fused-kernel
    affine parameter bundles (:func:`repro.core.plan.precompute_affines`) —
    compile-once callers hoist the per-layer bundle build out of the frame
    loop; missing keys fall back to the inline build (same values).

    ``taps``: optional mutable dict — when given, every layer records its
    tdBN output (the per-step LIF input drive, shape (T, N, H, W, C)) under
    its layer name, plus the raw head conv output under "head". Used by the
    ANN→SNN conversion front-end (:mod:`repro.convert`) to verify rescale
    exactness and fit the head readout scale; forces the unfused path.
    """
    if cfg.conv_exec != "dense" and cfg.mode != "snn":
        # compressed executors consume int8 binary spikes; ann/qnn/bnn
        # activations are multibit floats and would truncate silently
        raise ValueError(
            f"conv_exec={cfg.conv_exec!r} requires mode='snn' (got "
            f"mode={cfg.mode!r}: activations are not binary spikes)"
        )
    if cfg.conv_exec != "dense" and not cfg.weight_bits:
        raise ValueError(
            f"conv_exec={cfg.conv_exec!r} requires weight_bits > 0 (the "
            "compressed plan is FXP int8; weight_bits=0 means float weights)"
        )
    if plan is not None and tuple(plan.block_hw) != tuple(cfg.block_hw):
        raise ValueError(
            f"plan was built for block_hw={tuple(plan.block_hw)} but "
            f"cfg.block_hw={tuple(cfg.block_hw)}; rebuild the plan"
        )
    if plan is None and cfg.conv_exec != "dense":
        raise ValueError(
            f"conv_exec={cfg.conv_exec!r} needs a precompiled plan: use "
            "repro.models.snn_yolo.compile_detector(cfg, params) (which owns "
            "plan build/cache/staleness), or call "
            "repro.core.plan.build_plan(params, cfg) outside jit and pass it "
            "as forward(..., plan=plan)"
        )
    full_t = 1 if cfg.mode != "snn" else cfg.full_t
    new_state = dict(bn_state)
    aff = affines or {}
    mem = membrane or {}
    new_mem: dict[str, Any] = {}
    aux: dict[str, Any] = {"spikes": {}, "membrane": new_mem}

    x = images.astype(jnp.float32)
    x_t = x[None]  # encoding layer sees the raw image once (in_T = 1)

    # --- encode (ANN layer: fires once — or rate-codes when rate_encode) ---
    enc_t = full_t if (cfg.rate_encode and cfg.mode == "snn") else None
    pd = cfg.pool_drive and cfg.mode == "snn"  # pools already ran inside
    s_t, new_state["encode"], new_mem["encode"] = _conv_bn_act(
        x_t, params["encode"], bn_state["encode"], cfg, train, out_t=enc_t,
        name="encode", plan=plan, v0=mem.get("encode"),
        affine=aff.get("encode"), taps=taps, pool=True,
    )
    aux["spikes"]["encode"] = s_t
    if not pd:
        s_t = _pool_t(s_t, cfg)

    # --- conv block: in_T=1, out_T=full_t (mixed time steps) ---
    out_t = full_t if cfg.mixed_time else s_t.shape[0]
    if not cfg.mixed_time and cfg.mode == "snn":
        # non-mixed baseline: replicate the input spikes to full_t steps
        s_t = jnp.broadcast_to(s_t, (full_t,) + s_t.shape[1:])
        out_t = full_t
    s_t, new_state["conv_block"], new_mem["conv_block"] = _conv_bn_act(
        s_t, params["conv_block"], bn_state["conv_block"], cfg, train, out_t=out_t,
        name="conv_block", plan=plan, v0=mem.get("conv_block"),
        affine=aff.get("conv_block"), taps=taps, pool=True,
    )
    aux["spikes"]["conv_block"] = s_t
    if not pd:
        s_t = _pool_t(s_t, cfg)

    # --- CSP basic blocks ---
    for i in range(len(cfg.stage_channels)):
        name = f"stage{i}"

        def cba(x_in, lname, pool=False):
            return _conv_bn_act(
                x_in, params[lname], bn_state[lname], cfg, train, name=lname,
                plan=plan, v0=mem.get(lname), affine=aff.get(lname), taps=taps,
                pool=pool,
            )

        short, new_state[f"{name}/shortcut"], new_mem[f"{name}/shortcut"] = cba(
            s_t, f"{name}/shortcut"
        )
        m, new_state[f"{name}/main_in"], new_mem[f"{name}/main_in"] = cba(
            s_t, f"{name}/main_in"
        )
        m, new_state[f"{name}/main_a"], new_mem[f"{name}/main_a"] = cba(m, f"{name}/main_a")
        m, new_state[f"{name}/main_b"], new_mem[f"{name}/main_b"] = cba(m, f"{name}/main_b")
        cat = jnp.concatenate([m, short], axis=-1)
        s_t, new_state[f"{name}/agg"], new_mem[f"{name}/agg"] = cba(
            cat, f"{name}/agg", pool=i < cfg.pooled_stages - 1
        )
        aux["spikes"][name] = s_t
        if i < cfg.pooled_stages - 1 and not pd:
            s_t = _pool_t(s_t, cfg)

    # --- output conv: accumulate membrane with no reset, average over T ---
    y_t = _conv_t(s_t, params["head"], cfg, name="head", plan=plan)
    if taps is not None:
        taps["head"] = y_t
    if cfg.mode == "snn":
        head, new_mem["head"] = lifm.membrane_readout(
            y_t, leak=cfg.leak, v0=mem.get("head"), return_final=True
        )
        if cfg.head_readout == "final":
            # final membrane / T: every step weighted equally (the mean
            # readout weights step t by (T−t+1)/T, biased against the
            # late first-spikes of low-rate neurons)
            head = new_mem["head"] / y_t.shape[0]
    else:
        head = jnp.mean(y_t, axis=0)
    n, gh, gw, _ = head.shape
    head = head.reshape(n, gh, gw, cfg.num_anchors, 5 + cfg.num_classes)
    return head, new_state, aux


# ------------------------------------------------------- layer accounting --


# Per-layer post-pruning densities of the 3×3 kernels, shaped like paper
# Fig 3: a single global magnitude threshold keeps far more weights in the
# small early layers than in the large late ones. Calibrated so the model
# reproduces BOTH Table I (−70% params) and §IV-E (−47.3% ops) jointly.
FIG3_DENSITY_PROFILE = {
    "encode": 0.70,
    "conv_block": 0.70,
    "stage0": 0.70,
    "stage1": 0.50,
    "stage2": 0.50,
    "stage3": 0.12,
    "stage4": 0.12,
}


def layer_specs(
    cfg: SNNDetConfig, *, pruned_density: float | dict | None = None
) -> list[en.ConvLayerSpec]:
    """The network as a ConvLayerSpec list for the §IV-D/E energy model.

    density applies to 3×3 kernels only (paper prunes only those at 80%).
    ``pruned_density``: None → Fig 3 profile; float → uniform; dict →
    per-group override. Time steps follow the (1, full_t) mixed schedule.
    """
    H, W = cfg.input_hw
    t = cfg.full_t
    specs: list[en.ConvLayerSpec] = []
    if pruned_density is None:
        profile = FIG3_DENSITY_PROFILE
    elif isinstance(pruned_density, dict):
        profile = pruned_density
    else:
        profile = {k: pruned_density for k in FIG3_DENSITY_PROFILE}

    specs.append(
        en.ConvLayerSpec(
            "encode", H, W, 3, cfg.stem_channels, 3, 1, 1, bits_in=8, density=profile["encode"]
        )
    )
    h, w = H // 2, W // 2
    specs.append(
        en.ConvLayerSpec(
            "conv_block",
            h,
            w,
            cfg.stem_channels,
            cfg.conv_block_channels,
            3,
            1,
            t,
            density=profile["conv_block"],
        )
    )
    h, w = h // 2, w // 2
    for i, (cin, cout) in enumerate(cfg.stage_channels):
        half = cout // 2
        d3 = profile[f"stage{i}"]
        specs += [
            en.ConvLayerSpec(f"stage{i}/shortcut", h, w, cin, half, 1, t, t),
            en.ConvLayerSpec(f"stage{i}/main_in", h, w, cin, cout, 1, t, t),
            en.ConvLayerSpec(f"stage{i}/main_a", h, w, cout, cout, 3, t, t, density=d3),
            en.ConvLayerSpec(f"stage{i}/main_b", h, w, cout, cout, 3, t, t, density=d3),
            en.ConvLayerSpec(f"stage{i}/agg", h, w, cout + half, cout, 1, t, t),
        ]
        if i < cfg.pooled_stages - 1:
            h, w = h // 2, w // 2
    gh, gw = cfg.grid_hw
    specs.append(
        en.ConvLayerSpec(
            "head", gh, gw, cfg.stage_channels[-1][1], cfg.head_channels, 1, t, t, bits_out=8
        )
    )
    return specs


# ------------------------------------------------------------- YOLOv2 head -


def decode_head(head, anchors, *, threshold=None):
    """YOLOv2 box decode. head: (N, gh, gw, A, 5+C) raw.
    Returns (boxes_xywh [0-1 normalized], obj, class_probs).

    This is the EXACT inverse of the training-target encoding
    (``data/synthetic_detection.sample``: best-shape-IoU anchor, tx/ty as
    within-cell offsets, tw/th log-scale vs that anchor) — a head that
    fits its targets decodes to the ground-truth boxes, which is what
    makes ``repro.eval.detection_map`` mAP meaningful
    (tests/test_eval_map.py pins the round trip at mAP 1.0).

    ``threshold``: score threshold on the objectness — boxes whose obj
    score falls below it get obj zeroed, so downstream stages (NMS, the
    serve postprocess) can treat obj > 0 as the validity mask. Box
    coordinates and class probabilities are left intact.
    """
    txy = jax.nn.sigmoid(head[..., 0:2])
    twh = head[..., 2:4]
    obj = jax.nn.sigmoid(head[..., 4])
    if threshold is not None:
        obj = jnp.where(obj >= threshold, obj, 0.0)
    cls = jax.nn.softmax(head[..., 5:], axis=-1)
    n, gh, gw, a, _ = head.shape
    gy, gx = jnp.meshgrid(jnp.arange(gh), jnp.arange(gw), indexing="ij")
    cx = (gx[None, :, :, None] + txy[..., 0]) / gw
    cy = (gy[None, :, :, None] + txy[..., 1]) / gh
    anchors = jnp.asarray(anchors)  # (A, 2) in grid-cell units
    bw = anchors[:, 0] * jnp.exp(twh[..., 0]) / gw
    bh = anchors[:, 1] * jnp.exp(twh[..., 1]) / gh
    boxes = jnp.stack([cx, cy, bw, bh], axis=-1)
    return boxes, obj, cls


DEFAULT_ANCHORS = ((1.0, 1.0), (2.0, 2.0), (4.0, 2.5), (2.5, 4.0), (6.0, 6.0))


def compile_detector(cfg: SNNDetConfig, params, bn_state=None, **kwargs):
    """Compile-once entry point: returns a
    :class:`repro.serve.detector.CompiledDetector` owning the
    :class:`~repro.core.plan.DetectorPlan`, the jitted executor-backed
    forward, and the postprocess stage (decode → score threshold → NMS)::

        det = compile_detector(cfg, params)
        dets = det(frames)                    # Detections, zero plan plumbing
        sess = det.new_session()              # streaming membrane state

    See :mod:`repro.serve.detector` for the full handle/session API;
    ``**kwargs`` (anchors, score/iou thresholds, prune_rate, ...) forward to
    the ``CompiledDetector`` constructor.
    """
    from repro.serve.detector import CompiledDetector  # circular-import guard

    return CompiledDetector(cfg, params, bn_state, **kwargs)


def yolo_loss(head, targets, anchors=DEFAULT_ANCHORS, *, l_coord=5.0, l_noobj=0.5):
    """YOLOv2-style loss. targets: (N, gh, gw, A, 5+C) with
    [tx, ty, tw, th, obj, onehot-classes]; obj∈{0,1} marks assigned anchors.
    tx/ty are within-cell offsets in (0,1); tw/th are log-scale vs the
    assigned anchor — the ``decode_head`` inverse domain, so minimizing
    this loss directly maximizes decoded-box IoU (see decode_head)."""
    obj_mask = targets[..., 4]
    noobj_mask = 1.0 - obj_mask
    pxy = jax.nn.sigmoid(head[..., 0:2])
    pwh = head[..., 2:4]
    pobj = jax.nn.sigmoid(head[..., 4])
    plog = jax.nn.log_softmax(head[..., 5:], axis=-1)

    coord = jnp.sum(obj_mask[..., None] * ((pxy - targets[..., 0:2]) ** 2 + (pwh - targets[..., 2:4]) ** 2))
    obj_l = jnp.sum(obj_mask * (pobj - 1.0) ** 2)
    noobj_l = jnp.sum(noobj_mask * pobj**2)
    cls_l = -jnp.sum(obj_mask[..., None] * targets[..., 5:] * plog)
    n = head.shape[0]
    return (l_coord * coord + obj_l + l_noobj * noobj_l + cls_l) / n
