"""Dense decoder-only transformer (qwen / llama / llava backbone) with
scan-over-layers: params are stacked (L, ...) so HLO size and compile time
are depth-independent — a 126-layer 405B lowers as one scanned layer.

Also hosts the generic train/prefill/decode steps reused by the MoE, hybrid
and SSM families (they swap the per-layer body)."""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.distributed import sharding as shd
from repro.models import layers as L


# ------------------------------------------------------------------ params --


def layer_init(key, cfg: LMConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "attn": L.attn_init(k1, cfg),
        "mlp": L.mlp_init(k2, cfg),
        "ln1": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "ln2": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }


def layer_axes(cfg: LMConfig) -> dict:
    return {
        "attn": L.attn_axes(cfg),
        "mlp": L.mlp_axes(cfg),
        "ln1": (None,),
        "ln2": (None,),
    }


def init_params(key, cfg: LMConfig, layer_init_fn=layer_init) -> dict:
    ke, kl = jax.random.split(key)
    if cfg.scan_layers:
        keys = jax.random.split(kl, cfg.n_layers)
        layers = jax.vmap(lambda k: layer_init_fn(k, cfg))(keys)
    else:
        layers = [layer_init_fn(k, cfg) for k in jax.random.split(kl, cfg.n_layers)]
    return {"embed": L.embed_init(ke, cfg), "layers": layers}


def param_axes(cfg: LMConfig, layer_axes_fn=layer_axes) -> dict:
    lx = jax.tree_util.tree_map(
        lambda axes: ("layers",) + axes,
        layer_axes_fn(cfg),
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )
    if not cfg.scan_layers:
        lx = [lx] * cfg.n_layers
    return {"embed": L.embed_axes(cfg), "layers": lx}


# ----------------------------------------------------------------- forward --


def dense_block(x, lp, cfg: LMConfig, *, positions, kv=None, cache_pos=None, causal=True):
    """Pre-norm attention + SwiGLU block. Returns (x, new_kv)."""
    h, new_kv = L.attention(
        L.rmsnorm(x, lp["ln1"], cfg.norm_eps),
        lp["attn"],
        cfg,
        positions=positions,
        causal=causal,
        kv_cache=kv,
        cache_pos=cache_pos,
    )
    x = x + h
    x = x + L.mlp(L.rmsnorm(x, lp["ln2"], cfg.norm_eps), lp["mlp"])
    return x, new_kv


class KVCache(NamedTuple):
    """Stacked over layers: k/v (L, B, S_max, n_kv, hd)."""

    k: jax.Array
    v: jax.Array

    @staticmethod
    def zeros(cfg: LMConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
        shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd)
        return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def forward(
    params: dict,
    tokens: jax.Array,
    cfg: LMConfig,
    *,
    block_fn: Callable = dense_block,
    positions: Optional[jax.Array] = None,
    kv_cache: Optional[KVCache] = None,
    cache_pos: Optional[jax.Array] = None,
    extra_embeds: Optional[jax.Array] = None,
    collect_kv: bool = False,
) -> tuple[jax.Array, Optional[KVCache]]:
    """tokens (B, S) int32 → logits (B, S, V). Scan over stacked layers.

    collect_kv: return the per-layer K/V (prefill/decode). MUST stay False
    for training — a scanned KV output materializes (L, B, S, KV, hd).
    extra_embeds: (B, P, D) prepended modality embeddings (llava patches).
    """
    collect_kv = collect_kv or kv_cache is not None
    x = L.embed_tokens(tokens, params["embed"])
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    if positions is None:
        base = cache_pos if cache_pos is not None else 0
        if cache_pos is not None and jnp.ndim(cache_pos) == 1:
            base = cache_pos[:, None]  # per-slot positions (continuous batching)
        positions = base + jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    if cfg.scan_layers:
        def body(carry, layer_in):
            h = carry
            lp, kv_l = layer_in
            kv = KVSlice_or_none(kv_l)
            h, new_kv = block_fn(h, lp, cfg, positions=positions, kv=kv, cache_pos=cache_pos)
            # sequence-parallel layer boundary: the remat stash (this carry)
            # is seq-sharded over 'model' when the launcher enables it
            h = shd.constrain_act(h, ("batch", "act_seq", None))
            return h, (new_kv if collect_kv else None)

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        kv_in = (kv_cache.k, kv_cache.v) if kv_cache is not None else None
        xs = (params["layers"], kv_in)
        x, kv_out = jax.lax.scan(body, x, xs)
        new_cache = KVCache(*kv_out) if kv_out is not None and kv_out[0] is not None else None
    else:
        new_ks, new_vs = [], []
        for i, lp in enumerate(params["layers"]):
            kv = (
                L.KVSlice(kv_cache.k[i], kv_cache.v[i]) if kv_cache is not None else None
            )
            x, new_kv = block_fn(x, lp, cfg, positions=positions, kv=kv, cache_pos=cache_pos)
            if new_kv is not None and collect_kv:
                new_ks.append(new_kv.k)
                new_vs.append(new_kv.v)
        new_cache = (
            KVCache(jnp.stack(new_ks), jnp.stack(new_vs)) if new_ks else None
        )

    logits = L.logits_fn(x, params["embed"], cfg)
    return logits, new_cache


def KVSlice_or_none(kv_l):
    if kv_l is None or kv_l[0] is None:
        return None
    return L.KVSlice(kv_l[0], kv_l[1])


# ------------------------------------------------------------- step makers --


def make_loss_fn(cfg: LMConfig, block_fn=dense_block):
    def loss_fn(params, batch):
        logits, _ = forward(
            params,
            batch["tokens"],
            cfg,
            block_fn=block_fn,
            extra_embeds=batch.get("extra_embeds"),
        )
        # modality prefixes carry no LM loss
        labels = batch["labels"]
        if "extra_embeds" in batch and batch["extra_embeds"] is not None:
            logits = logits[:, -labels.shape[1] :]
        return L.cross_entropy(logits[:, :-1], labels[:, 1:])

    return loss_fn


def make_prefill_fn(cfg: LMConfig, block_fn=dense_block, max_seq: Optional[int] = None):
    """Prefill: run the prompt, return logits + populated KV cache.

    ``valid_len`` supports BUCKETED prefill: tokens padded past the real
    prompt share one compile per bucket length, the causal mask keeps
    positions < valid_len blind to the pad, and the returned logits come
    from position ``valid_len - 1`` (the true last prompt token) instead of
    the padded tail. Counts the full input sequence when ``extra_embeds``
    prefixes are present.
    """

    def prefill(params, tokens, extra_embeds=None, valid_len=None):
        logits, cache = forward(
            params, tokens, cfg, block_fn=block_fn, extra_embeds=extra_embeds, collect_kv=True
        )
        if valid_len is None:
            return logits[:, -1], cache
        return (
            jax.lax.dynamic_index_in_dim(logits, valid_len - 1, 1, keepdims=False),
            cache,
        )

    return prefill


def make_decode_fn(cfg: LMConfig, block_fn=dense_block):
    """One decode step: (params, cache, token, pos) → (logits, cache)."""

    def decode(params, cache, token, pos):
        logits, new_cache = forward(
            params,
            token[:, None],
            cfg,
            block_fn=block_fn,
            kv_cache=cache,
            cache_pos=pos,
        )
        return logits[:, 0], new_cache

    return decode


# ------------------------------------------------- serve fast path (§Perf) --
# The scan-based forward() above stacks per-layer KV as scan OUTPUTS, which
# XLA cannot alias with the input cache inside the while state — the HLO
# carries ~3 full-cache copies PER LAYER at decode (measured: qwen1.5-0.5b
# decode_32k moves 332 GB/step/chip; EXPERIMENTS.md §Perf). The serve path
# below instead CARRIES the stacked cache through a fori_loop and updates it
# in place with token/layer-granular dynamic_update_slice — while-state
# buffers alias, so the only cache traffic left is the true KV read.
#
# Optional int8 KV (cfg via `kv_quant`): the paper's FXP8 quantization
# applied to the cache — per-(token, head) scales, dequantized inside the
# attention read. Halves KV bytes (the decode memory term) again.


class QuantKVCache(NamedTuple):
    """int8 KV + per-(layer, batch, pos, head) f32 scales."""

    k: jax.Array  # (L, B, S, kv, hd) int8
    v: jax.Array
    k_scale: jax.Array  # (L, B, S, kv) f32
    v_scale: jax.Array

    @staticmethod
    def zeros(cfg: LMConfig, batch: int, max_seq: int):
        shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd)
        sshape = shape[:-1]
        return QuantKVCache(
            jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
            jnp.zeros(sshape, jnp.float32), jnp.zeros(sshape, jnp.float32),
        )


def _q8_kv(x):
    """(..., hd) -> int8 payload + f32 scale over the head dim."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    q = jnp.round(x.astype(jnp.float32) / jnp.maximum(scale[..., None], 1e-9))
    return q.astype(jnp.int8), scale


def cached_forward(
    params: dict,
    tokens: jax.Array,  # (B, S_new) — S_new=1 for decode, prompt len for prefill
    cfg: LMConfig,
    cache,  # KVCache or QuantKVCache, (L, B, S_max, kv, hd)
    pos0,  # scalar or (B,) int32: write offset of tokens[:, 0]
    *,
    mlp_fn: Callable = None,
    extra_embeds: Optional[jax.Array] = None,
    valid_len=None,
):
    """Prefill/decode over a carried stacked cache. Returns
    (last-position logits (B, V), updated cache). ``valid_len`` selects
    position ``valid_len - 1`` instead of the last (bucketed prefill over
    end-padded tokens — pad rows land in the cache past the prompt but the
    serving scatter only ever copies rows [:valid_len])."""
    mlp_fn = mlp_fn or (lambda h, lp: L.mlp(h, lp["mlp"]))
    quant = isinstance(cache, QuantKVCache)
    x = L.embed_tokens(tokens, params["embed"])
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    base = pos0[:, None] if jnp.ndim(pos0) == 1 else pos0
    positions = base + jnp.arange(s)[None]
    positions = jnp.broadcast_to(positions, (b, s))
    s_max = cache.k.shape[2]

    def body(i, carry):
        x, cache = carry
        lp = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            params["layers"],
        )
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L._qkv(h, lp["attn"], cfg, positions)

        # token-granular in-place write into the carried cache; on a
        # seq-sharded cache the write goes through the shard-local
        # ownership-checked path (distributed/kvops.py)
        def put(buf, val):
            if jnp.ndim(pos0) == 0:  # uniform offset
                from repro.distributed import kvops

                return kvops.cache_write(buf, val, i, pos0)
            rows = jnp.arange(b)[:, None]  # per-slot offsets (serving)
            cols = pos0[:, None] + jnp.arange(s)[None]
            return buf.at[i, rows, cols].set(val.astype(buf.dtype))

        kv_axes = ("layers", "batch", "kv_seq", "kv_heads", None)
        if quant:
            kq, ks = _q8_kv(k)
            vq, vs = _q8_kv(v)
            cache = QuantKVCache(
                shd.constrain_act(put(cache.k, kq), kv_axes),
                shd.constrain_act(put(cache.v, vq), kv_axes),
                shd.constrain_act(put(cache.k_scale, ks), kv_axes[:-1]),
                shd.constrain_act(put(cache.v_scale, vs), kv_axes[:-1]),
            )
            k_l = (
                jax.lax.dynamic_index_in_dim(cache.k, i, 0, keepdims=False).astype(jnp.bfloat16)
                * jax.lax.dynamic_index_in_dim(cache.k_scale, i, 0, keepdims=False)[..., None].astype(jnp.bfloat16)
            )
            v_l = (
                jax.lax.dynamic_index_in_dim(cache.v, i, 0, keepdims=False).astype(jnp.bfloat16)
                * jax.lax.dynamic_index_in_dim(cache.v_scale, i, 0, keepdims=False)[..., None].astype(jnp.bfloat16)
            )
        else:
            cache = KVCache(
                shd.constrain_act(put(cache.k, k), kv_axes),
                shd.constrain_act(put(cache.v, v), kv_axes),
            )
            k_l = jax.lax.dynamic_index_in_dim(cache.k, i, 0, keepdims=False)
            v_l = jax.lax.dynamic_index_in_dim(cache.v, i, 0, keepdims=False)

        if s == s_max and jnp.ndim(pos0) == 0 and s > L.CHUNKED_ATTN_THRESHOLD:
            # long prefill: flash-style chunked path (pos0 must be 0 for the
            # causal mask to be exact — prefill always starts at 0)
            att = L._chunked_sdpa(q, k_l, v_l, cfg, causal=True)
        else:
            # visibility: kv position j attends to query step t iff j <= pos0+t
            off = pos0[:, None, None] if jnp.ndim(pos0) == 1 else pos0
            valid = jnp.arange(s_max)[None, None, :] <= (off + jnp.arange(s)[None, :, None])
            att = L._sdpa(q, k_l, v_l, valid[:, None], cfg)
        x = x + att @ lp["attn"]["wo"]
        x = x + mlp_fn(L.rmsnorm(x, lp["ln2"], cfg.norm_eps), lp)
        return (x, cache)

    x, cache = jax.lax.fori_loop(0, cfg.n_layers, body, (x, cache))
    x_last = (
        x[:, -1:]
        if valid_len is None
        else jax.lax.dynamic_slice_in_dim(x, valid_len - 1, 1, 1)
    )
    logits = L.logits_fn(x_last, params["embed"], cfg)
    return logits[:, 0], cache
