"""Mamba2 (SSD) blocks for the zamba2-7b hybrid.

The SSM recurrence  h_t = a_t · h_{t-1} + dt_t · B_t ⊗ x_t,  y_t = C_t · h_t
is a data-dependent leaky integrator — structurally the paper's LIF membrane
update without the threshold (DESIGN.md §4), and it reuses the same
scan-over-time substrate.

Training/prefill use the CHUNKED SSD form (intra-chunk masked matmuls on the
MXU + inter-chunk state scan) rather than a per-step scan — the TPU-native
formulation. Decode is the single-step recurrence on a carried state.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models import layers as L

CONV_K = 4  # depthwise causal conv width (mamba2 default)


def d_inner(cfg: LMConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_ssm_heads(cfg: LMConfig) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def mamba_init(key, cfg: LMConfig) -> dict:
    d = cfg.d_model
    di = d_inner(cfg)
    nh = n_ssm_heads(cfg)
    ds = cfg.ssm_state
    dt = cfg.param_dtype
    ks = jax.random.split(key, 6)
    # in_proj → [z, x, B, C, dt]
    proj_out = di + di + ds + ds + nh
    return {
        "in_proj": L._init(ks[0], (d, proj_out), dt),
        "conv_w": L._init(ks[1], (CONV_K, di + 2 * ds), dt, scale=0.5),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "out_norm": jnp.ones((di,), dt),
        "out_proj": L._init(ks[2], (di, d), dt),
        "ln": jnp.ones((d,), dt),
    }


def mamba_axes(cfg: LMConfig) -> dict:
    return {
        "in_proj": ("embed", "mlp"),
        "conv_w": ("conv_k", "mlp"),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "out_norm": ("mlp",),
        "out_proj": ("mlp", "embed"),
        "ln": (None,),
    }


class MambaState(NamedTuple):
    h: jax.Array  # (B, nh, head_dim, d_state) SSM state
    conv: jax.Array  # (B, CONV_K-1, di + 2*ds) conv tail


def init_state(cfg: LMConfig, batch: int, dtype=jnp.float32) -> MambaState:
    di = d_inner(cfg)
    return MambaState(
        h=jnp.zeros((batch, n_ssm_heads(cfg), cfg.ssm_head_dim, cfg.ssm_state), dtype),
        conv=jnp.zeros((batch, CONV_K - 1, di + 2 * cfg.ssm_state), dtype),
    )


def _split_proj(xz, cfg: LMConfig):
    di = d_inner(cfg)
    ds = cfg.ssm_state
    nh = n_ssm_heads(cfg)
    z = xz[..., :di]
    xbc = xz[..., di : di + di + 2 * ds]
    dt = xz[..., di + di + 2 * ds :]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_state=None):
    """Depthwise causal conv1d. xbc (B, T, C); returns (out, new_tail)."""
    b, t, c = xbc.shape
    if conv_state is None:
        pad = jnp.zeros((b, CONV_K - 1, c), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)  # (B, T+K-1, C)
    out = sum(xp[:, i : i + t, :] * conv_w[i][None, None] for i in range(CONV_K))
    return jax.nn.silu(out), xp[:, -(CONV_K - 1) :, :]


def _ssd_chunked(xh, dt, a_log, b_in, c_in, d_skip, h0, *, chunk: int = 128):
    """Chunked SSD. Shapes:
      xh (B, T, nh, hd)  dt (B, T, nh)  b_in/c_in (B, T, ds)
      h0 (B, nh, hd, ds).  Returns (y (B,T,nh,hd), h_final).
    Scalar-per-head decay a_t = exp(-exp(a_log) * dt_t).
    """
    B, T, nh, hd = xh.shape
    ds = b_in.shape[-1]
    nc = T // chunk
    assert T % chunk == 0, (T, chunk)
    # fold into chunks
    xc = xh.reshape(B, nc, chunk, nh, hd)
    dtc = dt.reshape(B, nc, chunk, nh)
    bc = b_in.reshape(B, nc, chunk, ds)
    cc = c_in.reshape(B, nc, chunk, ds)

    neg_a = -jnp.exp(a_log)[None, None, None]  # (1,1,1,nh)
    log_a = neg_a * dtc  # (B, nc, chunk, nh) log decay per step
    s = jnp.cumsum(log_a, axis=2)  # within-chunk cumulative log decay

    # intra-chunk: Y[t] = Σ_{i<=t} C_t·B_i · e^{s_t - s_i} · dt_i x_i.
    # Factor e^{s_t - s_i} = e^{s_t}·e^{-s_i} so only the (t, i) score matrix
    # is materialized (never a (t, i, nh) decay tensor): flash-style memory.
    cb = jnp.einsum("bnts,bnis->bnti", cc, bc)  # (B,nc,chunk,chunk)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None]
    cb = jnp.where(tri, cb, 0.0)
    e_pos = jnp.exp(jnp.clip(s, -60.0, 0.0))  # e^{s_t}   (B,nc,chunk,nh)
    e_neg = jnp.exp(jnp.clip(-s, 0.0, 60.0))  # e^{-s_i}
    x_tilde = xc * (e_neg * dtc)[..., None]  # (B,nc,chunk,nh,hd)
    y_intra = jnp.einsum("bnti,bnihd->bnthd", cb, x_tilde) * e_pos[..., None]

    # chunk-level state update: h' = e^{s_last} h + Σ_i e^{s_last - s_i} dt_i B_i⊗x_i
    s_last = s[:, :, -1:, :]  # (B,nc,1,nh)
    rdecay = jnp.exp(jnp.clip(s_last - s, -60.0, 0.0))  # (B,nc,chunk,nh)
    u = jnp.einsum("bnth,bnthd,bnts->bnhds", dtc * rdecay, xc, bc)  # per-chunk injection

    chunk_decay = jnp.exp(jnp.clip(s_last[:, :, 0, :], -60.0, 0.0))  # (B,nc,nh)

    def scan_fn(h, inp):
        cd, uc = inp  # cd (B,nh), uc (B,nh,hd,ds)
        h_new = h * cd[:, :, None, None] + uc
        return h_new, h

    (h_final, h_prevs) = jax.lax.scan(
        scan_fn,
        h0,
        (chunk_decay.transpose(1, 0, 2), u.transpose(1, 0, 2, 3, 4)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)  # (B,nc,nh,hd,ds)

    # inter-chunk contribution: y_t += C_t · e^{s_t} h_chunk_start
    e_s = jnp.exp(jnp.clip(s, -60.0, 0.0))  # (B,nc,chunk,nh)
    y_inter = jnp.einsum("bnts,bnhds,bnth->bnthd", cc, h_prevs, e_s)

    y = (y_intra + y_inter).reshape(B, T, nh, hd)
    y = y + d_skip[None, None, :, None] * xh
    return y, h_final


def mamba_forward(x, p, cfg: LMConfig, *, state: Optional[MambaState] = None, chunk=128):
    """x (B, T, D) → (out, new_state). Works for T=1 decode (uses the
    recurrence) and T>1 train/prefill (chunked SSD)."""
    b, t, d = x.shape
    nh, hd, ds = n_ssm_heads(cfg), cfg.ssm_head_dim, cfg.ssm_state
    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    xz = h @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(xz, cfg)
    conv_in_state = state.conv if state is not None else None
    xbc, conv_tail = _causal_conv(xbc, p["conv_w"], conv_in_state)
    di = d_inner(cfg)
    xh = xbc[..., :di].reshape(b, t, nh, hd).astype(jnp.float32)
    b_in = xbc[..., di : di + ds].astype(jnp.float32)
    c_in = xbc[..., di + ds :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None])  # (B,T,nh)

    h0 = state.h if state is not None else jnp.zeros((b, nh, hd, ds), jnp.float32)

    if t == 1:  # decode: one recurrence step
        a = jnp.exp(-jnp.exp(p["A_log"])[None, :] * dt[:, 0])  # (B, nh)
        inj = jnp.einsum("bh,bhd,bs->bhds", dt[:, 0], xh[:, 0], b_in[:, 0])
        h_new = h0 * a[:, :, None, None] + inj
        y = jnp.einsum("bs,bhds->bhd", c_in[:, 0], h_new)
        y = y + p["D"][None, :, None] * xh[:, 0]
        y = y[:, None]  # (B,1,nh,hd)
        h_final = h_new
    else:
        pad = (-t) % chunk
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
            c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
        y, h_final = _ssd_chunked(xh, dt, p["A_log"], b_in, c_in, p["D"], h0, chunk=chunk)
        y = y[:, :t]

    y = y.reshape(b, t, di).astype(x.dtype)
    y = L.rmsnorm(y, p["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    new_state = MambaState(h=h_final, conv=conv_tail.astype(jnp.float32))
    return x + out, new_state
