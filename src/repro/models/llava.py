"""llava-next style VLM: dense LM backbone + anyres patch-embedding stub.

Per the assignment, the vision tower is a STUB: input_specs() supplies
precomputed patch features (B, n_patches, d_vision). The real model parts
are the multimodal projector (2-layer MLP, llava-1.6 convention) and the
full LM backbone (models/transformer.py). Patch embeddings are prepended
to the token embeddings; LM loss is computed on the token suffix only.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import layers as L
from repro.models import transformer as tfm

D_VISION = 1024  # CLIP-L/14 feature width (stub frontend output)


def init_params(key, cfg: LMConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = tfm.init_params(k1, cfg)
    p["mm_proj"] = {
        "w1": L._init(k2, (D_VISION, cfg.d_model), cfg.param_dtype),
        "w2": L._init(k3, (cfg.d_model, cfg.d_model), cfg.param_dtype),
    }
    return p


def param_axes(cfg: LMConfig) -> dict:
    a = tfm.param_axes(cfg)
    a["mm_proj"] = {"w1": (None, "embed"), "w2": ("embed", "embed2")}
    return a


def project_patches(params, patches: jax.Array) -> jax.Array:
    """(B, P, D_VISION) stub features → (B, P, d_model) LM embeddings."""
    p = params["mm_proj"]
    h = jax.nn.gelu(patches.astype(p["w1"].dtype) @ p["w1"])
    return h @ p["w2"]


def make_loss_fn(cfg: LMConfig):
    base = tfm.make_loss_fn(cfg)

    def loss_fn(params, batch):
        embeds = project_patches(params, batch["patches"])
        return base(params, {**batch, "extra_embeds": embeds})

    return loss_fn


def make_prefill_fn(cfg: LMConfig):
    def prefill(params, tokens, patches):
        embeds = project_patches(params, patches)
        logits, cache = tfm.forward(
            params, tokens, cfg, extra_embeds=embeds, collect_kv=True
        )
        return logits[:, -1], cache

    return prefill
