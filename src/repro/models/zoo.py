"""Unified model API over every assigned architecture family.

``get_api(cfg)`` returns a ModelAPI with the same five entry points for all
families (dense / moe / hybrid / ssm / vlm / audio):

    init_params(key)                  -> params pytree
    param_axes()                      -> logical-axis pytree (same structure)
    loss_fn(params, batch)            -> scalar loss          [train shapes]
    prefill_fn(params, *inputs)       -> (logits, cache)      [prefill shapes]
    decode_fn(params, cache, tok, pos)-> (logits, cache)      [decode shapes]
    init_cache(batch, max_seq)        -> cache pytree
    cache_axes(batch, max_seq)        -> logical-axis pytree for the cache

``input_specs(cfg, shape)`` produces ShapeDtypeStruct stand-ins for every
model input of that (arch x shape) cell — weak-type-correct, shardable, no
device allocation — which is what launch/dryrun.py lowers against.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig, ShapeSpec
from repro.models import hybrid as hyb
from repro.models import layers as L
from repro.models import llava
from repro.models import moe
from repro.models import rwkv6
from repro.models import transformer as tfm
from repro.models import whisper


class ModelAPI(NamedTuple):
    cfg: LMConfig
    init_params: Callable
    param_axes: Callable
    loss_fn: Callable
    prefill_fn: Callable
    decode_fn: Callable
    init_cache: Callable  # (batch, max_seq) -> cache
    cache_axes: Callable  # (batch, max_seq) -> logical axes pytree


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _axes_like(template: Any, axes_fn: Callable[[Any], tuple]) -> Any:
    return jax.tree_util.tree_map(lambda leaf: axes_fn(leaf), template)


# --------------------------------------------------------------- families --


def _dense_api(cfg: LMConfig, block_fn=tfm.dense_block, layer_init=tfm.layer_init,
               layer_ax=tfm.layer_axes, mlp_fn=None) -> ModelAPI:
    def init_cache(batch, max_seq, dtype=jnp.bfloat16):
        if cfg.kv_quant:
            return tfm.QuantKVCache.zeros(cfg, batch, max_seq)
        return tfm.KVCache.zeros(cfg, batch, max_seq, dtype)

    def cache_axes(batch, max_seq):
        kv = ("layers", "batch", "kv_seq", "kv_heads", None)
        if cfg.kv_quant:
            sc = ("layers", "batch", "kv_seq", "kv_heads")
            return tfm.QuantKVCache(kv, kv, sc, sc)
        return tfm.KVCache(kv, kv)

    def decode(params, cache, token, pos):
        if cfg.serve_fast:  # carry-aliased fori_loop path (§Perf OPT1/OPT3)
            return tfm.cached_forward(
                params, token[:, None], cfg, cache, pos, mlp_fn=mlp_fn
            )
        fn = tfm.make_decode_fn(cfg, block_fn)
        return fn(params, cache, token, pos)

    def prefill(params, tokens, extra_embeds=None, valid_len=None):
        if cfg.serve_fast:
            b = tokens.shape[0]
            s = tokens.shape[1] + (extra_embeds.shape[1] if extra_embeds is not None else 0)
            cache = init_cache(b, s)
            # pos0 as a STATIC 0: the full-range cache writes lower to
            # constant-start updates (no GSPMD dynamic-write masks)
            return tfm.cached_forward(
                params, tokens, cfg, cache, 0,
                mlp_fn=mlp_fn, extra_embeds=extra_embeds, valid_len=valid_len,
            )
        return tfm.make_prefill_fn(cfg, block_fn)(
            params, tokens, extra_embeds, valid_len=valid_len
        )

    return ModelAPI(
        cfg=cfg,
        init_params=lambda key: tfm.init_params(key, cfg, layer_init),
        param_axes=lambda: tfm.param_axes(cfg, layer_ax),
        loss_fn=tfm.make_loss_fn(cfg, block_fn),
        prefill_fn=prefill,
        decode_fn=decode,
        init_cache=init_cache,
        cache_axes=cache_axes,
    )


def _moe_mlp_fn(cfg):
    def f(h, lp):
        out, _aux = moe.moe_mlp_ep(h, lp["moe"], cfg)
        return out

    return f


def _moe_api(cfg: LMConfig) -> ModelAPI:
    # moe_block_ep routes each device's tokens to its LOCAL experts inside
    # shard_map (§Perf OPT6); it falls back to the jnp-level dispatch when
    # no mesh context is installed (CPU tests, single device)
    return _dense_api(cfg, moe.moe_block_ep, moe.moe_layer_init, moe.moe_layer_axes,
                      mlp_fn=_moe_mlp_fn(cfg))


def _vlm_api(cfg: LMConfig) -> ModelAPI:
    base = _dense_api(cfg)

    def prefill(params, tokens, patches):
        embeds = llava.project_patches(params, patches)
        return base.prefill_fn(params, tokens, embeds)

    return base._replace(
        init_params=lambda key: llava.init_params(key, cfg),
        param_axes=lambda: llava.param_axes(cfg),
        loss_fn=llava.make_loss_fn(cfg),
        prefill_fn=prefill,
    )


def _hybrid_api(cfg: LMConfig) -> ModelAPI:
    def loss_fn(params, batch):
        logits, _ = hyb.forward(params, batch["tokens"], cfg)
        return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:])

    def prefill(params, tokens):
        logits, cache = hyb.forward(params, tokens, cfg, collect_kv=True)
        return logits[:, -1], cache

    def decode(params, cache, token, pos):
        if cfg.serve_fast:  # carry-aliased fori_loop path (§Perf OPT1)
            return hyb.cached_decode(params, token, cfg, cache, pos)
        logits, new_cache = hyb.forward(
            params, token[:, None], cfg, cache=cache, cache_pos=pos
        )
        return logits[:, 0], new_cache

    def cache_axes(batch, max_seq):
        cache = jax.eval_shape(lambda: hyb.init_cache(cfg, batch, max_seq))
        kv = ("layers", "batch", "kv_seq", "kv_heads", None)

        def st_axes(lead):
            return hyb.mamba2.MambaState(
                h=lead + ("batch", "ssm_heads", None, None),
                conv=lead + ("batch", None, "mlp"),
            )

        return hyb.HybridCache(
            mamba=st_axes(("layers", None)),
            tail=st_axes(("layers",)) if cache.tail is not None else None,
            attn_k=kv,
            attn_v=kv,
        )

    return ModelAPI(
        cfg=cfg,
        init_params=lambda key: hyb.init_params(key, cfg),
        param_axes=lambda: hyb.param_axes(cfg),
        loss_fn=loss_fn,
        prefill_fn=prefill,
        decode_fn=decode,
        init_cache=lambda batch, max_seq: hyb.init_cache(cfg, batch, max_seq),
        cache_axes=cache_axes,
    )


def _ssm_api(cfg: LMConfig) -> ModelAPI:
    def loss_fn(params, batch):
        logits, _ = rwkv6.forward(params, batch["tokens"], cfg)
        return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:])

    def prefill(params, tokens):
        state = rwkv6.init_cache(cfg, tokens.shape[0])
        logits, new_state = rwkv6.forward(params, tokens, cfg, state=state)
        return logits[:, -1], new_state

    def decode(params, cache, token, pos):
        del pos  # recurrent state carries position implicitly
        logits, new_state = rwkv6.forward(params, token[:, None], cfg, state=cache)
        return logits[:, 0], new_state

    def cache_axes(batch, max_seq):
        return rwkv6.RWKVState(
            s=("layers", "batch", "heads", None, None),
            x_tm=("layers", "batch", "act_embed"),
            x_cm=("layers", "batch", "act_embed"),
        )

    return ModelAPI(
        cfg=cfg,
        init_params=lambda key: rwkv6.init_params(key, cfg),
        param_axes=lambda: rwkv6.param_axes(cfg),
        loss_fn=loss_fn,
        prefill_fn=prefill,
        decode_fn=decode,
        init_cache=lambda batch, max_seq: rwkv6.init_cache(cfg, batch),
        cache_axes=cache_axes,
    )


def _audio_api(cfg: LMConfig) -> ModelAPI:
    def loss_fn(params, batch):
        enc = whisper.encode(params, batch["frames"], cfg)
        cross = whisper.cross_kv(params, enc, cfg)
        logits, _ = whisper.decoder_forward(params, batch["tokens"], cfg, cross)
        return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:])

    def prefill(params, tokens, frames):
        enc = whisper.encode(params, frames, cfg)
        cross = whisper.cross_kv(params, enc, cfg)
        logits, cache = whisper.decoder_forward(
            params, tokens, cfg, cross, collect_kv=True
        )
        return logits[:, -1], cache

    def decode(params, cache, token, pos):
        cross = (cache.cross_k, cache.cross_v)
        logits, new_cache = whisper.decoder_forward(
            params, token[:, None], cfg, cross, cache=cache, cache_pos=pos
        )
        return logits[:, 0], new_cache

    def init_cache(batch, max_seq, dtype=jnp.bfloat16):
        L_ = cfg.n_layers
        kv = (L_, batch, max_seq, cfg.n_kv_heads, cfg.hd)
        ckv = (L_, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd)
        return whisper.WhisperCache(
            self_k=jnp.zeros(kv, dtype),
            self_v=jnp.zeros(kv, dtype),
            cross_k=jnp.zeros(ckv, dtype),
            cross_v=jnp.zeros(ckv, dtype),
        )

    def cache_axes(batch, max_seq):
        kv = ("layers", "batch", "kv_seq", "kv_heads", None)
        return whisper.WhisperCache(kv, kv, kv, kv)

    return ModelAPI(
        cfg=cfg,
        init_params=lambda key: whisper.init_params(key, cfg),
        param_axes=lambda: whisper.param_axes(cfg),
        loss_fn=loss_fn,
        prefill_fn=prefill,
        decode_fn=decode,
        init_cache=init_cache,
        cache_axes=cache_axes,
    )


_FAMILIES = {
    "dense": _dense_api,
    "moe": _moe_api,
    "vlm": _vlm_api,
    "hybrid": _hybrid_api,
    "ssm": _ssm_api,
    "audio": _audio_api,
}


def get_api(cfg: LMConfig) -> ModelAPI:
    return _FAMILIES[cfg.family](cfg)


# ------------------------------------------------------------ input specs --


def input_specs(cfg: LMConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every input of this (arch x shape).

    train  -> {"batch": {...}}                        for loss/train_step
    prefill-> {"args": (tokens[, patches|frames],)}   for prefill_fn
    decode -> {"cache": ..., "token": ..., "pos": ...} for decode_fn
    """
    B, S = shape.global_batch, shape.seq_len
    api = get_api(cfg)
    tok = _sds((B, S), jnp.int32)

    if shape.kind == "train":
        batch = {"tokens": tok, "labels": tok}
        if cfg.family == "vlm":
            batch["patches"] = _sds((B, cfg.n_patches, llava.D_VISION), jnp.bfloat16)
        if cfg.family == "audio":
            batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        return {"batch": batch}

    if shape.kind == "prefill":
        args = [tok]
        if cfg.family == "vlm":
            args.append(_sds((B, cfg.n_patches, llava.D_VISION), jnp.bfloat16))
        if cfg.family == "audio":
            args.append(_sds((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16))
        return {"args": tuple(args)}

    # decode: one new token against a populated cache of length S
    cache = jax.eval_shape(lambda: api.init_cache(B, S))
    return {
        "cache": cache,
        "token": _sds((B,), jnp.int32),
        "pos": _sds((), jnp.int32),
    }


def batch_axes(cfg: LMConfig, shape: ShapeSpec):
    """Logical axes for the input batch/args (mirrors input_specs)."""
    if shape.kind == "train":
        axes = {"tokens": ("batch", None), "labels": ("batch", None)}
        if cfg.family == "vlm":
            axes["patches"] = ("batch", None, None)
        if cfg.family == "audio":
            axes["frames"] = ("batch", None, None)
        return {"batch": axes}
    if shape.kind == "prefill":
        axes = [("batch", None)]
        if cfg.family in ("vlm", "audio"):
            axes.append(("batch", None, None))
        return {"args": tuple(axes)}
    api = get_api(cfg)
    return {
        "cache": api.cache_axes(shape.global_batch, shape.seq_len),
        "token": ("batch",),
        "pos": (),
    }
