"""Fig 6 — analysis of design parallelism under weight sparsity.

Discrete cycle simulation of the three PE organizations the paper compares
(576 PEs total), driven by per-channel nonzero-weight counts drawn from the
pruned network's density profile:

  (a) input-channel parallelism (C, H, W) = (8, 9, 8): channels race ahead
      independently; a FIFO of depth d absorbs imbalance, deeper FIFOs cost
      area. Latency is simulated with a bounded-queue producer model.
  (b) output-channel parallelism: all K-lanes share the input stream and
      must ALL finish an input pixel block before advancing -> latency is
      sum over blocks of max-over-lane work.
  (c) spatial parallelism (paper's choice): every PE handles one pixel of a
      32x18 tile; identical weight stream -> zero imbalance, latency = nnz.

Reproduces the paper's qualitative result: (a) needs deep FIFOs to approach
(c) and never beats it; (b) degrades as more PEs go to K; (c) is optimal
with no extra hardware.
"""
from __future__ import annotations

import numpy as np


def _nnz_per_channel(rng, cin: int, k2: int = 9, density: float = 0.3):
    """Nonzero taps per input channel for one output channel's kernel."""
    return rng.binomial(k2, density, size=cin)


def spatial_latency(nnz_c: np.ndarray) -> int:
    """(c): all 576 PEs process the same (channel, tap) stream: cycles =
    total nnz taps across channels (one tap/cycle, paper §III-C)."""
    return int(nnz_c.sum())


def input_parallel_latency(nnz_c: np.ndarray, c_par: int, fifo_depth: int) -> int:
    """(a): c_par channel lanes, each owning cin/c_par channels; a lane's
    output must be merged in channel order into the accumulator; a FIFO of
    `fifo_depth` per lane lets fast lanes run ahead. Simulated per tap."""
    lanes = [nnz_c[i::c_par] for i in range(c_par)]
    # each lane is a work list of per-channel tap counts, merged round-robin
    queues = [0] * c_par  # occupancy of each lane's output FIFO
    work = [list(l) for l in lanes]
    t = 0
    done = [sum(l) == 0 for l in work]
    progress = [0] * c_par  # taps finished in current channel
    merged = 0
    total = sum(sum(l) for l in work)
    while merged < total:
        t += 1
        # lanes execute one tap if FIFO has room
        for i in range(c_par):
            if not work[i]:
                continue
            if queues[i] < fifo_depth + 1:
                progress[i] += 1
                if progress[i] >= work[i][0]:
                    work[i].pop(0)
                    progress[i] = 0
                queues[i] += 1
        # merge drains one entry per cycle (single accumulator port)
        for i in range(c_par):
            if queues[i] > 0:
                queues[i] -= 1
                merged += 1
                break
    return t


def output_parallel_latency(nnz_k: np.ndarray, k_par: int) -> int:
    """(b): k_par output-channel lanes share one input stream; the stream
    advances when the SLOWEST lane finishes its kernel for this input."""
    groups = [nnz_k[i : i + k_par] for i in range(0, len(nnz_k), k_par)]
    return int(sum(g.max() for g in groups))


def run() -> dict:
    rng = np.random.default_rng(0)
    cin, cout, density = 256, 256, 0.3
    # one output channel processed against all input channels (inner loop)
    nnz_c = _nnz_per_channel(rng, cin, density=density)
    base = spatial_latency(nnz_c)

    print("Fig 6(a) — input-channel parallelism vs FIFO depth (relative latency)")
    rel_in = {}
    for depth in (0, 1, 2, 4, 8, 16):
        lat = input_parallel_latency(nnz_c, c_par=8, fifo_depth=depth)
        rel_in[depth] = lat / base
        print(f"  FIFO depth {depth:3d}: {lat / base:5.2f}x spatial")

    print("Fig 6(b) — output-channel parallelism (relative latency)")
    nnz_k = np.array([
        _nnz_per_channel(rng, cin, density=density).sum() for _ in range(cout)
    ])
    rel_out = {}
    for k_par in (1, 2, 4, 8, 16):
        # K lanes split the PE budget; fewer spatial PEs -> proportionally
        # more passes: latency_rel = (sum of per-group max)/(sum) * k_par-way
        lat = output_parallel_latency(nnz_k, k_par) / nnz_k.sum() * k_par
        rel_out[k_par] = lat
        print(f"  K-par {k_par:3d}: {lat:5.2f}x spatial")

    ok = min(rel_in.values()) >= 0.999 and all(v >= 0.999 for v in rel_out.values())
    print(f"spatial parallelism optimal (paper's choice): {'OK' if ok else 'MISMATCH'}")
    return {"input_par_rel": rel_in, "output_par_rel": rel_out, "spatial_optimal": ok}


if __name__ == "__main__":
    run()
