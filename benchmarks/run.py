"""Benchmark driver: one module per paper table/figure + the roofline
collation. ``python -m benchmarks.run [--fast]``"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="skip the training cells")
    ap.add_argument("--json", default=None, help="dump all results to a JSON file")
    args = ap.parse_args(argv)

    from benchmarks import (
        e2e_detector,
        eval_map,
        fig3_density,
        fig5_miout,
        fig6_parallelism,
        fig15_mixed_t,
        fig17_dram,
        kernel_bench,
        roofline,
        serve_bench,
        table1_ablation,
        table2_models,
        table3_hw,
    )

    suites = [
        ("table1_ablation", lambda: table1_ablation.run()),
        ("table2_models", lambda: table2_models.run(train_steps=0 if args.fast else 5)),
        ("fig3_density", lambda: fig3_density.run()),
        ("fig5_miout", lambda: fig5_miout.run()),
        ("fig6_parallelism", lambda: fig6_parallelism.run()),
        ("fig15_mixed_t", lambda: fig15_mixed_t.run()),
        ("fig17_dram", lambda: fig17_dram.run()),
        ("table3_hw", lambda: table3_hw.run()),
        ("kernel_bench", lambda: kernel_bench.run()),
        ("e2e_detector", lambda: e2e_detector.run()),
        # accuracy: --fast trains a smoke-scale pipeline (mAP then NOT
        # representative); the full run reproduces the checked-in BENCH_eval
        ("eval_map", lambda: eval_map.run(
            steps=60 if args.fast else 3500,
            finetune_steps=20 if args.fast else 600,
            batch=4 if args.fast else 6,
            eval_images=8 if args.fast else 48,
        )),
        ("serve_bench", lambda: serve_bench.run()),
        ("roofline", lambda: roofline.run()),
    ]
    results, failed = {}, []
    for name, fn in suites:
        print(f"\n{'=' * 70}\n{name}\n{'=' * 70}")
        try:
            results[name] = fn()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, default=str)
    print(f"\n{'=' * 70}")
    if failed:
        print(f"FAILED suites: {failed}")
        sys.exit(1)
    print(f"all {len(suites)} benchmark suites completed")


if __name__ == "__main__":
    main()
