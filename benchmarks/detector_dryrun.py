"""The paper's OWN architecture on the production mesh: spatial-parallel
block convolution as a DISTRIBUTION scheme (DESIGN.md C3+C4 -> TPU).

The paper chooses 576-PE spatial parallelism because block convolution
makes spatial tiles independent (no boundary partial sums). Distributed,
that translates to: shard the block grid over the 'model' axis and the
batch over 'data' — and the lowered HLO must contain ZERO halo exchange
(no collective-permute between spatial neighbors). This module proves it:
it lowers the full-resolution (1024x576) detector forward on the (16,16)
mesh, asserts the no-halo property on the compiled HLO, and reports the
roofline terms + the fps the analytic §IV-E model predicts at that
parallelism.

Run inside the dry-run env (512 host devices):
  PYTHONPATH=src python -m benchmarks.detector_dryrun
"""
from __future__ import annotations

import os


def run() -> dict:
    if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        print("detector_dryrun: needs the 512-device dry-run env; run via\n"
              "  REPRO_DRYRUN=1 python -m benchmarks.detector_dryrun  (skipping)")
        return {"skipped": True}
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.launch import hlo_cost
    from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS, parse_collective_bytes
    from repro.launch.mesh import make_production_mesh
    from repro.models import snn_yolo as sy

    cfg = get_config("snn-det")
    mesh = make_production_mesh()
    params, bn = jax.eval_shape(lambda k: sy.init_params(k, cfg), jax.random.PRNGKey(0))
    imgs = jax.ShapeDtypeStruct((16, cfg.input_hw[0], cfg.input_hw[1], 3), jnp.float32)

    def forward(p, b, im):
        head, _, _ = sy.forward(p, b, im, cfg)
        return head

    with mesh:
        # batch over 'data'; W (the 32-wide block-column grid) over 'model'
        img_sh = NamedSharding(mesh, P("data", None, "model", None))
        rep = NamedSharding(mesh, P())
        lowered = jax.jit(
            forward,
            in_shardings=(jax.tree_util.tree_map(lambda _: rep, params),
                          jax.tree_util.tree_map(lambda _: rep, bn),
                          img_sh),
        ).lower(params, bn, imgs)
        compiled = lowered.compile()

    text = compiled.as_text()
    coll = parse_collective_bytes(text)
    halo = coll.get("collective-permute", 0)
    acc = hlo_cost.analyze_text(text)
    out = {
        "halo_collective_permute_bytes": halo,
        "collectives": coll,
        "compute_s": acc["flops"] / PEAK_FLOPS,
        "memory_s": acc["bytes"] / HBM_BW,
        "collective_s": acc["collective_bytes"] / ICI_BW,
    }
    print("detector @1024x576 on (16,16) mesh — spatial block-grid sharding")
    print(f"  halo (collective-permute) bytes: {halo}  "
          f"{'ZERO-HALO OK (paper C4 distributed)' if halo == 0 else 'HALO PRESENT'}")
    print(f"  all collectives: {coll}")
    print(f"  roofline terms: compute {out['compute_s']:.2e}s  "
          f"memory {out['memory_s']:.2e}s  collective {out['collective_s']:.2e}s")
    assert halo == 0, "block convolution must shard spatially with no halo"
    return out


if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    run()
