"""Fig 15 — effect of mixed time steps on operation count (and the paper's
C1/C2/C2BX schedule family).

Cx = first x conv layers take 1-time-step input; C2BX additionally sets the
first X basic blocks to in_T=1. The paper selects C2: −4.13 GOps (−17%)
vs the all-3-time-step baseline. Accuracy cells need IVS 3cls; the op
accounting reproduces exactly.
"""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.models import snn_yolo as sy


def _sched_ops(cfg, one_t_layers: int, one_t_blocks: int = 0) -> float:
    """Total GOps with the first `one_t_layers` standalone conv layers
    (encode, conv_block) and the first `one_t_blocks` basic blocks at
    in_T=1; everything else runs at the full T=3 (the paper's original
    pre-mixed-time-step schedule)."""
    specs = sy.layer_specs(cfg)
    total = 0.0
    conv_seen = 0
    for s in specs:
        t_in = cfg.full_t  # base: every layer convolves once per time step
        if "/" not in s.name and s.name != "head":  # encode / conv_block
            conv_seen += 1
            if conv_seen <= one_t_layers:
                t_in = 1
        elif "/" in s.name:
            idx = int(s.name[5])  # stageN/...
            if idx < one_t_blocks:
                t_in = 1
        total += 2 * s.h * s.w * s.nnz * t_in * s.bits_in
    return total / 1e9


def run() -> dict:
    cfg = get_config("snn-det")
    rows = {
        "base(3T)": _sched_ops(cfg, 0),
        "C1": _sched_ops(cfg, 1),
        "C2": _sched_ops(cfg, 2),
        "C2B1": _sched_ops(cfg, 2, 1),
        "C2B2": _sched_ops(cfg, 2, 2),
        "C2B3": _sched_ops(cfg, 2, 3),
    }
    c2_saving = rows["base(3T)"] - rows["C2"]
    print("Fig 15 — mixed-time-step schedules, GOps/frame")
    for k, v in rows.items():
        print(f"  {k:9s} {v:7.2f} GOps")
    print(f"C2 saves {c2_saving:.2f} GOps ({c2_saving / rows['base(3T)'] * 100:.1f}%) "
          f"— paper: 4.13 GOps (17%)")
    return {**rows, "c2_saving_gops": c2_saving,
            "c2_saving_frac": c2_saving / rows["base(3T)"]}


if __name__ == "__main__":
    run()
