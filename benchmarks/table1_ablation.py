"""Table I — ablation of the SNN detector: parameters and operation counts
for SNN-a (baseline) → SNN-b (pruned) → SNN-c (+quant) → SNN-d (+block conv).

Accuracy cells of Table I require the IVS 3cls dataset (not redistributable;
DESIGN.md §8.3) — the reproducible cells are the parameter/op accounting,
checked against the paper's numbers:
  * SNN-a: 3.17 M params
  * SNN-b/c/d: 0.96 M params (−70%)
  * zero-weight skipping: −47.3 % operation count (§IV-E)
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_config
from repro.models import snn_yolo as sy


def run() -> dict:
    cfg = get_config("snn-det")
    params, _ = sy.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sy.param_count(params)

    dense = sy.layer_specs(cfg, pruned_density=1.0)
    pruned = sy.layer_specs(cfg)  # Fig 3 profile

    def tot_params(specs, density=True):
        return sum(s.nnz if density else s.params for s in specs)

    p_a = tot_params(dense, density=False)
    p_b = tot_params(pruned)
    ops_dense = sum(s.ops(sparse=False) for s in pruned)
    ops_sparse = sum(s.ops(sparse=True) for s in pruned)

    rows = [
        ("SNN-a", p_a / 1e6, ops_dense / 1e9, "baseline"),
        ("SNN-b", p_b / 1e6, ops_sparse / 1e9, "fine-grained pruning (3x3 @ 80%)"),
        ("SNN-c", p_b / 1e6, ops_sparse / 1e9, "+ 8-bit quantization"),
        ("SNN-d", p_b / 1e6, ops_sparse / 1e9, "+ block convolution 32x18"),
    ]
    out = {
        "init_params_M": n_params / 1e6,
        "snn_a_params_M": p_a / 1e6,
        "snn_d_params_M": p_b / 1e6,
        "param_reduction": 1 - p_b / p_a,
        "ops_reduction": 1 - ops_sparse / ops_dense,
        "paper": {"snn_a_params_M": 3.17, "snn_d_params_M": 0.96,
                  "param_reduction": 0.70, "ops_reduction": 0.473},
    }
    print("Table I — SNN model ablation (accounting cells)")
    print(f"{'model':7s} {'params(M)':>10s} {'GOps/frame':>11s}  notes")
    for name, p, g, note in rows:
        print(f"{name:7s} {p:10.2f} {g:11.2f}  {note}")
    print(f"reproduced: init {out['init_params_M']:.2f}M vs paper 3.17M | "
          f"param cut {out['param_reduction']*100:.1f}% (paper 70%) | "
          f"op cut {out['ops_reduction']*100:.1f}% (paper 47.3%)")
    return out


if __name__ == "__main__":
    run()
