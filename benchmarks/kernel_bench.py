"""Kernel microbenchmarks (§III): the Pallas gated one-to-all conv, the
fused LIF scan, and the bitmask matmul, validated in interpret mode against
their jnp oracles, with the accounting the ASIC exposes in hardware:

  * cycle model: taps executed = nnz weights (zero-weight skipping),
  * compressed weight bytes read vs dense (bit-mask format),
  * fused-LIF: membrane potential never round-trips HBM between time steps.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def run() -> dict:
    key = jax.random.PRNGKey(0)
    out = {}

    # --- gated one-to-all product (sparse spike conv) ---
    n, h, w_, cin, cout, density = 1, 18, 32, 32, 64, 0.2
    x = (jax.random.uniform(key, (n, h, w_, cin)) < 0.25).astype(jnp.int8)
    wd = np.array(jax.random.randint(jax.random.PRNGKey(1), (3, 3, cin, cout), -127, 127, jnp.int8))
    wd[np.random.default_rng(0).random(wd.shape) > density] = 0
    packed = ops.pack_conv_weights(wd)
    t0 = time.time()
    y = ops.gated_conv(x, packed, interpret=True)
    t_k = time.time() - t0
    y_ref = ref.gated_conv_ref(x, jnp.asarray(wd))
    err = int(jnp.max(jnp.abs(y.astype(jnp.int32) - y_ref.astype(jnp.int32))))
    nnz = int((wd != 0).sum())
    out["gated_one_to_all"] = {
        "max_err": err,
        "nnz_taps": nnz,
        "dense_taps": int(wd.size),
        "cycle_saving": 1 - nnz / wd.size,
        "weight_bytes_dense": int(wd.size),
        "weight_bytes_compressed": int(packed.compressed_bytes),
        "interpret_s": t_k,
    }
    print(f"gated_one_to_all : err={err} cycle_saving={out['gated_one_to_all']['cycle_saving']*100:.1f}% "
          f"bytes {packed.compressed_bytes}/{wd.size}")
    assert err == 0, "kernel must be exact vs oracle"

    # --- fused LIF ---
    t, m, c = 4, 512, 32
    cur = jax.random.normal(key, (t, m, c), jnp.float32)
    s_k = ops.fused_lif(cur, threshold=0.5, leak=0.25, interpret=True)
    s_r = ref.fused_lif_ref(cur, threshold=0.5, leak=0.25)
    lif_err = float(jnp.max(jnp.abs(s_k.astype(jnp.float32) - s_r.astype(jnp.float32))))
    out["fused_lif"] = {"max_err": lif_err, "spike_rate": float(jnp.mean(s_k.astype(jnp.float32)))}
    print(f"fused_lif        : err={lif_err} rate={out['fused_lif']['spike_rate']:.3f}")

    # --- bitmask matmul ---
    mm, kk, nn = 64, 512, 256
    w2 = np.array(jax.random.normal(jax.random.PRNGKey(2), (kk, nn)), np.float32)
    w2[np.abs(w2) < 1.2] = 0.0  # ~77% sparse (paper's weight regime)
    xs = jax.random.normal(jax.random.PRNGKey(3), (mm, kk), jnp.float32)
    pw = ops.pack_matmul_weights(w2)
    y2 = ops.bitmask_matmul(xs, pw, interpret=True)
    y2_ref = ref.bitmask_matmul_ref(xs, jnp.asarray(w2))
    mm_err = float(jnp.max(jnp.abs(y2 - y2_ref)))
    out["bitmask_matmul"] = {
        "max_err": mm_err,
        "density": float((w2 != 0).mean()),
        "compressed_bytes": int(pw.compressed_bytes),
        "dense_bytes": int(w2.size * 4),
    }
    print(f"bitmask_matmul   : err={mm_err:.2e} density={out['bitmask_matmul']['density']:.2f} "
          f"bytes {pw.compressed_bytes}/{int(w2.size*4)}")
    return out


if __name__ == "__main__":
    run()
