"""Kernel microbenchmarks (§III): the Pallas gated one-to-all conv, the
fused LIF scan, and the bitmask matmul, validated in interpret mode against
their jnp oracles, with the accounting the ASIC exposes in hardware:

  * cycle model: taps executed = nnz weights (zero-weight skipping),
  * compressed weight bytes read vs dense (bit-mask format),
  * fused-LIF: membrane potential never round-trips HBM between time steps.

``--fast`` runs only the fused layer-pipeline smoke: full-forward parity of
the fused conv→tdBN→LIF kernel against the jitted dense oracle (bit-exact,
exits nonzero on any mismatch) plus the encoding-layer dispatch-count
assertion — the 8 bit-serial planes must fold into ONE ``pallas_call``.
CI runs this under ``JAX_PLATFORMS=cpu`` as the kernel-bench gate.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def run_fused() -> dict:
    """Fused layer-pipeline gate at the reduced e2e scale: dense-oracle
    parity (bit-exact) and the single-dispatch bit-serial encode."""
    import dataclasses

    from benchmarks.e2e_detector import reduced_config
    from repro.core import plan as cplan, pruning
    from repro.kernels import backend
    from repro.models import snn_yolo as sy

    cfg = reduced_config()
    params, bn = sy.init_params(jax.random.PRNGKey(0), cfg)
    params = pruning.prune_tree(params, 0.8)
    rng = np.random.default_rng(0)
    h, w_ = cfg.input_hw
    imgs = jnp.asarray(rng.integers(0, 256, (1, h, w_, 3)) / 255.0, jnp.float32)
    bn = sy.calibrate_bn_state(params, bn, imgs, cfg)

    heads = {}
    det = None
    for ex in ("dense", "pallas"):
        det = sy.compile_detector(dataclasses.replace(cfg, conv_exec=ex),
                                  params, bn)
        _, head = det.detect(imgs)
        heads[ex] = np.asarray(head)
    err = float(np.abs(heads["pallas"] - heads["dense"]).max())
    print(f"fused_pipeline   : err={err:.2e} (pallas vs jitted dense oracle)")
    assert err == 0.0, f"fused pipeline diverges from dense oracle: {err}"

    # the encoding layer must be ONE dispatch: 8 bit planes folded by conv
    # linearity into a single fused pallas_call, not 8 serial sweeps
    pcfg = dataclasses.replace(cfg, conv_exec="pallas")
    lp = det.plan.layers["encode"]
    spec = next(s for s in sy.layer_specs(pcfg) if s.name == "encode")
    x_t = imgs[None]  # (t_in=1, N, H, W, 3)

    def encode_layer(x):
        return cplan.run_fused(
            x, lp, pcfg,
            gamma=params["encode"]["gamma"], beta=params["encode"]["beta"],
            mean=bn["encode"]["mean"], var=bn["encode"]["var"],
            v0=None, out_t=spec.t_out)

    n_calls = backend.count_pallas_calls(encode_layer, x_t)
    print(f"encode dispatches: {n_calls} (8 bit planes, one fused kernel)")
    assert n_calls == 1, f"bit-serial encode must be 1 dispatch, got {n_calls}"
    return {"fused_pipeline": {"max_err": err, "encode_dispatches": n_calls}}


def run() -> dict:
    key = jax.random.PRNGKey(0)
    out = {}

    # --- gated one-to-all product (sparse spike conv) ---
    n, h, w_, cin, cout, density = 1, 18, 32, 32, 64, 0.2
    x = (jax.random.uniform(key, (n, h, w_, cin)) < 0.25).astype(jnp.int8)
    wd = np.array(jax.random.randint(jax.random.PRNGKey(1), (3, 3, cin, cout), -127, 127, jnp.int8))
    wd[np.random.default_rng(0).random(wd.shape) > density] = 0
    packed = ops.pack_conv_weights(wd)
    t0 = time.time()
    y = ops.gated_conv(x, packed, interpret=True)
    t_k = time.time() - t0
    y_ref = ref.gated_conv_ref(x, jnp.asarray(wd))
    err = int(jnp.max(jnp.abs(y.astype(jnp.int32) - y_ref.astype(jnp.int32))))
    nnz = int((wd != 0).sum())
    out["gated_one_to_all"] = {
        "max_err": err,
        "nnz_taps": nnz,
        "dense_taps": int(wd.size),
        "cycle_saving": 1 - nnz / wd.size,
        "weight_bytes_dense": int(wd.size),
        "weight_bytes_compressed": int(packed.compressed_bytes),
        "interpret_s": t_k,
    }
    print(f"gated_one_to_all : err={err} cycle_saving={out['gated_one_to_all']['cycle_saving']*100:.1f}% "
          f"bytes {packed.compressed_bytes}/{wd.size}")
    assert err == 0, "kernel must be exact vs oracle"

    # --- fused LIF ---
    t, m, c = 4, 512, 32
    cur = jax.random.normal(key, (t, m, c), jnp.float32)
    s_k = ops.fused_lif(cur, threshold=0.5, leak=0.25, interpret=True)
    s_r = ref.fused_lif_ref(cur, threshold=0.5, leak=0.25)
    lif_err = float(jnp.max(jnp.abs(s_k.astype(jnp.float32) - s_r.astype(jnp.float32))))
    out["fused_lif"] = {"max_err": lif_err, "spike_rate": float(jnp.mean(s_k.astype(jnp.float32)))}
    print(f"fused_lif        : err={lif_err} rate={out['fused_lif']['spike_rate']:.3f}")

    # --- bitmask matmul ---
    mm, kk, nn = 64, 512, 256
    w2 = np.array(jax.random.normal(jax.random.PRNGKey(2), (kk, nn)), np.float32)
    w2[np.abs(w2) < 1.2] = 0.0  # ~77% sparse (paper's weight regime)
    xs = jax.random.normal(jax.random.PRNGKey(3), (mm, kk), jnp.float32)
    pw = ops.pack_matmul_weights(w2)
    y2 = ops.bitmask_matmul(xs, pw, interpret=True)
    y2_ref = ref.bitmask_matmul_ref(xs, jnp.asarray(w2))
    mm_err = float(jnp.max(jnp.abs(y2 - y2_ref)))
    out["bitmask_matmul"] = {
        "max_err": mm_err,
        "density": float((w2 != 0).mean()),
        "compressed_bytes": int(pw.compressed_bytes),
        "dense_bytes": int(w2.size * 4),
    }
    print(f"bitmask_matmul   : err={mm_err:.2e} density={out['bitmask_matmul']['density']:.2f} "
          f"bytes {pw.compressed_bytes}/{int(w2.size*4)}")
    out.update(run_fused())
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="fused-pipeline smoke only (parity + dispatch "
                    "count) — the CI kernel-bench gate")
    args = ap.parse_args(argv)
    return run_fused() if args.fast else run()


if __name__ == "__main__":
    main()
