"""§Roofline — collate the dry-run artifacts into the per-(arch x shape)
roofline table: three terms in seconds, dominant bottleneck, MODEL_FLOPS
ratio, and a one-line lever per cell.

Reads artifacts/dryrun/*.json produced by launch/dryrun.py. Hardware:
TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os

LEVERS = {
    ("train", "compute_s"): "raise arithmetic intensity: fuse, cut remat recompute",
    ("train", "memory_s"): "cut HBM traffic: fewer materialized intermediates, bf16 stashes, fused one-hot embedding",
    ("train", "collective_s"): "overlap DP all-reduce with backward; int8 gradient compression; hierarchical psum",
    ("prefill", "compute_s"): "at compute roofline — bigger attention chunks to lift MXU utilization",
    ("prefill", "memory_s"): "flash-style chunking; keep KV bf16; avoid reshape copies",
    ("prefill", "collective_s"): "shard seq (ring attention) instead of gathering KV; all-to-all MoE dispatch",
    ("decode", "compute_s"): "decode is never compute-bound at batch<=128 — check accounting",
    ("decode", "memory_s"): "KV cache read dominates: shard KV seq over more chips, quantize KV, GQA",
    ("decode", "collective_s"): "split-K combine traffic: fewer/larger decode steps per dispatch, KV-local layout",
}


def load(out_dir: str = "artifacts/dryrun", mesh: str = "single"):
    rows = []
    for f in sorted(glob.glob(os.path.join(out_dir, f"*__{mesh}.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def kind_of(shape: str) -> str:
    return {"train_4k": "train", "prefill_32k": "prefill",
            "decode_32k": "decode", "long_500k": "decode"}[shape]


def run(out_dir: str = "artifacts/dryrun", mesh: str = "single") -> dict:
    rows = load(out_dir, mesh)
    if not rows:
        print(f"no dry-run artifacts under {out_dir} (run python -m repro.launch.dryrun --all first)")
        return {}
    print(f"§Roofline — {mesh}-pod mesh, per-chip terms (s/step)")
    hdr = f"{'arch':18s} {'shape':12s} {'compute':>9s} {'memory':>9s} {'collect':>9s} {'dominant':>12s} {'useful':>7s}"
    print(hdr)
    print("-" * len(hdr))
    table = {}
    for r in rows:
        if r.get("status") == "skipped":
            print(f"{r['arch']:18s} {r['shape']:12s} {'—':>9s} {'—':>9s} {'—':>9s} {'skipped':>12s}")
            continue
        t = r["roofline"]
        key = (r["arch"], r["shape"])
        table[key] = t
        print(f"{r['arch']:18s} {r['shape']:12s} {t['compute_s']:9.2e} {t['memory_s']:9.2e} "
              f"{t['collective_s']:9.2e} {t['dominant'][:-2]:>12s} {t['useful_flops_ratio']:7.3f}")
    # roofline fraction = compute_s / bound_s (how far from the compute
    # roofline the dominant term pins us); one lever sentence per cell
    print("\nper-cell roofline fraction + dominant-term lever:")
    for (arch, shape), t in sorted(table.items(), key=lambda kv: -kv[1]["bound_s"]):
        lever = LEVERS.get((kind_of(shape), t["dominant"]), "")
        frac = t["compute_s"] / max(t["bound_s"], 1e-30)
        print(f"  {arch} x {shape}: {frac:5.1%} — {lever}")
    return table


if __name__ == "__main__":
    run()
