"""Table II — model-family comparison: ANN / QNN / BNN / SNN variants of the
same topology. Reproducible cells: model size (Mbits) and parameter counts;
plus a short synthetic-data training run per mode showing each variant
learns (loss decreases) — accuracy ordering on IVS 3cls is not reproducible
offline (DESIGN.md §8.3).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import synthetic_detection as sd
from repro.models import snn_yolo as sy


def model_size_mbits(n_params: float, weight_bits: int) -> float:
    return n_params * weight_bits / 1e6


def run(train_steps: int = 0) -> dict:
    cfg = get_config("snn-det")
    params, _ = sy.init_params(jax.random.PRNGKey(0), cfg)
    n = sy.param_count(params)
    n_pruned = int(n * 0.30)  # −70% (Table I)

    rows = [
        # name, act, weight_bits, params, block conv
        ("ANN", "Float32", 32, n, False),
        ("QNN-4", "FXP4", 32, n, False),
        ("QNN-3", "FXP3", 32, n, False),
        ("QNN-2", "FXP2", 32, n, False),
        ("BNN", "Binary", 1, n, False),
        ("SNN-a", "Binary(T=1,3)", 32, n, False),
        ("SNN-d", "Binary(T=1,3)", 8, n_pruned, True),
    ]
    print("Table II — model family accounting")
    print(f"{'model':8s} {'act':>14s} {'w_bits':>6s} {'params(M)':>10s} {'size(Mbit)':>11s}")
    out = {}
    for name, act, wb, p, blk in rows:
        sz = model_size_mbits(p, wb)
        out[name] = {"params_M": p / 1e6, "size_mbits": sz}
        print(f"{name:8s} {act:>14s} {wb:6d} {p/1e6:10.2f} {sz:11.2f}")
    print(f"paper: ANN 101.44 Mbit / SNN-d 7.68 Mbit; ours: "
          f"{out['ANN']['size_mbits']:.2f} / {out['SNN-d']['size_mbits']:.2f}")

    if train_steps:
        # one tiny reduced-config training run per mode on synthetic data
        small = dataclasses.replace(
            cfg, input_hw=(96, 160), stem_channels=8, conv_block_channels=16,
            stage_channels=((16, 16), (16, 32), (32, 32)), pooled_stages=3,
            use_block_conv=False,
        )
        for mode in ("snn", "ann", "qnn", "bnn"):
            mcfg = dataclasses.replace(small, mode=mode)
            losses = _short_train(mcfg, train_steps)
            out.setdefault("learning", {})[mode] = losses
            print(f"  {mode:4s} loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return out


def _short_train(cfg, steps: int):
    params, bn = sy.init_params(jax.random.PRNGKey(0), cfg)
    # reduced config downsamples /16 (stem + conv + pooled_stages-1 pools),
    # not the full model's /32 — match the target grid to the real head
    grid_div = 2 ** (2 + cfg.pooled_stages - 1)
    batch = next(sd.batches(2, hw=cfg.input_hw, steps=1, grid_div=grid_div))
    imgs = jnp.asarray(batch["image"])
    tgts = jnp.asarray(batch["target"])

    def loss_fn(p, bn):
        head, new_bn, _ = sy.forward(p, bn, imgs, cfg, train=True)
        return sy.yolo_loss(head, tgts), new_bn

    @jax.jit
    def step(p, bn):
        (l, new_bn), g = jax.value_and_grad(loss_fn, has_aux=True)(p, bn)
        p = jax.tree_util.tree_map(lambda w, gw: w - 5e-3 * gw, p, g)
        return p, new_bn, l

    losses = []
    for _ in range(steps):
        params, bn, l = step(params, bn)
        losses.append(float(l))
    return losses


if __name__ == "__main__":
    run(train_steps=5)
