"""Fig 4 + Fig 5 — mIoUT metric.

Fig 4: the worked example (4 neurons firing at all steps, 2 at some) must
give 0.67. Fig 5: mIoUT of the input features at each macro layer of the
detector on synthetic images — the paper's finding is that the SECOND layer
sees near-identical features across time steps (mIoUT ~1), justifying the
(1, 3) mixed schedule, while deep layers diverge.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import miout as mi
from repro.data import synthetic_detection as sd
from repro.models import snn_yolo as sy


def run() -> dict:
    # --- Fig 4 worked example ---
    t = 3
    spikes = np.zeros((t, 8, 1), np.float32)
    spikes[:, :4] = 1.0  # 4 neurons fire every step
    spikes[0, 4] = 1.0  # 2 neurons fire once each
    spikes[2, 5] = 1.0
    fig4 = float(mi.miout(jnp.asarray(spikes)))
    print(f"Fig 4 worked example: mIoUT = {fig4:.2f} (paper: 0.67)")

    # --- Fig 5 on the (reduced) detector with synthetic frames ---
    cfg = dataclasses.replace(
        get_config("snn-det"),
        input_hw=(144, 256), use_block_conv=False, mixed_time=False,
    )
    params, bn = sy.init_params(jax.random.PRNGKey(0), cfg)
    batch = next(sd.batches(2, hw=cfg.input_hw, steps=1))
    _, _, aux = sy.forward(params, bn, jnp.asarray(batch["image"]), cfg, train=False)
    out = {"fig4": fig4}
    print("Fig 5 — mIoUT per macro layer (T=3, untrained net, synthetic frames)")
    for name, s in aux["spikes"].items():
        if s.shape[0] == 1:
            continue
        v = float(mi.miout(s))
        out[name] = v
        print(f"  {name:12s} mIoUT = {v:.3f}")
    return out


if __name__ == "__main__":
    run()
