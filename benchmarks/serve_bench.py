"""Detection-serving benchmark: frame streams through the slot-pool Engine.

For each conv executor, compiles the smoke-scale detector once, serves a
fixed set of concurrent :class:`FrameRequest` streams through the Engine's
continuous-batching loop, and records throughput (frames/sec) plus per-step
latency percentiles (p50/p95 of one batched session step, jit warmup
excluded). Also asserts that every executor's served raw heads match the
dense executor's exactly (the compile-once path may not drift from the
oracle under slot batching / membrane carryover).

Writes ``BENCH_serve.json``.

  PYTHONPATH=src python -m benchmarks.serve_bench
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

# served heads must match the dense executor's BIT-EXACTLY — integer-domain
# accumulation makes every executor identical (tests/conformance/)
PARITY_ATOL = 0.0
EXECUTORS = ("dense", "gated", "pallas")


def run(*, requests: int = 8, slots: int = 4, frames: int = 2,
        out_json: str = "BENCH_serve.json") -> dict:
    from repro.configs import get_config, smoke_config
    from repro.models import snn_yolo as sy
    from repro.serve import Engine, FrameRequest
    from repro.serve.detector import demo_weights, step_latency_ms, synth_streams

    base = smoke_config(get_config("snn-det"))
    params, bn, rng = demo_weights(base)
    streams = synth_streams(rng, requests, frames, base.input_hw)

    results: dict = {
        "config": {"requests": requests, "slots": slots,
                   "frames_per_stream": frames, "input_hw": list(base.input_hw)},
        "executors": {},
    }
    served_heads = {}
    for ex in EXECUTORS:
        cfg = dataclasses.replace(base, conv_exec=ex)
        det = sy.compile_detector(cfg, params, bn)
        eng = Engine(det, n_slots=slots)
        reqs = [FrameRequest(rid=r, frames=s) for r, s in enumerate(streams)]
        for fr in reqs:
            eng.submit(fr)
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        assert len(done) == requests
        served_heads[ex] = {fr.rid: np.stack(fr.heads) for fr in reqs}
        diff = max(
            float(np.abs(served_heads[ex][rid] - served_heads["dense"][rid]).max())
            for rid in served_heads[ex]
        )
        assert diff <= PARITY_ATOL, f"{ex} served heads diverge from dense: {diff}"
        results["executors"][ex] = {
            "frames_per_s": requests * frames / dt,
            "wall_s": dt,
            **step_latency_ms(eng.core.step_wall),
            "max_abs_diff_vs_dense": diff,
        }
        r = results["executors"][ex]
        print(f"  {ex:7s} {r['frames_per_s']:7.1f} frames/s  "
              f"p50 {r['step_p50_ms']:6.1f}ms  p95 {r['step_p95_ms']:6.1f}ms  "
              f"max|Δ| vs dense {diff:.2e}")

    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=1)
        print(f"  wrote {out_json}")
    return results


if __name__ == "__main__":
    run()
