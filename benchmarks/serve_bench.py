"""Detection-serving benchmark: frame streams through the megabatched Engine.

Two sections, both written into ``BENCH_serve.json``:

* ``executors`` — for each conv executor, compiles the smoke-scale detector
  once, serves a fixed set of concurrent :class:`FrameRequest` streams
  through the Engine's continuous-batching loop, and records throughput
  (frames/sec) plus per-tick latency percentiles (p50/p95/p99 of one
  megabatched step, jit warmup excluded). Also asserts that every
  executor's served raw heads match the dense executor's exactly (the
  compile-once path may not drift from the oracle under slot batching /
  membrane carryover).

* ``load`` — the load generator: N fully-resident concurrent streams
  (``--streams 64 256 [1024]``) megabatched through one engine tick per
  frame, recording p50/p95/p99 tick latency, aggregate frames/s and
  per-stream fps (the paper's target is 29 fps/stream sustained across
  >= 64 streams). A sample of served streams is asserted BIT-IDENTICAL to
  an independent per-stream DetectorSession replay — megabatching, row
  remapping and the double-buffered upload may not change a single bit.

  PYTHONPATH=src python -m benchmarks.serve_bench [--streams 64 256] [--fast]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

# served heads must match the dense executor's BIT-EXACTLY — integer-domain
# accumulation makes every executor identical (tests/conformance/)
PARITY_ATOL = 0.0
EXECUTORS = ("dense", "gated", "pallas")
LOAD_EXECUTOR = "gated"  # fastest CPU executor at smoke scale (BENCH_e2e)


def _run_executors(base, params, bn, streams, *, requests, slots, frames):
    import dataclasses as dc

    from repro.models import snn_yolo as sy
    from repro.serve import Engine, FrameRequest
    from repro.serve.detector import step_latency_ms

    out = {}
    served_heads = {}
    for ex in EXECUTORS:
        cfg = dc.replace(base, conv_exec=ex)
        det = sy.compile_detector(cfg, params, bn)
        eng = Engine(det, n_slots=slots)
        reqs = [FrameRequest(rid=r, frames=s) for r, s in enumerate(streams)]
        for fr in reqs:
            eng.submit(fr)
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        assert len(done) == requests and done.status == "drained"
        served_heads[ex] = {fr.rid: np.stack(fr.heads) for fr in reqs}
        diff = max(
            float(np.abs(served_heads[ex][rid] - served_heads["dense"][rid]).max())
            for rid in served_heads[ex]
        )
        assert diff <= PARITY_ATOL, f"{ex} served heads diverge from dense: {diff}"
        out[ex] = {
            "frames_per_s": requests * frames / dt,
            "wall_s": dt,
            **step_latency_ms(eng.core.step_wall),
            "max_abs_diff_vs_dense": diff,
        }
        r = out[ex]
        print(f"  {ex:7s} {r['frames_per_s']:7.1f} frames/s  "
              f"p50 {r['step_p50_ms']:6.1f}ms  p95 {r['step_p95_ms']:6.1f}ms  "
              f"p99 {r['step_p99_ms']:6.1f}ms  max|Δ| vs dense {diff:.2e}")
    return out


def _run_load(base, params, bn, *, n_streams, frames, parity_streams):
    import dataclasses as dc

    from repro.models import snn_yolo as sy
    from repro.serve import AdmissionPolicy, Engine, FrameRequest
    from repro.serve.detector import step_latency_ms, synth_streams

    cfg = dc.replace(base, conv_exec=LOAD_EXECUTOR)
    det = sy.compile_detector(cfg, params, bn)
    rng = np.random.default_rng(1234 + n_streams)
    streams = synth_streams(rng, n_streams, frames, base.input_hw)
    eng = Engine(
        det,
        n_slots=n_streams,  # fully resident: true N-way concurrency
        admission=AdmissionPolicy(max_queue=n_streams),
    )
    reqs = [FrameRequest(rid=r, frames=s) for r, s in enumerate(streams)]
    for fr in reqs:
        assert eng.submit(fr)
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    assert len(done) == n_streams and done.status == "drained"

    # bit-parity gate: a sample of megabatched streams vs independent
    # per-stream session replay (exits nonzero on ANY mismatch)
    for fr in reqs[:parity_streams]:
        solo = det.new_session(batch=1)
        for k, f in enumerate(fr.frames):
            ref = np.asarray(solo.step(f[None]).head[0])
            diff = float(np.abs(fr.heads[k] - ref).max())
            assert diff <= PARITY_ATOL, (
                f"stream {fr.rid} frame {k}: megabatched head diverges from "
                f"solo DetectorSession replay by {diff}"
            )

    lat = step_latency_ms(eng.core.step_wall)
    rec = {
        "n_streams": n_streams,
        "frames_per_stream": frames,
        "wall_s": dt,
        "frames_per_s": n_streams * frames / dt,
        "per_stream_fps": frames / dt,
        "tick_p50_ms": lat["step_p50_ms"],
        "tick_p95_ms": lat["step_p95_ms"],
        "tick_p99_ms": lat["step_p99_ms"],
        "parity_streams": parity_streams,
        "max_abs_diff_vs_session": 0.0,
    }
    print(f"  load {n_streams:5d} streams  {rec['frames_per_s']:8.1f} frames/s "
          f"({rec['per_stream_fps']:6.2f} fps/stream)  tick p50 "
          f"{rec['tick_p50_ms']:7.1f}ms  p95 {rec['tick_p95_ms']:7.1f}ms  "
          f"p99 {rec['tick_p99_ms']:7.1f}ms")
    return rec


def run(*, requests: int = 8, slots: int = 4, frames: int = 2,
        load_streams=(64, 256), load_frames: int = 4, parity_streams: int = 3,
        out_json: str = "BENCH_serve.json") -> dict:
    from repro.configs import get_config, smoke_config
    from repro.serve.detector import demo_weights, synth_streams

    base = smoke_config(get_config("snn-det"))
    params, bn, rng = demo_weights(base)
    streams = synth_streams(rng, requests, frames, base.input_hw)

    results: dict = {
        "config": {"requests": requests, "slots": slots,
                   "frames_per_stream": frames, "input_hw": list(base.input_hw),
                   "load_streams": list(load_streams),
                   "load_frames": load_frames},
        "executors": _run_executors(
            base, params, bn, streams,
            requests=requests, slots=slots, frames=frames,
        ),
        "load": {},
    }
    for n in load_streams:
        results["load"][str(n)] = _run_load(
            base, params, bn,
            n_streams=n, frames=load_frames, parity_streams=parity_streams,
        )

    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=1)
        print(f"  wrote {out_json}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, nargs="*", default=None,
                    help="concurrent-stream counts for the load generator "
                         "(default: 64 256; the paper-scale target is 1024)")
    ap.add_argument("--frames", type=int, default=None,
                    help="frames per stream in the load section")
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: fewer frames and parity samples")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    kw: dict = {"out_json": args.out}
    if args.fast:
        kw.update(load_streams=(64,), load_frames=2, parity_streams=2)
    if args.streams is not None:
        kw["load_streams"] = tuple(args.streams)
    if args.frames is not None:
        kw["load_frames"] = args.frames
    run(**kw)


if __name__ == "__main__":
    main()
