"""Table III + Fig 16 + §IV-D/E — throughput / bandwidth / energy accounting
of the accelerator, from the analytic hardware model (core/energy.py):

  * peak 576 GOPS dense, 1093 GOPS effective with weight sparsity,
  * −47.3% computing latency from zero-weight skipping → 29 fps @1024×576,
  * DRAM bandwidth 5.6 GB/s (within DDR3's 12.8),
  * DRAM traffic 188.9/3.3/1.3 MB per frame (36 KB input SRAM) →
    5.456 MB input with 81 KB SRAM; 108.38 → 5.64 mJ DRAM energy,
  * core 1.05 mJ/frame.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import energy as en
from repro.models import snn_yolo as sy


def run() -> dict:
    cfg = get_config("snn-det")
    specs = sy.layer_specs(cfg)

    lat_dense = en.network_latency_s(specs, sparse=False)
    lat_sparse = en.network_latency_s(specs, sparse=True)
    fps = 1.0 / lat_sparse
    t36 = en.network_traffic(specs, sram_bits_per_pixel=en.SRAM_36KB_BITS_PER_PIXEL)
    t81 = en.network_traffic(specs, sram_bits_per_pixel=en.SRAM_81KB_BITS_PER_PIXEL)
    bw = t36.total_mb * 1e6 * fps / 1e9  # GB/s at the achieved frame rate
    core_mj = en.core_energy_mj_per_frame(specs)

    out = {
        "peak_gops_dense": en.peak_gops(),
        "peak_gops_sparse": en.peak_gops(sparse_speedup=1 / (1 - 0.473)),
        "latency_saving": 1 - lat_sparse / lat_dense,
        "fps": fps,
        "dram_mb_36k": {"input": t36.input_mb, "output": t36.output_mb, "param": t36.param_mb},
        "dram_mb_81k_input": t81.input_mb,
        "dram_energy_mj_36k": t36.dram_energy_mj(),
        "dram_energy_mj_81k": t81.dram_energy_mj(),
        "bandwidth_gbps": bw,
        "core_mj_per_frame": core_mj,
        "paper": {
            "peak_gops": (576, 1093), "latency_saving": 0.473, "fps": 29,
            "dram_mb": (188.928, 3.327, 1.292), "input_81k": 5.456,
            "dram_mj": (108.38, 5.64), "bandwidth_gbps": 5.6, "core_mj": 1.05,
        },
    }
    print("Table III / Fig 16 / §IV-D-E — hardware accounting")
    print(f"  peak GOPS      : {out['peak_gops_dense']:.0f} dense / "
          f"{out['peak_gops_sparse']:.0f} effective (paper 576 / 1093)")
    print(f"  latency saving : {out['latency_saving']*100:.1f}% (paper 47.3%)")
    print(f"  frame rate     : {out['fps']:.1f} fps (paper 29)")
    d = out["dram_mb_36k"]
    print(f"  DRAM/frame 36KB: in {d['input']:.1f} / out {d['output']:.2f} / "
          f"par {d['param']:.2f} MB (paper 188.9/3.3/1.3)")
    print(f"  input @81KB    : {out['dram_mb_81k_input']:.2f} MB (paper 5.456)")
    print(f"  DRAM energy    : {out['dram_energy_mj_36k']:.1f} -> "
          f"{out['dram_energy_mj_81k']:.2f} mJ (paper 108.38 -> 5.64)")
    print(f"  bandwidth      : {out['bandwidth_gbps']:.1f} GB/s (paper 5.6)")
    print(f"  core energy    : {out['core_mj_per_frame']:.2f} mJ/frame (paper 1.05)")
    return out


if __name__ == "__main__":
    run()
