"""End-to-end detector benchmark across conv executors (the executor
pipeline's acceptance harness).

Runs the full `snn_yolo` forward — encode, conv block, all five CSP stages,
head, at the (1, full_t) mixed time-step schedule — once per registered
executor (dense oracle / gated shift-accumulate reference / pallas
compressed kernel), asserts numerical parity against the dense oracle, and
writes ``BENCH_e2e.json`` with per-executor wall-clock, accumulate counts
(the paper's −47.3% op story) and compressed weight traffic (the −59.1%
Fig 17 story).

The default config is a reduced-resolution replica of the paper topology
(all layers, tiny spatial extent) so the interpret-mode Pallas kernel stays
tractable on CPU; pass a full config on real TPU hardware.

  PYTHONPATH=src python -m benchmarks.e2e_detector
"""
from __future__ import annotations

import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pruning
from repro.models import snn_yolo as sy

# executors accumulate in the integer domain and scale once, so parity vs
# the dense oracle is BIT-EXACT (tests/conformance/ enforces the same)
PARITY_ATOL = 0.0
EXECUTORS = ("dense", "gated", "pallas")
# wall_s is the MEDIAN of this many timed calls: the dense forward at the
# reduced scale runs in single-digit ms, where a one-shot sample is timer
# noise — and the CI regression gate consumes this number
N_TIMING_RUNS = 5


def reduced_config() -> sy.SNNDetConfig:
    """Paper topology (all macro layers, 5 CSP stages, mixed (1,3) time
    steps) at a spatial scale the interpreted kernel can sweep on CPU."""
    from repro.configs import get_config, smoke_config

    return dataclasses.replace(
        smoke_config(get_config("snn-det")), arch_id="snn-det-e2e",
        use_block_conv=True,
    )


def _accumulates(cfg, plan, *, sparse: bool) -> int:
    """Accumulate ops per frame under the gated dataflow: nnz × spatial ×
    input time steps × bit-serial planes (dense executors visit every
    weight instead)."""
    total = 0
    for spec in sy.layer_specs(cfg, pruned_density=1.0):
        nnz = plan.layers[spec.name].nnz if sparse else spec.params
        total += nnz * spec.h * spec.w * spec.t_in * spec.bits_in
    return total


def run(cfg: sy.SNNDetConfig | None = None, *, prune_rate: float = 0.8,
        batch: int = 1, out_json: str = "BENCH_e2e.json") -> dict:
    cfg = cfg or reduced_config()
    params, bn = sy.init_params(jax.random.PRNGKey(0), cfg)
    # prune ONCE and hand the identical tree to every executor — parity is
    # then purely about the conv dataflow, not the compression choices
    params = pruning.prune_tree(params, prune_rate)
    rng = np.random.default_rng(0)
    h, w = cfg.input_hw
    # uint8-grid images: the 8-bit bit-serial encode path is then exact
    imgs = jnp.asarray(rng.integers(0, 256, (batch, h, w, 3)) / 255.0, jnp.float32)
    # calibrated tdBN stats: fresh (0, 1) stats silence the deep layers of
    # an untrained net, which would make the parity sweep (and the reported
    # detection counts) vacuously zero past the first two layers
    bn = sy.calibrate_bn_state(params, bn, imgs, cfg)

    results: dict = {
        "config": {
            "input_hw": list(cfg.input_hw),
            "block_hw": list(cfg.block_hw),
            "full_t": cfg.full_t,
            "prune_rate": prune_rate,
            "batch": batch,
        },
        "executors": {},
    }
    heads = {}
    plan = None
    for ex in EXECUTORS:
        # the compile-once handle owns the plan + jitted forward + postprocess
        det = sy.compile_detector(dataclasses.replace(cfg, conv_exec=ex), params, bn)
        plan = det.plan
        dets, head = det.detect(imgs)  # warm caches
        head.block_until_ready()
        walls = []
        for _ in range(N_TIMING_RUNS):
            t0 = time.perf_counter()
            dets, head = det.detect(imgs)
            head.block_until_ready()
            walls.append(time.perf_counter() - t0)
        wall = float(np.median(walls))
        heads[ex] = np.asarray(head)
        diff = float(np.abs(heads[ex] - heads["dense"]).max())
        sparse = ex != "dense"
        results["executors"][ex] = {
            "wall_s": wall,
            "max_abs_diff_vs_dense": diff,
            "accumulates": _accumulates(cfg, det.plan, sparse=sparse),
            "detections": [int(c) for c in np.asarray(dets.count)],
        }
        print(f"  {ex:7s}  wall {wall:8.3f}s  max|Δ| vs dense {diff:.2e}  "
              f"accumulates {results['executors'][ex]['accumulates']:,}")
        assert diff <= PARITY_ATOL, f"{ex} diverges from dense oracle: {diff}"

    dense_b, comp_b = plan.dense_bytes, plan.compressed_bytes
    results["weight_bytes"] = {
        "dense": dense_b,
        "compressed": comp_b,
        "saving_frac": 1.0 - comp_b / max(dense_b, 1),
    }
    acc_d = results["executors"]["dense"]["accumulates"]
    acc_s = results["executors"]["pallas"]["accumulates"]
    results["accumulate_saving_frac"] = 1.0 - acc_s / max(acc_d, 1)
    print(f"  weight traffic: {comp_b}/{dense_b} B "
          f"(−{100 * results['weight_bytes']['saving_frac']:.1f}%)  "
          f"accumulates −{100 * results['accumulate_saving_frac']:.1f}%")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=1)
        print(f"  wrote {out_json}")
    return results


if __name__ == "__main__":
    run()
