"""End-to-end detector benchmark across conv executors (the executor
pipeline's acceptance harness).

Runs the full `snn_yolo` forward — encode, conv block, all five CSP stages,
head, at the (1, full_t) mixed time-step schedule — once per registered
executor (dense oracle / gated shift-accumulate reference / pallas
compressed kernel), asserts numerical parity against the dense oracle, and
writes ``BENCH_e2e.json`` with per-executor wall-clock, accumulate counts
(the paper's −47.3% op story) and compressed weight traffic (the −59.1%
Fig 17 story).

The default config is a reduced-resolution replica of the paper topology
(all layers, tiny spatial extent) so the interpret-mode Pallas kernel stays
tractable on CPU; pass a full config on real TPU hardware.

  PYTHONPATH=src python -m benchmarks.e2e_detector
  PYTHONPATH=src python -m benchmarks.e2e_detector \
      --input-hw 96x128 --out BENCH_e2e_96x128.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pruning
from repro.models import snn_yolo as sy

# executors accumulate in the integer domain and scale once, so parity vs
# the dense oracle is BIT-EXACT (tests/conformance/ enforces the same)
PARITY_ATOL = 0.0
EXECUTORS = ("dense", "gated", "pallas")
# wall_s is the MEDIAN of this many timed calls: the dense forward at the
# reduced scale runs in single-digit ms, where a one-shot sample is timer
# noise — and the CI regression gate consumes this number. The reps are
# INTERLEAVED round-robin across executors (A/B/C, A/B/C, ...) so scheduler
# drift and frequency excursions land on every executor equally instead of
# biasing whichever phase they fall into. At the default config a detect()
# is ~1 ms, so 200 reps cost ~1 s and pin the median tightly enough to
# resolve the few-percent executor gaps the gate cares about.
N_TIMING_RUNS = 200


def reduced_config(input_hw: tuple[int, int] | None = None) -> sy.SNNDetConfig:
    """Paper topology (all macro layers, 5 CSP stages, mixed (1,3) time
    steps) at a spatial scale the interpreted kernel can sweep on CPU.

    ``input_hw`` overrides the spatial extent (e.g. ``(96, 128)`` for the
    larger checked-in config); the 6×8 block grid divides any multiple of
    the default 24×32, so the blocked executors stay valid unchanged."""
    from repro.configs import get_config, smoke_config

    cfg = dataclasses.replace(
        smoke_config(get_config("snn-det")), arch_id="snn-det-e2e",
        use_block_conv=True,
    )
    if input_hw is not None:
        h, w = input_hw
        bh, bw = cfg.block_hw
        if h % (cfg.input_hw[0]) or w % (cfg.input_hw[1]):
            raise ValueError(
                f"--input-hw {h}x{w} must be a multiple of the reduced base "
                f"{cfg.input_hw[0]}x{cfg.input_hw[1]} so the {bh}x{bw} block "
                "grid keeps dividing every stage's feature map")
        cfg = dataclasses.replace(cfg, input_hw=(h, w))
    return cfg


def _accumulates(cfg, plan, *, sparse: bool) -> int:
    """Accumulate ops per frame under the gated dataflow: nnz × spatial ×
    input time steps × bit-serial planes (dense executors visit every
    weight instead)."""
    total = 0
    for spec in sy.layer_specs(cfg, pruned_density=1.0):
        nnz = plan.layers[spec.name].nnz if sparse else spec.params
        total += nnz * spec.h * spec.w * spec.t_in * spec.bits_in
    return total


def run(cfg: sy.SNNDetConfig | None = None, *, prune_rate: float = 0.8,
        batch: int = 1, out_json: str = "BENCH_e2e.json") -> dict:
    cfg = cfg or reduced_config()
    params, bn = sy.init_params(jax.random.PRNGKey(0), cfg)
    # prune ONCE and hand the identical tree to every executor — parity is
    # then purely about the conv dataflow, not the compression choices
    params = pruning.prune_tree(params, prune_rate)
    rng = np.random.default_rng(0)
    h, w = cfg.input_hw
    # uint8-grid images: the 8-bit bit-serial encode path is then exact
    imgs = jnp.asarray(rng.integers(0, 256, (batch, h, w, 3)) / 255.0, jnp.float32)
    # calibrated tdBN stats: fresh (0, 1) stats silence the deep layers of
    # an untrained net, which would make the parity sweep (and the reported
    # detection counts) vacuously zero past the first two layers
    bn = sy.calibrate_bn_state(params, bn, imgs, cfg)

    results: dict = {
        "config": {
            "input_hw": list(cfg.input_hw),
            "block_hw": list(cfg.block_hw),
            "full_t": cfg.full_t,
            "prune_rate": prune_rate,
            "batch": batch,
        },
        "executors": {},
    }
    heads = {}
    outs = {}
    plan = None
    detectors = {}
    for ex in EXECUTORS:
        # the compile-once handle owns the plan + jitted forward + postprocess
        det = sy.compile_detector(dataclasses.replace(cfg, conv_exec=ex), params, bn)
        detectors[ex] = det
        plan = det.plan
        dets, head = det.detect(imgs)  # warm caches
        head.block_until_ready()
        outs[ex] = dets
        heads[ex] = np.asarray(head)
    walls: dict = {ex: [] for ex in EXECUTORS}
    for _ in range(N_TIMING_RUNS):
        for ex, det in detectors.items():
            t0 = time.perf_counter()
            _, head = det.detect(imgs)
            head.block_until_ready()
            walls[ex].append(time.perf_counter() - t0)
    for ex in EXECUTORS:
        wall = float(np.median(walls[ex]))
        diff = float(np.abs(heads[ex] - heads["dense"]).max())
        sparse = ex != "dense"
        results["executors"][ex] = {
            "wall_s": wall,
            "max_abs_diff_vs_dense": diff,
            "accumulates": _accumulates(cfg, detectors[ex].plan, sparse=sparse),
            "detections": [int(c) for c in np.asarray(outs[ex].count)],
        }
        print(f"  {ex:7s}  wall {wall:8.3f}s  max|Δ| vs dense {diff:.2e}  "
              f"accumulates {results['executors'][ex]['accumulates']:,}")
        assert diff <= PARITY_ATOL, f"{ex} diverges from dense oracle: {diff}"

    dense_b, comp_b = plan.dense_bytes, plan.compressed_bytes
    results["weight_bytes"] = {
        "dense": dense_b,
        "compressed": comp_b,
        "saving_frac": 1.0 - comp_b / max(dense_b, 1),
    }
    acc_d = results["executors"]["dense"]["accumulates"]
    acc_s = results["executors"]["pallas"]["accumulates"]
    results["accumulate_saving_frac"] = 1.0 - acc_s / max(acc_d, 1)
    print(f"  weight traffic: {comp_b}/{dense_b} B "
          f"(−{100 * results['weight_bytes']['saving_frac']:.1f}%)  "
          f"accumulates −{100 * results['accumulate_saving_frac']:.1f}%")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=1)
        print(f"  wrote {out_json}")
    return results


def _parse_hw(text: str) -> tuple[int, int]:
    parts = text.replace(",", "x").lower().split("x")
    if len(parts) != 2:
        raise argparse.ArgumentTypeError(f"expected HxW, got {text!r}")
    return int(parts[0]), int(parts[1])


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--input-hw", type=_parse_hw, default=None,
                    metavar="HxW",
                    help="input resolution override, e.g. 96x128 "
                    "(default: the reduced 24x32 config)")
    ap.add_argument("--prune-rate", type=float, default=0.8)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--out", default=None,
                    help="output JSON path (default BENCH_e2e.json, or "
                    "BENCH_e2e_<HxW>.json when --input-hw is given)")
    args = ap.parse_args(argv)
    out = args.out
    if out is None:
        out = ("BENCH_e2e.json" if args.input_hw is None else
               "BENCH_e2e_{}x{}.json".format(*args.input_hw))
    return run(reduced_config(args.input_hw), prune_rate=args.prune_rate,
               batch=args.batch, out_json=out)


if __name__ == "__main__":
    main()
