"""Accuracy benchmark: the scaled-down Table I / Fig 15 reproduction.

Drives ``repro.eval.harness.run_pipeline`` — train float → prune 80% →
QAT fine-tune → evaluate — and writes ``BENCH_eval.json`` with mAP@0.5
per stage, the mixed (1,3) vs uniform T=3 schedule comparison, and the
worst-case accumulator magnitude vs the 16-bit claim.

At the demonstration scale (the defaults: ~3500 train steps, about an
hour on a 2-core CPU) the trained detector clears mAP@0.5 > 0.3 on
the synthetic val split; ``--fast`` runs a minutes-scale smoke version
whose numbers are NOT representative (expect mAP ≈ 0).

  PYTHONPATH=src python -m benchmarks.eval_map [--fast]
"""
from __future__ import annotations

import argparse
import json


def run(*, steps: int = 3500, finetune_steps: int = 600, batch: int = 6,
        eval_images: int = 48, out_json: str = "BENCH_eval.json") -> dict:
    from repro.eval import harness

    report = harness.run_pipeline(
        steps=steps, finetune_steps=finetune_steps, batch=batch,
        eval_images=eval_images, verbose=True,
    )
    s = report.summary()
    results = {
        "config": {
            "steps": steps, "finetune_steps": finetune_steps, "batch": batch,
            "eval_images": eval_images,
        },
        **s,
        "stages": {
            k: {kk: v[kk] for kk in ("map", "per_class_ap", "n_gt", "n_images")}
            for k, v in report.stages.items()
        },
        "final_loss": {k: v[-1] for k, v in report.losses.items() if v},
    }
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=1)
        print(f"  wrote {out_json}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smoke-scale (minutes; mAP not representative)")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args(argv)
    if args.fast:
        run(steps=args.steps or 60, finetune_steps=20, batch=4, eval_images=8)
    else:
        run(steps=args.steps or 3500)


if __name__ == "__main__":
    main()
