"""Accuracy benchmark: the scaled-down Table I / Fig 15 reproduction.

Drives ``repro.eval.harness.run_pipeline`` — train float → prune 80% →
QAT fine-tune → evaluate — and writes ``BENCH_eval.json`` with mAP@0.5
per stage, the mixed (1,3) vs uniform T=3 schedule comparison, and the
worst-case accumulator magnitude vs the 16-bit claim.

At the demonstration scale (the defaults: ~3500 train steps, about an
hour on a 2-core CPU) the trained detector clears mAP@0.5 > 0.3 on
the synthetic val split; ``--fast`` runs a minutes-scale smoke version
whose numbers are NOT representative (expect mAP ≈ 0).

``--shards K`` routes every stage evaluation through the mesh-sharded
path (``repro.eval.sharded``) and then re-scores the final weights
single-host, FAILING the run unless the two reports are bit-identical —
the acceptance gate the ``sharded-eval-sim`` CI lane runs under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

``--dataset coco:<json>|voc:<dir>`` swaps the synthetic split for real
annotated frames (``repro.data.detection_datasets``); ``--ckpt-dir``
commits detector checkpoints after the train and QAT stages for
``launch/serve.py --checkpoint`` to restore.

``--checkpoint <dir>`` skips training entirely and scores a saved
detector directly (``harness.restore_detector_checkpoint`` — any
committed detector checkpoint: trained, QAT'd, or ANN→SNN converted via
``repro.convert``). Composes with ``--shards`` (parity gate included)
and ``--dataset``; ``--fast`` only trims the image count.

  PYTHONPATH=src python -m benchmarks.eval_map [--fast] [--shards 4]
      [--dataset coco:tests/fixtures/coco_fixture/instances.json]
      [--ckpt-dir /tmp/snn_det_ckpt] [--out-json BENCH_eval.json]
  PYTHONPATH=src python -m benchmarks.eval_map --checkpoint /tmp/converted
"""
from __future__ import annotations

import argparse
import json


def run_checkpoint(ckpt: str, *, eval_images: int = 48, shards: int = 1,
                   dataset: str = "synthetic",
                   out_json: str = "BENCH_eval_ckpt.json") -> dict:
    """Score a saved detector checkpoint — no training anywhere."""
    from repro.data import detection_datasets as dd
    from repro.eval import harness

    from repro.distributed import runtime

    source = dd.parse_dataset_spec(dataset)
    ctx = runtime.get_context()
    cfg, params, bn, step = harness.restore_detector_checkpoint(ckpt)
    det = harness.compile_eval_detector(cfg, params, bn)
    rep = harness.evaluate_detector(
        det, n_images=eval_images, source=source,
        sharded=shards if shards > 1 else None, ctx=ctx,
    )
    print(f"  checkpoint {ckpt} (step {step}, arch {cfg.arch_id}): "
          f"mAP@0.5 {rep['map']:.4f} on {rep['n_images']} images")
    results = {
        "config": {
            "checkpoint": ckpt, "step": step, "arch_id": cfg.arch_id,
            "eval_images": eval_images, "eval_shards": shards,
            "dataset": dataset,
        },
        "map": rep["map"],
        "per_class_ap": rep["per_class_ap"],
        "n_gt": rep["n_gt"],
        "n_images": rep["n_images"],
    }
    if shards > 1:
        from repro.eval.sharded import reports_identical

        single = harness.evaluate_detector(
            det, n_images=eval_images, source=source
        )
        identical = reports_identical(rep, single)
        results["sharded_parity"] = {
            "n_shards": shards,
            "map_sharded": rep["map"],
            "map_single_host": single["map"],
            "bit_identical": identical,
        }
        if not identical:
            raise SystemExit(
                f"sharded ({shards}-way) checkpoint mAP is not bit-identical "
                f"to single-host: {rep['map']!r} vs {single['map']!r}"
            )
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=1)
        print(f"  wrote {out_json}")
    return results


def run(*, steps: int = 3500, finetune_steps: int = 600, batch: int = 6,
        eval_images: int = 48, shards: int = 1, dataset: str = "synthetic",
        ckpt_dir: str = None, out_json: str = "BENCH_eval.json") -> dict:
    from repro.data import detection_datasets as dd
    from repro.distributed import runtime
    from repro.eval import harness

    source = dd.parse_dataset_spec(dataset)
    report = harness.run_pipeline(
        steps=steps, finetune_steps=finetune_steps, batch=batch,
        eval_images=eval_images, eval_shards=shards, source=source,
        ckpt_dir=ckpt_dir, verbose=True, ctx=runtime.get_context(),
    )
    s = report.summary()
    results = {
        "config": {
            "steps": steps, "finetune_steps": finetune_steps, "batch": batch,
            "eval_images": eval_images, "eval_shards": shards,
            "dataset": dataset, "ckpt_dir": ckpt_dir,
        },
        **s,
        "stages": {
            k: {kk: v[kk] for kk in ("map", "per_class_ap", "n_gt", "n_images")}
            for k, v in report.stages.items()
        },
        "final_loss": {k: v[-1] for k, v in report.losses.items() if v},
    }
    if shards > 1:
        from repro.eval.sharded import reports_identical

        # the acceptance gate: the sharded pipeline numbers above must be
        # bit-identical to a single-host re-score of the same final weights
        sharded_rep = report.stages["qat"]
        single_rep = harness.evaluate_detector(
            report.final_det, n_images=eval_images, source=source
        )
        identical = reports_identical(sharded_rep, single_rep)
        results["sharded_parity"] = {
            "n_shards": shards,
            "gather": sharded_rep.get("gather"),
            "map_sharded": sharded_rep["map"],
            "map_single_host": single_rep["map"],
            "bit_identical": identical,
        }
        print(f"  sharded parity [{shards} shards, "
              f"{sharded_rep.get('gather')} gather]: "
              f"mAP {sharded_rep['map']:.6f} vs single-host "
              f"{single_rep['map']:.6f} — "
              f"{'BIT-IDENTICAL' if identical else 'MISMATCH'}")
        if not identical:
            raise SystemExit(
                f"sharded ({shards}-way) mAP is not bit-identical to the "
                f"single-host evaluation: {sharded_rep['map']!r} vs "
                f"{single_rep['map']!r}"
            )
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=1)
        print(f"  wrote {out_json}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smoke-scale (minutes; mAP not representative)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--shards", type=int, default=1,
                    help="evaluation shard count (mesh-sharded mAP; "
                    "asserts bit-identical parity vs single-host)")
    ap.add_argument("--dataset", default="synthetic",
                    help="train/eval data: synthetic | coco:<instances."
                         "json> | voc:<dir> (repro.data.detection_datasets)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="commit detector checkpoints (post-train and "
                         "post-QAT) here; launch/serve.py --checkpoint "
                         "restores them")
    ap.add_argument("--checkpoint", default=None,
                    help="score this saved detector checkpoint directly "
                         "(no training); any committed detector checkpoint "
                         "works, including repro.convert output")
    ap.add_argument("--out-json", default="BENCH_eval.json",
                    help="result file ('' skips writing — CI smoke runs "
                         "that must not clobber the checked-in numbers)")
    args = ap.parse_args(argv)
    if args.checkpoint:
        out = (args.out_json if args.out_json != "BENCH_eval.json"
               else "BENCH_eval_ckpt.json")
        run_checkpoint(
            args.checkpoint, shards=args.shards, dataset=args.dataset,
            eval_images=8 if args.fast else 48, out_json=out,
        )
        return
    kw = dict(shards=args.shards, dataset=args.dataset,
              ckpt_dir=args.ckpt_dir, out_json=args.out_json)
    if args.fast:
        run(steps=args.steps or 60, finetune_steps=20, batch=4,
            eval_images=8, **kw)
    else:
        run(steps=args.steps or 3500, **kw)


if __name__ == "__main__":
    main()
