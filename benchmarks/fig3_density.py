"""Fig 3 — per-layer density of the pruned weights.

Runs REAL magnitude pruning (80% global on 3x3 kernels, core/pruning.py) on
the initialized detector and reports per-layer density; the qualitative
shape must match the paper: early small layers keep most weights, late
large layers are pruned hardest.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_config
from repro.core import pruning
from repro.models import snn_yolo as sy


def run(rate: float = 0.8) -> dict:
    cfg = get_config("snn-det")
    params, _ = sy.init_params(jax.random.PRNGKey(0), cfg)
    pruned = pruning.prune_tree(params, rate=rate)
    print(f"Fig 3 — post-pruning 3x3 density per layer (global rate {rate:.0%})")
    out = {}
    for name in params:
        w = params[name].get("w") if isinstance(params[name], dict) else None
        if w is None or w.ndim != 4 or w.shape[0] != 3:
            continue
        d = pruning.density(pruned[name]["w"])
        out[name] = float(d)
        bar = "#" * int(d * 40)
        print(f"  {name:22s} {d*100:5.1f}%  {bar}")
    first = [v for k, v in out.items() if "encode" in k or "conv_block" in k or "stage0" in k]
    last = [v for k, v in out.items() if "stage3" in k or "stage4" in k]
    out["_monotone"] = bool(np.mean(first) > np.mean(last))
    print(f"early-vs-late density: {np.mean(first):.2f} vs {np.mean(last):.2f} "
          f"(paper Fig 3 shape: early >> late) -> {'OK' if out['_monotone'] else 'MISMATCH'}")
    return out


if __name__ == "__main__":
    run()
