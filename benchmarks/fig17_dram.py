"""Fig 17 — DRAM access amount of the network parameters, by storage format
(original dense / CSR / bit-mask). Paper: bit-mask saves 59.1% vs dense and
16.4% vs CSR at the pruned network's sparsity.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.models import snn_yolo as sy


def run() -> dict:
    cfg = get_config("snn-det")
    specs = sy.layer_specs(cfg)
    from repro.core import energy as en

    fmt_mb = {
        fmt: sum(en.param_dram_bytes(s, fmt) for s in specs) / 1e6
        for fmt in ("dense", "csr", "bitmask")
    }
    vs_dense = 1 - fmt_mb["bitmask"] / fmt_mb["dense"]
    vs_csr = 1 - fmt_mb["bitmask"] / fmt_mb["csr"]
    print("Fig 17 — parameter DRAM traffic by format (MB/frame)")
    for fmt, v in fmt_mb.items():
        print(f"  {fmt:8s} {v:6.3f} MB")
    print(f"bitmask vs dense: -{vs_dense*100:.1f}% (paper -59.1%) | "
          f"vs CSR: -{vs_csr*100:.1f}% (paper -16.4%)")
    return {**fmt_mb, "vs_dense": vs_dense, "vs_csr": vs_csr}


if __name__ == "__main__":
    run()
