# Developer entry points. Everything runs with PYTHONPATH=src (the tier-1
# contract in ROADMAP.md).
PY ?= python
PYTHONPATH := src

.PHONY: test regen-goldens check-goldens check-autotune bench-regression sharded-eval-sim distributed-smoke

# tier-1 suite
test:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m pytest -x -q

# Regenerate BOTH derived fixture sets together — the conformance golden
# (tests/conformance/fixtures/) and the pinned synthetic-data checksums
# (tests/fixtures/data_checksums.json). Run ONLY on an intentional
# numerics/data change, then commit both. The golden-regen CI job runs
# check-goldens and fails on any half-updated state.
regen-goldens:
	PYTHONPATH=$(PYTHONPATH) $(PY) scripts/regen_goldens.py

check-goldens:
	PYTHONPATH=$(PYTHONPATH) $(PY) scripts/regen_goldens.py --check

# The committed autotune cache must COVER every fused layer shape of the
# benchmarked configs (default 24x32 + the large-input 96x128) — lookups
# for uncovered shapes silently fall back to the untuned default, which
# is bit-identical but forfeits the tuned crossover. Fails on a stale
# (version-bumped) cache too, since that loads as empty. Regenerate with:
#   PYTHONPATH=src python -m repro.kernels.autotune --input-hw 96x128
check-autotune:
	JAX_PLATFORMS=cpu PYTHONPATH=$(PYTHONPATH) \
		$(PY) -m repro.kernels.autotune --check --input-hw 96x128

# Compare fresh BENCH_*.json against baselines (default: the checked-in
# copies snapshotted by CI before the benchmark run); fails on >20%
# throughput regression. BASELINE_DIR must hold the baseline copies.
BASELINE_DIR ?= .bench-baseline
bench-regression:
	$(PY) scripts/bench_regression.py --baseline-dir $(BASELINE_DIR)

# The sharded-evaluation CI lane, runnable locally: 8 simulated CPU
# devices, the shard-reduction tests, and the 4-shard vs single-host
# bit-identical parity gate.
sharded-eval-sim:
	JAX_PLATFORMS=cpu XLA_FLAGS=--xla_force_host_platform_device_count=8 \
		PYTHONPATH=$(PYTHONPATH) $(PY) -m pytest tests/test_sharded_eval.py -q
	JAX_PLATFORMS=cpu XLA_FLAGS=--xla_force_host_platform_device_count=8 \
		PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.eval_map --fast --shards 4

# The multi-CONTROLLER lane, runnable locally: each test spawns a REAL
# 2-process jax.distributed job (local coordinator, gloo CPU collectives,
# one device per process) and gates eval-mAP bit-parity, data-parallel
# train-loss parity, and the 2-host-save -> 1-host-restore checkpoint
# round-trip against single-host references.
distributed-smoke:
	JAX_PLATFORMS=cpu PYTHONPATH=$(PYTHONPATH) \
		$(PY) -m pytest tests/test_multihost.py -q
