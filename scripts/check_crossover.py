"""Large-input crossover gate: pallas must beat dense at scale.

The macro-tiled fused pipeline exists to amortize per-grid-step overhead
at LARGE inputs; ``BENCH_e2e_96x128.json`` records the crossover. This
gate keeps it from silently regressing: it fails when the pallas
executor's wall-clock exceeds dense's (beyond ``--tolerance`` headroom
for shared-runner noise) in a freshly regenerated benchmark file, and
also re-asserts the bit-exactness contract (``max_abs_diff_vs_dense``
must be 0.0 for every executor — a fast-but-wrong kernel is worse than a
slow one).

Both walls come from the SAME interleaved median-of-200 run, so the
comparison is relative and much less noisy than cross-machine absolute
thresholds — but sub-``--min-seconds`` walls are still timer noise and
skip the check rather than flake it.

    python scripts/check_crossover.py [--file BENCH_e2e_96x128.json]
"""
from __future__ import annotations

import argparse
import json
import sys


def check(data: dict, *, tolerance: float, min_seconds: float) -> tuple[bool, str]:
    """(ok, message) for one BENCH_e2e-style payload."""
    execs = data.get("executors", {})
    for ex, r in execs.items():
        diff = r.get("max_abs_diff_vs_dense")
        if diff is None or diff != 0.0:
            return False, f"{ex}: max_abs_diff_vs_dense={diff!r}, expected 0.0"
    dense = execs.get("dense", {}).get("wall_s")
    pallas = execs.get("pallas", {}).get("wall_s")
    if not dense or not pallas:
        return False, f"missing dense/pallas wall_s (dense={dense}, pallas={pallas})"
    if dense < min_seconds and pallas < min_seconds:
        return True, (f"skipped: walls below timing resolution "
                      f"(dense={dense*1e3:.3f}ms, pallas={pallas*1e3:.3f}ms "
                      f"< {min_seconds*1e3:.0f}ms)")
    ratio = pallas / dense
    msg = (f"dense={dense*1e3:.3f}ms pallas={pallas*1e3:.3f}ms "
           f"(pallas/dense={ratio:.3f}x, tolerance {1 + tolerance:.2f}x)")
    if ratio > 1 + tolerance:
        return False, "pallas slower than dense: " + msg
    return True, "crossover holds: " + msg


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--file", default="BENCH_e2e_96x128.json",
                    help="freshly regenerated large-input benchmark JSON")
    ap.add_argument("--tolerance", type=float, default=0.05,
                    help="fractional headroom before pallas > dense fails "
                    "(same-run medians still jitter a few %% on shared "
                    "runners)")
    ap.add_argument("--min-seconds", type=float, default=0.001,
                    help="skip the wall comparison when BOTH walls are "
                    "below this (sub-ms medians are timer noise)")
    args = ap.parse_args(argv)

    try:
        with open(args.file) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"crossover gate: cannot read {args.file}: {e}")
        return 1
    ok, msg = check(data, tolerance=args.tolerance,
                    min_seconds=args.min_seconds)
    print(f"crossover gate [{args.file}]: {msg}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
