"""Benchmark-regression gate: fresh BENCH_*.json vs checked-in baselines.

CI snapshots the checked-in BENCH files into a baseline dir BEFORE the
benchmark jobs overwrite them, then runs this script, which prints a delta
table for every throughput metric and exits 1 if any regresses by more
than ``--threshold`` (default 20%).

Throughput metrics per file (direction-normalized so a ratio < 1 is
always "slower"):

* ``BENCH_e2e.json``   — per-executor 1/wall_s
* ``BENCH_e2e_96x128.json`` — same metrics at the larger 96×128 input
* ``BENCH_serve.json`` — per-executor frames_per_s, plus the load
  generator's per-stream fps and 1/p50/p95/p99 tick latency per
  concurrent-stream count (latency inverted so ratio < 1 is "slower")
* ``BENCH_eval.json``  — 1/wall_s of the whole accuracy pipeline

A file is only compared when its recorded ``config`` matches the
baseline's — the checked-in BENCH_eval comes from the demonstration-scale
run, while CI regenerates ``--fast``; comparing those walls would be
noise, so mismatched configs are reported and skipped, never failed.

When ``$GITHUB_STEP_SUMMARY`` is set (every GitHub Actions step), the
same deltas are also appended there as a markdown table — baseline vs
fresh throughput per metric with the percent change — so the review UI
shows the numbers without digging through logs.

    python scripts/bench_regression.py --baseline-dir .bench-baseline \
        [--fresh-dir .] [--threshold 0.2] [--files BENCH_e2e.json ...]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_FILES = ("BENCH_e2e.json", "BENCH_e2e_96x128.json",
                 "BENCH_serve.json", "BENCH_eval.json")


def _throughputs(name: str, data: dict, min_seconds: float) -> tuple:
    """Flatten one BENCH file to {metric: throughput} (higher = faster).
    Wall-clock metrics shorter than ``min_seconds`` are noise-dominated
    (a ms-scale sample swings far more than any threshold even on one
    machine) and returned separately as skipped."""
    out, skipped = {}, []
    if name.startswith("BENCH_e2e"):  # BENCH_e2e.json + BENCH_e2e_<HxW>.json
        for ex, r in data.get("executors", {}).items():
            if r.get("wall_s"):
                if r["wall_s"] < min_seconds:
                    skipped.append(f"{ex}.1/wall_s")
                else:
                    out[f"{ex}.1/wall_s"] = 1.0 / r["wall_s"]
    elif name == "BENCH_serve.json":
        for ex, r in data.get("executors", {}).items():
            if "frames_per_s" in r:
                out[f"{ex}.frames_per_s"] = r["frames_per_s"]
        for n, r in data.get("load", {}).items():
            if r.get("wall_s", 0) >= min_seconds:
                out[f"load.{n}.per_stream_fps"] = r["per_stream_fps"]
            else:
                skipped.append(f"load.{n}.per_stream_fps")
            for pct in ("p50", "p95", "p99"):
                key = f"tick_{pct}_ms"
                if not r.get(key):
                    continue
                # ms-scale ticks are timer noise, same floor as wall_s
                if r[key] < min_seconds * 1e3:
                    skipped.append(f"load.{n}.1/{key}")
                else:
                    out[f"load.{n}.1/{key}"] = 1.0 / r[key]
    elif name == "BENCH_eval.json":
        if data.get("wall_s"):
            if data["wall_s"] < min_seconds:
                skipped.append("pipeline.1/wall_s")
            else:
                out["pipeline.1/wall_s"] = 1.0 / data["wall_s"]
    return out, skipped


def compare(name: str, fresh: dict, base: dict, threshold: float,
            min_seconds: float) -> tuple:
    """(rows, skipped): rows of (metric, base_thpt, fresh_thpt, ratio,
    regressed); skipped metric names (below the timing floor in either
    run)."""
    f, f_skip = _throughputs(name, fresh, min_seconds)
    b, b_skip = _throughputs(name, base, min_seconds)
    skipped = sorted(set(f_skip) | set(b_skip))
    rows = []
    for metric in sorted(set(f) & set(b) - set(skipped)):
        ratio = f[metric] / b[metric]
        rows.append((metric, b[metric], f[metric], ratio, ratio < 1 - threshold))
    return rows, skipped


def write_step_summary(sections: list, threshold: float) -> None:
    """Append a markdown delta table per compared file to the GitHub
    Actions step summary (no-op outside Actions). ``sections`` is
    [(file, rows, skipped, note)] as accumulated by main() — rows are
    the compare() tuples, note is a skip reason when rows is empty."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    lines = [f"### Benchmark deltas (fail below −{threshold:.0%})", ""]
    for name, rows, skipped, note in sections:
        lines += [f"#### `{name}`", ""]
        if note:
            lines += [f"_{note}_", ""]
            continue
        lines += ["| metric | baseline | fresh | change | |",
                  "|---|---:|---:|---:|---|"]
        for metric, bv, fv, ratio, bad in rows:
            pct = (ratio - 1.0) * 100.0
            flag = "❌ regressed" if bad else ("⬆️" if pct > 0 else "")
            lines.append(f"| `{metric}` | {bv:.4g} | {fv:.4g} "
                         f"| {pct:+.1f}% | {flag} |")
        for metric in skipped:
            lines.append(f"| `{metric}` | — | — | — | skipped (below "
                         "timing floor) |")
        lines.append("")
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", required=True,
                    help="dir holding the pre-run (checked-in) BENCH copies")
    ap.add_argument("--fresh-dir", default=".",
                    help="dir holding the freshly-written BENCH files")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="max tolerated fractional throughput drop")
    ap.add_argument("--min-seconds", type=float, default=0.01,
                    help="skip wall-clock metrics shorter than this "
                    "(single-digit-ms samples are timer noise)")
    ap.add_argument("--files", nargs="*", default=list(DEFAULT_FILES))
    args = ap.parse_args(argv)

    failed = []
    compared_any = False
    sections = []  # (file, rows, skipped, skip-note) for the step summary
    for name in args.files:
        fresh_p = os.path.join(args.fresh_dir, name)
        base_p = os.path.join(args.baseline_dir, name)
        if not os.path.exists(fresh_p) or not os.path.exists(base_p):
            which = "fresh" if not os.path.exists(fresh_p) else "baseline"
            print(f"{name}: skipped (missing {which})")
            sections.append((name, [], [], f"skipped: missing {which} file"))
            continue
        with open(fresh_p) as f:
            fresh = json.load(f)
        with open(base_p) as f:
            base = json.load(f)
        if fresh.get("config") != base.get("config"):
            print(f"{name}: skipped (config mismatch — fresh "
                  f"{fresh.get('config')} vs baseline {base.get('config')})")
            sections.append((name, [], [],
                             "skipped: config mismatch vs baseline"))
            continue
        rows, skipped = compare(name, fresh, base, args.threshold,
                                args.min_seconds)
        if not rows and not skipped:
            print(f"{name}: no comparable throughput metrics")
            sections.append((name, [], [], "no comparable throughput metrics"))
            continue
        sections.append((name, rows, skipped, None))
        print(f"\n{name} (threshold −{args.threshold:.0%}):")
        print(f"  {'metric':28s} {'baseline':>12s} {'fresh':>12s} "
              f"{'ratio':>7s}")
        for metric, bv, fv, ratio, bad in rows:
            compared_any = True
            flag = "  REGRESSED" if bad else ""
            print(f"  {metric:28s} {bv:12.4g} {fv:12.4g} {ratio:6.2f}x{flag}")
            if bad:
                failed.append(f"{name}:{metric} ({ratio:.2f}x)")
        for metric in skipped:
            print(f"  {metric:28s} skipped (wall < {args.min_seconds}s: "
                  "below timing resolution)")
    print()
    write_step_summary(sections, args.threshold)
    if failed:
        print(f"throughput regression > {args.threshold:.0%}: "
              + ", ".join(failed))
        return 1
    print("bench regression gate: "
          + ("OK" if compared_any else "nothing comparable (all skipped)"))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
