"""Regenerate the committed pretrained-ANN fixture
(tests/fixtures/ann_detector/ann_tiny_yolo.npz).

Trains the repo's ANN-mode demo detector (96×160, thinned channels — the
same architecture ``harness.demo_config`` evaluates) on the synthetic
train split and exports it as a ``repro.convert`` format-v1 npz bundle.
This is the ONLY place training happens in the conversion story; the
conversion itself (examples/convert_ann_detector.py, the convert-smoke CI
lane) starts from this file and runs zero training steps.

  PYTHONPATH=src python scripts/make_ann_fixture.py [--steps 4000]
      [--out tests/fixtures/ann_detector/ann_tiny_yolo.npz]

~10 minutes of CPU at the default 4000 steps (ANN mAP@0.5 ≈ 0.65–0.7 on
the 48-image synthetic val split; printed at the end for the record).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=4000)
    ap.add_argument("--batch", type=int, default=6)
    ap.add_argument("--eval-images", type=int, default=48)
    ap.add_argument("--out",
                    default="tests/fixtures/ann_detector/ann_tiny_yolo.npz")
    args = ap.parse_args(argv)

    from repro import convert as cv
    from repro.eval import harness

    ann_cfg = dataclasses.replace(
        harness.demo_config(), mode="ann", weight_bits=0, conv_exec="dense"
    )
    t0 = time.time()
    params, bn, _, losses = harness.train_steps(
        ann_cfg, steps=args.steps, batch=args.batch, verbose=True
    )
    print(f"trained {args.steps} ANN steps in {time.time() - t0:.0f}s "
          f"(final loss {losses[-1]:.3f})")

    det = harness.compile_eval_detector(ann_cfg, params, bn)
    rep = harness.evaluate_detector(det, n_images=args.eval_images)
    print(f"ANN mAP@0.5 = {rep['map']:.4f} on {rep['n_images']} val images")

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    cv.export_ann_npz(args.out, params, bn, ann_cfg)
    print(f"wrote {args.out} ({os.path.getsize(args.out)} bytes)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
