"""Regenerate EVERY derived fixture in one step — or verify them (--check).

The two fixture sets that must move together on any intentional numerics
change (and historically didn't):

* ``tests/conformance/fixtures/golden_conformance.npz`` — the dense-oracle
  conformance golden (``tests/conformance/make_golden.py`` semantics),
* ``tests/fixtures/data_checksums.json`` — the pinned crc32 checksums of
  the synthetic dataset samples that ``tests/test_data.py`` asserts.

``make regen-goldens`` runs this in write mode; the ``golden-regen`` CI job
runs ``--check``, which regenerates everything in memory and fails on ANY
divergence from the checked-in copies — so a PR that changes the data
stream or detector numerics without re-pinning both fixture sets cannot
land half-updated. (``--check`` compares array/JSON CONTENT, not file
bytes: npz zip members carry timestamps, so byte equality would be flaky.)

    PYTHONPATH=src python scripts/regen_goldens.py [--check]

After an intentional regen, also rerun the full ``benchmarks/eval_map.py``
if the data distribution changed — BENCH_eval.json numbers pin to it.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import zlib

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, os.path.join(REPO, "tests", "conformance"))

CHECKSUMS_PATH = os.path.join(REPO, "tests", "fixtures", "data_checksums.json")
# the sample grid tests/test_data.py pins: (96, 160) @ grid_div 16 — the
# harness demo scale every checked-in accuracy number is generated at
DATA_HW, DATA_GRID_DIV = (96, 160), 16
DATA_SAMPLES = (("train", 0), ("train", 123), ("val", 0), ("val", 31))
# the committed real-data fixture: letterboxed images + grid targets from
# the COCO-json loader are pinned at the same demo scale
COCO_FIXTURE = os.path.join("tests", "fixtures", "coco_fixture", "instances.json")


def _crc(arr) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def build_checksums() -> dict:
    from repro.data import detection_datasets as dd
    from repro.data import synthetic_detection as sd

    samples = []
    for split, idx in DATA_SAMPLES:
        img, tgt, _ = sd.sample(idx, split=split, hw=DATA_HW,
                                grid_div=DATA_GRID_DIV)
        samples.append({
            "split": split,
            "index": idx,
            "image_crc32": _crc(img),
            "target_crc32": _crc(tgt),
        })
    src = dd.CocoJsonSource(os.path.join(REPO, COCO_FIXTURE))
    n = src.num_eval_images("val")
    images, gts = src.eval_set(n, hw=DATA_HW, grid_div=DATA_GRID_DIV)
    batch = next(src.batches(n, hw=DATA_HW, steps=1, grid_div=DATA_GRID_DIV))
    coco = [
        {
            "index": i,
            "image_crc32": _crc(images[i]),
            "target_crc32": _crc(batch["target"][i]),
            "boxes_crc32": _crc(gts[i]["boxes"]),
            "classes": gts[i]["classes"].tolist(),
        }
        for i in range(n)
    ]
    return {
        "hw": list(DATA_HW), "grid_div": DATA_GRID_DIV, "samples": samples,
        "coco_fixture": {"json": COCO_FIXTURE.replace(os.sep, "/"),
                         "class_names": list(src.class_names),
                         "samples": coco},
    }


def build_conformance() -> dict:
    import golden

    # the ONE generation recipe, shared with tests/conformance/make_golden.py
    return golden.build_reference()


def _diff_conformance(fresh: dict) -> list:
    import golden

    if not os.path.exists(golden.FIXTURE):
        return [f"missing fixture {golden.FIXTURE}"]
    disk = golden.load_golden()
    problems = []
    for k in sorted(set(fresh) | set(disk)):
        if k not in disk:
            problems.append(f"conformance: {k} missing from checked-in npz")
        elif k not in fresh:
            problems.append(f"conformance: stale array {k} in checked-in npz")
        elif not np.array_equal(fresh[k], disk[k], equal_nan=True):
            problems.append(f"conformance: {k} differs from checked-in npz")
    return problems


def _diff_checksums(fresh: dict) -> list:
    if not os.path.exists(CHECKSUMS_PATH):
        return [f"missing {CHECKSUMS_PATH}"]
    with open(CHECKSUMS_PATH) as f:
        disk = json.load(f)
    if fresh != disk:
        return [f"data checksums differ from {CHECKSUMS_PATH} — the "
                "synthetic data stream changed"]
    return []


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="regenerate in memory and fail on any divergence "
                    "from the checked-in fixtures (no files written)")
    args = ap.parse_args(argv)

    import golden

    checks = build_checksums()
    conf = build_conformance()

    if args.check:
        problems = _diff_checksums(checks) + _diff_conformance(conf)
        if problems:
            for p in problems:
                print(f"STALE: {p}")
            print("\nfixtures are out of sync with the code — if the "
                  "numerics change is intentional, run `make regen-goldens` "
                  "and commit BOTH fixture sets")
            return 1
        print(f"fixtures up to date: {len(conf)} conformance arrays, "
              f"{len(checks['samples'])} data checksums")
        return 0

    # only touch files whose CONTENT changed — rewriting an identical npz
    # would still churn git (zip members carry timestamps)
    if _diff_checksums(checks):
        os.makedirs(os.path.dirname(CHECKSUMS_PATH), exist_ok=True)
        with open(CHECKSUMS_PATH, "w") as f:
            json.dump(checks, f, indent=1)
            f.write("\n")
        print(f"wrote {CHECKSUMS_PATH} ({len(checks['samples'])} samples)")
    else:
        print(f"unchanged: {CHECKSUMS_PATH}")
    if _diff_conformance(conf):
        os.makedirs(os.path.dirname(golden.FIXTURE), exist_ok=True)
        np.savez_compressed(golden.FIXTURE, **conf)
        print(f"wrote {golden.FIXTURE} "
              f"({os.path.getsize(golden.FIXTURE) / 1024:.1f} KiB, "
              f"{len(conf)} arrays)")
    else:
        print(f"unchanged: {golden.FIXTURE}")
    print("reminder: if the DATA stream changed, the checked-in "
          "BENCH_eval.json numbers are stale too (full eval_map rerun)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
