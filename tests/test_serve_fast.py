"""§Perf serve fast path (carry-aliased fori_loop cache) and int8 KV quant:
must be numerically equivalent (argmax-exact; bf16-cache atol) to the naive
scan path across families, including per-slot positions."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.models import zoo


def _pair(arch):
    cfg_f = smoke_config(get_config(arch))
    cfg_n = dataclasses.replace(cfg_f, serve_fast=False)
    return cfg_f, cfg_n


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "olmoe-1b-7b", "llava-next-34b"])
def test_fast_prefill_matches_naive(arch):
    cfg_f, cfg_n = _pair(arch)
    api_f, api_n = zoo.get_api(cfg_f), zoo.get_api(cfg_n)
    params = api_f.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 7), 0, cfg_f.vocab_size)
    args = (toks,)
    if cfg_f.family == "vlm":
        args = (toks, jnp.ones((2, 8, 1024), jnp.float32) * 0.1)
    lf, cf = api_f.prefill_fn(params, *args)
    ln, cn = api_n.prefill_fn(params, *args)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(ln), rtol=2e-2, atol=0.1)
    assert (lf.argmax(-1) == ln.argmax(-1)).all()
    # the fast path's cache rows must equal the naive stacked KV
    np.testing.assert_allclose(
        np.asarray(cf.k, np.float32), np.asarray(cn.k.astype(cf.k.dtype), np.float32),
        rtol=1e-2, atol=1e-2,
    )


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "olmoe-1b-7b", "zamba2-7b"])
def test_fast_decode_matches_naive(arch):
    cfg_f, cfg_n = _pair(arch)
    api_f, api_n = zoo.get_api(cfg_f), zoo.get_api(cfg_n)
    params = api_f.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 7), 0, cfg_f.vocab_size)
    _, small = api_n.prefill_fn(params, toks)
    cache = api_f.init_cache(2, 32)
    if hasattr(cache, "attn_k"):  # hybrid
        cache = type(cache)(
            mamba=small.mamba, tail=small.tail,
            attn_k=cache.attn_k.at[:, :, :7].set(small.attn_k.astype(cache.attn_k.dtype)),
            attn_v=cache.attn_v.at[:, :, :7].set(small.attn_v.astype(cache.attn_v.dtype)),
        )
    else:
        cache = type(cache)(
            cache.k.at[:, :, :7].set(small.k.astype(cache.k.dtype)),
            cache.v.at[:, :, :7].set(small.v.astype(cache.v.dtype)),
        )
    tok = jnp.array([3, 5], jnp.int32)
    df, _ = api_f.decode_fn(params, cache, tok, jnp.int32(7))
    dn, _ = api_n.decode_fn(params, cache, tok, jnp.int32(7))
    np.testing.assert_allclose(np.asarray(df), np.asarray(dn), rtol=2e-2, atol=0.1)
    assert (df.argmax(-1) == dn.argmax(-1)).all()


def test_fast_decode_per_slot_positions():
    """Vectorized cache_pos (continuous batching) through the fast path."""
    cfg_f, cfg_n = _pair("qwen1.5-0.5b")
    api_f, api_n = zoo.get_api(cfg_f), zoo.get_api(cfg_n)
    params = api_f.init_params(jax.random.PRNGKey(2))
    cache = api_f.init_cache(2, 32)
    # two slots at different positions
    pos = jnp.array([5, 9], jnp.int32)
    tok = jnp.array([7, 11], jnp.int32)
    df, cf = api_f.decode_fn(params, cache, tok, pos)
    dn, cn2 = api_n.decode_fn(params, cache, tok, pos)
    np.testing.assert_allclose(np.asarray(df), np.asarray(dn), rtol=2e-2, atol=0.1)
    # cache rows written at each slot's own position
    for b, p in enumerate([5, 9]):
        assert float(jnp.abs(cf.k[:, b, p]).sum()) > 0
        assert float(jnp.abs(cf.k[:, b, p + 1]).sum()) == 0


def test_kv_quant_accuracy():
    """int8 KV (the paper's FXP8 on the cache): <1% logit error, argmax
    agreement with the bf16 cache."""
    base = smoke_config(get_config("qwen1.5-0.5b"))
    cfg_q = dataclasses.replace(base, kv_quant=True)
    api_q, api_f = zoo.get_api(cfg_q), zoo.get_api(base)
    params = api_q.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, base.vocab_size)
    lq, cq = api_q.prefill_fn(params, toks)
    lf, _ = api_f.prefill_fn(params, toks)
    rel = float(jnp.max(jnp.abs(lq - lf)) / jnp.max(jnp.abs(lf)))
    assert rel < 0.02, rel
    assert (lq.argmax(-1) == lf.argmax(-1)).all()
    # int8 payload halves the bf16 cache bytes (+ one f32 scale per head
    # row: 4/(2*hd) relative — 3% at the real hd=128, 12.5% at smoke hd=16)
    hd = cq.k.shape[-1]
    bytes_q = cq.k.size + cq.v.size + 4 * (cq.k_scale.size + cq.v_scale.size)
    bytes_f = 2 * cq.k.size * 2
    assert bytes_q < (0.5 + 4 / (2 * hd) + 0.02) * bytes_f
