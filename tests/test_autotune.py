"""Autotune cache contract (kernels/autotune.py): deterministic
serialization, safe fallback on missing/stale/corrupt caches, and — the
load-bearing property — tile choice NEVER changes numerics, only speed.
"""
from __future__ import annotations

import dataclasses
import json
import warnings

import jax
import numpy as np
import pytest

from repro.core import plan as cplan
from repro.core import pruning
from repro.kernels import autotune as at
from repro.models import snn_yolo as sy


def _shape_1x1(**kw) -> at.LayerShape:
    base = dict(kh=1, kw=1, cin=8, kout=8, in_bits=1, t_in=2, t_out=2,
                h=12, w=16, bh=6, bw=8)
    base.update(kw)
    return at.LayerShape(**base)


class TestCacheRoundTrip:
    def test_save_load_round_trip(self, tmp_path):
        entries = {
            _shape_1x1().key: at.TileConfig(kblk=16, nbt=4),
            _shape_1x1(kh=3, kw=3).key: at.TileConfig(kblk=8, nbt=1),
        }
        p = tmp_path / "cache.json"
        at.save_cache(entries, str(p))
        assert at.load_cache(str(p)) == entries

    def test_serialization_is_deterministic(self, tmp_path):
        """Identical entry sets → byte-identical files, regardless of
        insertion order (the checked-in cache must be reproducible)."""
        a = {"k2": at.TileConfig(8, 1), "k1": at.TileConfig(16, 4)}
        b = {"k1": at.TileConfig(16, 4), "k2": at.TileConfig(8, 1)}
        pa, pb = tmp_path / "a.json", tmp_path / "b.json"
        at.save_cache(a, str(pa))
        at.save_cache(b, str(pb))
        assert pa.read_bytes() == pb.read_bytes()

    def test_tune_with_injected_measure_is_deterministic(self, tmp_path):
        """Same shapes + same (injected, wall-clock-free) measurements →
        identical winners → byte-identical cache files across runs."""

        def fake_measure(tile, run):  # prefers wide K-blocks, big groups
            return 1.0 / (tile.kblk * 100 + tile.nbt)

        shape = _shape_1x1(kh=3, kw=3, kout=16)
        blobs = []
        for name in ("x.json", "y.json"):
            tile, record = at.tune_layer(shape, measure_fn=fake_measure)
            p = tmp_path / name
            at.save_cache({shape.key: tile}, str(p))
            blobs.append(p.read_bytes())
        assert blobs[0] == blobs[1]
        # the fake metric's argmin is the largest legal (kblk, nbt)
        assert tile == max(at.candidates(shape),
                           key=lambda t: t.kblk * 100 + t.nbt)

    def test_record_covers_every_candidate(self):
        shape = _shape_1x1()
        seen = []

        def fake_measure(tile, run):
            seen.append(tile)
            return float(tile.nbt)

        at.tune_layer(shape, measure_fn=fake_measure)
        assert seen == at.candidates(shape)


class TestCacheFallback:
    def test_missing_cache_falls_back_to_default(self, tmp_path):
        missing = str(tmp_path / "nope.json")
        assert at.load_cache(missing) == {}
        assert at.lookup(_shape_1x1(), at.load_cache(missing)) == at.DEFAULT_TILE

    def test_stale_version_falls_back(self, tmp_path):
        p = tmp_path / "stale.json"
        payload = {"version": at.CACHE_VERSION + 1,
                   "entries": {_shape_1x1().key: {"kblk": 8, "nbt": 4}}}
        p.write_text(json.dumps(payload))
        assert at.load_cache(str(p)) == {}

    def test_corrupt_cache_falls_back(self, tmp_path):
        p = tmp_path / "corrupt.json"
        p.write_text("{not json")
        assert at.load_cache(str(p)) == {}

    def test_corrupt_cache_warns_once_with_details(self, tmp_path):
        """A cache file that EXISTS but is unusable warns exactly ONCE per
        process per path (plan builds consult it per layer — ~27× per
        detector compile) and the warning names the path; a version-stale
        file also reports found-vs-expected versions. A missing file stays
        silent (untuned is a supported state)."""
        corrupt = tmp_path / "corrupt.json"
        corrupt.write_text("{not json")
        stale = tmp_path / "stale.json"
        stale.write_text(json.dumps({"version": at.CACHE_VERSION + 1,
                                     "entries": {}}))
        saved = set(at._warned_paths)
        at._warned_paths.clear()
        try:
            with pytest.warns(RuntimeWarning, match=str(corrupt)):
                assert at.load_cache(str(corrupt)) == {}
            with warnings.catch_warnings():
                warnings.simplefilter("error")  # a repeat would raise
                assert at.load_cache(str(corrupt)) == {}
            with pytest.warns(RuntimeWarning) as rec:
                assert at.load_cache(str(stale)) == {}
            (msg,) = [str(w.message) for w in rec]
            assert str(stale) in msg
            assert str(at.CACHE_VERSION + 1) in msg
            assert str(at.CACHE_VERSION) in msg
            with warnings.catch_warnings():
                warnings.simplefilter("error")
                assert at.load_cache(str(tmp_path / "missing.json")) == {}
        finally:
            at._warned_paths.clear()
            at._warned_paths.update(saved)

    def test_one_bad_entry_keeps_the_rest(self, tmp_path):
        p = tmp_path / "partial.json"
        good = _shape_1x1().key
        payload = {"version": at.CACHE_VERSION,
                   "entries": {good: {"kblk": 16, "nbt": 2},
                               "broken": {"kblk": "wide"}}}
        p.write_text(json.dumps(payload))
        loaded = at.load_cache(str(p))
        assert loaded == {good: at.TileConfig(kblk=16, nbt=2)}


class TestTileNumericsInvariance:
    """The whole premise of tuning as a pure wall-clock search: any legal
    tile produces BIT-IDENTICAL detector output."""

    @pytest.fixture(scope="class")
    def setup(self):
        from repro.configs import get_config, smoke_config

        cfg = dataclasses.replace(
            smoke_config(get_config("snn-det")), arch_id="snn-det-tiletest",
            use_block_conv=True, conv_exec="pallas",
        )
        params, bn = sy.init_params(jax.random.PRNGKey(0), cfg)
        params = pruning.prune_tree(params, 0.8)
        rng = np.random.default_rng(0)
        h, w = cfg.input_hw
        frames = (rng.integers(0, 256, (1, h, w, 3)) / 255.0).astype(np.float32)
        bn = sy.calibrate_bn_state(params, bn, frames, cfg)
        return cfg, params, bn, frames

    def _head(self, setup, tile_cache):
        cfg, params, bn, frames = setup
        plan = cplan.build_plan(params, cfg, tile_cache=tile_cache)
        head, _, _ = sy.forward(params, bn, frames, cfg, train=False, plan=plan)
        return np.asarray(head)

    def test_untuned_equals_tuned(self, setup):
        """Empty cache (every layer at DEFAULT_TILE) vs the persisted
        autotuned cache: numerics must be bit-equal."""
        untuned = self._head(setup, tile_cache={})
        tuned = self._head(setup, tile_cache=None)  # packaged cache file
        np.testing.assert_array_equal(untuned, tuned)

    def test_arbitrary_tiles_are_bit_equal(self, setup):
        """Force a deliberately different legal tiling for every layer."""
        cfg, params, bn, frames = setup
        shapes = at.detector_layer_shapes(cfg)
        forced = {}
        for shape in shapes.values():
            cands = at.candidates(shape)
            forced[shape.key] = cands[-1]  # largest legal, != DEFAULT often
        got = self._head(setup, tile_cache=forced)
        want = self._head(setup, tile_cache={})
        np.testing.assert_array_equal(got, want)

    def test_macro_tile_choice_never_changes_numerics(self, setup):
        """The new macro-tile axis specifically: whole-row macros and the
        largest whole-grid macro must both be bit-equal to single-block
        dispatch, for every layer of the detector at once."""
        cfg, params, bn, frames = setup
        shapes = at.detector_layer_shapes(cfg)
        want = self._head(setup, tile_cache={})

        def pick_row(cands):  # widest 1×c row macro-tile
            return max(cands, key=lambda t: (t.mrows == 1, t.mcols, t.nbt))

        def pick_grid(cands):  # largest r×c macro-tile overall
            return max(cands, key=lambda t: (t.mrows * t.mcols, t.nbt))

        for pick in (pick_row, pick_grid):
            forced = {s.key: pick(at.candidates(s)) for s in shapes.values()}
            got = self._head(setup, tile_cache=forced)
            np.testing.assert_array_equal(got, want)


class TestCheckCache:
    """`make check-autotune` contract: the committed cache must cover every
    fused layer shape of the benchmarked configs — a silently-falling-back
    lookup is exactly what --check exists to catch."""

    def test_reports_all_missing_then_covered(self, tmp_path):
        from repro.configs import get_config, smoke_config

        cfg = dataclasses.replace(
            smoke_config(get_config("snn-det")), arch_id="snn-det-checktest",
            use_block_conv=True, conv_exec="pallas",
        )
        keys = {s.key for s in at.detector_layer_shapes(cfg).values()}
        p = str(tmp_path / "cache.json")
        assert sorted(at.check_cache([cfg], p)) == sorted(keys)  # no file
        at.save_cache({k: at.DEFAULT_TILE for k in keys}, p)
        assert at.check_cache([cfg], p) == []
        stale = dict(json.loads(open(p).read()))
        stale["version"] = at.CACHE_VERSION - 1  # stale cache == empty cache
        open(p, "w").write(json.dumps(stale))
        saved = set(at._warned_paths)
        at._warned_paths.clear()
        try:
            with pytest.warns(RuntimeWarning):
                assert sorted(at.check_cache([cfg], p)) == sorted(keys)
        finally:
            at._warned_paths.clear()
            at._warned_paths.update(saved)
