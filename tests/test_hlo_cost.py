"""The trip-count-aware HLO cost model (launch/hlo_cost.py) validated
against unrolled ground truth: scanned matmuls, nested scans, collectives
under shard_map."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze_text


def _flops_of(fn, *specs):
    return analyze_text(jax.jit(fn).lower(*specs).compile().as_text())


@pytest.mark.parametrize("n", [1, 3, 16, 64])
def test_scan_trip_count(n):
    def f(x):
        w = jnp.ones((256, 256), jnp.float32)
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=n)
        return y

    a = _flops_of(f, jax.ShapeDtypeStruct((256, 256), jnp.float32))
    ideal = 2 * 256**3 * n
    assert a["flops"] == pytest.approx(ideal, rel=0.02)


def test_nested_scan():
    def g(x):
        w = jnp.ones((128, 128), jnp.float32)
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    a = _flops_of(g, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    assert a["flops"] == pytest.approx(2 * 128**3 * 15, rel=0.02)


def test_unrolled_matches_scanned():
    def unrolled(x):
        w = jnp.ones((128, 128), jnp.float32)
        for _ in range(8):
            x = x @ w
        return x

    def scanned(x):
        w = jnp.ones((128, 128), jnp.float32)
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    s = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    au = _flops_of(unrolled, s)
    asc = _flops_of(scanned, s)
    assert au["flops"] == pytest.approx(asc["flops"], rel=0.02)


def test_elementwise_chains_are_hbm_free():
    """Perfect-fusion model: a chain of elementwise ops contributes flops
    but no HBM bytes beyond the surrounding physical ops."""
    def f(x):
        y = jnp.tanh(x) * 2 + 1
        y = jax.nn.sigmoid(y) - x
        return y

    a = _flops_of(f, jax.ShapeDtypeStruct((1024, 1024), jnp.float32))
    assert a["flops"] > 0
    # bytes should be far below per-op accounting (5 ops x 8MB operand+result)
    assert a["bytes"] < 30e6


def test_collectives_counted_with_trips():
    import os
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (dry-run env)")


def test_dot_flops_with_batch_dims():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)

    sa = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    sb = jax.ShapeDtypeStruct((4, 32, 48), jnp.float32)
    a = _flops_of(f, sa, sb)
    assert a["flops"] == pytest.approx(2 * 4 * 64 * 32 * 48, rel=0.05)
