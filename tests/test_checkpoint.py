"""Checkpoint/restore + fault tolerance: atomic commit, async save, restore
with resharding templates, supervisor restart-from-last-good, straggler
flagging — plus the lifecycle fixes: gc ignores uncommitted junk and joins
in-flight writers, failed async writes surface in wait_pending, and
template/manifest mismatches raise a diagnosable ValueError."""
from __future__ import annotations

import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train import ft


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.float32(3.5)},
    }


def _template(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.asarray(x).dtype), tree
    )


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t)
    restored, step = ckpt.restore(str(tmp_path), _template(t))
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    for s in (1, 5, 3, 9):
        ckpt.save(str(tmp_path), s, _tree(s))
    assert ckpt.latest_step(str(tmp_path)) == 9
    ckpt.gc_old(str(tmp_path), keep=2)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [5, 9]


def test_crash_mid_save_never_corrupts(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    # simulate a crash: a stale .tmp directory from a dead writer
    os.makedirs(tmp_path / "step_000000002.tmp")
    with open(tmp_path / "step_000000002.tmp" / "leaf_00000.npy", "w") as f:
        f.write("garbage")
    assert ckpt.latest_step(str(tmp_path)) == 1  # .tmp is invisible
    restored, step = ckpt.restore(str(tmp_path), _template(t))
    assert step == 1


def test_async_save(tmp_path):
    t = _tree()
    th = ckpt.save_async(str(tmp_path), 3, t)
    th.join()
    restored, step = ckpt.restore(str(tmp_path), _template(t))
    assert step == 3


def test_restore_validates_shape(tmp_path):
    ckpt.save(str(tmp_path), 0, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)})


def test_gc_ignores_uncommitted_junk(tmp_path):
    """A junk step dir without a manifest must not consume a keep slot
    (it used to, evicting a REAL checkpoint) nor be deleted (it might be
    a foreign writer's staging area), and unparsable names must not
    crash the sweep."""
    ckpt.save(str(tmp_path), 1, _tree(1))
    ckpt.save(str(tmp_path), 3, _tree(3))
    os.makedirs(tmp_path / "step_000000002")  # junk: no manifest.json
    os.makedirs(tmp_path / "step_junk")  # junk: unparsable step
    ckpt.gc_old(str(tmp_path), keep=2)
    kept = sorted(os.listdir(tmp_path))
    assert "step_000000001" in kept and "step_000000003" in kept
    assert "step_000000002" in kept and "step_junk" in kept


def test_gc_joins_pending_writer_for_doomed_step(tmp_path, monkeypatch):
    """gc_old must not race an in-flight save_async commit for a step it
    is deleting: it joins the writer first (here: a writer that has
    committed but not yet returned holds gc until released)."""
    committed, release = threading.Event(), threading.Event()
    real_write = ckpt._write

    def gated_write(root, step, paths, host, extra_files=None):
        out = real_write(root, step, paths, host, extra_files)
        if step == 1:
            committed.set()
            assert release.wait(timeout=10)
        return out

    monkeypatch.setattr(ckpt, "_write", gated_write)
    ckpt.save_async(str(tmp_path), 1, _tree(1))
    assert committed.wait(timeout=10)
    for s in (5, 6):
        ckpt.save(str(tmp_path), s, _tree(s))

    gc_done = threading.Event()

    def run_gc():
        ckpt.gc_old(str(tmp_path), keep=2)
        gc_done.set()

    t = threading.Thread(target=run_gc, daemon=True)
    t.start()
    assert not gc_done.wait(timeout=0.3)  # gc is blocked on the writer
    release.set()
    assert gc_done.wait(timeout=10)
    ckpt.wait_pending()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [5, 6]


def test_failed_async_write_raises_in_wait_pending(tmp_path, monkeypatch):
    """A background write that dies (disk full, perms) must not silently
    lose the checkpoint: wait_pending re-raises the first failure, then
    clears, so the next wait is clean."""

    def bad_write(root, step, paths, host, extra_files=None):
        raise OSError("no space left on device")

    monkeypatch.setattr(ckpt, "_write", bad_write)
    ckpt.save_async(str(tmp_path), 1, _tree())
    with pytest.raises(OSError, match="no space left"):
        ckpt.wait_pending()
    ckpt.wait_pending()  # recorded failures do not repeat


def test_restore_mismatch_lists_leaf_paths(tmp_path):
    """Template leaves absent from the manifest raise a ValueError naming
    BOTH sides' unmatched paths (not a bare KeyError), so a
    config/checkpoint mismatch is diagnosable from the message."""
    ckpt.save(str(tmp_path), 0, {"a": jnp.zeros((2,)), "extra": jnp.ones(())})
    template = {
        "a": jax.ShapeDtypeStruct((2,), jnp.float32),
        "missing_leaf": jax.ShapeDtypeStruct((), jnp.float32),
    }
    with pytest.raises(ValueError) as e:
        ckpt.restore(str(tmp_path), template)
    msg = str(e.value)
    assert "missing_leaf" in msg and "extra" in msg


def test_restore_tolerates_extra_manifest_leaves(tmp_path):
    """The inverse direction stays allowed: a template that is a sub-tree
    of the checkpoint (e.g. {params, bn} out of a {params, bn, opt}
    train state) restores fine."""
    ckpt.save(str(tmp_path), 0, {"a": jnp.full((2,), 7.0), "opt": jnp.ones(())})
    restored, step = ckpt.restore(
        str(tmp_path), {"a": jax.ShapeDtypeStruct((2,), jnp.float32)}
    )
    assert step == 0
    np.testing.assert_array_equal(np.asarray(restored["a"]), [7.0, 7.0])


def test_save_extra_files_commit_atomically(tmp_path):
    """extra_files sidecars land inside the committed step dir."""
    ckpt.save(str(tmp_path), 2, _tree(), extra_files={"meta.json": b"{}"})
    assert (tmp_path / "step_000000002" / "meta.json").read_bytes() == b"{}"
    with pytest.raises(ValueError, match="collides"):
        ckpt.save(str(tmp_path), 3, _tree(),
                  extra_files={"manifest.json": b"x"})


def test_supervisor_restarts_after_failures(tmp_path):
    """Simulated node failures at steps 4 and 12: the supervisor restores
    from the last committed checkpoint and completes all 20 steps."""
    failures = {4, 12}
    seen = []

    def init_state():
        return {"x": jnp.zeros(()), "step_sum": jnp.zeros(())}

    def template():
        return {"x": jax.ShapeDtypeStruct((), jnp.float32),
                "step_sum": jax.ShapeDtypeStruct((), jnp.float32)}

    def step_fn(state, step):
        if step in failures:
            failures.discard(step)  # fail once per step
            raise RuntimeError(f"simulated node loss at {step}")
        seen.append(step)
        return {"x": state["x"] + 1, "step_sum": state["step_sum"] + step}

    sup = ft.Supervisor(ckpt_root=str(tmp_path), max_restarts=5, save_every=2,
                        heartbeat=ft.Heartbeat(str(tmp_path / "hb.json")))
    final = sup.run(init_state=init_state, state_template=template,
                    step_fn=step_fn, n_steps=20)
    assert sup.restarts == 2
    # every step 0..19 was eventually executed (some twice after restore)
    assert set(seen) == set(range(20))
    assert float(final["x"]) == 20  # checkpoint/restore kept the count exact
    hb = sup.heartbeat.last()
    assert hb["step"] == 19


def test_supervisor_gives_up(tmp_path):
    def bad_step(state, step):
        raise RuntimeError("always fails")

    sup = ft.Supervisor(ckpt_root=str(tmp_path), max_restarts=2)
    with pytest.raises(RuntimeError) as exc_info:
        sup.run(init_state=lambda: {"x": jnp.zeros(())},
                state_template=lambda: {"x": jax.ShapeDtypeStruct((), jnp.float32)},
                step_fn=bad_step, n_steps=5)
    assert sup.restarts == 3
    # the give-up re-raise attributes the failure to its host of origin
    # (multi-process CI shows "[host i/P]"; the identity context is 0/1)
    # while keeping the original exception type and chaining the cause
    assert "[host 0/1]" in str(exc_info.value)
    assert "always fails" in str(exc_info.value)
    assert isinstance(exc_info.value.__cause__, RuntimeError)


def test_straggler_monitor():
    mon = ft.StragglerMonitor(alpha=0.5, threshold=2.0)
    assert not mon.observe(0, 1.0)
    assert not mon.observe(1, 1.1)
    assert mon.observe(2, 5.0)  # 5x the EWMA -> flagged
    assert len(mon.flagged) == 1
