"""Checkpoint/restore + fault tolerance: atomic commit, async save, restore
with resharding templates, supervisor restart-from-last-good, straggler
flagging."""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train import ft


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.float32(3.5)},
    }


def _template(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.asarray(x).dtype), tree
    )


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t)
    restored, step = ckpt.restore(str(tmp_path), _template(t))
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    for s in (1, 5, 3, 9):
        ckpt.save(str(tmp_path), s, _tree(s))
    assert ckpt.latest_step(str(tmp_path)) == 9
    ckpt.gc_old(str(tmp_path), keep=2)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [5, 9]


def test_crash_mid_save_never_corrupts(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    # simulate a crash: a stale .tmp directory from a dead writer
    os.makedirs(tmp_path / "step_000000002.tmp")
    with open(tmp_path / "step_000000002.tmp" / "leaf_00000.npy", "w") as f:
        f.write("garbage")
    assert ckpt.latest_step(str(tmp_path)) == 1  # .tmp is invisible
    restored, step = ckpt.restore(str(tmp_path), _template(t))
    assert step == 1


def test_async_save(tmp_path):
    t = _tree()
    th = ckpt.save_async(str(tmp_path), 3, t)
    th.join()
    restored, step = ckpt.restore(str(tmp_path), _template(t))
    assert step == 3


def test_restore_validates_shape(tmp_path):
    ckpt.save(str(tmp_path), 0, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)})


def test_supervisor_restarts_after_failures(tmp_path):
    """Simulated node failures at steps 4 and 12: the supervisor restores
    from the last committed checkpoint and completes all 20 steps."""
    failures = {4, 12}
    seen = []

    def init_state():
        return {"x": jnp.zeros(()), "step_sum": jnp.zeros(())}

    def template():
        return {"x": jax.ShapeDtypeStruct((), jnp.float32),
                "step_sum": jax.ShapeDtypeStruct((), jnp.float32)}

    def step_fn(state, step):
        if step in failures:
            failures.discard(step)  # fail once per step
            raise RuntimeError(f"simulated node loss at {step}")
        seen.append(step)
        return {"x": state["x"] + 1, "step_sum": state["step_sum"] + step}

    sup = ft.Supervisor(ckpt_root=str(tmp_path), max_restarts=5, save_every=2,
                        heartbeat=ft.Heartbeat(str(tmp_path / "hb.json")))
    final = sup.run(init_state=init_state, state_template=template,
                    step_fn=step_fn, n_steps=20)
    assert sup.restarts == 2
    # every step 0..19 was eventually executed (some twice after restore)
    assert set(seen) == set(range(20))
    assert float(final["x"]) == 20  # checkpoint/restore kept the count exact
    hb = sup.heartbeat.last()
    assert hb["step"] == 19


def test_supervisor_gives_up(tmp_path):
    def bad_step(state, step):
        raise RuntimeError("always fails")

    sup = ft.Supervisor(ckpt_root=str(tmp_path), max_restarts=2)
    with pytest.raises(RuntimeError):
        sup.run(init_state=lambda: {"x": jnp.zeros(())},
                state_template=lambda: {"x": jax.ShapeDtypeStruct((), jnp.float32)},
                step_fn=bad_step, n_steps=5)
    assert sup.restarts == 3


def test_straggler_monitor():
    mon = ft.StragglerMonitor(alpha=0.5, threshold=2.0)
    assert not mon.observe(0, 1.0)
    assert not mon.observe(1, 1.1)
    assert mon.observe(2, 5.0)  # 5x the EWMA -> flagged
    assert len(mon.flagged) == 1
