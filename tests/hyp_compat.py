"""Optional-hypothesis shim: property tests degrade to explicit skips when
`hypothesis` is not installed (it is a test-only dependency; see
requirements.txt) instead of breaking collection of the whole module.

Usage in test modules:

    from hyp_compat import given, settings, st
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        """Replace the test with a skip that names the missing dependency.
        The replacement takes (*args) so pytest sees no fixture params."""

        def deco(fn):
            def skipper(*args, **kwargs):
                pytest.skip("property test skipped: hypothesis is not installed")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _StrategyStub:
        """Accepts any strategy construction (st.integers(...), st.floats(...),
        st.sampled_from(...)); the strategies are never drawn from because
        `given` skips the test body."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None

            return _strategy

    st = _StrategyStub()
