"""The CI gate scripts (scripts/check_crossover.py, the step-summary
writer in scripts/bench_regression.py) as units: the crossover gate is
what keeps the macro-tiled pallas win at large inputs from silently
regressing, so its skip/tolerance/parity edges need pinning."""
from __future__ import annotations

import importlib.util
import os
import sys

import pytest

_SCRIPTS = os.path.join(os.path.dirname(__file__), os.pardir, "scripts")


def _load(name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(_SCRIPTS, f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def crossover():
    return _load("check_crossover")


@pytest.fixture(scope="module")
def bench_regression():
    return _load("bench_regression")


def _payload(dense_s, pallas_s, *, diff=0.0):
    return {"executors": {
        "dense": {"wall_s": dense_s, "max_abs_diff_vs_dense": 0.0},
        "gated": {"wall_s": dense_s, "max_abs_diff_vs_dense": diff},
        "pallas": {"wall_s": pallas_s, "max_abs_diff_vs_dense": diff},
    }}


class TestCrossoverGate:
    def test_pallas_faster_passes(self, crossover):
        ok, msg = crossover.check(_payload(0.008, 0.0078),
                                  tolerance=0.05, min_seconds=0.001)
        assert ok and "crossover holds" in msg

    def test_pallas_within_tolerance_passes(self, crossover):
        ok, _ = crossover.check(_payload(0.008, 0.0082),
                                tolerance=0.05, min_seconds=0.001)
        assert ok  # 1.025x < 1.05x headroom

    def test_pallas_slower_fails(self, crossover):
        ok, msg = crossover.check(_payload(0.0079, 0.0108),  # the pre-
                                  tolerance=0.05, min_seconds=0.001)
        assert not ok and "slower than dense" in msg  # macro-tile state

    def test_sub_floor_walls_skip(self, crossover):
        ok, msg = crossover.check(_payload(0.0004, 0.0009),
                                  tolerance=0.05, min_seconds=0.001)
        assert ok and "skipped" in msg

    def test_nonzero_diff_fails_even_when_faster(self, crossover):
        """A fast-but-wrong kernel must fail: bit-exactness is part of
        the crossover contract, not a separate gate."""
        ok, msg = crossover.check(_payload(0.008, 0.004, diff=1e-6),
                                  tolerance=0.05, min_seconds=0.001)
        assert not ok and "max_abs_diff_vs_dense" in msg

    def test_missing_executor_fails(self, crossover):
        ok, _ = crossover.check({"executors": {
            "dense": {"wall_s": 0.008, "max_abs_diff_vs_dense": 0.0}}},
            tolerance=0.05, min_seconds=0.001)
        assert not ok

    def test_cli_exit_codes(self, crossover, tmp_path, capsys):
        import json
        good = tmp_path / "good.json"
        good.write_text(json.dumps(_payload(0.008, 0.0078)))
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps(_payload(0.0079, 0.0108)))
        assert crossover.main(["--file", str(good)]) == 0
        assert crossover.main(["--file", str(bad)]) == 1
        assert crossover.main(["--file", str(tmp_path / "absent.json")]) == 1
        capsys.readouterr()


class TestStepSummary:
    def test_writes_markdown_table(self, bench_regression, tmp_path,
                                   monkeypatch):
        out = tmp_path / "summary.md"
        monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(out))
        rows = [("pallas.1/wall_s", 100.0, 90.0, 0.9, False),
                ("dense.1/wall_s", 100.0, 70.0, 0.7, True)]
        bench_regression.write_step_summary(
            [("BENCH_e2e.json", rows, ["gated.1/wall_s"], None),
             ("BENCH_eval.json", [], [], "skipped: config mismatch")], 0.2)
        text = out.read_text()
        assert "| `pallas.1/wall_s` | 100 | 90 | -10.0% |" in text
        assert "regressed" in text  # the -30% row is flagged
        assert "`gated.1/wall_s`" in text and "skipped" in text
        assert "config mismatch" in text

    def test_noop_outside_actions(self, bench_regression, monkeypatch):
        monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)
        bench_regression.write_step_summary(
            [("BENCH_e2e.json", [], [], "note")], 0.2)  # must not raise
