"""Compile-once detector API: CompiledDetector plan ownership + staleness,
DetectorSession streaming semantics (membrane carryover, reset()/state
contract, batch-of-sessions, mixed (1,3) schedule), and FrameRequest
serving through the Engine slot pool with executor parity vs the dense
oracle."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core import pruning
from repro.models import snn_yolo as sy
from repro.models.postprocess import Detections
from repro.serve import (
    CompiledDetector,
    DetectorEngineCore,
    Engine,
    EngineAPI,
    FrameRequest,
    LMEngineCore,
    StalePlanError,
)


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(get_config("snn-det"))
    params, bn = sy.init_params(jax.random.PRNGKey(0), cfg)
    params = pruning.prune_tree(params, 0.8)
    rng = np.random.default_rng(0)
    h, w = cfg.input_hw
    # uint8-grid frames keep the bit-serial 8-bit encode path exact
    frames = jnp.asarray(rng.integers(0, 256, (6, 2, h, w, 3)) / 255.0, jnp.float32)
    # calibrated tdBN stats: fresh (0, 1) stats silence the deep layers of
    # an untrained net, which would make streaming tests vacuous
    bn = sy.calibrate_bn_state(params, bn, frames[0], cfg)
    return cfg, params, bn, frames


@pytest.fixture(scope="module")
def det(setup):
    cfg, params, bn, _ = setup
    return sy.compile_detector(
        dataclasses.replace(cfg, conv_exec="gated"), params, bn
    )


class TestCompiledDetector:
    def test_call_returns_detections(self, det, setup):
        _, _, _, frames = setup
        dets = det(frames[0])
        assert isinstance(dets, Detections)
        assert dets.boxes.shape[0] == 2 and dets.boxes.shape[-1] == 4
        assert dets.valid.dtype == jnp.bool_

    def test_plan_owned_and_stable(self, det, setup):
        _, _, _, frames = setup
        plan = det.plan
        assert plan is not None and plan.compressed_bytes < plan.dense_bytes
        det(frames[0])
        det(frames[1])
        assert det.plan is plan  # compiled once, never re-packed per call

    def test_dense_handle_owns_plan_and_float_handle_has_none(self, setup):
        """Quantized dense handles build the plan at compile time too —
        the dense executor reads its w_q/scale so every executor runs the
        same integer-domain math (the conformance suite's bit-exactness
        guarantee). Float handles have nothing to pack."""
        cfg, params, bn, frames = setup
        d = sy.compile_detector(cfg, params, bn)  # dense executor
        plan = d.plan
        assert plan is not None and plan.compressed_bytes < plan.dense_bytes
        d(frames[0])
        assert d.plan is plan  # compiled once, never re-packed
        f = sy.compile_detector(
            dataclasses.replace(cfg, weight_bits=0), params, bn
        )
        assert f.plan is None  # float weights: legacy fake-quant path
        f(frames[0])  # still serves

    def test_stale_params_raise(self, setup):
        cfg, params, bn, frames = setup
        d = sy.compile_detector(cfg, dict(params), bn)
        d(frames[0])
        # swap a weight leaf after compile: the owned plan no longer
        # describes the model -> every entry point must refuse
        d.params["encode"] = dict(d.params["encode"])
        d.params["encode"]["w"] = d.params["encode"]["w"] + 1e-3
        with pytest.raises(StalePlanError, match="compile"):
            d(frames[0])
        with pytest.raises(StalePlanError):
            d.detect(frames[0])

    def test_stale_params_raise_in_session(self, setup):
        cfg, params, bn, frames = setup
        d = sy.compile_detector(cfg, dict(params), bn)
        sess = d.new_session(batch=2)
        sess.step(frames[0])
        d.params["head"] = {"w": d.params["head"]["w"] * 2}
        with pytest.raises(StalePlanError):
            sess.step(frames[1])

    def test_forward_without_plan_raises(self, setup):
        """Migrated from the removed snn_yolo._cached_plan: the free
        function no longer auto-builds — plan ownership lives in the
        handle."""
        cfg, params, bn, frames = setup
        c = dataclasses.replace(cfg, conv_exec="pallas")
        with pytest.raises(ValueError, match="compile_detector"):
            sy.forward(params, bn, frames[0], c)

    def test_float_weights_cannot_compile_compressed(self, setup):
        cfg, params, bn, _ = setup
        c = dataclasses.replace(cfg, weight_bits=0, conv_exec="gated")
        with pytest.raises(ValueError, match="weight_bits"):
            sy.compile_detector(c, params, bn)

    def test_default_bn_state(self, setup):
        cfg, params, _, frames = setup
        d = sy.compile_detector(cfg, params)  # no bn given -> fresh stats
        assert set(d.bn_state) == {n for n in params if n != "head"}
        d(frames[0])  # runs


class TestDetectorSession:
    def test_cold_start_matches_stateless(self, det, setup):
        _, _, _, frames = setup
        sess = det.new_session(batch=2)
        step = sess.step(frames[0])
        dets, head = det.detect(frames[0])
        np.testing.assert_array_equal(np.asarray(step.head), np.asarray(head))
        np.testing.assert_array_equal(
            np.asarray(step.detections.scores), np.asarray(dets.scores)
        )

    def test_carryover_vs_fresh_parity_on_static_sequence(self, det, setup):
        """Replaying the same frame sequence from reset() reproduces the
        fresh session bit-exactly — carryover is a pure function of the
        streamed frames."""
        _, _, _, frames = setup
        sess = det.new_session(batch=2)
        heads_fresh = [np.asarray(sess.step(frames[0]).head) for _ in range(3)]
        sess.reset()
        heads_replay = [np.asarray(sess.step(frames[0]).head) for _ in range(3)]
        for a, b in zip(heads_fresh, heads_replay):
            np.testing.assert_array_equal(a, b)
        # and state genuinely flows: the warm second step differs from cold
        assert np.abs(heads_fresh[1] - heads_fresh[0]).max() > 0

    def test_reset_restores_cold_start_outputs(self, det, setup):
        _, _, _, frames = setup
        sess = det.new_session(batch=2)
        cold = np.asarray(sess.step(frames[0]).head)
        sess.step(frames[1])
        sess.step(frames[2])
        sess.reset()
        assert sess.frames_seen == 0
        np.testing.assert_array_equal(np.asarray(sess.step(frames[0]).head), cold)

    def test_state_contract(self, det, setup):
        _, _, _, frames = setup
        sess = det.new_session(batch=2)
        assert all(
            float(jnp.abs(v).max()) == 0.0
            for v in jax.tree_util.tree_leaves(sess.state)
        )
        assert "head" in sess.state  # the no-reset output accumulator
        sess.step(frames[0])
        assert any(
            float(jnp.abs(v).max()) > 0
            for v in jax.tree_util.tree_leaves(sess.state)
        )
        with pytest.raises(ValueError, match="batch"):
            sess.step(frames[0][:1])  # wrong batch size

    def test_batch_of_sessions_rows_independent(self, det, setup):
        """The vectorized path: row i of a batched session must equal an
        independent single-stream session fed row i's frames."""
        _, _, _, frames = setup
        batched = det.new_session(batch=2)
        outs = [np.asarray(batched.step(f).head) for f in frames[:3]]
        for row in range(2):
            solo = det.new_session(batch=1)
            for k, f in enumerate(frames[:3]):
                h = np.asarray(solo.step(f[row : row + 1]).head)
                np.testing.assert_array_equal(h[0], outs[k][row])

    def test_reset_out_of_range_raises(self, det):
        """Regression: jnp scatter drops OOB indices silently, so a typo'd
        stream index must fail loudly instead of resetting nothing."""
        sess = det.new_session(batch=2)
        with pytest.raises(IndexError, match="out of range"):
            sess.reset(2)
        sess.reset(-1)  # negative indices within range are fine

    def test_per_row_reset(self, det, setup):
        _, _, _, frames = setup
        sess = det.new_session(batch=2)
        cold = np.asarray(sess.step(frames[0]).head)
        warm = np.asarray(sess.step(frames[0]).head)
        sess.reset()
        sess.step(frames[0])
        sess.reset(0)  # row 0 cold, row 1 stays warm
        h = np.asarray(sess.step(frames[0]).head)
        np.testing.assert_array_equal(h[0], cold[0])
        np.testing.assert_array_equal(h[1], warm[1])

    @pytest.mark.parametrize("mixed", [True, False])
    def test_time_step_schedules(self, setup, mixed):
        """Both the paper's mixed (1, 3) schedule and the uniform-T
        baseline stream through the session path."""
        cfg, params, bn, frames = setup
        c = dataclasses.replace(cfg, conv_exec="gated", mixed_time=mixed)
        d = sy.compile_detector(c, params, bn)
        sess = d.new_session(batch=2)
        s1, s2 = sess.step(frames[0]), sess.step(frames[1])
        assert s1.head.shape == s2.head.shape
        assert bool(jnp.isfinite(s1.head).all() & jnp.isfinite(s2.head).all())
        _, head0 = d.detect(frames[0])
        np.testing.assert_array_equal(np.asarray(s1.head), np.asarray(head0))

    def test_non_snn_mode_has_no_sessions(self, setup):
        cfg, params, bn, _ = setup
        c = dataclasses.replace(cfg, mode="ann", conv_exec="dense")
        d = sy.compile_detector(c, params, bn)
        with pytest.raises(ValueError, match="mode"):
            d.new_session()


class TestFrameServing:
    """Acceptance: ≥8 concurrent FrameRequests through the slot pool, with
    compressed-executor outputs exactly matching the dense oracle."""

    N_REQUESTS, N_SLOTS, N_FRAMES = 9, 4, 2

    def _streams(self, cfg):
        rng = np.random.default_rng(7)
        h, w = cfg.input_hw
        return [
            (rng.integers(0, 256, (self.N_FRAMES, h, w, 3)) / 255.0).astype(np.float32)
            for _ in range(self.N_REQUESTS)
        ]

    @pytest.mark.parametrize("executor", ["gated", "pallas"])
    def test_slot_pool_matches_dense_oracle(self, setup, executor):
        cfg, params, bn, _ = setup
        streams = self._streams(cfg)
        d = sy.compile_detector(
            dataclasses.replace(cfg, conv_exec=executor), params, bn
        )
        eng = Engine(d, n_slots=self.N_SLOTS)
        reqs = [FrameRequest(rid=r, frames=s) for r, s in enumerate(streams)]
        for fr in reqs:
            eng.submit(fr)
        done = eng.run()
        assert len(done) == self.N_REQUESTS and all(r.done for r in done)
        assert all(len(r.out) == self.N_FRAMES for r in reqs)

        # oracle: each stream through its own dense sequential session
        dense = sy.compile_detector(
            dataclasses.replace(cfg, conv_exec="dense"), params, bn
        )
        for fr in reqs:
            solo = dense.new_session(batch=1)
            for f, served_head, served_dets in zip(fr.frames, fr.heads, fr.out):
                step = solo.step(f[None])
                # bit-exact: compressed executors share the dense oracle's
                # integer-domain math (tests/conformance/)
                np.testing.assert_array_equal(
                    served_head, np.asarray(step.head[0])
                )
                np.testing.assert_array_equal(
                    served_dets.valid, np.asarray(step.detections.valid[0])
                )

    def test_slot_reuse_and_admission(self, det, setup):
        cfg, _, _, _ = setup
        streams = self._streams(cfg)
        eng = Engine(det, n_slots=1)  # single slot recycled for every stream
        for r, s in enumerate(streams[:3]):
            eng.submit(FrameRequest(rid=r, frames=s))
        done = eng.run()
        assert [r.rid for r in done] == [0, 1, 2]

    def test_cores_satisfy_engine_api(self, det):
        assert isinstance(DetectorEngineCore(det, n_slots=2), EngineAPI)
        assert issubclass(LMEngineCore, object) and hasattr(LMEngineCore, "admit")

    def test_bad_frames_rejected_at_admission(self, det, setup):
        _, _, _, frames = setup
        eng = Engine(det, n_slots=2)
        eng.submit(FrameRequest(rid=0, frames=np.zeros((8, 8, 3))))  # no F axis
        with pytest.raises(ValueError, match="FrameRequest"):
            eng.run()

    def test_engine_rejects_unknown_config(self):
        with pytest.raises(TypeError, match="serve"):
            Engine(object(), None)
