"""Compile-once detector API: CompiledDetector plan ownership + staleness,
DetectorSession streaming semantics (membrane carryover, reset()/state
contract, batch-of-sessions, mixed (1,3) schedule), and FrameRequest
serving through the Engine slot pool with executor parity vs the dense
oracle."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core import pruning
from repro.models import snn_yolo as sy
from repro.models.postprocess import Detections
from repro.serve import (
    AdmissionPolicy,
    CompiledDetector,
    DetectorEngineCore,
    Engine,
    EngineAPI,
    FrameRequest,
    LMEngineCore,
    StalePlanError,
)
from repro.serve.detector import step_latency_ms


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(get_config("snn-det"))
    params, bn = sy.init_params(jax.random.PRNGKey(0), cfg)
    params = pruning.prune_tree(params, 0.8)
    rng = np.random.default_rng(0)
    h, w = cfg.input_hw
    # uint8-grid frames keep the bit-serial 8-bit encode path exact
    frames = jnp.asarray(rng.integers(0, 256, (6, 2, h, w, 3)) / 255.0, jnp.float32)
    # calibrated tdBN stats: fresh (0, 1) stats silence the deep layers of
    # an untrained net, which would make streaming tests vacuous
    bn = sy.calibrate_bn_state(params, bn, frames[0], cfg)
    return cfg, params, bn, frames


@pytest.fixture(scope="module")
def det(setup):
    cfg, params, bn, _ = setup
    return sy.compile_detector(
        dataclasses.replace(cfg, conv_exec="gated"), params, bn
    )


class TestCompiledDetector:
    def test_call_returns_detections(self, det, setup):
        _, _, _, frames = setup
        dets = det(frames[0])
        assert isinstance(dets, Detections)
        assert dets.boxes.shape[0] == 2 and dets.boxes.shape[-1] == 4
        assert dets.valid.dtype == jnp.bool_

    def test_plan_owned_and_stable(self, det, setup):
        _, _, _, frames = setup
        plan = det.plan
        assert plan is not None and plan.compressed_bytes < plan.dense_bytes
        det(frames[0])
        det(frames[1])
        assert det.plan is plan  # compiled once, never re-packed per call

    def test_dense_handle_owns_plan_and_float_handle_has_none(self, setup):
        """Quantized dense handles build the plan at compile time too —
        the dense executor reads its w_q/scale so every executor runs the
        same integer-domain math (the conformance suite's bit-exactness
        guarantee). Float handles have nothing to pack."""
        cfg, params, bn, frames = setup
        d = sy.compile_detector(cfg, params, bn)  # dense executor
        plan = d.plan
        assert plan is not None and plan.compressed_bytes < plan.dense_bytes
        d(frames[0])
        assert d.plan is plan  # compiled once, never re-packed
        f = sy.compile_detector(
            dataclasses.replace(cfg, weight_bits=0), params, bn
        )
        assert f.plan is None  # float weights: legacy fake-quant path
        f(frames[0])  # still serves

    def test_stale_params_raise(self, setup):
        cfg, params, bn, frames = setup
        d = sy.compile_detector(cfg, dict(params), bn)
        d(frames[0])
        # swap a weight leaf after compile: the owned plan no longer
        # describes the model -> every entry point must refuse
        d.params["encode"] = dict(d.params["encode"])
        d.params["encode"]["w"] = d.params["encode"]["w"] + 1e-3
        with pytest.raises(StalePlanError, match="compile"):
            d(frames[0])
        with pytest.raises(StalePlanError):
            d.detect(frames[0])

    def test_stale_params_raise_in_session(self, setup):
        cfg, params, bn, frames = setup
        d = sy.compile_detector(cfg, dict(params), bn)
        sess = d.new_session(batch=2)
        sess.step(frames[0])
        d.params["head"] = {"w": d.params["head"]["w"] * 2}
        with pytest.raises(StalePlanError):
            sess.step(frames[1])

    def test_forward_without_plan_raises(self, setup):
        """Migrated from the removed snn_yolo._cached_plan: the free
        function no longer auto-builds — plan ownership lives in the
        handle."""
        cfg, params, bn, frames = setup
        c = dataclasses.replace(cfg, conv_exec="pallas")
        with pytest.raises(ValueError, match="compile_detector"):
            sy.forward(params, bn, frames[0], c)

    def test_float_weights_cannot_compile_compressed(self, setup):
        cfg, params, bn, _ = setup
        c = dataclasses.replace(cfg, weight_bits=0, conv_exec="gated")
        with pytest.raises(ValueError, match="weight_bits"):
            sy.compile_detector(c, params, bn)

    def test_default_bn_state(self, setup):
        cfg, params, _, frames = setup
        d = sy.compile_detector(cfg, params)  # no bn given -> fresh stats
        assert set(d.bn_state) == {n for n in params if n != "head"}
        d(frames[0])  # runs


class TestDetectorSession:
    def test_cold_start_matches_stateless(self, det, setup):
        _, _, _, frames = setup
        sess = det.new_session(batch=2)
        step = sess.step(frames[0])
        dets, head = det.detect(frames[0])
        np.testing.assert_array_equal(np.asarray(step.head), np.asarray(head))
        np.testing.assert_array_equal(
            np.asarray(step.detections.scores), np.asarray(dets.scores)
        )

    def test_carryover_vs_fresh_parity_on_static_sequence(self, det, setup):
        """Replaying the same frame sequence from reset() reproduces the
        fresh session bit-exactly — carryover is a pure function of the
        streamed frames."""
        _, _, _, frames = setup
        sess = det.new_session(batch=2)
        heads_fresh = [np.asarray(sess.step(frames[0]).head) for _ in range(3)]
        sess.reset()
        heads_replay = [np.asarray(sess.step(frames[0]).head) for _ in range(3)]
        for a, b in zip(heads_fresh, heads_replay):
            np.testing.assert_array_equal(a, b)
        # and state genuinely flows: the warm second step differs from cold
        assert np.abs(heads_fresh[1] - heads_fresh[0]).max() > 0

    def test_reset_restores_cold_start_outputs(self, det, setup):
        _, _, _, frames = setup
        sess = det.new_session(batch=2)
        cold = np.asarray(sess.step(frames[0]).head)
        sess.step(frames[1])
        sess.step(frames[2])
        sess.reset()
        assert sess.frames_seen == 0
        np.testing.assert_array_equal(np.asarray(sess.step(frames[0]).head), cold)

    def test_state_contract(self, det, setup):
        _, _, _, frames = setup
        sess = det.new_session(batch=2)
        assert all(
            float(jnp.abs(v).max()) == 0.0
            for v in jax.tree_util.tree_leaves(sess.state)
        )
        assert "head" in sess.state  # the no-reset output accumulator
        sess.step(frames[0])
        assert any(
            float(jnp.abs(v).max()) > 0
            for v in jax.tree_util.tree_leaves(sess.state)
        )
        with pytest.raises(ValueError, match="batch"):
            sess.step(frames[0][:1])  # wrong batch size

    def test_batch_of_sessions_rows_independent(self, det, setup):
        """The vectorized path: row i of a batched session must equal an
        independent single-stream session fed row i's frames."""
        _, _, _, frames = setup
        batched = det.new_session(batch=2)
        outs = [np.asarray(batched.step(f).head) for f in frames[:3]]
        for row in range(2):
            solo = det.new_session(batch=1)
            for k, f in enumerate(frames[:3]):
                h = np.asarray(solo.step(f[row : row + 1]).head)
                np.testing.assert_array_equal(h[0], outs[k][row])

    def test_reset_out_of_range_raises(self, det):
        """Regression: jnp scatter drops OOB indices silently, so a typo'd
        stream index must fail loudly instead of resetting nothing."""
        sess = det.new_session(batch=2)
        with pytest.raises(IndexError, match="out of range"):
            sess.reset(2)
        sess.reset(-1)  # negative indices within range are fine

    def test_per_row_reset(self, det, setup):
        _, _, _, frames = setup
        sess = det.new_session(batch=2)
        cold = np.asarray(sess.step(frames[0]).head)
        warm = np.asarray(sess.step(frames[0]).head)
        sess.reset()
        sess.step(frames[0])
        sess.reset(0)  # row 0 cold, row 1 stays warm
        h = np.asarray(sess.step(frames[0]).head)
        np.testing.assert_array_equal(h[0], cold[0])
        np.testing.assert_array_equal(h[1], warm[1])

    @pytest.mark.parametrize("mixed", [True, False])
    def test_time_step_schedules(self, setup, mixed):
        """Both the paper's mixed (1, 3) schedule and the uniform-T
        baseline stream through the session path."""
        cfg, params, bn, frames = setup
        c = dataclasses.replace(cfg, conv_exec="gated", mixed_time=mixed)
        d = sy.compile_detector(c, params, bn)
        sess = d.new_session(batch=2)
        s1, s2 = sess.step(frames[0]), sess.step(frames[1])
        assert s1.head.shape == s2.head.shape
        assert bool(jnp.isfinite(s1.head).all() & jnp.isfinite(s2.head).all())
        _, head0 = d.detect(frames[0])
        np.testing.assert_array_equal(np.asarray(s1.head), np.asarray(head0))

    def test_non_snn_mode_has_no_sessions(self, setup):
        cfg, params, bn, _ = setup
        c = dataclasses.replace(cfg, mode="ann", conv_exec="dense")
        d = sy.compile_detector(c, params, bn)
        with pytest.raises(ValueError, match="mode"):
            d.new_session()


class TestFrameServing:
    """Acceptance: ≥8 concurrent FrameRequests through the slot pool, with
    compressed-executor outputs exactly matching the dense oracle."""

    N_REQUESTS, N_SLOTS, N_FRAMES = 9, 4, 2

    def _streams(self, cfg):
        rng = np.random.default_rng(7)
        h, w = cfg.input_hw
        return [
            (rng.integers(0, 256, (self.N_FRAMES, h, w, 3)) / 255.0).astype(np.float32)
            for _ in range(self.N_REQUESTS)
        ]

    @pytest.mark.parametrize("executor", ["gated", "pallas"])
    def test_slot_pool_matches_dense_oracle(self, setup, executor):
        cfg, params, bn, _ = setup
        streams = self._streams(cfg)
        d = sy.compile_detector(
            dataclasses.replace(cfg, conv_exec=executor), params, bn
        )
        eng = Engine(d, n_slots=self.N_SLOTS)
        reqs = [FrameRequest(rid=r, frames=s) for r, s in enumerate(streams)]
        for fr in reqs:
            eng.submit(fr)
        done = eng.run()
        assert len(done) == self.N_REQUESTS and all(r.done for r in done)
        assert all(len(r.out) == self.N_FRAMES for r in reqs)

        # oracle: each stream through its own dense sequential session
        dense = sy.compile_detector(
            dataclasses.replace(cfg, conv_exec="dense"), params, bn
        )
        for fr in reqs:
            solo = dense.new_session(batch=1)
            for f, served_head, served_dets in zip(fr.frames, fr.heads, fr.out):
                step = solo.step(f[None])
                # bit-exact: compressed executors share the dense oracle's
                # integer-domain math (tests/conformance/)
                np.testing.assert_array_equal(
                    served_head, np.asarray(step.head[0])
                )
                np.testing.assert_array_equal(
                    served_dets.valid, np.asarray(step.detections.valid[0])
                )

    def test_slot_reuse_and_admission(self, det, setup):
        cfg, _, _, _ = setup
        streams = self._streams(cfg)
        eng = Engine(det, n_slots=1)  # single slot recycled for every stream
        for r, s in enumerate(streams[:3]):
            eng.submit(FrameRequest(rid=r, frames=s))
        done = eng.run()
        assert [r.rid for r in done] == [0, 1, 2]

    def test_cores_satisfy_engine_api(self, det):
        assert isinstance(DetectorEngineCore(det, n_slots=2), EngineAPI)
        assert issubclass(LMEngineCore, object) and hasattr(LMEngineCore, "admit")

    def test_bad_frames_rejected_at_submit(self, det, setup):
        """Malformed requests get a typed rejection at submit — they never
        enter the queue, so the run loop never sees them."""
        _, _, _, frames = setup
        eng = Engine(det, n_slots=2)
        res = eng.submit(FrameRequest(rid=0, frames=np.zeros((8, 8, 3))))  # no F axis
        assert not res and not res.accepted
        assert "FrameRequest" in res.reason
        assert eng.queue == [] and eng.rejected[0].rid == 0
        out = eng.run()
        assert out.status == "drained" and len(out) == 0

    def test_mismatched_hw_rejected_before_touching_state(self, det, setup):
        """Regression: a FrameRequest whose H/W/channels don't match
        cfg.input_hw used to reset the slot's membrane and then explode
        later inside the batched step with an unrelated np.stack error.
        admit must validate FIRST and leave all state untouched."""
        cfg, _, _, frames = setup
        core = DetectorEngineCore(det, n_slots=2)
        h, w = cfg.input_hw
        good = FrameRequest(rid=0, frames=np.zeros((2, h, w, 3), np.float32))
        core.admit(good, 0)
        mem_before = jax.tree_util.tree_map(np.asarray, core._mem)
        rows_before = (dict(core._row_of), list(core._rows), set(core._cold))
        bad = FrameRequest(rid=1, frames=np.zeros((2, h + 2, w, 3), np.float32))
        with pytest.raises(ValueError, match="input_hw"):
            core.admit(bad, 1)
        assert (dict(core._row_of), list(core._rows), set(core._cold)) == rows_before
        for a, b in zip(
            jax.tree_util.tree_leaves(mem_before),
            jax.tree_util.tree_leaves(
                jax.tree_util.tree_map(np.asarray, core._mem)
            ),
        ):
            np.testing.assert_array_equal(a, b)
        # wrong channel count is caught too
        with pytest.raises(ValueError, match="input_hw"):
            core.admit(
                FrameRequest(rid=2, frames=np.zeros((2, h, w, 1), np.float32)), 1
            )

    def test_engine_rejects_unknown_config(self):
        with pytest.raises(TypeError, match="serve"):
            Engine(object(), None)


class TestMegabatchServing:
    """The megabatched continuous-stream core: capacity buckets, join/leave
    row remapping, inactive-lane masking, double-buffered upload — all
    pinned bit-identical to independent single-stream DetectorSessions."""

    def _streams(self, cfg, lengths, seed=11):
        rng = np.random.default_rng(seed)
        h, w = cfg.input_hw
        return [
            (rng.integers(0, 256, (f, h, w, 3)) / 255.0).astype(np.float32)
            for f in lengths
        ]

    def _solo_replay(self, det, frames):
        solo = det.new_session(batch=1)
        return [np.asarray(solo.step(f[None]).head[0]) for f in frames]

    def test_join_leave_remap_parity_vs_solo_sessions(self, det, setup):
        """Staggered stream lengths + fewer slots than requests: every
        tick sees joins/leaves, rows swap-remove and the capacity bucket
        grows and shrinks — and every served head must STILL be
        bit-identical to an independent single-stream session replay."""
        cfg, _, _, _ = setup
        lengths = [1, 4, 2, 5, 3, 1, 2, 6, 1, 3]
        streams = self._streams(cfg, lengths)
        eng = Engine(det, n_slots=4)
        reqs = [FrameRequest(rid=r, frames=s) for r, s in enumerate(streams)]
        for fr in reqs:
            assert eng.submit(fr)
        out = eng.run()
        assert out.status == "drained" and len(out) == len(lengths)
        for fr in reqs:
            assert len(fr.heads) == len(fr.frames)
            for served, ref in zip(fr.heads, self._solo_replay(det, fr.frames)):
                np.testing.assert_array_equal(served, ref)

    def test_capacity_buckets_grow_and_shrink_without_losing_state(self, det, setup):
        """Crossing a bucket boundary (pad) and draining back down
        (shrink) must preserve resident rows bit-exactly."""
        cfg, _, _, _ = setup
        core = DetectorEngineCore(det, n_slots=16, min_bucket=2)
        assert core.cap == 2
        streams = self._streams(cfg, [6] * 5 + [2] * 2)
        reqs = [FrameRequest(rid=r, frames=s) for r, s in enumerate(streams)]
        # two long streams fill the min bucket...
        core.admit(reqs[0], 0)
        core.admit(reqs[1], 1)
        active = {0: reqs[0], 1: reqs[1]}
        core.step(active)
        assert core.cap == 2
        # ...then three more force growth 2 -> 4 -> 8
        for slot, r in [(2, reqs[2]), (3, reqs[3]), (4, reqs[4])]:
            core.admit(r, slot)
            active[slot] = r
        assert core.cap == 8
        while active:
            for slot in core.step(active):
                del active[slot]
        assert core.cap == 2  # drained back to the min bucket
        for fr in reqs[:5]:
            for served, ref in zip(fr.heads, self._solo_replay(det, fr.frames)):
                np.testing.assert_array_equal(served, ref)

    def test_inactive_lanes_masked_out_of_the_step(self, det, setup):
        """Satellite: dead bucket lanes must not evolve membrane between
        occupants, and active-row outputs must be bit-identical no matter
        what the dead lanes hold."""
        cfg, _, _, frames = setup
        mem = det.zero_state(4)
        active = np.array([True, True, False, False])
        batch = np.zeros((4,) + frames[0].shape[1:], np.float32)
        batch[:2] = np.asarray(frames[0])
        h1, m1, _ = det.masked_step(
            jnp.asarray(batch), mem, jnp.asarray(active)
        )
        # same active rows, garbage in the dead lanes
        garbage = batch.copy()
        garbage[2:] = 0.7
        h2, m2, _ = det.masked_step(
            jnp.asarray(garbage), mem, jnp.asarray(active)
        )
        np.testing.assert_array_equal(np.asarray(h1[:2]), np.asarray(h2[:2]))
        for a, b in zip(
            jax.tree_util.tree_leaves(m1), jax.tree_util.tree_leaves(m2)
        ):
            np.testing.assert_array_equal(np.asarray(a[:2]), np.asarray(b[:2]))
            # dead lanes: membrane frozen at its prior (zero) state
            assert float(jnp.abs(a[2:]).max()) == 0.0
            assert float(jnp.abs(b[2:]).max()) == 0.0

    def test_cold_mask_resets_a_dirty_lane_in_step(self, det, setup):
        """Satellite: the masked cold-start reset happens INSIDE the jitted
        step — a lane holding a retired stream's stale membrane must serve
        its new occupant bit-identically to an explicitly zeroed lane."""
        cfg, _, _, frames = setup
        batch = np.asarray(frames[0][:1])
        batch = np.concatenate([batch, batch], axis=0)  # rows 0 and 1 alike
        active = jnp.asarray(np.array([True, True]))
        no_cold = jnp.asarray(np.zeros(2, bool))
        # dirty both rows' membrane, then re-serve with row 1 marked cold
        _, dirty, _ = det.masked_step(
            jnp.asarray(batch), det.zero_state(2), active
        )
        h_cold, m_cold, _ = det.masked_step(
            jnp.asarray(batch), dirty, active,
            jnp.asarray(np.array([False, True])),
        )
        # reference: row 1 explicitly zeroed before the step
        zeroed = jax.tree_util.tree_map(lambda v: v.at[1].set(0.0), dirty)
        h_ref, m_ref, _ = det.masked_step(
            jnp.asarray(batch), zeroed, active, no_cold
        )
        np.testing.assert_array_equal(np.asarray(h_cold), np.asarray(h_ref))
        for a, b in zip(
            jax.tree_util.tree_leaves(m_cold), jax.tree_util.tree_leaves(m_ref)
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_double_buffered_upload_changes_nothing(self, det, setup):
        """The staged next-tick upload is a pure latency optimization: a
        long steady-state stream (staging hits every tick) must serve
        bit-identically to the solo replay."""
        cfg, _, _, _ = setup
        (stream,) = self._streams(cfg, [6])
        eng = Engine(det, n_slots=2)
        fr = FrameRequest(rid=0, frames=stream)
        eng.submit(fr)
        eng.run()
        for served, ref in zip(fr.heads, self._solo_replay(det, stream)):
            np.testing.assert_array_equal(served, ref)

    def test_run_truncation_reports_pending(self, det, setup):
        """Satellite regression: run(max_steps) exhaustion used to drop
        queued and in-flight requests silently — they appeared in neither
        finished nor any error. Now the result says 'truncated' and lists
        every undone request, and a later run() resumes them."""
        cfg, _, _, _ = setup
        streams = self._streams(cfg, [4, 4, 4])
        eng = Engine(det, n_slots=2)
        reqs = [FrameRequest(rid=r, frames=s) for r, s in enumerate(streams)]
        for fr in reqs:
            eng.submit(fr)
        out = eng.run(max_steps=2)
        assert out.status == "truncated" and not out.drained
        assert len(out) == 0  # nothing finished in 2 ticks of 4-frame streams
        assert {r.rid for r in out.pending} == {0, 1, 2}
        assert all(not r.done for r in out.pending)
        # resume: in-flight slot state survived, everything drains
        out2 = eng.run()
        assert out2.status == "drained" and {r.rid for r in out2} == {0, 1, 2}
        for fr in reqs:  # and the interrupted run didn't corrupt anything
            for served, ref in zip(fr.heads, self._solo_replay(det, fr.frames)):
                np.testing.assert_array_equal(served, ref)

    def test_bounded_queue_rejects(self, det, setup):
        cfg, _, _, _ = setup
        streams = self._streams(cfg, [2] * 5)
        eng = Engine(
            det, n_slots=2, admission=AdmissionPolicy(max_queue=2)
        )
        results = [
            eng.submit(FrameRequest(rid=r, frames=s))
            for r, s in enumerate(streams)
        ]
        assert [bool(r) for r in results] == [True, True, False, False, False]
        assert all(r.reason == "queue-full" for r in results[2:])
        assert [r.rid for r in eng.rejected] == [2, 3, 4]
        out = eng.run()
        assert out.status == "drained" and {r.rid for r in out} == {0, 1}

    def test_shed_oldest_keeps_fresh_traffic(self, det, setup):
        cfg, _, _, _ = setup
        streams = self._streams(cfg, [2] * 5)
        eng = Engine(
            det,
            n_slots=2,
            admission=AdmissionPolicy(max_queue=2, on_full="shed-oldest"),
        )
        reqs = [FrameRequest(rid=r, frames=s) for r, s in enumerate(streams)]
        r0, r1 = eng.submit(reqs[0]), eng.submit(reqs[1])
        assert r0 and r1 and r0.shed == ()
        r2 = eng.submit(reqs[2])  # queue full: rid 0 (oldest) is shed
        assert r2 and r2.reason == "shed-oldest"
        assert tuple(r.rid for r in r2.shed) == (0,)
        assert [r.rid for r in eng.queue] == [1, 2]
        out = eng.run()
        assert {r.rid for r in out} == {1, 2}
        assert not reqs[0].done

    def test_admission_policy_validates(self):
        with pytest.raises(ValueError, match="on_full"):
            AdmissionPolicy(on_full="drop-newest")
        with pytest.raises(ValueError, match="max_queue"):
            AdmissionPolicy(max_queue=0)

    def test_step_latency_percentiles_over_synthetic_load(self, det, setup):
        cfg, _, _, _ = setup
        streams = self._streams(cfg, [3] * 6)
        eng = Engine(det, n_slots=4)
        for r, s in enumerate(streams):
            eng.submit(FrameRequest(rid=r, frames=s))
        eng.run()
        lat = step_latency_ms(eng.core.step_wall)
        assert set(lat) == {"step_p50_ms", "step_p95_ms", "step_p99_ms"}
        assert 0 < lat["step_p50_ms"] <= lat["step_p95_ms"] <= lat["step_p99_ms"]
        assert len(eng.core.step_wall) >= 3
