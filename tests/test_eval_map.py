"""Hand-computed fixtures for repro.eval.detection_map: known AP values
for small synthetic prediction sets (duplicate detections, no-prediction
classes, cross-image ranking, localization misses) plus the
target-encoding ↔ decode_head inverse contract."""
from __future__ import annotations

import numpy as np
import pytest

from repro.data import synthetic_detection as sd
from repro.eval import detection_map as dm
from repro.models import snn_yolo as sy
from repro.models.postprocess import Detections, postprocess


def box(cx, cy, w, h):
    return np.array([cx, cy, w, h], np.float64)


class TestIoU:
    def test_identical_boxes(self):
        b = box(0.5, 0.5, 0.2, 0.2)[None]
        np.testing.assert_allclose(dm.iou_matrix_xywh(b, b), [[1.0]])

    def test_disjoint_boxes(self):
        a = box(0.2, 0.2, 0.1, 0.1)[None]
        b = box(0.8, 0.8, 0.1, 0.1)[None]
        np.testing.assert_allclose(dm.iou_matrix_xywh(a, b), [[0.0]])

    def test_half_shift_is_one_third(self):
        # inter = 0.1*0.2 = 0.02, union = 0.04+0.04-0.02 = 0.06 -> 1/3
        a = box(0.5, 0.5, 0.2, 0.2)[None]
        b = box(0.6, 0.5, 0.2, 0.2)[None]
        np.testing.assert_allclose(dm.iou_matrix_xywh(a, b), [[1 / 3]], atol=1e-9)


class TestMatching:
    def test_higher_score_matches_first(self):
        """Greedy VOC rule: the 0.9 pred takes the only GT even though the
        0.8 pred overlaps it more — the late duplicate is a FP."""
        gt = box(0.5, 0.5, 0.2, 0.2)[None]
        preds = np.stack([box(0.52, 0.5, 0.2, 0.2), box(0.5, 0.5, 0.2, 0.2)])
        tp = dm.match_image(preds, np.array([0.9, 0.8]), gt)
        np.testing.assert_array_equal(tp, [True, False])

    def test_below_threshold_is_fp(self):
        gt = box(0.5, 0.5, 0.2, 0.2)[None]
        pred = box(0.8, 0.8, 0.2, 0.2)[None]
        tp = dm.match_image(pred, np.array([0.9]), gt, iou_threshold=0.5)
        np.testing.assert_array_equal(tp, [False])

    def test_empty_inputs(self):
        assert dm.match_image(np.zeros((0, 4)), np.zeros(0), np.zeros((1, 4))).size == 0
        np.testing.assert_array_equal(
            dm.match_image(np.zeros((1, 4)), np.ones(1), np.zeros((0, 4))), [False]
        )


class TestAveragePrecision:
    def test_perfect_detector_ap_1(self):
        assert dm.average_precision(np.array([0.9]), np.array([True]), 1) == 1.0

    def test_no_predictions_present_class_ap_0(self):
        assert dm.average_precision(np.zeros(0), np.zeros(0, bool), 3) == 0.0

    def test_absent_class_is_nan(self):
        assert np.isnan(dm.average_precision(np.array([0.5]), np.array([False]), 0))

    def test_duplicate_detection_hand_computed(self):
        """2 GT. Ranked [TP, dup-FP, TP] -> recall (.5,.5,1), precision
        (1,.5,2/3), envelope (1,2/3,2/3): AP = .5*1 + .5*2/3 = 5/6."""
        scores = np.array([0.9, 0.8, 0.7])
        tp = np.array([True, False, True])
        assert dm.average_precision(scores, tp, 2) == pytest.approx(5 / 6)

    def test_trailing_fp_after_full_recall_free(self):
        """VOC envelope: an FP ranked after recall has reached 1.0 does not
        reduce AP (precision envelope at r=1 is still 1)."""
        assert dm.average_precision(
            np.array([0.9, 0.1]), np.array([True, False]), 1
        ) == pytest.approx(1.0)

    def test_fp_ranked_first_hand_computed(self):
        """Ranked [FP(.9), TP(.8)] over 2 GT -> AP = .5 * .5 = .25."""
        assert dm.average_precision(
            np.array([0.9, 0.8]), np.array([False, True]), 2
        ) == pytest.approx(0.25)


class TestEvaluateDetections:
    def test_perfect_single_image(self):
        gt = [{"boxes": box(0.5, 0.5, 0.2, 0.2)[None], "classes": np.array([0])}]
        pred = [{"boxes": box(0.5, 0.5, 0.2, 0.2)[None],
                 "scores": np.array([0.9]), "classes": np.array([0])}]
        r = dm.evaluate_detections(pred, gt, num_classes=3)
        assert r["map"] == 1.0
        assert r["per_class_ap"][0] == 1.0
        assert np.isnan(r["per_class_ap"][1]) and np.isnan(r["per_class_ap"][2])
        assert r["n_gt"] == [1, 0, 0]

    def test_unpredicted_present_class_drags_mean(self):
        """class0 found (AP 1), class1 present but never predicted (AP 0)
        -> mAP 0.5."""
        gt = [{
            "boxes": np.stack([box(0.3, 0.3, 0.2, 0.2), box(0.7, 0.7, 0.2, 0.2)]),
            "classes": np.array([0, 1]),
        }]
        pred = [{"boxes": box(0.3, 0.3, 0.2, 0.2)[None],
                 "scores": np.array([0.9]), "classes": np.array([0])}]
        r = dm.evaluate_detections(pred, gt, num_classes=2)
        assert r["map"] == pytest.approx(0.5)

    def test_fp_on_absent_class_not_counted(self):
        """Predictions for a class with zero GT are excluded from the mean
        (VOC behavior) — they don't nuke mAP to 0."""
        gt = [{"boxes": box(0.5, 0.5, 0.2, 0.2)[None], "classes": np.array([0])}]
        pred = [{
            "boxes": np.stack([box(0.5, 0.5, 0.2, 0.2), box(0.2, 0.2, 0.1, 0.1)]),
            "scores": np.array([0.9, 0.8]),
            "classes": np.array([0, 1]),
        }]
        r = dm.evaluate_detections(pred, gt, num_classes=2)
        assert r["map"] == 1.0 and np.isnan(r["per_class_ap"][1])

    def test_cross_image_ranking_hand_computed(self):
        """Pooled ranking across images: img1 has a high-score FP, img2 a
        lower-score TP -> ranked [FP, TP], 2 GT total, AP = 0.25."""
        gts = [
            {"boxes": box(0.5, 0.5, 0.2, 0.2)[None], "classes": np.array([0])},
            {"boxes": box(0.5, 0.5, 0.2, 0.2)[None], "classes": np.array([0])},
        ]
        preds = [
            {"boxes": box(0.9, 0.9, 0.05, 0.05)[None],
             "scores": np.array([0.9]), "classes": np.array([0])},
            {"boxes": box(0.5, 0.5, 0.2, 0.2)[None],
             "scores": np.array([0.8]), "classes": np.array([0])},
        ]
        r = dm.evaluate_detections(preds, gts, num_classes=1)
        assert r["map"] == pytest.approx(0.25)

    def test_map50_of_empty_split_is_nan(self):
        assert np.isnan(dm.map50([], [], num_classes=3))

    def test_accepts_detections_namedtuple_rows(self):
        dets = Detections(
            boxes=np.array([[[0.5, 0.5, 0.2, 0.2], [0.0, 0.0, 0.0, 0.0]]]),
            scores=np.array([[0.9, 0.0]]),
            classes=np.array([[0, 0]]),
            valid=np.array([[True, False]]),
        )
        gt = [{"boxes": box(0.5, 0.5, 0.2, 0.2)[None], "classes": np.array([0])}]
        assert dm.map50(dm.detections_to_predictions(dets), gt, num_classes=1) == 1.0
        assert dm.map50([dets.row(0)], gt, num_classes=1) == 1.0


class TestTargetDecodeInverse:
    """synthetic_detection targets and snn_yolo.decode_head are exact
    inverses: a head built from a sample's target tensor must decode and
    postprocess to mAP 1.0 against that sample's ground-truth boxes."""

    def test_anchors_pinned_to_model(self):
        assert sd.ANCHORS == sy.DEFAULT_ANCHORS

    def _head_from_target(self, tgt):
        """Invert decode_head: txy -> logit(offset), twh passthrough,
        obj/cls -> saturated logits."""
        head = np.zeros_like(tgt)
        off = np.clip(tgt[..., 0:2], 1e-4, 1 - 1e-4)
        head[..., 0:2] = np.log(off / (1 - off))
        head[..., 2:4] = tgt[..., 2:4]
        head[..., 4] = np.where(tgt[..., 4] > 0, 12.0, -12.0)
        head[..., 5:] = np.where(tgt[..., 5:] > 0, 12.0, -12.0)
        return head[None]

    def test_oracle_head_reaches_map_1(self):
        hw, grid_div = (96, 160), 16
        for idx in range(25):
            img, tgt, (boxes, classes) = sd.sample(idx, split="val", hw=hw,
                                                   grid_div=grid_div)
            if int(tgt[..., 4].sum()) == len(boxes):  # no cell/anchor collisions
                break
        else:
            pytest.fail("no collision-free sample in the first 25 val indices")
        dets = postprocess(self._head_from_target(tgt), sy.DEFAULT_ANCHORS,
                           score_threshold=0.25, max_detections=32)
        gt = [{"boxes": np.asarray(boxes, np.float64),
               "classes": np.asarray(classes, np.int64)}]
        score = dm.map50(dm.detections_to_predictions(dets), gt,
                         num_classes=len(sd.CLASSES))
        assert score == pytest.approx(1.0, abs=1e-6)
