"""Serving engine: continuous batching correctness (== sequential decode),
slot reuse, multi-family support, per-slot position handling."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.models import zoo
from repro.serve import Engine, Request


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "rwkv6-3b", "zamba2-7b", "olmoe-1b-7b"])
def test_engine_serves_all_families(arch):
    cfg = smoke_config(get_config(arch))
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, n_slots=3, max_seq=64)
    for r in range(5):
        eng.submit(Request(rid=r, prompt=list(range(1, 4 + r)), max_new_tokens=6))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.out) == 6 for r in done)


def test_continuous_batching_matches_sequential():
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(7))
    prompts = [[5, 9, 2, 11, 4], [1, 2, 3], [7, 7, 7, 7, 7, 7, 7]]

    eng = Engine(cfg, params, n_slots=2, max_seq=64)  # fewer slots than reqs
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    batched = {r.rid: r.out for r in eng.run()}

    for i, prompt in enumerate(prompts):
        seq = _sequential_decode(api, params, prompt, 6, max_seq=64)
        assert batched[i] == seq, f"request {i}: {batched[i]} != {seq}"


def _sequential_decode(api, params, prompt, n, *, max_seq):
    logits, small = api.prefill_fn(params, jnp.asarray(np.asarray(prompt, np.int32)[None]))
    cache = api.init_cache(1, max_seq)
    plen = len(prompt)
    cache = type(cache)(
        cache.k.at[:, :, :plen].set(small.k.astype(cache.k.dtype)),
        cache.v.at[:, :, :plen].set(small.v.astype(cache.v.dtype)),
    )
    seq = [int(jnp.argmax(logits[0]))]
    pos = plen
    for _ in range(n - 1):
        lg, cache = api.decode_fn(params, cache, jnp.asarray([seq[-1]], jnp.int32), jnp.int32(pos))
        seq.append(int(jnp.argmax(lg[0])))
        pos += 1
    return seq


def test_slot_reuse():
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, n_slots=1, max_seq=32)
    for r in range(3):
        eng.submit(Request(rid=r, prompt=[1 + r, 2, 3], max_new_tokens=4))
    done = eng.run()
    assert len(done) == 3  # single slot recycled three times


def test_eos_terminates():
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(3))
    # find the greedy first token, then use it as EOS for a second request
    eng = Engine(cfg, params, n_slots=1, max_seq=32)
    eng.submit(Request(rid=0, prompt=[4, 5, 6], max_new_tokens=8))
    first = eng.run()[0].out
    eng2 = Engine(cfg, params, n_slots=1, max_seq=32)
    eng2.submit(Request(rid=0, prompt=[4, 5, 6], max_new_tokens=8, eos_id=first[1]))
    out = eng2.run()[0].out
    assert len(out) <= len(first)
    assert out[-1] == first[1]
