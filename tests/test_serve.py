"""Serving engine: continuous batching correctness (== sequential decode),
slot reuse, multi-family support, per-slot position handling."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.models import zoo
from repro.serve import Engine, Request


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "rwkv6-3b", "zamba2-7b", "olmoe-1b-7b"])
def test_engine_serves_all_families(arch):
    cfg = smoke_config(get_config(arch))
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, n_slots=3, max_seq=64)
    for r in range(5):
        eng.submit(Request(rid=r, prompt=list(range(1, 4 + r)), max_new_tokens=6))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.out) == 6 for r in done)


def test_continuous_batching_matches_sequential():
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(7))
    prompts = [[5, 9, 2, 11, 4], [1, 2, 3], [7, 7, 7, 7, 7, 7, 7]]

    eng = Engine(cfg, params, n_slots=2, max_seq=64)  # fewer slots than reqs
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    batched = {r.rid: r.out for r in eng.run()}

    for i, prompt in enumerate(prompts):
        seq = _sequential_decode(api, params, prompt, 6, max_seq=64)
        assert batched[i] == seq, f"request {i}: {batched[i]} != {seq}"


def _sequential_decode(api, params, prompt, n, *, max_seq):
    logits, small = api.prefill_fn(params, jnp.asarray(np.asarray(prompt, np.int32)[None]))
    cache = api.init_cache(1, max_seq)
    plen = len(prompt)
    cache = type(cache)(
        cache.k.at[:, :, :plen].set(small.k.astype(cache.k.dtype)),
        cache.v.at[:, :, :plen].set(small.v.astype(cache.v.dtype)),
    )
    seq = [int(jnp.argmax(logits[0]))]
    pos = plen
    for _ in range(n - 1):
        lg, cache = api.decode_fn(params, cache, jnp.asarray([seq[-1]], jnp.int32), jnp.int32(pos))
        seq.append(int(jnp.argmax(lg[0])))
        pos += 1
    return seq


def test_slot_reuse():
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, n_slots=1, max_seq=32)
    for r in range(3):
        eng.submit(Request(rid=r, prompt=[1 + r, 2, 3], max_new_tokens=4))
    done = eng.run()
    assert len(done) == 3  # single slot recycled three times


def test_prefill_bucketing_bounds_compile_cache():
    """Satellite regression: _prefill_cache used to hold one jit entry per
    EXACT prompt length (unbounded under varied traffic). Bucketed pad+mask
    prefill keeps one entry per power-of-two bucket — and every request
    still decodes exactly like the sequential oracle."""
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(5))
    eng = Engine(cfg, params, n_slots=3, max_seq=64)
    rng = np.random.default_rng(3)
    lengths = [3, 4, 5, 6, 7, 9, 11, 13, 17, 21]  # 10 distinct lengths
    prompts = [list(rng.integers(1, cfg.vocab_size, n)) for n in lengths]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    done = eng.run()
    assert done.status == "drained" and len(done) == len(prompts)
    # lengths <= 16 share one bucket, 17/21 share the 32 bucket
    assert sorted(eng.core._prefill_cache) == [16, 32]
    batched = {r.rid: r.out for r in done}
    for i, p in enumerate(prompts):
        assert batched[i] == _sequential_decode(api, params, p, 5, max_seq=64)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "olmoe-1b-7b"])
@pytest.mark.parametrize("serve_fast", [True, False])
def test_bucketed_prefill_first_token_logits_bit_exact(arch, serve_fast):
    """Pad+mask prefill at a bucketed length must reproduce the
    exact-length prefill BIT-exactly: first-token logits AND the prompt's
    cache rows (the only rows the engine ever scatters)."""
    import dataclasses

    cfg = dataclasses.replace(
        smoke_config(get_config(arch)), serve_fast=serve_fast
    )
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    prompt = [5, 9, 2, 11, 4]
    plen = len(prompt)
    exact_logits, exact_cache = api.prefill_fn(
        params, jnp.asarray(np.asarray(prompt, np.int32)[None])
    )
    padded = np.zeros((1, 16), np.int32)
    padded[0, :plen] = prompt
    bucket_logits, bucket_cache = api.prefill_fn(
        params, jnp.asarray(padded), valid_len=jnp.int32(plen)
    )
    np.testing.assert_array_equal(
        np.asarray(exact_logits), np.asarray(bucket_logits)
    )
    np.testing.assert_array_equal(
        np.asarray(exact_cache.k[:, :, :plen], np.float32),
        np.asarray(bucket_cache.k[:, :, :plen], np.float32),
    )
    np.testing.assert_array_equal(
        np.asarray(exact_cache.v[:, :, :plen], np.float32),
        np.asarray(bucket_cache.v[:, :, :plen], np.float32),
    )


def test_recurrent_families_keep_exact_length_prefill():
    """ssm/hybrid prefill folds the whole padded sequence into O(1) state,
    so bucketing would contaminate it — they stay exact-length."""
    cfg = smoke_config(get_config("rwkv6-3b"))
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, n_slots=2, max_seq=32)
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=3))
    eng.submit(Request(rid=1, prompt=[4, 5, 6, 7], max_new_tokens=3))
    done = eng.run()
    assert len(done) == 2
    assert not eng.core._bucketed
    assert sorted(eng.core._prefill_cache) == [3, 4]  # exact lengths


def test_lm_run_truncation_reports_pending():
    """max_steps exhaustion surfaces queued AND in-flight requests in
    .pending with done=False instead of dropping them silently."""
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, n_slots=1, max_seq=32)
    for r in range(3):
        eng.submit(Request(rid=r, prompt=[1 + r, 2, 3], max_new_tokens=8))
    out = eng.run(max_steps=2)
    assert out.status == "truncated"
    assert {r.rid for r in out.pending} == {0, 1, 2} - {r.rid for r in out}
    assert all(not r.done for r in out.pending)
    out2 = eng.run()  # resumes: in-flight slot state survived
    assert out2.status == "drained" and len(out2) == 3


def test_eos_terminates():
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(3))
    # find the greedy first token, then use it as EOS for a second request
    eng = Engine(cfg, params, n_slots=1, max_seq=32)
    eng.submit(Request(rid=0, prompt=[4, 5, 6], max_new_tokens=8))
    first = eng.run()[0].out
    eng2 = Engine(cfg, params, n_slots=1, max_seq=32)
    eng2.submit(Request(rid=0, prompt=[4, 5, 6], max_new_tokens=8, eos_id=first[1]))
    out = eng2.run()[0].out
    assert len(out) <= len(first)
    assert out[-1] == first[1]
