"""Training substrate: optimizer math, microbatch-accumulation equivalence,
int8 moments, grad compression, LR schedule — plus an end-to-end loss-drop
run on the LM data pipeline."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.configs import get_config, smoke_config
from repro.data import lm_data
from repro.models import zoo
from repro.train import optimizer as opt
from repro.train import trainer


def _setup(arch="qwen1.5-0.5b", **okw):
    cfg = smoke_config(get_config(arch))
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    ocfg = opt.AdamWConfig(warmup_steps=2, total_steps=20, **okw)
    return cfg, api, params, ocfg


def test_lr_schedule_shape():
    ocfg = opt.AdamWConfig(lr_init=1e-5, lr_peak=1e-4, lr_final=1e-6,
                           warmup_steps=5, total_steps=100)
    lrs = [float(opt.lr_schedule(ocfg, jnp.int32(s))) for s in range(0, 101, 5)]
    assert lrs[0] == pytest.approx(1e-5)
    assert max(lrs) == pytest.approx(1e-4, rel=1e-2)
    assert lrs[-1] == pytest.approx(1e-6, rel=1e-2)
    assert lrs[1] > lrs[0]  # warming up


def test_microbatch_accumulation_equivalence():
    """n_microbatch=4 must give the same update as n_microbatch=1."""
    cfg, api, params, ocfg = _setup()
    batch = lm_data.batch_at(0, batch_size=8, seq_len=16, vocab=cfg.vocab_size)
    batch = jax.tree_util.tree_map(jnp.asarray, batch)
    outs = []
    for n_mb in (1, 4):
        state = trainer.init_train_state(params, ocfg)
        step = jax.jit(trainer.make_train_step(api.loss_fn, ocfg, n_microbatch=n_mb))
        new_state, m = step(state, batch)
        outs.append((new_state, m))
    l1, l4 = float(outs[0][1]["loss"]), float(outs[1][1]["loss"])
    assert l1 == pytest.approx(l4, rel=1e-5)
    p1 = jax.tree_util.tree_leaves(outs[0][0].params)
    p4 = jax.tree_util.tree_leaves(outs[1][0].params)
    for a, b in zip(p1, p4):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-5)


def test_int8_moments_roundtrip_small_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 1000)) * 0.01
    q, s = opt._q8_pack(x)
    x2 = opt._q8_unpack(q, s)
    err = jnp.max(jnp.abs(x - x2), axis=-1)
    bound = jnp.max(jnp.abs(x), axis=-1) / 127 + 1e-9
    assert bool(jnp.all(err <= bound))


@given(st.integers(1, 512), st.integers(1, 6))
@settings(max_examples=30, deadline=None)
def test_q8_shape_preserving_property(n, rows):
    """int8 payload keeps the parameter's exact shape (so it inherits the
    parameter's sharding — no cross-shard reshape); unpack restores shape."""
    x = jnp.arange(rows * n, dtype=jnp.float32).reshape(rows, n) / max(n, 1)
    q, s = opt._q8_pack(x)
    assert q.shape == x.shape and q.dtype == jnp.int8
    assert s.shape == x.shape[:-1]
    assert opt._q8_unpack(q, s).shape == x.shape


def test_int8_training_converges():
    """Fixed-batch memorization: per-step loss must fall. (Per-step losses
    on FRESH batches fluctuate more than 6 steps of learning signal.)"""
    cfg, api, params, ocfg = _setup(int8_moments=True)
    state = trainer.init_train_state(params, ocfg)
    step = jax.jit(trainer.make_train_step(api.loss_fn, ocfg, n_microbatch=2))
    batch = jax.tree_util.tree_map(
        jnp.asarray, lm_data.batch_at(0, batch_size=4, seq_len=16, vocab=cfg.vocab_size)
    )
    losses = []
    for _ in range(6):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_grad_compression_error_feedback():
    """int8 EF compression: the residual carries the quantization error so
    the time-averaged applied gradient is unbiased (per-row scales are
    coarse, so the average needs more rounds to settle than blockwise)."""
    g = jax.random.normal(jax.random.PRNGKey(0), (512,)) * 1e-3
    res = jnp.zeros_like(g)
    applied = jnp.zeros_like(g)
    n = 64
    for _ in range(n):
        ghat, res = opt.compress_decompress(g, res)
        applied += ghat
    np.testing.assert_allclose(np.asarray(applied / n), np.asarray(g), rtol=0.05, atol=2e-7)


def test_end_to_end_loss_drops():
    """Data pipeline -> trainer end to end; mean loss of the last 3 steps
    must beat the first step (stream of fresh batches, so compare means)."""
    cfg, api, params, ocfg = _setup("olmoe-1b-7b")
    state = trainer.init_train_state(params, ocfg)
    step = jax.jit(trainer.make_train_step(api.loss_fn, ocfg))
    batch0 = jax.tree_util.tree_map(
        jnp.asarray, lm_data.batch_at(0, batch_size=4, seq_len=16, vocab=cfg.vocab_size)
    )
    losses = []
    for batch in lm_data.stream(batch_size=4, seq_len=16, vocab=cfg.vocab_size, steps=6):
        state, m = step(state, jax.tree_util.tree_map(jnp.asarray, batch))
        losses.append(float(m["loss"]))
    # re-evaluate the FIRST batch after training: must have improved
    _, m_end = step(state, batch0)
    assert float(m_end["loss"]) < losses[0], (losses, float(m_end["loss"]))
