"""Cross-executor conformance: dense / gated / pallas must agree
BIT-EXACTLY through plan-compile → forward → decode → NMS, stateless and
streamed (DetectorSession membrane carryover), and must reproduce the
checked-in dense-oracle golden fixture.

Bit-exactness is by construction, not luck: every executor accumulates
binary spikes × int8 weights as integer-valued f32 (exact for any
summation order below 2^24) and applies the FXP scale once on the final
integer (core/plan.py). Downstream tdBN/LIF/decode/NMS is the one shared
jitted graph, so identical conv outputs imply identical everything.

Cross-executor assertions are exact (np.array_equal). Assertions against
the checked-in fixture are exact on structure (valid/classes) and
tight-tolerance on floats — float reductions inside tdBN may legitimately
reorder across XLA releases, and the fixture should catch semantic drift,
not compiler upgrades. Regenerate intentionally with
``PYTHONPATH=src python tests/conformance/make_golden.py``.
"""
from __future__ import annotations

import numpy as np
import pytest

import golden

GOLDEN_FLOAT_ATOL = 1e-5
COMPRESSED = [e for e in golden.EXECUTORS if e != "dense"]


@pytest.fixture(scope="module")
def inputs():
    return golden.build_inputs()


@pytest.fixture(scope="module")
def results(inputs):
    """Every executor's full conformance surface, computed once."""
    params, bn, frames = inputs
    return {
        ex: golden.run_executor(ex, params, bn, frames)
        for ex in golden.EXECUTORS
    }


@pytest.fixture(scope="module")
def checked_in():
    return golden.load_golden()


class TestCrossExecutorBitExact:
    @pytest.mark.parametrize("executor", COMPRESSED)
    def test_forward_head(self, results, executor):
        np.testing.assert_array_equal(
            results[executor]["head"], results["dense"]["head"]
        )

    @pytest.mark.parametrize("executor", COMPRESSED)
    def test_decode_nms_detections(self, results, executor):
        for field in ("boxes", "scores", "classes", "valid"):
            np.testing.assert_array_equal(
                results[executor][field], results["dense"][field],
                err_msg=f"{executor} diverges from dense on Detections.{field}",
            )

    @pytest.mark.parametrize("executor", COMPRESSED)
    def test_streamed_session_heads(self, results, executor):
        """Membrane carryover: every streamed frame's head is bit-equal,
        so state drift cannot accumulate silently across a video."""
        for k in range(golden.N_FRAMES):
            np.testing.assert_array_equal(
                results[executor][f"stream_head_{k}"],
                results["dense"][f"stream_head_{k}"],
                err_msg=f"{executor} drifts from dense at streamed frame {k}",
            )

    @pytest.mark.parametrize("executor", COMPRESSED)
    def test_final_membrane_state(self, results, executor):
        mem_keys = [k for k in results["dense"] if k.startswith("mem/")]
        assert mem_keys, "dense reference exposes no membrane state"
        for k in mem_keys:
            np.testing.assert_array_equal(
                results[executor][k], results["dense"][k],
                err_msg=f"{executor} membrane state {k} diverges",
            )


class TestAgainstCheckedInGolden:
    def test_fixture_inputs_match(self, inputs, checked_in):
        """The deterministic frame stream is reproduced bit-exactly —
        if this fails, the data/seed pipeline changed, not the executors."""
        _, _, frames = inputs
        np.testing.assert_array_equal(np.asarray(frames), checked_in["frames"])

    @pytest.mark.parametrize("executor", list(golden.EXECUTORS))
    def test_against_golden(self, results, checked_in, executor):
        got = results[executor]
        for k, want in checked_in.items():
            if k == "frames":
                continue
            assert k in got, f"missing conformance surface {k!r}"
            if want.dtype.kind in "fc":
                np.testing.assert_allclose(
                    got[k], want, atol=GOLDEN_FLOAT_ATOL, rtol=0,
                    err_msg=f"{executor} drifts from golden on {k}",
                )
            else:
                np.testing.assert_array_equal(
                    got[k], want, err_msg=f"{executor} drifts from golden on {k}"
                )

    def test_membrane_pytree_structure_stable(self, results, checked_in):
        """The DetectorSession state contract: same layer keys as the
        golden (a renamed/dropped membrane leaf breaks stream resume)."""
        want = {k for k in checked_in if k.startswith("mem/")}
        got = {k for k in results["dense"] if k.startswith("mem/")}
        assert got == want


class TestSessionContract:
    """Streaming semantics, asserted per executor (satellite: membrane
    carry across frames differs from reset streams; batched rows evolve
    independently)."""

    @pytest.mark.parametrize("executor", list(golden.EXECUTORS))
    def test_carry_differs_from_reset_stream(self, inputs, executor):
        import dataclasses

        from repro.models import snn_yolo as sy

        params, bn, frames = inputs
        cfg = dataclasses.replace(
            golden.conformance_config(), conv_exec=executor
        )
        det = sy.compile_detector(cfg, params, bn)
        carry = det.new_session(batch=golden.BATCH)
        reset = det.new_session(batch=golden.BATCH)
        h_carry, h_reset = [], []
        for k in range(golden.N_FRAMES):
            h_carry.append(np.asarray(carry.step(frames[k]).head))
            reset.reset()
            h_reset.append(np.asarray(reset.step(frames[k]).head))
        # frame 0: cold state on both paths -> identical
        np.testing.assert_array_equal(h_carry[0], h_reset[0])
        # later frames: warm membrane must actually matter
        assert any(
            np.abs(a - b).max() > 0 for a, b in zip(h_carry[1:], h_reset[1:])
        ), "membrane carryover had no effect — streaming state is dead"

    @pytest.mark.parametrize("executor", list(golden.EXECUTORS))
    def test_batched_rows_evolve_independently(self, inputs, executor):
        import dataclasses

        from repro.models import snn_yolo as sy

        params, bn, frames = inputs
        cfg = dataclasses.replace(
            golden.conformance_config(), conv_exec=executor
        )
        det = sy.compile_detector(cfg, params, bn)
        # row 0 streams frames in order, row 1 in reverse: rows see
        # different histories, so their states must not mix
        batched = det.new_session(batch=2)
        seq0 = [frames[k][0:1] for k in range(golden.N_FRAMES)]
        seq1 = [frames[golden.N_FRAMES - 1 - k][1:2] for k in range(golden.N_FRAMES)]
        outs = [
            np.asarray(batched.step(np.concatenate([a, b], axis=0)).head)
            for a, b in zip(seq0, seq1)
        ]
        for row, seq in ((0, seq0), (1, seq1)):
            solo = det.new_session(batch=1)
            for k, f in enumerate(seq):
                h = np.asarray(solo.step(f).head)
                np.testing.assert_array_equal(
                    h[0], outs[k][row],
                    err_msg=f"{executor} row {row} state mixed at frame {k}",
                )


class TestFusedPathActive:
    """The pallas rows above must test the FUSED pipeline, not a silent
    fallback: the compiled step's trace must contain exactly one
    pallas_call per fused-eligible (conv+tdBN+LIF) layer — encode's 8 bit-
    serial planes fold into its single dispatch, and the pointwise head
    (no tdBN/LIF to fuse) contracts outside the kernel."""

    def test_one_dispatch_per_fused_layer(self, inputs):
        import dataclasses

        from repro.kernels import backend
        from repro.models import snn_yolo as sy

        params, bn, frames = inputs
        cfg = dataclasses.replace(
            golden.conformance_config(), conv_exec="pallas"
        )
        det = sy.compile_detector(cfg, params, bn)
        fused_layers = [n for n in det.plan.layers if "gamma" in params[n]]
        assert fused_layers, "no fused-eligible layers — config degenerate"
        n_calls = backend.count_pallas_calls(
            lambda f: det._step(det.params, det.bn_state, f, None)[0],
            frames[0],
        )
        assert n_calls == len(fused_layers), (
            f"pallas step traced {n_calls} pallas_calls for "
            f"{len(fused_layers)} fused-eligible layers — the fused "
            "pipeline is not one-dispatch-per-layer"
        )
