"""Shared builders for the cross-executor conformance suite.

One frozen small config + seeded weights + seeded frame stream, and the
dense-oracle reference outputs for them. ``make_golden.py`` serializes the
reference to ``fixtures/golden_conformance.npz`` (checked in);
``test_conformance.py`` asserts every executor reproduces it and that all
executors agree bit-exactly among themselves.

Regenerate (only when the detector's semantics intentionally change):

    PYTHONPATH=src python tests/conformance/make_golden.py
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core import pruning
from repro.models import snn_yolo as sy

EXECUTORS = ("dense", "gated", "pallas")
SEED = 0
PRUNE_RATE = 0.8
N_FRAMES = 3
BATCH = 2
FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures", "golden_conformance.npz")


def conformance_config() -> sy.SNNDetConfig:
    """Smoke-scale paper topology. use_block_conv=True is REQUIRED for
    conformance: the gated and Pallas executors always use block-conv
    border semantics, so the dense oracle must too."""
    return dataclasses.replace(
        smoke_config(get_config("snn-det")), arch_id="snn-det-conformance",
        use_block_conv=True,
    )


def build_inputs(cfg: sy.SNNDetConfig | None = None):
    """Deterministic (params, bn, frames): pruned seeded weights, tdBN
    calibrated on the first frame, uint8-grid frames (exact under the
    bit-serial 8-bit encode path). frames: (N_FRAMES, BATCH, H, W, 3)."""
    cfg = cfg or conformance_config()
    params, bn = sy.init_params(jax.random.PRNGKey(SEED), cfg)
    params = pruning.prune_tree(params, PRUNE_RATE)
    rng = np.random.default_rng(SEED)
    h, w = cfg.input_hw
    frames = jnp.asarray(
        rng.integers(0, 256, (N_FRAMES, BATCH, h, w, 3)) / 255.0, jnp.float32
    )
    bn = sy.calibrate_bn_state(params, bn, frames[0], cfg)
    return params, bn, frames


def run_executor(executor: str, params, bn, frames, cfg=None) -> dict:
    """The full conformance surface for one executor: plan-compile →
    stateless forward → decode → NMS, plus a streamed session (membrane
    carryover across N_FRAMES) and its final state."""
    cfg = dataclasses.replace(cfg or conformance_config(), conv_exec=executor)
    det = sy.compile_detector(cfg, params, bn)
    dets, head = det.detect(frames[0])
    out = {
        "head": np.asarray(head),
        "boxes": np.asarray(dets.boxes),
        "scores": np.asarray(dets.scores),
        "classes": np.asarray(dets.classes),
        "valid": np.asarray(dets.valid),
    }
    sess = det.new_session(batch=BATCH)
    for k in range(N_FRAMES):
        step = sess.step(frames[k])
        out[f"stream_head_{k}"] = np.asarray(step.head)
        out[f"stream_valid_{k}"] = np.asarray(step.detections.valid)
    for name, v in sess.state.items():
        out[f"mem/{name}"] = np.asarray(v)
    return out


def build_reference() -> dict:
    """The full fixture payload: dense-oracle outputs + the input frames.
    THE one recipe — ``make_golden.py`` (write) and
    ``scripts/regen_goldens.py`` (write + --check) both call this, so the
    two entry points can never drift apart."""
    params, bn, frames = build_inputs()
    ref = run_executor("dense", params, bn, frames)
    ref["frames"] = np.asarray(frames)
    return ref


def load_golden() -> dict:
    with np.load(FIXTURE) as z:
        return {k: z[k] for k in z.files}
