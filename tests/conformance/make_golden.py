"""Regenerate the checked-in conformance fixture from the dense oracle.

    PYTHONPATH=src python tests/conformance/make_golden.py

Only rerun when the detector's numerics intentionally change (new
quantization scheme, different NMS, ...) — the whole point of the fixture
is that unintentional drift fails tests/conformance/test_conformance.py.
"""
from __future__ import annotations

import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import golden  # noqa: E402


def main():
    ref = golden.build_reference()
    os.makedirs(os.path.dirname(golden.FIXTURE), exist_ok=True)
    np.savez_compressed(golden.FIXTURE, **ref)
    size = os.path.getsize(golden.FIXTURE)
    print(f"wrote {golden.FIXTURE} ({size/1024:.1f} KiB, {len(ref)} arrays)")
    for k in sorted(ref):
        print(f"  {k:20s} {ref[k].shape} {ref[k].dtype}")


if __name__ == "__main__":
    main()
