"""2-process multi-controller smoke (the ``distributed-smoke`` CI lane).

Each test spawns TWO subprocesses under ``JAX_PLATFORMS=cpu`` that meet at
a local ``jax.distributed.initialize`` coordinator (gloo CPU collectives),
build the real multi-controller :class:`DistributedContext`, and exercise
the per-host ownership paths end to end:

* the launch-mesh regression: a 2-process mesh spans BOTH hosts' devices,
* sharded eval mAP is BIT-identical to the single-host evaluation (both
  the precomputed-predictions path and the full detector path), and an
  uneven ``n_shards % n_hosts`` launch is refused,
* data-parallel training over the context's batch axis matches the
  single-host loss trajectory,
* a checkpoint SAVED on 2 hosts (leaf-striped, ``shard_manifest.json``
  sidecar) restores bit-exact on 1 host — the topology-change round-trip.

The parent process computes every single-host reference itself (it is a
single-controller context), so parity is cross-process by construction.
"""
from __future__ import annotations

import inspect
import json
import os
import socket
import subprocess
import sys
import textwrap

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_ENV = {**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"}
# one device per process: the cross-host paths must not lean on simulated
# local multi-device meshes
_ENV.pop("XLA_FLAGS", None)

NUM_CLASSES = 3


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


_PREAMBLE = """\
import json
import numpy as np
from repro.distributed import runtime
ctx = runtime.initialize(coordinator_address="127.0.0.1:{port}",
                         num_processes=2, process_id={pid})
"""


def _run_pair(body: str, *, prelude: str = "", timeout: int = 420) -> list[str]:
    """Spawn the same worker body as process 0 and 1 of a 2-process job;
    returns both stdouts (asserting both exited cleanly)."""
    port = _free_port()
    procs = []
    for pid in range(2):
        code = (prelude + "\n" + _PREAMBLE.format(port=port, pid=pid)
                + textwrap.dedent(body))
        procs.append(subprocess.Popen(
            [sys.executable, "-c", code], env=_ENV, cwd=ROOT,
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        ))
    outs = []
    failures = []
    for pid, p in enumerate(procs):
        try:
            out, err = p.communicate(timeout=timeout)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            raise
        if p.returncode != 0:
            failures.append(f"host {pid} rc={p.returncode}\nstdout:\n{out}"
                            f"\nstderr:\n{err[-4000:]}")
        outs.append(out)
    assert not failures, "\n\n".join(failures)
    return outs


def _report_line(out: str) -> dict:
    lines = [l for l in out.splitlines() if l.startswith("REPORT=")]
    assert len(lines) == 1, f"expected one REPORT= line, got:\n{out}"
    return json.loads(lines[0][len("REPORT="):])


def _random_split(seed: int, n_images: int):
    """Seeded (predictions, ground_truths) with overlapping boxes and
    one-decimal score ties — pooling ORDER is observable in AP, so parity
    here proves the cross-host gather reconstructs the single-host order."""
    rng = np.random.default_rng(seed)
    preds, gts = [], []
    for _ in range(n_images):
        g = int(rng.integers(0, 5))
        g_boxes = rng.uniform(0.2, 0.8, (g, 4)).astype(np.float32)
        g_cls = rng.integers(0, NUM_CLASSES, g)
        gts.append({"boxes": g_boxes, "classes": g_cls})
        p_extra = int(rng.integers(0, 6))
        near = g_boxes + rng.normal(0, 0.02, g_boxes.shape).astype(np.float32)
        p_boxes = np.concatenate(
            [near, rng.uniform(0.2, 0.8, (p_extra, 4)).astype(np.float32)]
        )
        p_cls = np.concatenate([g_cls, rng.integers(0, NUM_CLASSES, p_extra)])
        scores = np.round(rng.uniform(0, 1, len(p_boxes)), 1)
        preds.append({"boxes": p_boxes, "scores": scores.astype(np.float32),
                      "classes": p_cls})
    return preds, gts


def test_two_process_mesh_spans_all_devices():
    """The launch/mesh.py regression: mesh axes cross process boundaries."""
    outs = _run_pair("""
        from repro.launch.mesh import make_host_mesh
        assert ctx.is_multi_controller and ctx.n_hosts == 2
        assert len(ctx.global_devices) == 2, ctx.global_devices
        assert len(ctx.local_devices) == 1, ctx.local_devices
        mesh = make_host_mesh(n_data=2, n_model=1, ctx=ctx)
        assert mesh.devices.size == 2
        procs = sorted(d.process_index for d in mesh.devices.flat)
        assert procs == [0, 1], procs
        stripe = ctx.stripe_mesh()
        assert [d.process_index for d in stripe.devices.flat] == [0, 1]
        assert ctx.owned_shards(4) == [ctx.host_id, ctx.host_id + 2]
        print("MESH_OK", ctx.describe())
    """)
    assert all("MESH_OK" in o for o in outs)


def test_sharded_predictions_map_bit_parity():
    """evaluate_predictions_sharded over 2 hosts x 2 owned shards ==
    detection_map.evaluate_detections, bit for bit; n_shards=3 refused."""
    from repro.eval import detection_map as dm
    from repro.eval import sharded as se

    outs = _run_pair(prelude=inspect.getsource(_random_split)
                     + f"\nNUM_CLASSES = {NUM_CLASSES}\n", body="""
        from repro.eval import sharded as se
        preds, gts = _random_split(5, 12)
        rep = se.evaluate_predictions_sharded(
            preds, gts, num_classes=NUM_CLASSES,
            eval_cfg=se.ShardedEvalConfig(n_shards=4), ctx=ctx)
        assert rep["n_hosts"] == 2 and rep["gather"] == "process"
        assert rep["n_shards"] == 4
        try:
            se.evaluate_predictions_sharded(
                preds, gts, num_classes=NUM_CLASSES,
                eval_cfg=se.ShardedEvalConfig(n_shards=3), ctx=ctx)
        except ValueError as e:
            assert "stripe evenly" in str(e), e
        else:
            raise AssertionError("n_shards=3 over 2 hosts must raise")
        print("REPORT=" + json.dumps(rep))
    """)
    preds, gts = _random_split(5, 12)
    ref = dm.evaluate_detections(preds, gts, num_classes=NUM_CLASSES,
                                 iou_threshold=0.5)
    for out in outs:  # every host returns the same full report
        assert se.reports_identical(_report_line(out), ref)


def test_sharded_detector_map_bit_parity():
    """The full forward→decode→NMS path: each host runs only its owned
    shard of the val split; the report matches the parent's single-host
    harness.evaluate_detector on the same demo weights, bit for bit."""
    from repro.configs import get_config, smoke_config
    from repro.eval import harness
    from repro.eval import sharded as se
    from repro.serve.detector import demo_weights

    outs = _run_pair("""
        from repro.configs import get_config, smoke_config
        from repro.eval import harness
        from repro.eval import sharded as se
        from repro.serve.detector import demo_weights
        cfg = smoke_config(get_config("snn-det"))
        params, bn, _ = demo_weights(cfg)
        det = harness.compile_eval_detector(cfg, params, bn)
        rep = se.evaluate_detector_sharded(
            det, n_images=6, eval_cfg=se.ShardedEvalConfig(n_shards=2),
            ctx=ctx)
        assert rep["n_hosts"] == 2 and rep["gather"] == "process"
        print("REPORT=" + json.dumps(rep))
    """)
    cfg = smoke_config(get_config("snn-det"))
    params, bn, _ = demo_weights(cfg)
    det = harness.compile_eval_detector(cfg, params, bn)
    ref = harness.evaluate_detector(det, n_images=6)
    for out in outs:
        assert se.reports_identical(_report_line(out), ref)


def test_data_parallel_train_loss_parity(tmp_path):
    """launch.train with --coordinator (global batch 8 striped over 2
    hosts, gradient psum over the data axis) reproduces the single-host
    loss trajectory."""
    steps = 11  # the smoke loss curve is noisy early; by step 11 the
    # launcher's own loss-decrease gate holds with margin on both runs
    common = [sys.executable, "-m", "repro.launch.train",
              "--arch", "qwen1.5-0.5b", "--steps", str(steps),
              "--batch", "8", "--seq", "16"]
    port = _free_port()
    multi_out = tmp_path / "multi.json"
    procs = [
        subprocess.Popen(
            common + ["--coordinator", f"127.0.0.1:{port}",
                      "--num-processes", "2", "--process-id", str(pid),
                      "--losses-out", str(multi_out)],
            env=_ENV, cwd=ROOT, stdout=subprocess.PIPE,
            stderr=subprocess.PIPE, text=True)
        for pid in range(2)
    ]
    for pid, p in enumerate(procs):
        out, err = p.communicate(timeout=420)
        assert p.returncode == 0, (f"host {pid} rc={p.returncode}\nstdout:\n"
                                   f"{out}\nstderr:\n{err[-4000:]}")
    single_out = tmp_path / "single.json"
    r = subprocess.run(common + ["--losses-out", str(single_out)],
                       env=_ENV, cwd=ROOT, capture_output=True, text=True,
                       timeout=420)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    multi = json.loads(multi_out.read_text())
    single = json.loads(single_out.read_text())
    assert len(multi) == len(single) == steps and steps >= 3
    # the 2-host global batch is a row permutation of the single-host batch
    # (striping contract), so the mean loss agrees to numerical tolerance
    np.testing.assert_allclose(multi, single, rtol=2e-4, atol=1e-5)


def test_checkpoint_two_host_save_one_host_restore(tmp_path):
    """Leaf-striped save on 2 hosts (host i writes leaf j where
    j % 2 == i), then the PARENT — a single-controller context — restores
    bit-exact and reads the shard manifest."""
    from repro.train import checkpoint as ckpt

    root = tmp_path / "ckpt"
    full_w = np.arange(12, dtype=np.float32).reshape(4, 3) / 7.0
    full_m = (np.arange(8, dtype=np.int32) * 3).reshape(8, 1)
    outs = _run_pair(body=f"""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as ckpt
        root = {str(root)!r}
        mesh = ctx.data_mesh()
        sh = NamedSharding(mesh, P("data"))
        full_w = np.arange(12, dtype=np.float32).reshape(4, 3) / 7.0
        full_m = (np.arange(8, dtype=np.int32) * 3).reshape(8, 1)
        def glob(full):
            n = full.shape[0] // 2
            local = full[ctx.host_id * n:(ctx.host_id + 1) * n]
            return jax.make_array_from_process_local_data(sh, local, full.shape)
        tree = {{"w": glob(full_w), "b": np.full((3,), 7, np.int16),
                 "m": glob(full_m)}}
        assert not tree["w"].is_fully_addressable  # exercises replication
        out = ckpt.save(root, 3, tree, ctx=ctx,
                        extra_files={{"note.txt": b"hi"}})
        try:
            ckpt.save_async(root, 4, tree)
        except NotImplementedError:
            pass
        else:
            raise AssertionError("save_async must refuse multi-controller")
        print("SAVED", out)
    """)
    assert all("SAVED" in o for o in outs)

    template = {"w": np.zeros((4, 3), np.float32),
                "b": np.zeros((3,), np.int16),
                "m": np.zeros((8, 1), np.int32)}
    state, step = ckpt.restore(str(root), template)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(state["w"]), full_w)  # bit-exact
    np.testing.assert_array_equal(np.asarray(state["m"]), full_m)
    np.testing.assert_array_equal(np.asarray(state["b"]),
                                  np.full((3,), 7, np.int16))

    step_dir = root / "step_000000003"
    manifest = json.loads((step_dir / "shard_manifest.json").read_text())
    assert manifest["n_hosts"] == 2
    assert set(manifest["hosts"]) == {"0", "1"}
    # dict flatten order: b, m, w -> host 0 owns leaves 0 and 2, host 1 leaf 1
    assert manifest["hosts"]["0"] == ["leaf_00000.npy", "leaf_00002.npy"]
    assert manifest["hosts"]["1"] == ["leaf_00001.npy"]
    assert (step_dir / "note.txt").read_bytes() == b"hi"
    # receipts and manifest survive the commit for debuggability
    assert (step_dir / "manifest.json").exists()
