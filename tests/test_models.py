"""Model-family unit tests: chunked attention == dense oracle, GQA grouping,
RoPE invariants, MoE routing/capacity, SSD chunked == step recurrence,
WKV chunked == step recurrence, hypothesis property sweeps."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.configs import get_config, smoke_config
from repro.configs.base import LMConfig
from repro.models import layers as L
from repro.models import mamba2, moe, rwkv6


CFG = LMConfig("t", "dense", 2, 64, 4, 2, 128, 256, head_dim=16, dtype="float32")


# ----------------------------------------------------------- attention ----


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("s", [96, 200, 256])
def test_chunked_attention_matches_dense(causal, s):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, s, 4, 16))
    k = jax.random.normal(ks[1], (2, s, 2, 16))
    v = jax.random.normal(ks[2], (2, s, 2, 16))
    mask = jnp.tril(jnp.ones((s, s), bool))[None, None] if causal else None
    ref = L._sdpa(q, k, v, mask, CFG)
    out = L._chunked_sdpa(q, k, v, CFG, causal=causal, q_chunk=64, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)


@given(st.integers(1, 4), st.sampled_from([1, 2, 4]))
@settings(max_examples=10, deadline=None)
def test_gqa_grouping_property(g, nkv):
    """GQA with all KV heads equal must match MHA with repeated heads."""
    nh = g * nkv
    cfg = LMConfig("t", "dense", 1, 16 * nh, nh, nkv, 32, 64, head_dim=16, dtype="float32")
    ks = jax.random.split(jax.random.PRNGKey(g * 7 + nkv), 3)
    q = jax.random.normal(ks[0], (1, 8, nh, 16))
    k = jax.random.normal(ks[1], (1, 8, nkv, 16))
    v = jax.random.normal(ks[2], (1, 8, nkv, 16))
    out = L._sdpa(q, k, v, None, cfg)
    # reference: expand kv to nh heads and run head-by-head
    k_full = jnp.repeat(k, g, axis=2)
    v_full = jnp.repeat(v, g, axis=2)
    cfg_mha = LMConfig("t", "dense", 1, 16 * nh, nh, nh, 32, 64, head_dim=16, dtype="float32")
    ref = L._sdpa(q, k_full, v_full, None, cfg_mha)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-6)


def test_rope_relative_shift_invariance():
    """RoPE: q·k depends only on relative distance — shifting both positions
    by a constant leaves attention scores unchanged."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    q = jax.random.normal(k1, (1, 4, 2, 32))
    k = jax.random.normal(k2, (1, 4, 2, 32))
    pos = jnp.arange(4)[None]
    def scores(shift):
        qr = L.apply_rope(q, pos + shift, 10_000.0)
        kr = L.apply_rope(k, pos + shift, 10_000.0)
        return jnp.einsum("bqhd,bkhd->bhqk", qr, kr)
    np.testing.assert_allclose(np.asarray(scores(0)), np.asarray(scores(17)),
                               rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------- MoE ----


def test_moe_routing_topk_and_gates():
    cfg = smoke_config(get_config("olmoe-1b-7b"))
    x = jax.random.normal(jax.random.PRNGKey(0), (32, cfg.d_model))
    rw = jax.random.normal(jax.random.PRNGKey(1), (cfg.d_model, cfg.n_experts))
    ids, gates, aux = moe.route(x, rw, cfg)
    assert ids.shape == (32, cfg.top_k)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert float(aux) > 0
    # ids are the true top-k of the softmax
    probs = jax.nn.softmax(x @ rw, axis=-1)
    ref_ids = jnp.argsort(-probs, axis=-1)[:, : cfg.top_k]
    assert (jnp.sort(ids, axis=-1) == jnp.sort(ref_ids, axis=-1)).all()


def test_moe_dispatch_respects_capacity():
    cfg = smoke_config(get_config("olmoe-1b-7b"))
    t = 64
    ids = jnp.zeros((t, cfg.top_k), jnp.int32)  # всех tokens to expert 0 -> overflow
    slot_token, entry_slot, C = moe.dispatch_group(ids, t, cfg)
    kept = int((entry_slot >= 0).sum())
    assert kept <= C  # expert 0 takes at most its capacity
    assert slot_token.shape[0] == cfg.n_experts * C


def test_moe_output_matches_dense_when_single_expert():
    """n_experts=1, top_k=1, capacity ≥ tokens → MoE == that expert's MLP."""
    cfg = smoke_config(get_config("olmoe-1b-7b"))
    import dataclasses
    cfg = dataclasses.replace(cfg, n_experts=1, top_k=1, n_shared_experts=0,
                              capacity_factor=4.0)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 0.1
    out, _ = moe.moe_mlp(x, p, cfg)
    ew = p["experts"]
    ref = (jax.nn.silu(x @ ew["wg"][0]) * (x @ ew["wi"][0])) @ ew["wo"][0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-2, atol=2e-3)


# ------------------------------------------------------------- mamba2 -----


def test_ssd_chunked_matches_stepwise():
    """Chunked SSD (train path) == per-step recurrence (decode path)."""
    cfg = smoke_config(get_config("zamba2-7b"))
    p = mamba2.mamba_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 24, cfg.d_model)) * 0.3

    y_chunk, st_chunk = mamba2.mamba_forward(x, p, cfg, chunk=8)

    st = mamba2.init_state(cfg, 1)
    outs = []
    for t in range(24):
        y_t, st = mamba2.mamba_forward(x[:, t : t + 1], p, cfg, state=st)
        outs.append(y_t)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step), rtol=2e-2, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_chunk.h), np.asarray(st.h), rtol=2e-2, atol=2e-3)


# -------------------------------------------------------------- rwkv6 -----


def test_wkv_chunked_matches_stepwise():
    cfg = smoke_config(get_config("rwkv6-3b"))
    lp = rwkv6.rwkv_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 20, cfg.d_model)) * 0.3

    y_chunk, st_chunk = rwkv6.rwkv_block(x, lp, cfg, chunk=8)

    st = rwkv6.init_state(cfg, 1)
    outs = []
    for t in range(20):
        y_t, st = rwkv6.rwkv_block(x[:, t : t + 1], lp, cfg, state=st)
        outs.append(y_t)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step), rtol=2e-2, atol=5e-3)
    np.testing.assert_allclose(np.asarray(st_chunk.s), np.asarray(st.s), rtol=2e-2, atol=5e-3)


@given(st.integers(2, 30))
@settings(max_examples=8, deadline=None)
def test_wkv_chunk_size_invariance(t_len):
    """WKV output must not depend on the chunking (property)."""
    cfg = smoke_config(get_config("rwkv6-3b"))
    lp = rwkv6.rwkv_init(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(t_len), (1, t_len, cfg.d_model)) * 0.2
    y1, _ = rwkv6.rwkv_block(x, lp, cfg, chunk=4)
    y2, _ = rwkv6.rwkv_block(x, lp, cfg, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-2, atol=2e-3)
