"""Mesh-sharded mAP evaluation (repro.eval.sharded): the shard reduction
must be EXACT — for any split of detections across k shards, gathering the
pooled per-class (score, TP) lists and re-sweeping AP is bit-identical to
the unsharded sweep, including empty shards, no-prediction classes and
deliberate score ties (where pooling ORDER changes AP, so the canonical
re-sort by global image index is load-bearing). Plus: the striping contract
matches ``synthetic_detection.batches`` host striping, the sharded detector
path matches ``harness.evaluate_detector`` bitwise, and the device
collective gather (``collectives.eval_stats_allgather``) agrees with the
host gather under a real simulated multi-device mesh (subprocess, like
tests/test_distributed.py)."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.data import synthetic_detection as sd
from repro.eval import detection_map as dm
from repro.eval import sharded as se

NUM_CLASSES = 3


def _random_split(seed: int, n_images: int, *, tie_decimals: int | None = 1,
                  max_gt: int = 4, max_pred: int = 5):
    """Seeded (predictions, ground_truths) with overlapping boxes (so TPs
    exist) and — by default — scores rounded to one decimal, which forces
    the score ties that make pooling order observable in AP."""
    rng = np.random.default_rng(seed)
    preds, gts = [], []
    for _ in range(n_images):
        g = int(rng.integers(0, max_gt + 1))
        g_boxes = rng.uniform(0.2, 0.8, (g, 4)).astype(np.float32)
        g_cls = rng.integers(0, NUM_CLASSES, g)
        gts.append({"boxes": g_boxes, "classes": g_cls})
        p_extra = int(rng.integers(0, max_pred + 1))
        near = g_boxes + rng.normal(0, 0.02, g_boxes.shape).astype(np.float32)
        p_boxes = np.concatenate(
            [near, rng.uniform(0.2, 0.8, (p_extra, 4)).astype(np.float32)]
        )
        p_cls = np.concatenate([g_cls, rng.integers(0, NUM_CLASSES, p_extra)])
        scores = rng.uniform(0, 1, len(p_boxes))
        if tie_decimals is not None:
            scores = np.round(scores, tie_decimals)
        preds.append({
            "boxes": p_boxes,
            "scores": scores.astype(np.float32),
            "classes": p_cls,
        })
    return preds, gts


def assert_reports_identical(got: dict, ref: dict):
    """Bitwise (NaN-aware) equality on every shared report key — the one
    canonical predicate the eval_map parity gate also uses."""
    assert se.reports_identical(got, ref), (
        {k: got.get(k) for k in ("map", "per_class_ap", "n_gt", "n_pred",
                                 "n_images", "iou_threshold")},
        {k: ref.get(k) for k in ("map", "per_class_ap", "n_gt", "n_pred",
                                 "n_images", "iou_threshold")},
    )


class TestStripingContract:
    def test_matches_batches_host_striping(self):
        """Shard s of k owns s, s+k, s+2k, ... — the exact index set
        ``batches(host_id=s, n_hosts=k)`` consumes."""
        assert sd.eval_shard_indices(10, 1, 3) == [1, 4, 7]
        for n, k in ((10, 3), (8, 1), (2, 5), (0, 4)):
            shards = [sd.eval_shard_indices(n, s, k) for s in range(k)]
            flat = sorted(i for sh in shards for i in sh)
            assert flat == list(range(n))  # disjoint + complete
            for s, sh in enumerate(shards):
                assert all(i % k == s for i in sh)

    def test_out_of_range_shard_raises(self):
        with pytest.raises(ValueError):
            sd.eval_shard_indices(8, 3, 3)

    def test_eval_set_shards_partition_the_split(self):
        hw, grid_div = (96, 160), 16
        full, full_gts = sd.eval_set(5, hw=hw, grid_div=grid_div)
        parts = [sd.eval_set(5, hw=hw, grid_div=grid_div, shard_id=s, n_shards=2)
                 for s in range(2)]
        np.testing.assert_array_equal(parts[0][0], full[0::2])
        np.testing.assert_array_equal(parts[1][0], full[1::2])
        for got, want in zip(parts[0][1], full_gts[0::2]):
            np.testing.assert_array_equal(got["boxes"], want["boxes"])

    def test_eval_set_empty_shard(self):
        imgs, gts = sd.eval_set(2, hw=(96, 160), grid_div=16,
                                shard_id=3, n_shards=4)
        assert imgs.shape == (0, 96, 160, 3) and gts == []


class TestShardReductionExact:
    @pytest.mark.parametrize("k", [1, 2, 3, 5, 8])
    def test_bit_identical_for_any_shard_count(self, k):
        preds, gts = _random_split(seed=k, n_images=9)
        ref = dm.evaluate_detections(preds, gts, num_classes=NUM_CLASSES)
        got = se.evaluate_predictions_sharded(
            preds, gts, num_classes=NUM_CLASSES,
            eval_cfg=se.ShardedEvalConfig(n_shards=k),
        )
        assert_reports_identical(got, ref)

    def test_empty_shards(self):
        """k > n_images: the trailing shards hold zero images."""
        preds, gts = _random_split(seed=0, n_images=2)
        ref = dm.evaluate_detections(preds, gts, num_classes=NUM_CLASSES)
        got = se.evaluate_predictions_sharded(
            preds, gts, num_classes=NUM_CLASSES,
            eval_cfg=se.ShardedEvalConfig(n_shards=7),
        )
        assert_reports_identical(got, ref)

    def test_no_predictions_at_all(self):
        """Present classes with zero predictions: AP 0.0 per class, exactly
        like the unsharded evaluator."""
        _, gts = _random_split(seed=3, n_images=4, max_gt=3)
        empty = [{"boxes": np.zeros((0, 4), np.float32),
                  "scores": np.zeros(0, np.float32),
                  "classes": np.zeros(0, np.int64)} for _ in gts]
        ref = dm.evaluate_detections(empty, gts, num_classes=NUM_CLASSES)
        got = se.evaluate_predictions_sharded(
            empty, gts, num_classes=NUM_CLASSES,
            eval_cfg=se.ShardedEvalConfig(n_shards=3),
        )
        assert_reports_identical(got, ref)

    def test_empty_split(self):
        got = se.evaluate_predictions_sharded([], [], num_classes=NUM_CLASSES)
        assert np.isnan(got["map"]) and got["n_images"] == 0

    def test_mismatched_pairing_raises(self):
        preds, gts = _random_split(seed=1, n_images=3)
        with pytest.raises(ValueError):
            se.evaluate_predictions_sharded(preds[:2], gts,
                                            num_classes=NUM_CLASSES)

    def test_tie_order_is_canonical(self):
        """The regression the re-sort exists for: one class, two images,
        tied scores, FP on image 0 and TP on image 1 — the stable sort
        pools [FP, TP] (AP 0.25 over 2 GT); a shard-major concatenation
        that put image 1 first would pool [TP, FP] and report 0.5."""
        gt = {"boxes": np.array([[0.5, 0.5, 0.2, 0.2]], np.float32),
              "classes": np.array([0])}
        tp_pred = {"boxes": np.array([[0.5, 0.5, 0.2, 0.2]], np.float32),
                   "scores": np.array([0.7], np.float32),
                   "classes": np.array([0])}
        fp_pred = {"boxes": np.array([[0.9, 0.9, 0.05, 0.05]], np.float32),
                   "scores": np.array([0.7], np.float32),
                   "classes": np.array([0])}
        preds = [fp_pred, tp_pred]  # image 0: FP, image 1: TP, same score
        gts = [gt, gt]
        ref = dm.evaluate_detections(preds, gts, num_classes=1)
        assert ref["map"] == pytest.approx(0.25)  # FP pools first
        got = se.evaluate_predictions_sharded(
            preds, gts, num_classes=1,
            eval_cfg=se.ShardedEvalConfig(n_shards=2),
        )
        assert_reports_identical(got, ref)

    @settings(max_examples=30, deadline=None)
    @given(
        st.integers(0, 10_000),
        st.integers(0, 8),
        st.integers(1, 6),
        st.sampled_from([None, 0, 1]),
    )
    def test_reduction_property(self, seed, n_images, k, tie_decimals):
        """For ANY split of detections across k shards, pooling the
        per-class score/TP lists and sweeping AP is bit-identical to the
        unsharded sweep — across image counts (including 0 and < k),
        shard counts and tie densities (decimals=0 makes almost every
        score collide)."""
        preds, gts = _random_split(seed, n_images, tie_decimals=tie_decimals)
        ref = dm.evaluate_detections(preds, gts, num_classes=NUM_CLASSES)
        got = se.evaluate_predictions_sharded(
            preds, gts, num_classes=NUM_CLASSES,
            eval_cfg=se.ShardedEvalConfig(n_shards=k),
        )
        assert_reports_identical(got, ref)


class TestShardedDetectorEval:
    @pytest.fixture(scope="class")
    def det(self):
        from repro.configs import get_config, smoke_config
        from repro.eval import harness
        from repro.serve.detector import demo_weights

        cfg = smoke_config(get_config("snn-det"))
        params, bn, _ = demo_weights(cfg)
        return harness.compile_eval_detector(cfg, params, bn)

    @pytest.mark.parametrize("k", [2, 4])
    def test_detector_sharded_matches_single_host(self, det, k):
        """End-to-end: striped eval split, per-shard forward→decode→NMS
        under the executor plan, reduced report == the legacy single-host
        ``harness.evaluate_detector`` bitwise."""
        from repro.eval import harness

        ref = harness.evaluate_detector(det, n_images=6)
        got = harness.evaluate_detector(det, n_images=6, sharded=k)
        assert got["n_shards"] == k and got["split"] == ref["split"]
        assert_reports_identical(got, ref)

    def test_batch_chunking_does_not_change_result(self, det):
        from repro.eval import harness

        a = harness.evaluate_detector(
            det, n_images=5, sharded=se.ShardedEvalConfig(n_shards=2, batch=2)
        )
        b = harness.evaluate_detector(
            det, n_images=5, sharded=se.ShardedEvalConfig(n_shards=2, batch=8)
        )
        assert_reports_identical(a, b)


_ENV = {**os.environ, "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PYTHONPATH": "src"}


def _run(body: str):
    code = textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], env=_ENV,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_mesh_gather_matches_host_gather():
    """The device-collective reduction (all_gather + int psum through
    ``collectives.eval_stats_allgather`` on a simulated 8-device mesh) is
    bit-identical to both the host gather and the unsharded evaluator."""
    out = _run("""
        import sys; sys.path.insert(0, "tests")
        import numpy as np, jax
        assert len(jax.devices()) == 8, jax.devices()
        from repro.eval import detection_map as dm, sharded as se
        from test_sharded_eval import _random_split, assert_reports_identical
        preds, gts = _random_split(seed=11, n_images=10)
        ref = dm.evaluate_detections(preds, gts, num_classes=3)
        assert not np.isnan(ref["map"]) and ref["map"] > 0  # non-vacuous
        for k in (2, 4, 8):
            mesh = se.evaluate_predictions_sharded(
                preds, gts, num_classes=3,
                eval_cfg=se.ShardedEvalConfig(n_shards=k, use_device_mesh=True))
            host = se.evaluate_predictions_sharded(
                preds, gts, num_classes=3,
                eval_cfg=se.ShardedEvalConfig(n_shards=k, use_device_mesh=False))
            assert mesh["gather"] == "mesh" and host["gather"] == "host"
            assert_reports_identical(mesh, ref)
            assert_reports_identical(host, ref)
        print("MESH_GATHER_OK")
    """)
    assert "MESH_GATHER_OK" in out


def test_mesh_gather_requires_devices():
    """Forcing the collective without enough devices fails loudly (the
    parent test process runs single-device)."""
    import jax

    if len(jax.devices()) >= 4:
        pytest.skip("test process already has a multi-device backend")
    preds, gts = _random_split(seed=2, n_images=4)
    with pytest.raises(ValueError, match="devices"):
        se.evaluate_predictions_sharded(
            preds, gts, num_classes=NUM_CLASSES,
            eval_cfg=se.ShardedEvalConfig(n_shards=4, use_device_mesh=True),
        )
