"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes/dtypes/sparsity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.bitmask_matmul import pack_weights


def _sparse_int8_weights(key, kh, kw, cin, k, density):
    rng = np.random.default_rng(key)
    w = rng.integers(-127, 128, (kh, kw, cin, k)).astype(np.int8)
    mask = rng.random((kh, kw, cin, k)) < density
    return (w * mask).astype(np.int8)


class TestGatedOneToAllKernel:
    @pytest.mark.parametrize(
        "cin,k,density",
        [(8, 16, 0.2), (16, 8, 0.5), (3, 40, 0.3), (32, 32, 0.05), (8, 8, 1.0)],
    )
    def test_matches_block_conv_3x3(self, cin, k, density):
        w = _sparse_int8_weights(cin * 7 + k, 3, 3, cin, k, density)
        pw = ops.pack_conv_weights(w, kblk=8)
        rng = np.random.default_rng(0)
        spikes = jnp.asarray(rng.integers(0, 2, (2, 18, 32, cin)), jnp.int8)
        got = ops.gated_conv(spikes, pw)
        want = ref.gated_conv_ref(spikes, jnp.asarray(w))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.5)

    def test_1x1_kernel(self):
        w = _sparse_int8_weights(3, 1, 1, 16, 24, 0.7)
        pw = ops.pack_conv_weights(w, kblk=8)
        spikes = jnp.asarray(np.random.default_rng(1).integers(0, 2, (1, 18, 32, 16)), jnp.int8)
        got = ops.gated_conv(spikes, pw)
        want = ref.gated_conv_ref(spikes, jnp.asarray(w))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.5)

    def test_multi_spatial_blocks(self):
        """Input larger than one 32×18 tile → independent block conv."""
        w = _sparse_int8_weights(9, 3, 3, 8, 16, 0.3)
        pw = ops.pack_conv_weights(w, kblk=16)
        spikes = jnp.asarray(np.random.default_rng(2).integers(0, 2, (2, 36, 64, 8)), jnp.int8)
        got = ops.gated_conv(spikes, pw)
        want = ref.gated_conv_ref(spikes, jnp.asarray(w))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.5)

    def test_all_zero_weights(self):
        w = np.zeros((3, 3, 8, 8), np.int8)
        pw = ops.pack_conv_weights(w, kblk=8)
        spikes = jnp.ones((1, 18, 32, 8), jnp.int8)
        got = ops.gated_conv(spikes, pw)
        assert np.all(np.asarray(got) == 0)

    def test_multiple_k_blocks(self):
        w = _sparse_int8_weights(5, 3, 3, 8, 40, 0.25)
        pw = ops.pack_conv_weights(w, kblk=16)  # 40 -> 3 blocks of 16
        assert pw.maskp.shape[0] == 3
        spikes = jnp.asarray(np.random.default_rng(3).integers(0, 2, (1, 18, 32, 8)), jnp.int8)
        got = ops.gated_conv(spikes, pw)
        want = ref.gated_conv_ref(spikes, jnp.asarray(w))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.5)

    def test_compressed_bytes_smaller_than_dense(self):
        w = _sparse_int8_weights(11, 3, 3, 64, 64, 0.2)
        pw = ops.pack_conv_weights(w, kblk=64)
        dense_bytes = w.size
        assert pw.compressed_bytes < 0.5 * dense_bytes  # ~0.325 at 20% density


class TestFusedLIFKernel:
    @pytest.mark.parametrize("t,m,c", [(3, 100, 16), (1, 7, 8), (4, 600, 32)])
    def test_matches_scan_oracle(self, t, m, c):
        x = jax.random.normal(jax.random.PRNGKey(t * m), (t, m, c))
        got = ops.fused_lif(x)
        want = ref.fused_lif_ref(x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_threshold_leak_variants(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (3, 64, 8)) * 0.5
        got = ops.fused_lif(x, threshold=0.3, leak=0.5)
        want = np.asarray(
            ref.fused_lif_ref(x, threshold=0.3, leak=0.5)
            if False
            else None
        )
        from repro.core import lif as lifm

        spikes, _ = lifm.lif_over_time(x, threshold=0.3, leak=0.5)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(spikes.astype(jnp.int8)))


class TestBitmaskMatmulKernel:
    @pytest.mark.parametrize(
        "m,k,n,density", [(32, 64, 48, 0.2), (100, 128, 64, 0.5), (16, 512, 256, 0.1)]
    )
    def test_matches_dense(self, m, k, n, density):
        rng = np.random.default_rng(m + k + n)
        w = rng.standard_normal((k, n)).astype(np.float32)
        w[rng.random((k, n)) >= density] = 0.0
        packed = pack_weights(w, kblk=min(64, k), nblk=min(32, n))
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        got = ops.bitmask_matmul(x, packed, mblk=32)
        want = ref.bitmask_matmul_ref(x, jnp.asarray(w))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)

    def test_compression_ratio(self):
        rng = np.random.default_rng(0)
        w = rng.standard_normal((512, 512)).astype(np.float32)
        w[rng.random(w.shape) >= 0.2] = 0.0
        packed = pack_weights(w, kblk=128, nblk=128)
        dense_bytes = w.size * 4
        # f32 values: 0.2*4 bytes + 1/8 mask byte per element ≈ 0.93/4 of dense
        assert packed.compressed_bytes < 0.35 * dense_bytes
