"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes/dtypes/sparsity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.bitmask_matmul import pack_weights


def _sparse_int8_weights(key, kh, kw, cin, k, density):
    rng = np.random.default_rng(key)
    w = rng.integers(-127, 128, (kh, kw, cin, k)).astype(np.int8)
    mask = rng.random((kh, kw, cin, k)) < density
    return (w * mask).astype(np.int8)


class TestGatedOneToAllKernel:
    @pytest.mark.parametrize(
        "cin,k,density",
        [(8, 16, 0.2), (16, 8, 0.5), (3, 40, 0.3), (32, 32, 0.05), (8, 8, 1.0)],
    )
    def test_matches_block_conv_3x3(self, cin, k, density):
        w = _sparse_int8_weights(cin * 7 + k, 3, 3, cin, k, density)
        pw = ops.pack_conv_weights(w, kblk=8)
        rng = np.random.default_rng(0)
        spikes = jnp.asarray(rng.integers(0, 2, (2, 18, 32, cin)), jnp.int8)
        got = ops.gated_conv(spikes, pw)
        want = ref.gated_conv_ref(spikes, jnp.asarray(w))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.5)

    def test_1x1_kernel(self):
        w = _sparse_int8_weights(3, 1, 1, 16, 24, 0.7)
        pw = ops.pack_conv_weights(w, kblk=8)
        spikes = jnp.asarray(np.random.default_rng(1).integers(0, 2, (1, 18, 32, 16)), jnp.int8)
        got = ops.gated_conv(spikes, pw)
        want = ref.gated_conv_ref(spikes, jnp.asarray(w))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.5)

    def test_multi_spatial_blocks(self):
        """Input larger than one 32×18 tile → independent block conv."""
        w = _sparse_int8_weights(9, 3, 3, 8, 16, 0.3)
        pw = ops.pack_conv_weights(w, kblk=16)
        spikes = jnp.asarray(np.random.default_rng(2).integers(0, 2, (2, 36, 64, 8)), jnp.int8)
        got = ops.gated_conv(spikes, pw)
        want = ref.gated_conv_ref(spikes, jnp.asarray(w))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.5)

    def test_all_zero_weights(self):
        w = np.zeros((3, 3, 8, 8), np.int8)
        pw = ops.pack_conv_weights(w, kblk=8)
        spikes = jnp.ones((1, 18, 32, 8), jnp.int8)
        got = ops.gated_conv(spikes, pw)
        assert np.all(np.asarray(got) == 0)

    def test_multiple_k_blocks(self):
        w = _sparse_int8_weights(5, 3, 3, 8, 40, 0.25)
        pw = ops.pack_conv_weights(w, kblk=16)  # 40 -> 3 blocks of 16
        assert pw.maskp.shape[0] == 3
        spikes = jnp.asarray(np.random.default_rng(3).integers(0, 2, (1, 18, 32, 8)), jnp.int8)
        got = ops.gated_conv(spikes, pw)
        want = ref.gated_conv_ref(spikes, jnp.asarray(w))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.5)

    def test_compressed_bytes_smaller_than_dense(self):
        w = _sparse_int8_weights(11, 3, 3, 64, 64, 0.2)
        pw = ops.pack_conv_weights(w, kblk=64)
        dense_bytes = w.size
        assert pw.compressed_bytes < 0.5 * dense_bytes  # ~0.325 at 20% density


class TestFusedLIFKernel:
    @pytest.mark.parametrize("t,m,c", [(3, 100, 16), (1, 7, 8), (4, 600, 32)])
    def test_matches_scan_oracle(self, t, m, c):
        x = jax.random.normal(jax.random.PRNGKey(t * m), (t, m, c))
        got = ops.fused_lif(x)
        want = ref.fused_lif_ref(x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_threshold_leak_variants(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (3, 64, 8)) * 0.5
        got = ops.fused_lif(x, threshold=0.3, leak=0.5)
        want = np.asarray(
            ref.fused_lif_ref(x, threshold=0.3, leak=0.5)
            if False
            else None
        )
        from repro.core import lif as lifm

        spikes, _ = lifm.lif_over_time(x, threshold=0.3, leak=0.5)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(spikes.astype(jnp.int8)))


class TestFusedPipelineKernel:
    """The fused conv→FXP→tdBN→LIF dispatch vs the unfused op chain it
    replaces, bit-for-bit — and predecode (decoder stage hoisted to trace
    time) vs in-kernel decode, which must be indistinguishable."""

    def _setup(self, *, kh, cin, kout, t_in, t_out, h=12, w=16, bh=6, bw=8,
               in_bits=1, seed=0):
        from repro.core import block_conv as bc
        from repro.core import lif as lifm

        rng = np.random.default_rng(seed)
        w_int = _sparse_int8_weights(seed + 1, kh, kh, cin, kout, 0.3)
        pw = ops.pack_conv_weights(w_int, kblk=8)
        scale = jnp.float32(1.0 / 128)
        mean = jnp.asarray(rng.normal(size=kout), jnp.float32)
        var = jnp.asarray(rng.random(kout) + 0.5, jnp.float32)
        gamma = jnp.asarray(rng.normal(size=kout), jnp.float32)
        beta = jnp.asarray(rng.normal(size=kout), jnp.float32)
        affine = ops.affine_bundle(pw, scale, mean, var, gamma, beta)
        if in_bits == 8:
            x_t = jnp.asarray(
                rng.integers(0, 256, (t_in, 2, h, w, cin)), jnp.float32
            )
        else:
            x_t = jnp.asarray(
                rng.integers(0, 2, (t_in, 2, h, w, cin)), jnp.float32
            )
        thr, leak = 0.5, 0.25

        def unfused(x_t):
            """The op chain the kernel replaces — conv → FXP scale → tdBN
            (training=False) → hard-reset LIF — run EAGERLY, op by op: each
            primitive is its own dispatch and rounds separately. This is the
            strictest reference there is: inside any jitted graph XLA/LLVM
            contracts mul+add into FMAs (single rounding) and no in-graph
            barrier stops it on CPU, so the fused kernel's *membranes* may
            sit a few ulp off this chain while its integer surfaces (conv
            accumulators, spike trains) are exact by construction."""
            t, n = x_t.shape[:2]
            y = bc.block_conv2d(
                x_t.reshape((t * n,) + x_t.shape[2:]),
                jnp.asarray(w_int, jnp.float32), block_h=bh, block_w=bw,
            ) * scale
            y = y.reshape((t, n) + y.shape[1:])
            p = lifm.TdBNParams(gamma=gamma, beta=beta)
            st = lifm.TdBNState(mean=mean, var=var, count=jnp.zeros((), jnp.int32))
            y, _ = lifm.tdbn_apply(p, st, y, threshold=thr, training=False)
            if t == 1 and t_out > 1:
                y = jnp.broadcast_to(y, (t_out,) + y.shape[1:])
            v = jnp.zeros(y.shape[1:], jnp.float32)
            spikes = []
            for k in range(t_out):  # eager LIF: mul, add, cmp, where — one
                v = v * leak + y[k]  # dispatch each, like lif_step unfused
                s = (v >= thr).astype(jnp.float32)
                spikes.append(s)
                v = jnp.where(s > 0, 0.0, v)
            return jnp.stack(spikes), v

        def fused(x_t, predecode):
            return ops.fused_conv_bn_lif(
                x_t, pw, affine, v0=None, out_t=t_out, in_bits=in_bits,
                bn_scale=thr, threshold=thr, leak=leak, bh=bh, bw=bw,
                nbt=2, predecode=predecode,
            )

        return x_t, unfused, fused

    @pytest.mark.parametrize(
        "kh,cin,kout,t_in,t_out",
        [(3, 8, 16, 2, 2), (1, 16, 8, 3, 3), (3, 8, 8, 1, 3)],
    )
    def test_matches_unfused_chain(self, kh, cin, kout, t_in, t_out):
        """Spike trains must be BIT-EXACT against the eager unfused chain;
        membranes within a few ulp (FMA contraction inside the fused graph
        single-rounds mul+add where the eager chain rounds twice — see the
        unfused docstring). Exact membrane parity against the *production*
        dense executor — where both sides are jitted and contract
        identically — is asserted at 0.0 diff by the conformance suite."""
        x_t, unfused, fused = self._setup(
            kh=kh, cin=cin, kout=kout, t_in=t_in, t_out=t_out
        )
        spk_w, mem_w = unfused(x_t)  # eagerly, NOT jitted — see docstring
        spk_g, mem_g = fused(x_t, predecode=True)
        np.testing.assert_array_equal(np.asarray(spk_g), np.asarray(spk_w))
        np.testing.assert_allclose(
            np.asarray(mem_g), np.asarray(mem_w), atol=1e-6, rtol=0
        )

    @pytest.mark.parametrize(
        "kh,cin,kout,t_in,t_out,in_bits",
        [(3, 8, 16, 2, 2, 1), (1, 16, 8, 3, 3, 1), (3, 3, 8, 1, 3, 8)],
    )
    def test_predecode_equals_in_kernel_decode(
        self, kh, cin, kout, t_in, t_out, in_bits
    ):
        """The docstring promise: decoder-in-kernel (streaming weights) and
        predecoded (static weights, decode at trace time) are bit-identical."""
        x_t, _, fused = self._setup(
            kh=kh, cin=cin, kout=kout, t_in=t_in, t_out=t_out, in_bits=in_bits
        )
        spk_p, mem_p = fused(x_t, predecode=True)
        spk_k, mem_k = fused(x_t, predecode=False)
        np.testing.assert_array_equal(np.asarray(spk_p), np.asarray(spk_k))
        np.testing.assert_array_equal(np.asarray(mem_p), np.asarray(mem_k))

    def test_encode_in_bits8_matches_bitserial_reference(self):
        """u8 values folded into one dispatch ≡ the literal 8-plane
        bit-serial accumulation (conv linearity over exact integers)."""
        from repro.core import bitserial, block_conv as bc

        x_t, unfused, fused = self._setup(
            kh=3, cin=3, kout=8, t_in=1, t_out=2, in_bits=8, seed=3
        )
        spk_g, _ = fused(x_t, predecode=True)
        # plane-serial reference conv, then the same affine/LIF chain via
        # the unfused oracle path run on conv outputs is overkill here —
        # instead assert the fold at the conv level feeding the kernel:
        x_u8 = np.asarray(x_t[0], np.uint8)
        planes = bitserial.to_bitplanes(jnp.asarray(x_u8))
        acc = sum(
            (2**b)
            * np.asarray(
                bc.block_conv2d(planes[b], jnp.zeros((3, 3, 3, 8)) + 1.0,
                                block_h=6, block_w=8)
            )
            for b in range(8)
        )
        whole = np.asarray(
            bc.block_conv2d(x_t[0], jnp.zeros((3, 3, 3, 8)) + 1.0,
                            block_h=6, block_w=8)
        )
        np.testing.assert_array_equal(acc, whole)


class TestMacroTileFusedPipeline:
    """Macro-tiling (mrows×mcols blocks per grid step) is pure dispatch
    layout: every macro shape — including ragged ones that zero-pad the
    block grid — must be BIT-identical to the single-block-per-step path,
    for both reset modes and any T."""

    def _run(self, *, h, w, t_in, t_out, reset, mrows, mcols, nbt=None,
             bh=6, bw=8, kh=3, cin=8, kout=16, seed=0, v0=None):
        rng = np.random.default_rng(seed)
        w_int = _sparse_int8_weights(seed + 1, kh, kh, cin, kout, 0.3)
        pw = ops.pack_conv_weights(w_int, kblk=8)
        affine = ops.affine_bundle(
            pw,
            jnp.float32(1.0 / 128),
            jnp.asarray(rng.normal(size=kout), jnp.float32),
            jnp.asarray(rng.random(kout) + 0.5, jnp.float32),
            jnp.asarray(rng.normal(size=kout), jnp.float32),
            jnp.asarray(rng.normal(size=kout), jnp.float32),
        )
        x_t = jnp.asarray(rng.integers(0, 2, (t_in, 2, h, w, cin)), jnp.float32)
        return ops.fused_conv_bn_lif(
            x_t, pw, affine, v0=v0, out_t=t_out, in_bits=1,
            bn_scale=0.5, threshold=0.5, leak=0.25, reset=reset,
            bh=bh, bw=bw, nbt=nbt if nbt is not None else mrows * mcols,
            mrows=mrows, mcols=mcols,
        )

    @pytest.mark.parametrize("t_in,t_out", [(1, 1), (3, 3), (1, 3)])
    @pytest.mark.parametrize("reset", ["hard", "soft"])
    def test_macro_tile_bit_equals_single_block(self, t_in, t_out, reset):
        """2×2 macro-tile over an exactly-divisible 4×4 block grid vs the
        single-block baseline: spikes AND membranes bit-equal."""
        kw = dict(h=24, w=32, t_in=t_in, t_out=t_out, reset=reset)
        spk_b, mem_b = self._run(mrows=1, mcols=1, **kw)
        spk_m, mem_m = self._run(mrows=2, mcols=2, **kw)
        np.testing.assert_array_equal(np.asarray(spk_m), np.asarray(spk_b))
        np.testing.assert_array_equal(np.asarray(mem_m), np.asarray(mem_b))

    @pytest.mark.parametrize("mrows,mcols", [(2, 2), (1, 3), (3, 1), (4, 4)])
    def test_ragged_block_grid(self, mrows, mcols):
        """18×24 at 6×8 blocks is a 3×3 block grid — NOT divisible by any
        of these macro shapes, so whole zero blocks are padded in and
        stripped out. Still bit-exact (macros > grid clip to it)."""
        kw = dict(h=18, w=24, t_in=3, t_out=3, reset="hard")
        spk_b, mem_b = self._run(mrows=1, mcols=1, **kw)
        spk_m, mem_m = self._run(mrows=mrows, mcols=mcols, **kw)
        np.testing.assert_array_equal(np.asarray(spk_m), np.asarray(spk_b))
        np.testing.assert_array_equal(np.asarray(mem_m), np.asarray(mem_b))

    def test_dot_granularity_inside_macro(self):
        """nbt (blocks per MXU dot) sweeps independently of the macro
        shape; every divisor of the macro-tile size is bit-equal."""
        kw = dict(h=24, w=32, t_in=3, t_out=3, reset="soft")
        spk_b, mem_b = self._run(mrows=1, mcols=1, **kw)
        for nbt in (1, 2, 4):
            spk_m, mem_m = self._run(mrows=2, mcols=2, nbt=nbt, **kw)
            np.testing.assert_array_equal(np.asarray(spk_m), np.asarray(spk_b))
            np.testing.assert_array_equal(np.asarray(mem_m), np.asarray(mem_b))

    def test_warm_membrane_macro(self):
        """v0-carrying (streaming session) dispatch under a macro-tile."""
        rng = np.random.default_rng(7)
        v0 = jnp.asarray(rng.normal(size=(2, 24, 32, 16)) * 0.3, jnp.float32)
        kw = dict(h=24, w=32, t_in=3, t_out=3, reset="hard", v0=v0)
        spk_b, mem_b = self._run(mrows=1, mcols=1, **kw)
        spk_m, mem_m = self._run(mrows=4, mcols=2, **kw)
        np.testing.assert_array_equal(np.asarray(spk_m), np.asarray(spk_b))
        np.testing.assert_array_equal(np.asarray(mem_m), np.asarray(mem_b))

    def test_legacy_flat_nbt_maps_to_row_macro(self):
        """Bare nbt>1 with no macro shape keeps working (normalized to a
        1×nbt macro-tile) and stays bit-equal to nbt=1."""
        kw = dict(h=24, w=32, t_in=3, t_out=3, reset="hard")
        spk_b, mem_b = self._run(mrows=1, mcols=1, **kw)
        spk_f, mem_f = self._run(mrows=1, mcols=1, nbt=4, **kw)
        np.testing.assert_array_equal(np.asarray(spk_f), np.asarray(spk_b))
        np.testing.assert_array_equal(np.asarray(mem_f), np.asarray(mem_b))


class TestBitmaskMatmulKernel:
    @pytest.mark.parametrize(
        "m,k,n,density", [(32, 64, 48, 0.2), (100, 128, 64, 0.5), (16, 512, 256, 0.1)]
    )
    def test_matches_dense(self, m, k, n, density):
        rng = np.random.default_rng(m + k + n)
        w = rng.standard_normal((k, n)).astype(np.float32)
        w[rng.random((k, n)) >= density] = 0.0
        packed = pack_weights(w, kblk=min(64, k), nblk=min(32, n))
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        got = ops.bitmask_matmul(x, packed, mblk=32)
        want = ref.bitmask_matmul_ref(x, jnp.asarray(w))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)

    def test_compression_ratio(self):
        rng = np.random.default_rng(0)
        w = rng.standard_normal((512, 512)).astype(np.float32)
        w[rng.random(w.shape) >= 0.2] = 0.0
        packed = pack_weights(w, kblk=128, nblk=128)
        dense_bytes = w.size * 4
        # f32 values: 0.2*4 bytes + 1/8 mask byte per element ≈ 0.93/4 of dense
        assert packed.compressed_bytes < 0.35 * dense_bytes
