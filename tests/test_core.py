"""Unit + property tests for the paper-core modules (LIF, bitmask, block
conv, pruning, quant, mIoUT, gated one-to-all, bit-serial)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.core import (
    bitmask as bm,
    bitserial,
    block_conv as bc,
    lif,
    miout,
    pruning,
    quant,
    spike_conv as sc,
)

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------- LIF ------
class TestLIF:
    def test_fires_above_threshold(self):
        st0 = lif.lif_init((4,))
        _, s = lif.lif_step(st0, jnp.array([0.6, 0.4, 0.5, -1.0]))
        np.testing.assert_array_equal(s, [1.0, 0.0, 1.0, 0.0])

    def test_hard_reset_zeroes_potential(self):
        st0 = lif.lif_init((1,))
        st1, s = lif.lif_step(st0, jnp.array([0.7]))
        assert s[0] == 1.0 and st1.v[0] == 0.0

    def test_leak_accumulation(self):
        # v1 = 0.3 (no spike), v2 = 0.25*0.3 + 0.3 = 0.375 (no spike),
        # v3 = 0.25*0.375 + 0.3 = 0.39375... never reaches 0.5 with x=0.3?
        # fixed point v* = x / (1 - leak) = 0.4 < 0.5 -> never fires.
        x = jnp.full((10, 1), 0.3)
        spikes, _ = lif.lif_over_time(x)
        assert jnp.sum(spikes) == 0

    def test_integration_fires_eventually(self):
        # x = 0.4: fixed point 0.5333 > 0.5 -> fires.
        x = jnp.full((10, 1), 0.4)
        spikes, _ = lif.lif_over_time(x)
        assert jnp.sum(spikes) > 0

    def test_soft_reset_subtracts(self):
        st0 = lif.lif_init((1,))
        st1, s = lif.lif_step(st0, jnp.array([0.9]), reset="soft")
        assert s[0] == 1.0
        np.testing.assert_allclose(st1.v, [0.4], atol=1e-6)

    def test_surrogate_gradient_window(self):
        g = jax.grad(lambda v: lif.spike_fn(v).sum())(jnp.array([0.5, 0.95, 1.1, -0.6]))
        np.testing.assert_array_equal(g, [1.0, 1.0, 0.0, 0.0])

    def test_membrane_readout_no_reset(self):
        x = jnp.ones((3, 2)) * 1.0  # would spike every step if resetting
        out = lif.membrane_readout(x)
        # v: 1, 1.25, 1.3125 -> mean
        np.testing.assert_allclose(out, np.full((2,), np.mean([1, 1.25, 1.3125])), rtol=1e-6)

    def test_spikes_are_binary_property(self):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (5, 3, 7))
        spikes, _ = lif.lif_over_time(x)
        assert set(np.unique(np.asarray(spikes))).issubset({0.0, 1.0})

    def test_grad_flows_through_time(self):
        def loss(x):
            s, _ = lif.lif_over_time(x)
            return jnp.sum(s)

        x = jnp.full((4, 8), 0.3)
        g = jax.grad(loss)(x)
        assert jnp.any(g != 0)


class TestTdBN:
    def test_normalizes_to_threshold_scale(self):
        params, state = lif.tdbn_init(4)
        x = jax.random.normal(jax.random.PRNGKey(0), (3, 8, 6, 6, 4)) * 5 + 2
        y, new_state = lif.tdbn_apply(params, state, x, training=True)
        # mean ~ 0, std ~ threshold (0.5)
        np.testing.assert_allclose(np.asarray(jnp.mean(y)), 0.0, atol=1e-2)
        np.testing.assert_allclose(np.asarray(jnp.std(y)), lif.THRESHOLD, atol=1e-2)
        assert new_state.count == 1

    def test_inference_uses_running_stats(self):
        params, state = lif.tdbn_init(2)
        x = jnp.ones((2, 4, 2))
        y, st2 = lif.tdbn_apply(params, state, x, training=False)
        assert st2.count == 0  # unchanged


# ------------------------------------------------------------- bitmask -----
class TestBitmask:
    def test_roundtrip(self):
        w = np.array([[0, 1.5, 0], [2.0, 0, -3.0]], np.float32)
        cw = bm.encode(w)
        np.testing.assert_array_equal(bm.decode(cw), w)
        assert cw.nnz == 3

    @settings(max_examples=50, deadline=None)
    @given(st.integers(1, 6), st.integers(1, 48), st.floats(0.0, 1.0))
    def test_roundtrip_property(self, rows, cols, rate):
        rng = np.random.default_rng(rows * 100 + cols)
        w = rng.standard_normal((rows, cols)).astype(np.float32)
        w[rng.random((rows, cols)) < rate] = 0.0
        cw = bm.encode(w)
        np.testing.assert_array_equal(np.asarray(bm.decode(cw)), w)

    def test_padded_roundtrip(self):
        w = np.array([1.0, 0.0, 2.0], np.float32)
        cw = bm.encode(w, pad_to=8)
        assert cw.values.shape == (8,)
        np.testing.assert_array_equal(bm.decode(cw), w)

    def test_csr_roundtrip(self):
        rng = np.random.default_rng(0)
        w = rng.standard_normal((8, 27)).astype(np.float32)
        w[rng.random(w.shape) < 0.8] = 0
        np.testing.assert_array_equal(np.asarray(bm.decode_csr(bm.encode_csr(w))), w)

    def test_format_bits_orders_match_paper_regime(self):
        # at 80% sparsity of 3x3 kernels, bitmask < csr < dense (Fig 17)
        shape = (64, 64 * 9)
        nnz = int(0.2 * 64 * 64 * 9)
        dense = bm.format_bits(shape, nnz, fmt="dense")
        mask = bm.format_bits(shape, nnz, fmt="bitmask")
        csr = bm.format_bits(shape, nnz, fmt="csr")
        assert mask < csr < dense


# ---------------------------------------------------------- block conv -----
class TestBlockConv:
    def test_interior_matches_full_conv(self):
        """Away from block borders, block conv == plain SAME conv."""
        key = jax.random.PRNGKey(1)
        x = jax.random.normal(key, (1, 36, 64, 3))
        w = jax.random.normal(jax.random.PRNGKey(2), (3, 3, 3, 4))
        full = bc.conv2d(x, w)
        blocked = bc.block_conv2d(x, w, block_h=18, block_w=32)
        # interior of the (0,0) block: rows 1..16, cols 1..30
        np.testing.assert_allclose(
            np.asarray(blocked[:, 1:17, 1:31]), np.asarray(full[:, 1:17, 1:31]), rtol=1e-4, atol=1e-4
        )

    def test_single_block_equals_replicate_pad_conv(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 18, 32, 5))
        w = jax.random.normal(jax.random.PRNGKey(4), (3, 3, 5, 7))
        blocked = bc.block_conv2d(x, w)
        padded = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)), mode="edge")
        ref = jax.lax.conv_general_dilated(
            padded, w, (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        np.testing.assert_allclose(np.asarray(blocked), np.asarray(ref), rtol=1e-4, atol=1e-4)

    def test_1x1_blocked_equals_full(self):
        x = jax.random.normal(jax.random.PRNGKey(5), (1, 36, 64, 4))
        w = jax.random.normal(jax.random.PRNGKey(6), (1, 1, 4, 8))
        np.testing.assert_allclose(
            np.asarray(bc.block_conv2d(x, w)), np.asarray(bc.conv2d(x, w)), rtol=1e-4, atol=1e-4
        )

    def test_blocks_roundtrip(self):
        x = jax.random.normal(jax.random.PRNGKey(7), (2, 54, 96, 3))
        np.testing.assert_array_equal(np.asarray(bc.from_blocks(bc.to_blocks(x))), np.asarray(x))

    def test_block_independence(self):
        """Changing one block never affects another block's output — the
        property that makes spatial sharding communication-free."""
        x = jnp.zeros((1, 36, 64, 1))
        w = jnp.ones((3, 3, 1, 1))
        y0 = bc.block_conv2d(x, w)
        x2 = x.at[0, 0, 0, 0].set(100.0)  # corner of block (0,0)
        y2 = bc.block_conv2d(x2, w)
        # block (0,1) spans cols 32..63 — untouched
        np.testing.assert_array_equal(np.asarray(y2[:, :, 32:]), np.asarray(y0[:, :, 32:]))
        np.testing.assert_array_equal(np.asarray(y2[:, 18:, :]), np.asarray(y0[:, 18:, :]))


# -------------------------------------------------------------- pruning ----
class TestPruning:
    def test_rate(self):
        w = jnp.arange(1, 101, dtype=jnp.float32).reshape(10, 10)
        pruned = pruning.prune_by_rate(w, 0.8)
        assert float(jnp.mean((pruned == 0).astype(jnp.float32))) == pytest.approx(0.8)

    def test_keeps_largest(self):
        w = jnp.array([0.1, -5.0, 0.2, 3.0], jnp.float32)
        pruned = pruning.prune_by_rate(w, 0.5)
        np.testing.assert_array_equal(pruned, [0.0, -5.0, 0.0, 3.0])

    @settings(max_examples=30, deadline=None)
    @given(st.floats(0.0, 0.95), st.integers(2, 12))
    def test_rate_property(self, rate, n):
        rng = np.random.default_rng(n)
        w = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
        pruned = pruning.prune_by_rate(w, rate)
        got = float(jnp.mean((pruned == 0).astype(jnp.float32)))
        assert got == pytest.approx(np.floor(rate * n * n) / (n * n), abs=1e-6)

    def test_tree_selects_3x3_only(self):
        params = {
            "conv3": jnp.ones((3, 3, 8, 8)),
            "conv1": jnp.ones((1, 1, 8, 8)),
            "bias": jnp.ones((8,)),
        }
        rng = np.random.default_rng(0)
        params["conv3"] = jnp.asarray(rng.standard_normal((3, 3, 8, 8)).astype(np.float32))
        pruned = pruning.prune_tree(params, 0.8)
        assert pruning.density(pruned["conv3"]) == pytest.approx(0.2, abs=0.01)
        assert pruning.density(pruned["conv1"]) == 1.0
        assert pruning.density(pruned["bias"]) == 1.0


# ---------------------------------------------------------------- quant ----
class TestQuant:
    def test_roundtrip_error_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (64,)) * 3
        qx = quant.quantize(x)
        err = jnp.max(jnp.abs(dq := quant.dequantize(qx) - x))
        assert float(err) <= float(qx.scale) / 2 + 1e-6

    def test_int8_payload(self):
        qx = quant.quantize(jnp.linspace(-1, 1, 100))
        assert qx.q.dtype == jnp.int8

    def test_ste_gradient_passthrough(self):
        g = jax.grad(lambda x: jnp.sum(quant.fake_quant_tensor(x)))(jnp.linspace(-1, 1, 16))
        np.testing.assert_allclose(np.asarray(g), 1.0)

    @settings(max_examples=30, deadline=None)
    @given(st.integers(2, 8))
    def test_quant_idempotent(self, bits):
        x = jnp.linspace(-2, 2, 37)
        q1 = quant.dequantize(quant.quantize(x, bits=bits))
        q2 = quant.dequantize(quant.quantize(q1, bits=bits))
        np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------- mIoUT ----
class TestMIoUT:
    def test_fig4_example(self):
        """Paper Fig 4: 4 neurons fire at all 3 steps, 2 fire partially
        -> mIoUT = 4/6 = 0.67."""
        # single channel, 8 neurons: 4 always, 2 partial, 2 silent
        T = 3
        s = np.zeros((T, 8, 1), np.float32)
        s[:, :4, 0] = 1.0  # always fire
        s[0, 4, 0] = 1.0  # partial
        s[1:, 5, 0] = 1.0  # partial (2 of 3)
        got = float(miout.miout(jnp.asarray(s)))
        assert got == pytest.approx(4 / 6, abs=1e-6)

    def test_identical_steps_give_one(self):
        s = jnp.asarray(np.random.default_rng(0).integers(0, 2, (1, 4, 4, 3)).astype(np.float32))
        s3 = jnp.broadcast_to(s, (3, 4, 4, 3))
        assert float(miout.miout(s3)) == pytest.approx(1.0)

    def test_disjoint_steps_give_zero(self):
        s = np.zeros((2, 4, 1), np.float32)
        s[0, :2, 0] = 1.0
        s[1, 2:, 0] = 1.0
        assert float(miout.miout(jnp.asarray(s))) == 0.0

    def test_schedule_prefix_rule(self):
        in_ts = miout.choose_schedule([0.9, 0.8, 0.4, 0.9], [100, 100, 100, 100], threshold=0.6)
        assert in_ts == [1, 1, 3, 3]  # late high-mIoUT layer NOT dropped

    def test_schedule_ops(self):
        assert miout.schedule_ops([10, 20], [1, 3]) == 70


# ------------------------------------------------- gated one-to-all --------
class TestGatedOneToAll:
    @pytest.mark.parametrize("k,cin,cout", [(3, 4, 8), (1, 6, 5), (3, 1, 1)])
    def test_matches_dense_conv(self, k, cin, cout):
        key = jax.random.PRNGKey(k * 100 + cin)
        spikes = (jax.random.uniform(key, (2, 9, 12, cin)) > 0.7).astype(jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (k, k, cin, cout))
        w = pruning.prune_by_rate(w, 0.7)
        ref = sc.conv_reference(spikes, w)
        got = sc.gated_one_to_all(spikes, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4)

    def test_compressed_path(self):
        spikes = (jax.random.uniform(jax.random.PRNGKey(0), (1, 6, 6, 3)) > 0.5).astype(jnp.float32)
        w = pruning.prune_by_rate(jax.random.normal(jax.random.PRNGKey(1), (3, 3, 3, 4)), 0.8)
        cw = bm.encode(np.asarray(w))
        np.testing.assert_allclose(
            np.asarray(sc.gated_one_to_all_compressed(spikes, cw)),
            np.asarray(sc.conv_reference(spikes, w)),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_accumulate_count_sparsity_saving(self):
        w = np.zeros((3, 3, 10, 10), np.float32)
        w[0, 0, :, :] = 1.0  # 1/9 density
        assert sc.accumulate_count(jnp.asarray(w), 576) == 100 * 576
        assert sc.dense_count(jnp.asarray(w), 576) == 900 * 576

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 100))
    def test_equivalence_property(self, seed):
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        spikes = (jax.random.uniform(k1, (1, 5, 7, 3)) > 0.6).astype(jnp.float32)
        w = jax.random.normal(k2, (3, 3, 3, 2))
        w = jnp.where(jax.random.uniform(k2, w.shape) > 0.5, w, 0.0)
        np.testing.assert_allclose(
            np.asarray(sc.gated_one_to_all(spikes, w)),
            np.asarray(sc.conv_reference(spikes, w)),
            rtol=1e-4,
            atol=1e-4,
        )


# ------------------------------------------------------------ bit-serial ---
class TestBitSerial:
    def test_bitplane_roundtrip(self):
        x = jnp.asarray(np.random.default_rng(0).integers(0, 256, (1, 4, 4, 3)), jnp.uint8)
        planes = bitserial.to_bitplanes(x)
        np.testing.assert_array_equal(
            np.asarray(bitserial.from_bitplanes(planes)), np.asarray(x).astype(np.float32)
        )

    def test_bitserial_conv_equals_direct(self):
        """Paper §III-C.2: bit-serial multibit conv == direct conv."""
        x = jnp.asarray(np.random.default_rng(1).integers(0, 256, (1, 8, 8, 3)), jnp.uint8)
        w = jax.random.normal(jax.random.PRNGKey(2), (3, 3, 3, 4))
        direct = sc.conv_reference(x.astype(jnp.float32), w)
        serial = bitserial.bitserial_conv(x, w, sc.gated_one_to_all)
        np.testing.assert_allclose(np.asarray(serial), np.asarray(direct), rtol=1e-3, atol=1e-3)
