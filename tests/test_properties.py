"""Property-test hardening (hypothesis via tests/hyp_compat.py — degrades
to explicit skips when hypothesis is absent) plus the deterministic edge
cases the properties are anchored on:

* bitmask pack → unpack round-trips EVERY sparsity pattern, including
  all-zero kernels and K-blocks sitting exactly on the VPAD boundary,
* FXP quantize/dequantize error is bounded by scale/2 with the int8
  payload honoring its bounds,
* the 16-bit accumulator claim (core/quant.ACC_BITS — previously
  "asserted in tests" with no test calling acc_range_ok) holds at the
  paper's layer sizes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.core import pruning, quant
from repro.kernels import ops


def _sparse_int8(rng, kh, kw, cin, k, density):
    w = rng.integers(-127, 128, (kh, kw, cin, k)).astype(np.int8)
    mask = rng.random((kh, kw, cin, k)) < density
    return (w * mask).astype(np.int8)


def _assert_roundtrip(w, **pack_kw):
    pw = ops.pack_conv_weights(w, **pack_kw)
    got = ops.unpack_conv_weights(pw)
    cin = w.shape[2]
    np.testing.assert_array_equal(got[:, :, :cin, :], w)
    # channel padding must be zeros, never stray values
    np.testing.assert_array_equal(got[:, :, cin:, :], 0)


class TestPackRoundtrip:
    @settings(max_examples=40, deadline=None)
    @given(
        st.integers(0, 10_000),
        st.sampled_from([1, 3]),
        st.integers(1, 12),
        st.integers(1, 20),
        st.sampled_from([8, 16]),
        st.floats(0.0, 1.0),
    )
    def test_roundtrip_property(self, seed, kh, cin, k, kblk, density):
        """pack→unpack is the identity for any shape × sparsity pattern."""
        rng = np.random.default_rng(seed)
        _assert_roundtrip(_sparse_int8(rng, kh, kh, cin, k, density), kblk=kblk)

    def test_all_zero_kernel(self):
        """nnz = 0 everywhere: vals degenerates to the 1-entry pad buffer
        and the masks must decode back to all-zeros."""
        w = np.zeros((3, 3, 8, 16), np.int8)
        pw = ops.pack_conv_weights(w, kblk=8)
        assert int(np.asarray(pw.tap_any).sum()) == 0
        _assert_roundtrip(w, kblk=8)

    def test_one_kblock_all_zero_between_dense_blocks(self):
        """A dead K-block sandwiched between live ones keeps its vals row
        padded and decodes to zeros (per-block offsets must not slip)."""
        w = _sparse_int8(np.random.default_rng(0), 3, 3, 8, 24, 0.5)
        w[..., 8:16] = 0  # middle K-block dead
        _assert_roundtrip(w, kblk=8)

    def test_vpad_boundary_exact_fit(self):
        """vpad == max per-block nnz is legal (the boundary case the
        kernel's clipped gather depends on) — and one less must raise."""
        w = _sparse_int8(np.random.default_rng(1), 3, 3, 8, 8, 0.4)
        pw0 = ops.pack_conv_weights(w, kblk=8)
        max_nnz = max(
            int(np.count_nonzero(w[..., kb * 8 : (kb + 1) * 8]))
            for kb in range(w.shape[-1] // 8)
        )
        _assert_roundtrip(w, kblk=8, vpad=max_nnz)
        assert pw0.vals.shape[1] == max_nnz
        with pytest.raises(ValueError, match="vpad"):
            ops.pack_conv_weights(w, kblk=8, vpad=max_nnz - 1)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 10_000), st.integers(1, 8))
    def test_vpad_padding_roundtrips(self, seed, extra):
        """Over-padded vals (uniform VPAD across a plan) change nothing."""
        rng = np.random.default_rng(seed)
        w = _sparse_int8(rng, 3, 3, 4, 8, 0.3)
        _assert_roundtrip(w, kblk=8, vpad=int(np.count_nonzero(w)) + extra)


class TestQuantProperties:
    @settings(max_examples=50, deadline=None)
    @given(st.integers(0, 10_000), st.floats(1e-3, 1e3), st.sampled_from([4, 8]))
    def test_roundtrip_error_le_half_scale(self, seed, spread, bits):
        """|dequant(quantize(x)) − x| <= scale/2 everywhere (symmetric
        round-to-nearest), int8 payload within [-2^(b-1), 2^(b-1)-1]."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal(64).astype(np.float32) * spread)
        qx = quant.quantize(x, bits=bits)
        qmax = 2 ** (bits - 1) - 1
        q = np.asarray(qx.q)
        assert q.dtype == np.int8
        assert q.min() >= -qmax - 1 and q.max() <= qmax
        err = np.abs(np.asarray(quant.dequantize(qx)) - np.asarray(x))
        assert err.max() <= float(qx.scale) / 2 + 1e-6

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 10_000))
    def test_per_channel_error_bound(self, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((8, 16)).astype(np.float32))
        qx = quant.quantize(x, axis=1)
        err = np.abs(np.asarray(quant.dequantize(qx)) - np.asarray(x))
        assert np.all(err <= np.asarray(qx.scale) / 2 + 1e-6)

    def test_all_zero_channel_has_finite_scale(self):
        """Per-axis quantization of a tensor with an all-zero channel (a
        pruned or conversion-dead channel) must keep every scale finite
        and nonzero — amax=0 would otherwise make scale 0 and dequant
        0·0/0 = NaN — and round-trip the zero channel to exact zeros."""
        x = np.random.default_rng(0).standard_normal((6, 4)).astype(np.float32)
        x[:, 1] = 0.0
        qx = quant.quantize(jnp.asarray(x), axis=1)
        scale = np.asarray(qx.scale)
        assert np.all(np.isfinite(scale)) and np.all(scale > 0)
        deq = np.asarray(quant.dequantize(qx))
        assert np.all(np.isfinite(deq))
        np.testing.assert_array_equal(deq[:, 1], 0.0)
        # the live channels still meet the half-scale bound
        err = np.abs(deq - x)
        assert np.all(err <= scale / 2 + 1e-6)


class TestAccumulator16Bit:
    """core/quant.py claims 16-bit accumulators "asserted in tests, not
    enforced" — these are those tests, at the paper's layer sizes."""

    @pytest.fixture(scope="class")
    def paper_plan(self):
        from repro.configs import get_config
        from repro.core import plan as cplan
        from repro.models import snn_yolo as sy

        cfg = get_config("snn-det")  # full channel plan (3.17M params)
        params, _ = sy.init_params(jax.random.PRNGKey(0), cfg)
        params = pruning.prune_tree(params, 0.8)
        return cplan.build_plan(params, cfg)

    def test_worst_case_acc_within_16b_at_paper_sizes(self, paper_plan):
        """Analytic bound: no binary-spike input can overflow a 16-bit
        accumulator on ANY layer of the full pruned+quantized model."""
        lim = 2 ** (quant.ACC_BITS - 1)
        worst = {
            name: quant.conv_acc_worst_case(np.asarray(lp.w_q))
            for name, lp in paper_plan.layers.items()
        }
        assert all(v < lim for v in worst.values()), f"16b overflow: {worst}"
        # the late 3×3 stages are the widest accumulations — sanity-check
        # the bound is actually exercising them, not trivially zero
        assert worst["stage4/main_a"] > 1_000

    def test_acc_range_ok_on_real_accumulation(self, paper_plan):
        """Empirical: run the int8 conv accumulation (worst-case all-ones
        spikes) through the widest layer and the encode layer; the int32
        result must satisfy acc_range_ok and the analytic bound."""
        dn = ("NHWC", "HWIO", "NHWC")
        for name in ("stage4/main_a", "encode", "head"):
            w_q = paper_plan.layers[name].w_q
            cin = w_q.shape[2]
            ones = jnp.ones((1, 8, 8, cin), jnp.int8)
            acc = quant.int8_conv_accumulate(ones, w_q, dn)
            assert bool(quant.acc_range_ok(acc)), f"{name} overflows 16b"
            bound = quant.conv_acc_worst_case(np.asarray(w_q))
            assert int(jnp.abs(acc).max()) <= bound

    def test_acc_range_ok_rejects_overflow(self):
        assert not bool(quant.acc_range_ok(jnp.asarray([2**15], jnp.int32)))
        assert bool(quant.acc_range_ok(jnp.asarray([2**15 - 1], jnp.int32)))
