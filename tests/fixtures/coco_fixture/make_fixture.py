"""Generator for the committed COCO-json fixture (run once; committed so
the fixture is reproducible and auditable, NOT executed by the suite).

Four tiny synthetic street-scene-ish images at four DIFFERENT resolutions
— none matching the harness demo input (96, 160), so every consumer
exercises the letterbox path (pure resize, pad-width, pad-height, and
both) — stored as binary PPM (numpy-only decode, no imaging dependency),
with boxes drawn as filled class-colored rectangles so a detector
actually has something to fit. Annotations use standard COCO structure:
bbox = [x, y, w, h] absolute pixels, category ids 1..3 mapping to the
IVS-3cls classes (vehicle / bike / pedestrian).

    python tests/fixtures/coco_fixture/make_fixture.py
"""
from __future__ import annotations

import json
import os

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))

# (h, w), [(class_idx, cx, cy, bw, bh) normalized]
SCENES = [
    ((72, 100), [(0, 0.30, 0.60, 0.34, 0.22), (2, 0.75, 0.55, 0.10, 0.30)]),
    ((60, 160), [(1, 0.50, 0.70, 0.12, 0.20)]),
    ((96, 90), [(0, 0.60, 0.75, 0.40, 0.20), (1, 0.20, 0.50, 0.14, 0.18),
                (2, 0.85, 0.45, 0.08, 0.26)]),
    ((48, 48), [(2, 0.40, 0.60, 0.18, 0.45)]),
]
SHADE = {0: (38, 64, 140), 1: (140, 51, 51), 2: (51, 128, 64)}


def render(rng, hw, objs):
    h, w = hw
    sky = np.linspace(166, 64, h)[:, None, None]
    img = np.clip(sky + rng.normal(0, 12, (h, w, 3)), 0, 255)
    for c, cx, cy, bw, bh in objs:
        x0, x1 = int((cx - bw / 2) * w), int((cx + bw / 2) * w)
        y0, y1 = int((cy - bh / 2) * h), int((cy + bh / 2) * h)
        img[y0:y1, x0:x1] = np.asarray(SHADE[c]) + rng.normal(0, 6, 3)
    return np.clip(img, 0, 255).astype(np.uint8)


def write_ppm(path, arr):
    h, w, _ = arr.shape
    with open(path, "wb") as f:
        f.write(b"P6\n%d %d\n255\n" % (w, h))
        f.write(arr.tobytes())


def main():
    rng = np.random.default_rng(7)
    images, annotations = [], []
    ann_id = 1
    for i, (hw, objs) in enumerate(SCENES):
        h, w = hw
        name = f"img_{i:03d}.ppm"
        write_ppm(os.path.join(HERE, name), render(rng, hw, objs))
        images.append({"id": i + 1, "file_name": name, "height": h, "width": w})
        for c, cx, cy, bw, bh in objs:
            annotations.append({
                "id": ann_id, "image_id": i + 1, "category_id": c + 1,
                "bbox": [round((cx - bw / 2) * w, 2), round((cy - bh / 2) * h, 2),
                         round(bw * w, 2), round(bh * h, 2)],
                "area": round(bw * w * bh * h, 2), "iscrowd": 0,
            })
            ann_id += 1
    coco = {
        "info": {"description": "tiny IVS-3cls-like fixture for repo tests"},
        "images": images,
        "annotations": annotations,
        "categories": [{"id": 1, "name": "vehicle"},
                       {"id": 2, "name": "bike"},
                       {"id": 3, "name": "pedestrian"}],
    }
    with open(os.path.join(HERE, "instances.json"), "w") as f:
        json.dump(coco, f, indent=1)
        f.write("\n")
    print(f"wrote {len(images)} ppm images + instances.json under {HERE}")


if __name__ == "__main__":
    main()
