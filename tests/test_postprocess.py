"""Detection postprocess: decode_head score thresholding (regression — the
threshold kwarg used to be silently ignored), pure-JAX class-aware NMS, and
the full decode→threshold→NMS serving stage."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import snn_yolo as sy
from repro.models.postprocess import (
    Detections,
    class_aware_nms,
    iou_xywh,
    nms,
    postprocess,
)


class TestDecodeHeadThreshold:
    """Regression: decode_head(threshold=...) must actually threshold."""

    def _head(self, seed=0):
        rng = np.random.default_rng(seed)
        return jnp.asarray(rng.normal(0, 2.0, (2, 3, 4, 5, 8)), jnp.float32)

    def test_threshold_zeroes_low_obj(self):
        head = self._head()
        _, obj_raw, _ = sy.decode_head(head, sy.DEFAULT_ANCHORS)
        _, obj_thr, _ = sy.decode_head(head, sy.DEFAULT_ANCHORS, threshold=0.5)
        below = np.asarray(obj_raw) < 0.5
        assert below.any() and (~below).any()  # the case is non-degenerate
        np.testing.assert_array_equal(np.asarray(obj_thr)[below], 0.0)
        np.testing.assert_array_equal(
            np.asarray(obj_thr)[~below], np.asarray(obj_raw)[~below]
        )

    def test_threshold_leaves_boxes_and_classes_intact(self):
        head = self._head(1)
        boxes_raw, _, cls_raw = sy.decode_head(head, sy.DEFAULT_ANCHORS)
        boxes_thr, _, cls_thr = sy.decode_head(head, sy.DEFAULT_ANCHORS, threshold=0.9)
        np.testing.assert_array_equal(np.asarray(boxes_raw), np.asarray(boxes_thr))
        np.testing.assert_array_equal(np.asarray(cls_raw), np.asarray(cls_thr))

    def test_none_threshold_is_identity(self):
        head = self._head(2)
        _, obj_a, _ = sy.decode_head(head, sy.DEFAULT_ANCHORS)
        _, obj_b, _ = sy.decode_head(head, sy.DEFAULT_ANCHORS, threshold=None)
        np.testing.assert_array_equal(np.asarray(obj_a), np.asarray(obj_b))


class TestNMS:
    def test_iou_suppression(self):
        boxes = jnp.asarray([
            [0.50, 0.50, 0.20, 0.20],   # winner
            [0.51, 0.50, 0.20, 0.20],   # heavy overlap with winner -> dies
            [0.90, 0.90, 0.10, 0.10],   # disjoint -> survives
        ])
        scores = jnp.asarray([0.9, 0.8, 0.7])
        idx, ok = nms(boxes, scores, iou_threshold=0.5, max_out=3)
        picked = set(np.asarray(idx)[np.asarray(ok)].tolist())
        assert picked == {0, 2}

    def test_per_class_independence(self):
        boxes = jnp.asarray([
            [0.5, 0.5, 0.2, 0.2],
            [0.5, 0.5, 0.2, 0.2],  # identical box, other class
        ])
        scores = jnp.asarray([0.9, 0.8])
        classes = jnp.asarray([0, 1], jnp.int32)
        _, ok_aware = class_aware_nms(boxes, scores, classes, max_out=2)
        assert int(ok_aware.sum()) == 2  # different classes never suppress
        _, ok_blind = nms(boxes, scores, max_out=2)
        assert int(ok_blind.sum()) == 1  # class-blind: duplicate dies

    def test_empty_input(self):
        idx, ok = nms(jnp.zeros((0, 4)), jnp.zeros((0,)), max_out=4)
        assert idx.shape == (4,) and ok.shape == (4,)
        assert not bool(ok.any())

    def test_zero_scores_are_dead(self):
        boxes = jnp.asarray([[0.5, 0.5, 0.1, 0.1], [0.2, 0.2, 0.1, 0.1]])
        scores = jnp.asarray([0.0, 0.6])  # thresholded-out upstream
        idx, ok = nms(boxes, scores, max_out=2)
        picked = set(np.asarray(idx)[np.asarray(ok)].tolist())
        assert picked == {1}

    def test_ranked_by_score_and_jittable(self):
        rng = np.random.default_rng(0)
        boxes = jnp.asarray(rng.uniform(0.05, 0.95, (16, 4)) * [1, 1, 0.05, 0.05])
        scores = jnp.asarray(rng.uniform(0.1, 1.0, (16,)))
        idx, ok = jax.jit(lambda b, s: nms(b, s, max_out=8))(boxes, scores)
        s = np.asarray(scores)[np.asarray(idx)]
        assert (np.diff(s[np.asarray(ok)]) <= 1e-6).all()  # descending picks


class TestPostprocess:
    def test_shapes_and_validity(self):
        rng = np.random.default_rng(3)
        head = jnp.asarray(rng.normal(0, 2.0, (2, 3, 4, 5, 8)), jnp.float32)
        dets = postprocess(head, sy.DEFAULT_ANCHORS, score_threshold=0.3,
                           max_detections=16)
        assert isinstance(dets, Detections)
        assert dets.boxes.shape == (2, 16, 4)
        assert dets.scores.shape == dets.valid.shape == (2, 16)
        v = np.asarray(dets.valid)
        assert (np.asarray(dets.scores)[v] > 0).all()
        # padding rows are zeroed
        assert (np.asarray(dets.scores)[~v] == 0).all()
        assert (np.asarray(dets.boxes)[~v] == 0).all()
        assert int(dets.count.max()) <= 16

    def test_high_threshold_empties(self):
        head = jnp.zeros((1, 3, 4, 5, 8))  # obj sigmoid(0)=0.5 everywhere
        dets = postprocess(head, sy.DEFAULT_ANCHORS, score_threshold=0.95)
        assert int(dets.count[0]) == 0

    def test_iou_xywh_known_values(self):
        a = jnp.asarray([0.5, 0.5, 0.2, 0.2])
        assert float(iou_xywh(a, a)) == pytest.approx(1.0)
        b = jnp.asarray([0.9, 0.9, 0.05, 0.05])
        assert float(iou_xywh(a, b)) == 0.0
        # half-overlapping equal squares: IoU = 1/3
        c = jnp.asarray([0.6, 0.5, 0.2, 0.2])
        assert float(iou_xywh(a, c)) == pytest.approx(1 / 3, abs=1e-6)
