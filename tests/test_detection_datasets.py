"""Real-data adapter tests: letterbox geometry, the COCO/VOC loaders, the
committed fixture's pinned checksums, the target↔decode inverse on
letterboxed real data, and the fixture round-tripped through
``evaluate_detector`` (single-host vs sharded bit-identical, and through
a detector-checkpoint save/restore)."""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np
import pytest

from repro.data import detection_datasets as dd
from repro.data import synthetic_detection as sd
from repro.eval import detection_map as dm
from repro.eval import harness
from repro.eval.sharded import reports_identical
from repro.models import snn_yolo as sy
from repro.models.postprocess import postprocess

FIXTURE_DIR = os.path.join(os.path.dirname(__file__), "fixtures", "coco_fixture")
FIXTURE_JSON = os.path.join(FIXTURE_DIR, "instances.json")
CHECKSUMS_PATH = os.path.join(
    os.path.dirname(__file__), "fixtures", "data_checksums.json"
)
with open(CHECKSUMS_PATH) as _f:
    _COCO_PINNED = json.load(_f)["coco_fixture"]
HW, GRID_DIV = (96, 160), 16


@pytest.fixture(scope="module")
def coco_source():
    return dd.CocoJsonSource(FIXTURE_JSON)


class TestLetterbox:
    def test_pure_resize_no_pad(self):
        """(48, 80) -> (96, 160): uniform 2x, no padding."""
        img = np.arange(48 * 80 * 3, dtype=np.float32).reshape(48, 80, 3) / 1e5
        out, (top, left, nh, nw) = dd.letterbox_image(img, (96, 160))
        assert (top, left, nh, nw) == (0, 0, 96, 160)
        # nearest-neighbor with integer index math: out[i, j] = img[i//2, j//2]
        np.testing.assert_array_equal(out[2, 0::2], img[1])
        np.testing.assert_array_equal(out[2, 1::2], img[1])
        np.testing.assert_array_equal(out[:, 5], img[:, 2][(np.arange(96) * 48) // 96])

    def test_pad_width(self):
        """(96, 90) -> (96, 160): height-limited (scale 1), width pads."""
        img = np.ones((96, 90, 3), np.float32)
        out, (top, left, nh, nw) = dd.letterbox_image(img, (96, 160))
        assert (top, left, nh, nw) == (0, 35, 96, 90)
        assert np.all(out[:, 35:125] == 1.0)
        assert np.all(out[:, :35] == dd.LETTERBOX_PAD_VALUE)
        assert np.all(out[:, 125:] == dd.LETTERBOX_PAD_VALUE)

    def test_pad_height(self):
        """(60, 160) -> (96, 160): width-limited, height pads top 18."""
        img = np.zeros((60, 160, 3), np.float32)
        out, geom = dd.letterbox_image(img, (96, 160))
        assert geom == (18, 0, 60, 160)
        assert np.all(out[:18] == dd.LETTERBOX_PAD_VALUE)
        assert np.all(out[78:] == dd.LETTERBOX_PAD_VALUE)

    def test_boxes_follow_placed_pixels(self):
        """Box transform uses the SAME (top, left, nh, nw) as the pixels:
        a box centered mid-image maps to the placed region's center."""
        boxes = np.array([[0.5, 0.5, 0.2, 0.5]], np.float32)
        out = dd.letterbox_boxes(boxes, (18, 0, 60, 160), (96, 160))
        np.testing.assert_allclose(
            out, [[0.5, (0.5 * 60 + 18) / 96, 0.2, 0.5 * 60 / 96]], atol=1e-7
        )

    def test_grayscale_promotes_to_rgb(self):
        out, _ = dd.letterbox_image(np.zeros((10, 10), np.float32), (20, 20))
        assert out.shape == (20, 20, 3)


class TestCocoFixture:
    def test_classes_match_paper_3cls(self, coco_source):
        assert coco_source.class_names == ("vehicle", "bike", "pedestrian")
        assert coco_source.num_eval_images("val") == 4

    def test_pinned_checksums(self, coco_source):
        """The letterboxed images, grid targets and gt boxes are pinned in
        data_checksums.json — regenerate via `make regen-goldens` ONLY on
        an intentional loader/fixture change."""
        import zlib

        n = coco_source.num_eval_images("val")
        images, gts = coco_source.eval_set(n, hw=HW, grid_div=GRID_DIV)
        batch = next(coco_source.batches(n, hw=HW, steps=1, grid_div=GRID_DIV))
        for pin in _COCO_PINNED["samples"]:
            i = pin["index"]
            crc = lambda a: zlib.crc32(np.ascontiguousarray(a).tobytes())
            assert crc(images[i]) == pin["image_crc32"], f"image {i} changed"
            assert crc(batch["target"][i]) == pin["target_crc32"], f"target {i}"
            assert crc(gts[i]["boxes"]) == pin["boxes_crc32"], f"boxes {i}"
            assert gts[i]["classes"].tolist() == pin["classes"]

    def test_eval_set_structure_matches_synthetic(self, coco_source):
        """The loader emits EXACTLY the {boxes, classes} structure the
        synthetic split produces (the DetectionSource contract)."""
        images, gts = coco_source.eval_set(4, hw=HW, grid_div=GRID_DIV)
        s_images, s_gts = sd.eval_set(4, hw=HW, grid_div=GRID_DIV)
        assert images.shape == s_images.shape and images.dtype == s_images.dtype
        for g, s in zip(gts, s_gts):
            assert set(g) == set(s)
            assert g["boxes"].dtype == s["boxes"].dtype and g["boxes"].ndim == 2
            assert g["classes"].dtype == s["classes"].dtype

    def test_shard_union_is_single_host_set(self, coco_source):
        images, gts = coco_source.eval_set(4, hw=HW, grid_div=GRID_DIV)
        i0, g0 = coco_source.eval_set(4, hw=HW, grid_div=GRID_DIV,
                                      shard_id=0, n_shards=2)
        i1, g1 = coco_source.eval_set(4, hw=HW, grid_div=GRID_DIV,
                                      shard_id=1, n_shards=2)
        merged = np.empty_like(images)
        merged[0::2], merged[1::2] = i0, i1
        np.testing.assert_array_equal(merged, images)
        for i, g in enumerate(g0):
            np.testing.assert_array_equal(g["boxes"], gts[2 * i]["boxes"])

    def test_batches_cycle_and_stripe(self, coco_source):
        """4 records cycle (index modulo) and host striping matches the
        synthetic contract: host h of n owns indices h, h+n, ..."""
        single = next(coco_source.batches(4, hw=HW, steps=1, grid_div=GRID_DIV))
        h0 = next(coco_source.batches(2, hw=HW, steps=1, grid_div=GRID_DIV,
                                      host_id=0, n_hosts=2))
        h1 = next(coco_source.batches(2, hw=HW, steps=1, grid_div=GRID_DIV,
                                      host_id=1, n_hosts=2))
        merged = np.empty_like(single["image"])
        merged[0::2], merged[1::2] = h0["image"], h1["image"]
        np.testing.assert_array_equal(merged, single["image"])
        wrapped = next(coco_source.batches(8, hw=HW, steps=1, grid_div=GRID_DIV))
        np.testing.assert_array_equal(wrapped["image"][4:], wrapped["image"][:4])

    def test_class_count_mismatch_raises(self, coco_source):
        with pytest.raises(ValueError, match="classes"):
            coco_source.eval_set(4, hw=HW, num_classes=2)

    def test_target_decode_inverse_on_letterboxed_real_data(self, coco_source):
        """The exact-inverse contract survives letterboxing: an oracle head
        built from the fixture's grid target decodes + postprocesses to
        mAP 1.0 against the letterboxed ground truth."""
        batch = next(coco_source.batches(4, hw=HW, steps=1, grid_div=GRID_DIV))
        _, gts = coco_source.eval_set(4, hw=HW, grid_div=GRID_DIV)
        for i in range(4):
            tgt = batch["target"][i]
            if int(tgt[..., 4].sum()) != len(gts[i]["boxes"]):
                continue  # cell/anchor collision: inverse can't be exact
            head = np.zeros_like(tgt)
            off = np.clip(tgt[..., 0:2], 1e-4, 1 - 1e-4)
            head[..., 0:2] = np.log(off / (1 - off))
            head[..., 2:4] = tgt[..., 2:4]
            head[..., 4] = np.where(tgt[..., 4] > 0, 12.0, -12.0)
            head[..., 5:] = np.where(tgt[..., 5:] > 0, 12.0, -12.0)
            dets = postprocess(head[None], sy.DEFAULT_ANCHORS,
                               score_threshold=0.25, max_detections=32)
            score = dm.map50(dm.detections_to_predictions(dets), [gts[i]],
                             num_classes=3)
            assert score == pytest.approx(1.0, abs=1e-6), f"image {i}"


class TestVocLoader:
    def _write_voc(self, root, with_layout=True):
        ann = os.path.join(root, "Annotations") if with_layout else root
        imgd = os.path.join(root, "JPEGImages") if with_layout else root
        os.makedirs(ann, exist_ok=True)
        os.makedirs(imgd, exist_ok=True)
        img = np.full((40, 60, 3), 128, np.uint8)
        with open(os.path.join(imgd, "a.ppm"), "wb") as f:
            f.write(b"P6\n60 40\n255\n" + img.tobytes())
        xml = """<annotation><filename>a.ppm</filename>
          <size><width>60</width><height>40</height><depth>3</depth></size>
          <object><name>vehicle</name>
            <bndbox><xmin>6</xmin><ymin>8</ymin><xmax>30</xmax><ymax>24</ymax></bndbox>
          </object>
          <object><name>pedestrian</name>
            <bndbox><xmin>42</xmin><ymin>10</ymin><xmax>48</xmax><ymax>30</ymax></bndbox>
          </object></annotation>"""
        with open(os.path.join(ann, "a.xml"), "w") as f:
            f.write(xml)

    def test_voc_layout_and_boxes(self, tmp_path):
        self._write_voc(str(tmp_path))
        src = dd.VocXmlSource(str(tmp_path),
                              class_names=("vehicle", "bike", "pedestrian"))
        assert src.num_eval_images("val") == 1
        _, gts = src.eval_set(1, hw=(40, 60))
        np.testing.assert_allclose(
            gts[0]["boxes"],
            [[18 / 60, 16 / 40, 24 / 60, 16 / 40],
             [45 / 60, 20 / 40, 6 / 60, 20 / 40]],
            atol=1e-6,
        )
        np.testing.assert_array_equal(gts[0]["classes"], [0, 2])

    def test_flat_dir_and_inferred_classes(self, tmp_path):
        self._write_voc(str(tmp_path), with_layout=False)
        src = dd.VocXmlSource(str(tmp_path))
        assert src.class_names == ("pedestrian", "vehicle")  # sorted names

    def test_unknown_class_raises(self, tmp_path):
        self._write_voc(str(tmp_path))
        with pytest.raises(ValueError, match="pedestrian"):
            dd.VocXmlSource(str(tmp_path), class_names=("vehicle",))


class TestParseSpec:
    def test_synthetic_default(self):
        assert isinstance(dd.parse_dataset_spec(None), dd.SyntheticSource)
        assert isinstance(dd.parse_dataset_spec("synthetic"), dd.SyntheticSource)

    def test_coco_spec(self):
        src = dd.parse_dataset_spec(f"coco:{FIXTURE_JSON}")
        assert isinstance(src, dd.CocoJsonSource)

    def test_bad_specs_raise(self):
        for spec in ("coco:", "imagenet:/x", "nonsense"):
            with pytest.raises(ValueError):
                dd.parse_dataset_spec(spec)

    def test_sources_satisfy_protocol(self):
        assert isinstance(dd.SyntheticSource(), dd.DetectionSource)
        assert isinstance(dd.parse_dataset_spec(f"coco:{FIXTURE_JSON}"),
                          dd.DetectionSource)


# -------------------------------------------- end-to-end on a compiled det --


@pytest.fixture(scope="module")
def small_det():
    """One compiled quantized detector at a reduced (48, 80) input —
    shared by the round-trip tests to keep compile count down."""
    from repro.serve.detector import demo_weights

    cfg = dataclasses.replace(harness.demo_config(), input_hw=(48, 80))
    params, bn, _ = demo_weights(cfg)
    return cfg, params, bn, harness.compile_eval_detector(cfg, params, bn)


class TestEvalRoundTrip:
    def test_fixture_single_vs_sharded_bit_identical(self, coco_source, small_det):
        """The acceptance gate at test scale: COCO-fixture mAP through the
        sharded evaluator is bit-identical to single-host."""
        _, _, _, det = small_det
        single = harness.evaluate_detector(det, n_images=4, source=coco_source)
        twoway = harness.evaluate_detector(det, n_images=4, source=coco_source,
                                           sharded=2)
        assert single["n_images"] == 4
        assert reports_identical(single, twoway)

    def test_n_images_clamps_to_source(self, coco_source, small_det):
        _, _, _, det = small_det
        r = harness.evaluate_detector(det, n_images=64, source=coco_source)
        assert r["n_images"] == 4


class TestDetectorCheckpoint:
    def test_save_restore_round_trip_bit_identical(self, tmp_path, coco_source,
                                                   small_det):
        """save_detector_checkpoint → restore_detector_checkpoint →
        evaluate: the restored handle scores the fixture bit-identically
        to the original weights (serve --checkpoint's contract)."""
        cfg, params, bn, det = small_det
        harness.save_detector_checkpoint(str(tmp_path), 7, params, bn, cfg)
        cfg2, p2, b2, step = harness.restore_detector_checkpoint(str(tmp_path))
        assert step == 7 and cfg2 == cfg
        det2 = harness.compile_eval_detector(cfg2, p2, b2)
        r1 = harness.evaluate_detector(det, n_images=4, source=coco_source)
        r2 = harness.evaluate_detector(det2, n_images=4, source=coco_source)
        assert reports_identical(r1, r2)

    def test_config_json_round_trip(self):
        cfg = harness.demo_config(conv_exec="gated")
        assert sy.config_from_dict(sy.config_to_dict(cfg)) == cfg
        with pytest.raises(ValueError, match="unknown"):
            sy.config_from_dict({"not_a_field": 1})

    def test_missing_sidecar_is_diagnosable(self, tmp_path, small_det):
        """A bare train-state checkpoint (no config sidecar) names the
        problem and the cfg= escape hatch instead of crashing."""
        from repro.train import checkpoint as ckpt

        cfg, params, bn, _ = small_det
        ckpt.save(str(tmp_path), 3, {"params": params, "bn": bn, "opt": 0.0})
        with pytest.raises(FileNotFoundError, match="cfg="):
            harness.restore_detector_checkpoint(str(tmp_path))
        # the escape hatch: explicit cfg restores (extra opt leaf ignored)
        cfg2, p2, _, step = harness.restore_detector_checkpoint(
            str(tmp_path), cfg=cfg
        )
        assert step == 3 and cfg2 == cfg
        np.testing.assert_array_equal(
            np.asarray(p2["encode"]["w"]), np.asarray(params["encode"]["w"])
        )

    def test_mismatched_config_raises_leaf_paths(self, tmp_path, small_det):
        """Restoring under a different architecture surfaces the
        checkpoint-lifecycle ValueError (shape or leaf-path mismatch),
        not a bare KeyError."""
        cfg, params, bn, _ = small_det
        harness.save_detector_checkpoint(str(tmp_path), 1, params, bn, cfg)
        other = dataclasses.replace(cfg, stem_channels=cfg.stem_channels * 2)
        with pytest.raises(ValueError):
            harness.restore_detector_checkpoint(str(tmp_path), cfg=other)
