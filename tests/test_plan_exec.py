"""Tests for the pluggable conv-executor pipeline (core/plan.py):
kernel-vs-reference parity sweeps, full-model executor parity, plan
compilation, and VPAD overflow validation."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan as cplan
from repro.core import pruning
from repro.core import spike_conv as sc
from repro.kernels import ops
from repro.models import snn_yolo as sy


def _sparse_int8_weights(seed, kh, kw, cin, k, density):
    rng = np.random.default_rng(seed)
    w = rng.integers(-127, 128, (kh, kw, cin, k)).astype(np.int8)
    mask = rng.random((kh, kw, cin, k)) < density
    return (w * mask).astype(np.int8)


def _gated_blocked_ref(spikes, w, bh=18, bw=32):
    """spike_conv.gated_one_to_all with block-conv border semantics:
    replicate-pad the tile, SAME-conv, crop the center."""
    kh = w.shape[0]
    pad = (kh - 1) // 2
    x = spikes.astype(jnp.float32)
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)), mode="edge")
    out = sc.gated_one_to_all(x, w.astype(jnp.float32))
    if pad:
        out = out[:, pad:-pad, pad:-pad, :]
    return out


class TestKernelVsGatedOneToAll:
    """Satellite: Pallas kernel vs the paper-faithful shift-accumulate
    reference across kernel size × channel width × sparsity."""

    @pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.9])
    @pytest.mark.parametrize("cin", [8, 32])
    @pytest.mark.parametrize("kh", [1, 3])
    def test_parity(self, kh, cin, sparsity):
        w = _sparse_int8_weights(31 * kh + cin, kh, kh, cin, 16, 1.0 - sparsity)
        pw = ops.pack_conv_weights(w, kblk=16)
        rng = np.random.default_rng(cin + kh)
        spikes = jnp.asarray(rng.integers(0, 2, (2, 18, 32, cin)), jnp.int8)
        got = ops.gated_conv(spikes, pw)
        want = _gated_blocked_ref(spikes, jnp.asarray(w))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.5)


class TestPlan:
    def test_build_plan_covers_every_conv_layer(self):
        cfg = sy.SNNDetConfig(
            input_hw=(24, 32), stem_channels=8, conv_block_channels=8,
            stage_channels=((8, 8), (8, 16)), pooled_stages=1, block_hw=(6, 8),
        )
        params, _ = sy.init_params(jax.random.PRNGKey(0), cfg)
        plan = cplan.build_plan(params, cfg, prune_rate=0.8)
        assert set(plan.layers) == set(params)
        assert plan.block_hw == (6, 8)
        enc = plan.layers["encode"]
        assert enc.in_bits == 8 and enc.packed.kh == 3
        assert all(lp.in_bits == 1 for n, lp in plan.layers.items() if n != "encode")
        # pruning reached the packed form: 3×3 kernels are ~80% zero
        main_a = plan.layers["stage0/main_a"]
        assert main_a.nnz < 0.35 * np.prod(main_a.w_q.shape)
        assert plan.compressed_bytes < plan.dense_bytes

    def test_executor_registry(self):
        assert {"dense", "gated", "pallas"} <= set(cplan.CONV_EXECUTORS)
        cfg = sy.SNNDetConfig(conv_exec="nope")
        with pytest.raises(ValueError, match="unknown conv_exec"):
            cplan.run_conv(jnp.zeros((1, 1, 6, 8, 8)), None, cfg)

    def test_vpad_overflow_raises_at_pack_time(self):
        """Bugfix: the kernel clips gather indices into the packed values,
        so an undersized VPAD must fail loudly at plan/pack time."""
        w = _sparse_int8_weights(0, 3, 3, 8, 8, 1.0)  # fully dense: nnz=576
        with pytest.raises(ValueError, match="vpad"):
            ops.pack_conv_weights(w, kblk=8, vpad=4)

    def test_validate_packed_detects_corrupt_vals_buffer(self):
        w = _sparse_int8_weights(1, 3, 3, 8, 8, 0.5)
        pw = ops.pack_conv_weights(w, kblk=8)
        bad = pw._replace(vals=pw.vals[:, :2])
        with pytest.raises(ValueError, match="VPAD"):
            ops.validate_packed(bad)
        ops.validate_packed(pw)  # the honest pack passes


class TestFullModelParity:
    """Satellite + acceptance: the whole detector through each executor
    matches the dense oracle at the (1, full_t=3) mixed time schedule."""

    @pytest.fixture(scope="class")
    def setup(self):
        cfg = sy.SNNDetConfig(
            arch_id="snn-det-tiny",
            input_hw=(24, 32),
            stem_channels=8,
            conv_block_channels=8,
            stage_channels=((8, 8), (8, 8), (8, 16), (16, 16), (16, 16)),
            pooled_stages=1,
            full_t=3,
            mode="snn",
            weight_bits=8,
            use_block_conv=True,
            mixed_time=True,
            block_hw=(6, 8),
        )
        params, bn = sy.init_params(jax.random.PRNGKey(0), cfg)
        params = pruning.prune_tree(params, 0.8)
        plan = cplan.build_plan(params, cfg)
        rng = np.random.default_rng(0)
        # uint8-grid images: the bit-serial 8-bit encode path is then exact
        imgs = jnp.asarray(rng.integers(0, 256, (1, 24, 32, 3)) / 255.0, jnp.float32)
        # dense oracle through the compile-once handle (zero plan plumbing)
        _, head = sy.compile_detector(cfg, params, bn).detect(imgs)
        return cfg, params, bn, plan, imgs, np.asarray(head)

    @pytest.mark.parametrize("executor", ["gated", "pallas"])
    def test_matches_dense_oracle(self, setup, executor):
        cfg, params, bn, plan, imgs, head_dense = setup
        c = dataclasses.replace(cfg, conv_exec=executor)
        _, head = sy.compile_detector(c, params, bn).detect(imgs)
        assert head.shape == head_dense.shape
        np.testing.assert_allclose(np.asarray(head), head_dense, atol=1e-4)
        # intermediate spike maps stay binary through the compressed path
        # (forward is the internal core the handle wraps)
        _, _, aux = sy.forward(params, bn, imgs, c, plan=plan)
        s = np.asarray(aux["spikes"]["stage4"])
        assert set(np.unique(s)).issubset({0.0, 1.0})

    def test_compressed_exec_requires_plan(self, setup):
        """Plan ownership moved from the removed snn_yolo._cached_plan into
        CompiledDetector — the free function now refuses to run a
        compressed executor without an explicit plan and points at the
        compile-once API."""
        cfg, params, bn, _, imgs, _ = setup
        c = dataclasses.replace(cfg, conv_exec="pallas")
        with pytest.raises(ValueError, match="compile_detector"):
            sy.forward(params, bn, imgs, c)  # no plan passed
        assert not hasattr(sy, "_cached_plan")

    def test_non_snn_mode_rejected(self):
        """Compressed executors consume binary spikes; multibit ann/qnn/bnn
        activations must fail loudly instead of truncating to int8."""
        cfg = sy.SNNDetConfig(mode="ann", conv_exec="pallas")
        with pytest.raises(ValueError, match="mode='snn'"):
            sy.forward({}, {}, jnp.zeros((1, 32, 32, 3)), cfg)

    def test_float_weights_rejected(self):
        """weight_bits=0 means float weights — the FXP8 compressed plan
        would silently quantize them, so it must refuse."""
        cfg = sy.SNNDetConfig(weight_bits=0, conv_exec="pallas")
        with pytest.raises(ValueError, match="weight_bits"):
            sy.forward({}, {}, jnp.zeros((1, 32, 32, 3)), cfg)

    def test_block_hw_mismatch_rejected(self, tiny_setup):
        cfg, params, bn, plan, imgs = tiny_setup
        c = dataclasses.replace(cfg, conv_exec="pallas", block_hw=(3, 4))
        with pytest.raises(ValueError, match="block_hw"):
            sy.forward(params, bn, imgs, c, plan=plan)

    @pytest.fixture()
    def tiny_setup(self):
        cfg = sy.SNNDetConfig(
            input_hw=(24, 32), stem_channels=8, conv_block_channels=8,
            stage_channels=((8, 8), (8, 16)), pooled_stages=1, block_hw=(6, 8),
        )
        params, bn = sy.init_params(jax.random.PRNGKey(0), cfg)
        plan = cplan.build_plan(params, cfg)
        imgs = jnp.zeros((1, 24, 32, 3), jnp.float32)
        return cfg, params, bn, plan, imgs
