"""Per-architecture smoke tests: a REDUCED same-family config per assigned
arch runs one forward/train step and one decode step on CPU; asserts output
shapes and no NaNs. The FULL configs are exercised only via the dry-run."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cells, get_config, smoke_config
from repro.models import llava, zoo


def _smoke_batch(cfg, b=2, s=16):
    key = jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros((b, 8, llava.D_VISION), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros((b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = smoke_config(get_config(arch))
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg)
    loss, grads = jax.value_and_grad(api.loss_fn)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0, f"{arch} gradients vanished or NaN"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_smoke(arch):
    cfg = smoke_config(get_config(arch))
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    b, max_seq = 2, 32
    cache = api.init_cache(b, max_seq)
    logits, new_cache = api.decode_fn(
        params, cache, jnp.ones((b,), jnp.int32), jnp.int32(3)
    )
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch} decode logits NaN"
    assert jax.tree_util.tree_structure(new_cache) == jax.tree_util.tree_structure(cache)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_consistent(arch):
    """Prefill(t1..tn) + decode(tn+1) must match prefill(t1..tn+1) logits."""
    cfg = smoke_config(get_config(arch))
    if cfg.family in ("vlm", "audio"):
        pytest.skip("prefill takes modality args; covered by family tests")
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 9), 0, cfg.vocab_size)
    full_logits, _ = api.prefill_fn(params, toks)

    prefix_logits, cache = api.prefill_fn(params, toks[:, :8])
    if hasattr(cache, "k"):  # pad dense KV to a bigger cache
        big = api.init_cache(1, 32)
        cache = type(cache)(
            big.k.at[:, :, :8].set(cache.k.astype(big.k.dtype)),
            big.v.at[:, :, :8].set(cache.v.astype(big.v.dtype)),
        )
    elif hasattr(cache, "attn_k"):
        big = api.init_cache(1, 32)
        cache = type(cache)(
            mamba=cache.mamba,
            tail=cache.tail,
            attn_k=big.attn_k.at[:, :, :8].set(cache.attn_k.astype(big.attn_k.dtype)),
            attn_v=big.attn_v.at[:, :, :8].set(cache.attn_v.astype(big.attn_v.dtype)),
        )
    step_logits, _ = api.decode_fn(params, cache, toks[:, 8], jnp.int32(8))
    # tolerance: the serving cache holds K/V in bf16 (1/128 relative
    # rounding) — logit noise ~0.05; real masking bugs give O(10) diffs
    np.testing.assert_allclose(
        np.asarray(step_logits[0]), np.asarray(full_logits[0]), rtol=2e-2, atol=0.1
    )
    assert int(step_logits[0].argmax()) == int(full_logits[0].argmax())


def test_cells_assignment():
    """40 assigned cells; long_500k only for sub-quadratic archs."""
    all_cells = cells(include_skipped=True)
    assert len(all_cells) == 40
    runnable = cells()
    long_archs = {a for a, s in runnable if s == "long_500k"}
    assert long_archs == {"zamba2-7b", "rwkv6-3b"}


def test_exact_configs_match_assignment():
    spec = {
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
            L, d, h, kv, ff, v), arch
    assert get_config("deepseek-moe-16b").n_experts == 64
    assert get_config("deepseek-moe-16b").top_k == 6
    assert get_config("deepseek-moe-16b").n_shared_experts == 2
    assert get_config("olmoe-1b-7b").top_k == 8
    assert get_config("zamba2-7b").ssm_state == 64
    assert get_config("qwen1.5-0.5b").qkv_bias
