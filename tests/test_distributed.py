"""Distributed helpers under a real multi-device mesh. These tests spawn a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the
parent process has already initialized jax with 1 device)."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

_ENV = {**os.environ, "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PYTHONPATH": "src"}


def _run(body: str):
    code = textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], env=_ENV, cwd=os.path.dirname(os.path.dirname(__file__)),
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_int8_psum_and_hierarchical():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed import collectives as C
        from repro.distributed.compat import make_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        x = jnp.arange(24, dtype=jnp.float32).reshape(4, 6) / 7.0
        with mesh:
            y = jax.jit(C.int8_psum(mesh, "data"))(x)
            # replicated input -> psum over data multiplies by the axis size;
            # two int8 rounding passes vs the row max: atol = 2*2*max/127
            atol = 4 * float(jnp.max(jnp.abs(x))) / 127
            np.testing.assert_allclose(np.asarray(y), np.asarray(x) * 2, atol=atol)
            z = jax.jit(C.hierarchical_psum(mesh))(x)
            np.testing.assert_allclose(np.asarray(z), np.asarray(x) * 4, rtol=1e-5)
        print("COLLECTIVES_OK")
    """)
    assert "COLLECTIVES_OK" in out


def test_overlap_allgather_matmul():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed import collectives as C
        from repro.distributed.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 12))
        with mesh:
            wsh = jax.device_put(w, NamedSharding(mesh, P("model", None)))
            y = jax.jit(C.overlap_allgather_matmul(mesh, "model"))(x, wsh)
            np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-4, atol=1e-4)
        print("OVERLAP_OK")
    """)
    assert "OVERLAP_OK" in out


def test_distributed_embedding_grads_sharded():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed import sharding as shd, embedding as de
        from repro.distributed.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = shd.default_rules(mesh, fsdp=True)
        V, D, B, S = 32, 16, 4, 8
        table = jax.random.normal(jax.random.PRNGKey(0), (V, D))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, V)
        with mesh, shd.use_rules(rules, mesh=mesh):
            tsh = jax.device_put(table, NamedSharding(mesh, P("model", "data")))
            tok = jax.device_put(tokens, NamedSharding(mesh, P("data", None)))
            out = jax.jit(de.embed_lookup)(tok, tsh)
            np.testing.assert_allclose(np.asarray(out), np.asarray(table[tokens]), atol=1e-6)
            g = jax.jit(jax.grad(lambda t: jnp.sum(de.embed_lookup(tok, t) ** 2)))(tsh)
            np.testing.assert_allclose(
                np.asarray(g),
                np.asarray(jax.grad(lambda t: jnp.sum((t[tokens]) ** 2))(table)),
                rtol=1e-4, atol=1e-5)
            # THE point: the gradient arrives sharded, not replicated
            assert g.sharding.spec == P("model", "data"), g.sharding
        print("EMBED_OK")
    """)
    assert "EMBED_OK" in out


def test_kvops_seq_sharded_write():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed import sharding as shd, kvops
        from repro.distributed.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = shd.default_rules(mesh)
        L_, B, S, KV, HD = 3, 2, 16, 2, 4
        buf = jnp.zeros((L_, B, S, KV, HD), jnp.float32)
        val = jnp.ones((B, 1, KV, HD), jnp.float32) * 7
        with mesh, shd.use_rules(rules, mesh=mesh):
            bsh = jax.device_put(buf, NamedSharding(mesh, P(None, "data", "model", None, None)))
            for layer, pos in ((0, 0), (1, 5), (2, 13)):  # hits different shards
                new = jax.jit(kvops.cache_write)(bsh, val, jnp.int32(layer), jnp.int32(pos))
                ref = buf.at[layer, :, pos].set(7.0)
                np.testing.assert_array_equal(np.asarray(new), np.asarray(ref))
        print("KVOPS_OK")
    """)
    assert "KVOPS_OK" in out
