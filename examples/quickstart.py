"""Quickstart: the paper's pipeline end to end in under a minute on CPU.

1. Build a reduced SNN detector (same family as the paper's 1024x576 model).
2. Run a forward pass on a synthetic cityscape frame; look at spike sparsity.
3. Fine-grained-prune (80% on 3x3), bitmask-compress, and compare formats.
4. Compute mIoUT and pick the mixed-time-step schedule.
5. Run the sparse conv through the gated one-to-all Pallas kernel
   (interpret mode) and check it against the oracle.
6. Compile-once serving: ``compile_detector`` -> Detections, then stream
   frames through a DetectorSession (membrane state carries across frames).

Usage:  PYTHONPATH=src python examples/quickstart.py
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import bitmask, miout, pruning
from repro.data import synthetic_detection as sd
from repro.kernels import ops, ref
from repro.models import snn_yolo as sy


def main():
    # 1. reduced detector (paper topology, smaller input for CPU)
    cfg = dataclasses.replace(get_config("snn-det"), input_hw=(144, 256),
                              use_block_conv=False, mixed_time=True)
    params, bn = sy.init_params(jax.random.PRNGKey(0), cfg)
    print(f"model: {sy.param_count(params)/1e6:.2f}M params "
          f"(full-size paper model: 3.17M)")

    # 2. forward on a synthetic frame
    batch = next(sd.batches(1, hw=cfg.input_hw, steps=1))
    head, _, aux = sy.forward(params, bn, jnp.asarray(batch["image"]), cfg)
    print(f"head: {head.shape} (grid x anchors x (5+classes))")
    for name, s in aux["spikes"].items():
        print(f"  {name:12s} spike rate {float(s.mean()):.3f} "
              f"(paper: ~77% sparsity -> rate ~0.23)")

    # 3. prune + compress
    pruned = pruning.prune_tree(params, rate=0.8)
    w = np.asarray(pruned["stage4/main_a"]["w"])
    dense_bits, csr_bits, bm_bits = (
        bitmask.format_bits((w.shape[3], w.size // w.shape[3]),
                            int((w != 0).sum()), weight_bits=8, fmt=f)
        for f in ("dense", "csr", "bitmask")
    )
    print(f"stage4/main_a: density {(w != 0).mean():.2f} | "
          f"dense {dense_bits//8}B csr {csr_bits//8}B bitmask {bm_bits//8}B")

    # 4. mIoUT -> mixed schedule
    for name in ("conv_block", "stage3"):
        v = float(miout.miout(aux["spikes"][name]))
        print(f"mIoUT[{name}] = {v:.3f} -> in_T = {1 if v > 0.9 else cfg.full_t}")

    # 5. the gated one-to-all kernel on the pruned stage-4 conv weights,
    # over one 32x18 hardware tile of spikes (paper's PE array geometry)
    rng = np.random.default_rng(0)
    spikes = (rng.random((1, 18, 32, w.shape[2])) < 0.23).astype(np.int8)
    wq = np.asarray(np.clip(np.round(w * 127), -127, 127), np.int8)
    packed = ops.pack_conv_weights(wq)
    y = ops.gated_conv(jnp.asarray(spikes), packed, interpret=True)
    y_ref = ref.gated_conv_ref(jnp.asarray(spikes), jnp.asarray(wq))
    err = int(jnp.max(jnp.abs(y.astype(jnp.int32) - y_ref.astype(jnp.int32))))
    print(f"gated one-to-all kernel vs oracle: max err {err} "
          f"(taps executed: {int((wq != 0).sum())}/{wq.size})")
    assert err == 0

    # 6. compile-once serving: the handle owns plan + jit + postprocess —
    # no plan/config/state plumbing at the call site
    bn = sy.calibrate_bn_state(pruned, bn, jnp.asarray(batch["image"]), cfg)
    det = sy.compile_detector(cfg, pruned, bn)
    frame = jnp.asarray(batch["image"])
    dets = det(frame)
    print(f"compile_detector: {int(dets.count[0])} detections "
          f"(score_threshold {det.score_threshold}, class-aware NMS)")
    sess = det.new_session(batch=1)
    counts = [int(sess.step(frame).detections.count[0]) for _ in range(3)]
    print(f"streaming session over 3 frames: detections {counts} "
          f"(membrane potentials carry across frames; reset() cold-starts)")
    print("quickstart OK")


if __name__ == "__main__":
    main()
