"""ANN→SNN conversion quickstart: import a pretrained dense detector,
calibrate channel-wise thresholds, and serve the converted spiking model —
NO training steps anywhere (Spiking-YOLO-style channel norm, arXiv
1903.06530, emitted straight into the compressed executor plan).

Usage:
  PYTHONPATH=src python examples/convert_ann_detector.py \
      [--npz tests/fixtures/ann_detector/ann_tiny_yolo.npz] \
      [--out /tmp/converted_det] [--eval-images 48] [--dataset synthetic]

The committed fixture is the repo's own ANN-mode demo detector (trained by
scripts/make_ann_fixture.py); any npz-exported tiny YOLO with matching
layer shapes works (see repro/convert/importer.py for the format). The
emitted checkpoint is self-describing — score or serve it directly:

  PYTHONPATH=src python -m benchmarks.eval_map --checkpoint /tmp/converted_det
  PYTHONPATH=src python -m repro.launch.serve --arch snn-det \
      --checkpoint /tmp/converted_det --eval-map
"""
from __future__ import annotations

import argparse
import json
import time

from repro import convert as cv
from repro.data import detection_datasets as dd
from repro.eval import harness

DEFAULT_FIXTURE = "tests/fixtures/ann_detector/ann_tiny_yolo.npz"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--npz", default=DEFAULT_FIXTURE,
                    help="ANN detector bundle (repro/convert/importer.py "
                         "format; default: the committed fixture)")
    ap.add_argument("--out", default="/tmp/converted_det",
                    help="checkpoint dir for the converted detector")
    ap.add_argument("--dataset", default="synthetic",
                    help="calibration + eval data: synthetic | "
                         "coco:<instances.json> | voc:<dir>")
    ap.add_argument("--calib-images", type=int, default=32)
    ap.add_argument("--percentile", type=float, default=None,
                    help="λ coverage percentile (default: ConvertConfig)")
    ap.add_argument("--full-t", type=int, default=None,
                    help="time steps of the converted net")
    ap.add_argument("--leak", type=float, default=None,
                    help="LIF leak (1.0 = pure integrate-and-fire)")
    ap.add_argument("--gain", type=float, default=None,
                    help="hidden-layer drive gain")
    ap.add_argument("--encode-duty", type=float, default=None,
                    help="encode duty point τ (spike iff act ≥ τ·λ)")
    ap.add_argument("--conv-exec", default=None,
                    choices=("dense", "gated", "pallas"))
    ap.add_argument("--eval-images", type=int, default=48,
                    help="0 skips the mAP evaluation")
    args = ap.parse_args(argv)

    overrides = {
        k: v for k, v in (
            ("percentile", args.percentile), ("full_t", args.full_t),
            ("leak", args.leak), ("gain", args.gain),
            ("encode_duty", args.encode_duty), ("conv_exec", args.conv_exec),
            ("calib_images", args.calib_images),
        ) if v is not None
    }
    cc = cv.ConvertConfig(**overrides)
    source = dd.parse_dataset_spec(args.dataset)

    print(f"importing {args.npz} ...")
    ann = cv.load_ann_npz(args.npz)
    print(f"  arch {ann.cfg.arch_id}: {len(ann.layers)} conv+BN layers, "
          f"input {ann.cfg.input_hw}")

    t0 = time.time()
    out = cv.convert_ann(ann, source=source, cc=cc)
    ps = out.report["plan_summary"]
    print(f"converted in {time.time() - t0:.1f}s: full_t={out.cfg.full_t} "
          f"leak={out.cfg.leak} exec={out.cfg.conv_exec}")
    print(f"  head readout scale ρ={out.report['readout_scale']:.3f}, "
          f"empirical fit α={out.report['head_scale_fit']:.3f}")
    print(f"  plan: {ps['dense_bytes']} dense → {ps['compressed_bytes']} "
          f"packed bytes ({ps['compression_ratio']}x)")
    dead = sum(l["dead_channels"] for l in out.report["layers"].values())
    if dead:
        print(f"  {dead} dead channels across "
              f"{len(out.report['layers'])} layers")

    path = out.save(args.out)
    print(f"committed converted checkpoint: {path}")
    print(f"  (conversion report: {path}/{cv.ConvertedDetector.REPORT_FILE})")

    if args.eval_images:
        det = harness.compile_eval_detector(out.cfg, out.params, out.bn_state)
        rep = harness.evaluate_detector(
            det, n_images=args.eval_images, source=source
        )
        print(f"converted mAP@0.5 = {rep['map']:.4f} on {rep['n_images']} "
              f"val images (per-class "
              f"{[round(a, 3) for a in rep['per_class_ap']]})")
        print("score it again any time without retraining:")
        print(f"  PYTHONPATH=src python -m benchmarks.eval_map "
              f"--checkpoint {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
