"""Batched LM serving with continuous batching: a reduced qwen-family model
behind the Engine, a burst of requests with mixed prompt lengths, and
throughput accounting. Also demos the recurrent-state families (rwkv6 /
zamba2) behind the SAME serving API — their O(1) state is why they run the
long_500k cell.

Usage:  PYTHONPATH=src python examples/serve_lm.py
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import zoo
from repro.serve import Engine, Request


def serve_burst(arch: str, n_requests: int = 12, n_slots: int = 4):
    cfg = smoke_config(get_config(arch))
    api = zoo.get_api(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, n_slots=n_slots, max_seq=128)
    rng = np.random.default_rng(0)
    total_new = 0
    for r in range(n_requests):
        plen = int(rng.integers(3, 24))
        n_new = int(rng.integers(4, 17))
        total_new += n_new
        eng.submit(Request(rid=r, prompt=list(rng.integers(1, cfg.vocab_size, plen)),
                           max_new_tokens=n_new))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    assert len(done) == n_requests
    print(f"{arch:14s} {n_requests} reqs / {n_slots} slots: "
          f"{total_new} tokens in {dt:5.1f}s "
          f"({total_new/dt:6.1f} tok/s CPU, continuous batching)")
    return done


def main():
    for arch in ("qwen1.5-0.5b", "olmoe-1b-7b", "rwkv6-3b", "zamba2-7b"):
        serve_burst(arch)
    print("serve_lm OK")


if __name__ == "__main__":
    main()
