"""End-to-end training driver: the paper's SNN detector on the synthetic
IVS-3cls-like dataset, with the full substrate — AdamW + paper's LR
schedule, STBP surrogate gradients through the LIF, tdBN, checkpointing +
supervisor restart, straggler monitor, and post-training fine-grained
pruning + quantization (the SNN-a -> SNN-d pipeline of Table I).

Reduced size for CPU (96x160 input, thinner channels); a few hundred steps.
Usage:  PYTHONPATH=src python examples/train_snn_detector.py [--steps 300]
            [--dataset coco:<instances.json>|voc:<dir>]
Real annotated frames swap in via --dataset (letterboxed to the input
resolution); the final SNN-d weights are committed as a detector
checkpoint that launch/serve.py --checkpoint restores.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core import pruning, quant
from repro.data import detection_datasets as dd
from repro.eval import harness
from repro.models import snn_yolo as sy
from repro.train import checkpoint as ckpt
from repro.train import ft
from repro.train import optimizer as opt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt", default="/tmp/snn_det_ckpt")
    ap.add_argument("--dataset", default="synthetic",
                    help="train/eval data: synthetic | coco:<instances."
                         "json> | voc:<dir> (repro.data.detection_datasets)")
    ap.add_argument("--eval-images", type=int, default=16,
                    help="val images for the post-training mAP report")
    ap.add_argument("--eval-shards", type=int, default=1,
                    help="shard the post-training mAP evaluation "
                         "(repro.eval.sharded; bit-identical to 1 shard)")
    args = ap.parse_args(argv)
    source = dd.parse_dataset_spec(args.dataset)

    # the harness's trainable-size config (96x160, thinner channels) so the
    # reported mAP is comparable with BENCH_eval.json
    cfg = harness.demo_config()
    ocfg = opt.AdamWConfig(lr_peak=2e-3, lr_init=2e-4, lr_final=2e-5,
                           warmup_steps=20, total_steps=args.steps,
                           weight_decay=1e-3)

    def init_state():
        params, bn = sy.init_params(jax.random.PRNGKey(0), cfg)
        return {"params": params, "bn": bn, "opt": opt.init_state(params, ocfg)}

    def template():
        return jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.asarray(x).dtype),
            init_state(),
        )

    def loss_fn(params, bn, imgs, tgts):
        head, new_bn, _ = sy.forward(params, bn, imgs, cfg, train=True)
        return sy.yolo_loss(head, tgts), new_bn

    @jax.jit
    def train_step(state, imgs, tgts):
        (loss, new_bn), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], state["bn"], imgs, tgts
        )
        new_params, new_opt = opt.apply_updates(state["params"], grads, state["opt"], ocfg)
        return {"params": new_params, "bn": new_bn, "opt": new_opt}, loss

    # reduced config downsamples /16 (stem + conv + 2 stage pools), not /32
    grid_div = harness.grid_div(cfg)
    stream = source.batches(args.batch, hw=cfg.input_hw, steps=args.steps,
                            grid_div=grid_div)
    losses = []

    def step_fn(state, step):
        batch = next(stream)
        state, loss = train_step(state, jnp.asarray(batch["image"]), jnp.asarray(batch["target"]))
        losses.append(float(loss))
        if step % 25 == 0:
            print(f"step {step:4d} loss {losses[-1]:8.4f} "
                  f"lr {float(opt.lr_schedule(ocfg, jnp.int32(step))):.2e}")
        return state

    sup = ft.Supervisor(ckpt_root=args.ckpt, save_every=50,
                        heartbeat=ft.Heartbeat(args.ckpt + "/heartbeat.json"))
    t0 = time.time()
    state = sup.run(init_state=init_state, state_template=template,
                    step_fn=step_fn, n_steps=args.steps)
    print(f"trained {args.steps} steps in {time.time()-t0:.0f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    # --- SNN-a -> SNN-d: prune 80% on 3x3, quantize weights to 8b, then
    # hand the trained tree to the compile-once serving API ---
    params = state["params"]
    pruned = pruning.prune_tree(params, rate=0.8)
    rep = pruning.tree_sparsity_report(pruned)
    q = jax.tree_util.tree_map(
        lambda x: quant.fake_quant_tensor(x, bits=8) if x.ndim == 4 else x, pruned
    )
    det = sy.compile_detector(cfg, q, state["bn"])
    imgs = jnp.asarray(next(source.batches(2, hw=cfg.input_hw, steps=1,
                                           grid_div=grid_div))["image"])
    dets, head = det.detect(imgs)
    print(f"pruned: kept {rep['kept_frac']*100:.1f}% of {rep['total_params']/1e3:.0f}k "
          f"params (paper SNN-b: 30%)")
    print(f"SNN-d compile_detector OK: head {head.shape}, "
          f"finite={bool(jnp.all(jnp.isfinite(head)))}, "
          f"detections/frame {[int(c) for c in dets.count]}")

    # --- accuracy: mAP@0.5 on the synthetic val split, trained vs SNN-d
    # (the eval subsystem; benchmarks/eval_map.py runs the full Table I /
    # Fig 15 pipeline and writes BENCH_eval.json) ---
    # "trained" evaluates FLOAT weights (weight_bits=0, no plan) exactly
    # like the harness's trained stage, so the two reports are comparable
    for tag, (c, p, b) in {
        "trained": (dataclasses.replace(cfg, weight_bits=0), params, state["bn"]),
        "pruned+quant": (cfg, q, state["bn"]),
    }.items():
        r = harness.evaluate_detector(
            harness.compile_eval_detector(c, p, b), n_images=args.eval_images,
            sharded=args.eval_shards if args.eval_shards > 1 else None,
            source=source,
        )
        aps = ", ".join(f"{a:.3f}" for a in r["per_class_ap"])
        shard_note = (f" [{r['n_shards']} shards, {r['gather']} gather]"
                      if "n_shards" in r else "")
        print(f"mAP@0.5 [{tag}] {r['map']:.3f} (per-class {aps}) "
              f"on {r['n_images']} val images{shard_note}")

    # commit the SNN-d weights as a self-describing detector checkpoint —
    # `launch/serve.py --arch snn-det --checkpoint <dir>` restores it
    det_ckpt = args.ckpt + "/detector"
    harness.save_detector_checkpoint(det_ckpt, args.steps, q, state["bn"], cfg)
    print(f"detector checkpoint committed to {det_ckpt} — serve it with:\n"
          f"  PYTHONPATH=src python -m repro.launch.serve --arch snn-det "
          f"--eval-map --checkpoint {det_ckpt}")
    # surfaces any failed async checkpoint write before we declare success
    ckpt.wait_pending()
    if losses[-1] >= losses[0]:
        raise SystemExit("loss did not decrease")
    print("train_snn_detector OK")


if __name__ == "__main__":
    main()
