"""The paper's mIoUT-driven mixed-time-step schedule search (§II-D, Fig 15)
as a reusable tool: run the detector on sample frames, measure mIoUT per
macro layer, propose the schedule (layers above the threshold drop to
in_T=1), and report the operation savings — the C1/C2/C2BX family.

Usage:  PYTHONPATH=src python examples/mixed_timestep_search.py
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import miout as mi
from repro.data import synthetic_detection as sd
from repro.models import snn_yolo as sy


def main(threshold: float = 0.9):
    cfg = dataclasses.replace(get_config("snn-det"), input_hw=(144, 256),
                              use_block_conv=False, mixed_time=False)
    params, bn = sy.init_params(jax.random.PRNGKey(0), cfg)
    batch = next(sd.batches(2, hw=cfg.input_hw, steps=1))
    _, _, aux = sy.forward(params, bn, jnp.asarray(batch["image"]), cfg)

    print(f"mIoUT per macro layer (threshold {threshold} -> in_T=1):")
    schedule = {}
    for name, s in aux["spikes"].items():
        if s.shape[0] == 1:
            schedule[name] = 1
            print(f"  {name:12s} (encoding layer)            in_T = 1")
            continue
        v = float(mi.miout(s))
        schedule[name] = 1 if v >= threshold else cfg.full_t
        print(f"  {name:12s} mIoUT = {v:.3f}  ->  in_T = {schedule[name]}")

    # operation accounting for the proposed schedule vs all-3T
    specs = sy.layer_specs(get_config("snn-det"))
    def ops_for(in_t_of):
        tot = 0.0
        for sp in specs:
            macro = sp.name.split("/")[0]
            t = in_t_of(macro)
            tot += 2 * sp.h * sp.w * sp.nnz * t * sp.bits_in
        return tot / 1e9

    base = ops_for(lambda m: cfg.full_t)
    prop = ops_for(lambda m: schedule.get(m, cfg.full_t))
    print(f"\nops: all-3T {base:.2f} GOps -> proposed {prop:.2f} GOps "
          f"(-{(1 - prop / base) * 100:.1f}%)  [paper C2: -17%]")
    print("mixed_timestep_search OK")


if __name__ == "__main__":
    main()
